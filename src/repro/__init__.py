"""repro — dynamic graph algorithms for multiple backends from one DSL.

Public surface (see ``repro.api`` for the full story):

    import repro

    prog = repro.compile("path/to/algo.sp")
    sess = prog.bind(csr, backend="pallas", capacity="auto")
    res = sess.run("DynSSSP", updateBatch=stream, batchSize=16, src=0)

Exports are lazy (PEP 562) so ``import repro`` stays cheap and free of
import cycles; heavyweight backends only load when first used.
"""

__all__ = [
    "api", "serve", "compile", "bind_graph", "CompiledProgram", "Session",
    "GraphSession", "SessionResult", "PropertyView", "register_engine",
    "available_backends", "restore_session", "SessionPool",
    "AdmissionError", "PoolOverflowError", "KernelFailure",
    "DivergenceError", "PoolSaturatedError", "SessionHealth", "PoolHealth",
]

_API_NAMES = {"compile", "bind_graph", "CompiledProgram", "Session",
              "GraphSession", "SessionResult", "PropertyView",
              "register_engine", "available_backends", "restore_session",
              "AdmissionError", "PoolOverflowError", "KernelFailure",
              "DivergenceError", "SessionHealth"}

_SERVE_NAMES = {"SessionPool"}

_RUNTIME_NAMES = {"PoolSaturatedError", "PoolHealth"}


def __getattr__(name):
    if name == "api":
        import repro.api as api
        return api
    if name == "serve":
        import repro.serve as serve
        return serve
    if name in _API_NAMES:
        import repro.api as api
        return getattr(api, name)
    if name in _SERVE_NAMES:
        import repro.serve as serve
        return getattr(serve, name)
    if name in _RUNTIME_NAMES:
        import repro.runtime as runtime
        return getattr(runtime, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
