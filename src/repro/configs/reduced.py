"""Reduced (smoke-test) variants of every assigned architecture.

Same family, pattern and code paths; tiny dims so a forward/train step
runs on CPU in seconds.  The FULL configs are only ever exercised via the
dry-run (ShapeDtypeStruct, no allocation), per the brief.
"""
from __future__ import annotations

import dataclasses

from repro.configs.archs import ArchConfig, MoECfg, MambaCfg, REGISTRY


def reduced(cfg: ArchConfig) -> ArchConfig:
    moe = None
    if cfg.moe is not None:
        moe = MoECfg(n_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff=64,
                     capacity_factor=2.0)
    return dataclasses.replace(
        cfg,
        n_layers=len(cfg.pattern) * min(cfg.repeat, 2),
        d_model=64,
        n_heads=4,
        n_kv=min(cfg.n_kv, 2) if cfg.n_kv < cfg.n_heads else 4,
        head_dim=16,
        d_ff=96 if cfg.d_ff else 0,
        vocab=256,
        repeat=min(cfg.repeat, 2),
        moe=moe,
        mamba=MambaCfg(d_state=4, d_conv=4, expand=2) if cfg.mamba else None,
        enc_layers=2 if cfg.enc_layers else 0,
        enc_seq=16 if cfg.enc_seq else 0,
        n_img_tokens=8 if cfg.n_img_tokens else 0,
        local_window=8,
    )


def get_reduced(name: str) -> ArchConfig:
    return reduced(REGISTRY[name])
