"""Assigned architecture pool: 10 architectures × their input shapes.

Every config below is the exact assignment from the brief (sources in
brackets there).  ``pattern`` is the repeating *unit* of layers the
forward pass scans over; ``repeat × len(pattern) == n_layers``.

Layer dicts:  {"mixer": ..., "ffn": ...}
  mixer ∈ attn | attn_local | attn_bidir | xattn | mamba | mlstm | slstm
  ffn   ∈ mlp | moe | none
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

REGISTRY: Dict[str, "ArchConfig"] = {}


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff: int                 # per-expert hidden
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MambaCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | vlm | hybrid | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    pattern: Tuple[dict, ...]
    repeat: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    local_window: int = 4096          # for attn_local
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    moe: Optional[MoECfg] = None
    mamba: Optional[MambaCfg] = None
    # encoder-decoder (audio): encoder pattern scanned separately
    enc_layers: int = 0
    enc_seq: int = 0                  # fixed source length (frames/patches)
    n_img_tokens: int = 0             # vlm: stubbed patch-embedding count
    tie_embeddings: bool = False
    optimizer: str = "adamw"          # adamw | adafactor (big archs)
    attn_shard: str = "head"          # 'head' (H%tp==0) | 'dh' (fallback)
    # long-context support: sub-quadratic decode path exists
    long_context: bool = False
    # how many pattern entries express ONE published layer (whisper's
    # decoder layer = self-attn + cross-attn+mlp => 2 sublayer groups)
    pattern_entries_per_layer: int = 1

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline terms)."""
        D, V = self.d_model, self.vocab
        total = V * D * (1 if self.tie_embeddings else 2)
        def layer_params(layer):
            p = 0
            m = layer["mixer"]
            if m in ("attn", "attn_local", "attn_bidir", "xattn"):
                p += D * self.n_heads * self.dh          # q
                p += 2 * D * self.n_kv * self.dh         # k, v
                p += self.n_heads * self.dh * D          # o
                if m == "xattn":
                    p += D * self.n_heads * self.dh      # extra gate proj
            elif m == "mamba":
                e = self.mamba.expand * D
                p += D * 2 * e + e * self.mamba.d_conv
                p += e * (2 * self.mamba.d_state + 2) + e * D
            elif m == "mlstm":
                e = 2 * D
                p += D * 3 * e                    # q, k, v
                p += 2 * D * self.n_heads         # per-head i/f gates
                p += D * e + e * D                # output gate + out_proj
            elif m == "slstm":
                p += 4 * D * D + 4 * D * D + D * D
            f = layer["ffn"]
            if f == "mlp":
                p += 3 * D * self.d_ff                    # gate/up/down
            elif f == "moe":
                p += D * self.moe.n_experts
                p += self.moe.n_experts * 3 * D * self.moe.d_ff
            return p
        per_unit = sum(layer_params(l) for l in self.pattern)
        total += per_unit * self.repeat
        if self.enc_layers:
            enc_unit = {"mixer": "attn_bidir", "ffn": "mlp"}
            total += layer_params(enc_unit) * self.enc_layers
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if self.moe is None:
            return self.param_count()
        dense = self.param_count()
        n_moe_layers = sum(1 for l in self.pattern if l["ffn"] == "moe") \
            * self.repeat
        all_experts = n_moe_layers * self.moe.n_experts * 3 * self.d_model \
            * self.moe.d_ff
        active = n_moe_layers * self.moe.top_k * 3 * self.d_model \
            * self.moe.d_ff
        return dense - all_experts + active


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str                # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524_288, 1, "decode"),
}


def register(cfg: ArchConfig) -> ArchConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    return REGISTRY[name]


def _attn(ffn="mlp"):
    return {"mixer": "attn", "ffn": ffn}


# --- the ten assigned architectures -----------------------------------------

register(ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv=8, d_ff=8192, vocab=202_048,
    moe=MoECfg(n_experts=128, top_k=1, d_ff=8192),
    pattern=({"mixer": "attn", "ffn": "moe"},), repeat=48,
    optimizer="adafactor", attn_shard="dh",          # 40 heads % 16 != 0
))

register(ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv=4, d_ff=1536, vocab=151_936,
    moe=MoECfg(n_experts=128, top_k=8, d_ff=1536),
    pattern=({"mixer": "attn", "ffn": "moe"},), repeat=94,
    optimizer="adafactor", attn_shard="head",
))

register(ArchConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv=1, d_ff=24_576, vocab=49_152,
    pattern=(_attn(),), repeat=52, attn_shard="head",
))

register(ArchConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv=40, d_ff=27_392, vocab=152_064,
    qkv_bias=True, pattern=(_attn(),), repeat=64, attn_shard="dh",
))

register(ArchConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv=4, d_ff=18_432, vocab=49_152,
    pattern=(_attn(),), repeat=32, attn_shard="dh",
))

register(ArchConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv=8, d_ff=14_336, vocab=256_000,
    head_dim=256, attn_softcap=50.0, final_softcap=30.0, local_window=4096,
    pattern=({"mixer": "attn_local", "ffn": "mlp"},
             {"mixer": "attn", "ffn": "mlp"}), repeat=21,
    attn_shard="head", tie_embeddings=True,
    long_context=True,   # local/global alternation; global layers windowed
))                       # over the cache in long mode (DESIGN.md §4)

register(ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv=8, d_ff=14_336, vocab=128_256,
    n_img_tokens=1024,
    pattern=(_attn(), _attn(), _attn(), _attn(),
             {"mixer": "xattn", "ffn": "mlp"}), repeat=8,
    attn_shard="head",
))

register(ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14_336, vocab=65_536,
    moe=MoECfg(n_experts=16, top_k=2, d_ff=14_336),
    mamba=MambaCfg(),
    pattern=(
        {"mixer": "mamba", "ffn": "mlp"},
        {"mixer": "mamba", "ffn": "moe"},
        {"mixer": "mamba", "ffn": "mlp"},
        {"mixer": "mamba", "ffn": "moe"},
        {"mixer": "attn", "ffn": "mlp"},
        {"mixer": "mamba", "ffn": "moe"},
        {"mixer": "mamba", "ffn": "mlp"},
        {"mixer": "mamba", "ffn": "moe"},
    ), repeat=4,
    optimizer="adafactor", attn_shard="head", long_context=True,
))

register(ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv=4, d_ff=0, vocab=50_304,
    pattern=({"mixer": "slstm", "ffn": "none"},
             {"mixer": "mlstm", "ffn": "none"},
             {"mixer": "mlstm", "ffn": "none"},
             {"mixer": "mlstm", "ffn": "none"}), repeat=3,
    attn_shard="dh", long_context=True, tie_embeddings=True,
))

register(ArchConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv=20, d_ff=5120,
    vocab=51_872,        # padded from 51866 to a multiple of 32 for TP
    enc_layers=32, enc_seq=1500,
    pattern=({"mixer": "attn", "ffn": "none"},
             {"mixer": "xattn", "ffn": "mlp"}), repeat=32,
    attn_shard="dh", pattern_entries_per_layer=2,
))
# whisper decoder layer = self-attn + cross-attn + mlp; we express it as a
# 2-entry unit (self-attn, then cross-attn+mlp) so n_layers=32 decoder
# layers => repeat=32 units of 2 sublayer-groups.


def long_500k_supported(cfg: ArchConfig) -> bool:
    return cfg.long_context


def cells(include_skips: bool = False):
    """All (arch × shape) dry-run cells; long_500k only where sub-quadratic."""
    out = []
    for name, cfg in REGISTRY.items():
        for sname, shape in SHAPES.items():
            if sname == "long_500k" and not cfg.long_context:
                if include_skips:
                    out.append((name, sname, "SKIP: full attention is "
                                "quadratic at 512k; no sub-quadratic path "
                                "in the published config"))
                continue
            out.append((name, sname))
    return out
