"""Deterministic sharded data pipeline.

Synthetic-corpus token stream with the properties a real pipeline needs
for 1000-node training:

  * **host-sharded**: each data-parallel host computes only its slice of
    the global batch, indexed by (step, host_id) — no coordinator;
  * **deterministic & resumable**: batch contents are a pure function of
    (seed, step), so restoring a checkpoint at step k replays the exact
    stream with no state file beyond the step counter;
  * **prefetchable**: ``iterate`` yields ahead-of-time on a background
    thread (double-buffering compute against host data generation).

A file-backed tokenized corpus (memory-mapped .npy shards) is supported
through ``CorpusSource``; the synthetic source is the default for the
examples and benchmarks (no data download in this environment).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


class SyntheticSource:
    """Zipf-ish token stream — pure function of (seed, step, host)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks
        self.p = p / p.sum()

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        per_host = cfg.global_batch // cfg.n_hosts
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + cfg.host_id)
        toks = rng.choice(cfg.vocab, size=(per_host, cfg.seq_len + 1),
                          p=self.p).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class CorpusSource:
    """Memory-mapped token shards; host h reads rows ≡ h (mod n_hosts)."""

    def __init__(self, cfg: DataConfig, paths):
        self.cfg = cfg
        self.shards = [np.load(p, mmap_mode="r") for p in paths]
        self.rows = sum(s.shape[0] for s in self.shards)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        per_host = cfg.global_batch // cfg.n_hosts
        base = (step * cfg.global_batch + cfg.host_id * per_host) % self.rows
        rows = []
        for i in range(per_host):
            r = (base + i) % self.rows
            for s in self.shards:
                if r < s.shape[0]:
                    rows.append(np.asarray(s[r, : cfg.seq_len + 1]))
                    break
                r -= s.shape[0]
        toks = np.stack(rows).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def iterate(source, start_step: int = 0, prefetch: int = 2) -> Iterator[dict]:
    """Background-thread prefetching iterator (overlap host data gen)."""
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put((step, source.batch(step)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
