"""Sharded, preemption-safe checkpointing (no orbax in this environment).

Layout:  <dir>/step_<k>/
           manifest.json        — step, leaf index, data-pipeline state
           shard_<i>.npz        — flattened leaves, one file per host
           COMMITTED            — atomic-rename commit marker

Fault-tolerance contract (DESIGN.md §5):
  * every payload file (shard, manifest) is fsynced before the
    ``COMMITTED`` marker is created, the tmp directory is fsynced before
    the rename, and the parent directory is fsynced after it — a crash
    at ANY point leaves either the previous committed step or the new
    one, never a ``COMMITTED`` step with a truncated shard;
  * ``latest_step`` ignores uncommitted directories, so restart always
    resumes from the newest complete checkpoint;
  * per-host shard files: on a real cluster each host serializes only its
    addressable shards (here: host 0 writes everything it owns);
  * the manifest stores the data-pipeline step so the input stream
    replays deterministically after restore.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
from typing import Any, Callable, Optional, Tuple

import numpy as np
import jax

# Test seam for the crash-injection suite (tests/test_ckpt_protocol.py):
# when set, it is called with a named commit-protocol point ("shard",
# "manifest", "committed", "renamed") and may raise to simulate a kill
# at exactly that boundary.  None in production.  The same points also
# cross the chaos harness's "checkpoint_write" seam
# (repro.runtime.faults), which generalizes this hook; _crash_point is
# kept for the PR 7 protocol tests.
_crash_point: Optional[Callable[[str], None]] = None


def _maybe_crash(point: str) -> None:
    from repro.runtime import faults as _faults
    _faults.fire("checkpoint_write", point=point)
    if _crash_point is not None:
        _crash_point(point)


def _fsync_dir(path) -> None:
    """Durably record directory-entry changes (create/rename) on POSIX."""
    fd = os.open(str(path), os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir, step: int, tree: Any, extra: Optional[dict] = None,
         host_id: int = 0, keep: int = 3):
    ckpt_dir = pathlib.Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    with open(tmp / f"shard_{host_id}.npz", "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    _maybe_crash("shard")
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "extra": extra or {},
    }
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    _maybe_crash("manifest")
    # the marker is created only after BOTH payload files are durable,
    # and is itself fsynced (file + directory entry) before the rename
    with open(tmp / "COMMITTED", "w") as f:
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(tmp)
    _maybe_crash("committed")
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit
    _fsync_dir(ckpt_dir)                       # make the rename durable
    _maybe_crash("renamed")
    _gc(ckpt_dir, keep)
    return final


def _committed_dirs(ckpt_dir: pathlib.Path):
    """Renamed, committed step directories only.  A crash between the
    marker write and the rename leaves a ``step_*.tmp`` dir that
    *contains* COMMITTED but was never renamed — the rename is the
    commit, so those must not count (and their name doesn't parse as a
    step number)."""
    return [d for d in ckpt_dir.glob("step_*")
            if d.is_dir() and not d.name.endswith(".tmp")
            and (d / "COMMITTED").exists()]


def _gc(ckpt_dir: pathlib.Path, keep: int):
    steps = sorted(_committed_dirs(ckpt_dir))
    doomed = steps if keep <= 0 else steps[:-keep]
    for d in doomed:
        shutil.rmtree(d, ignore_errors=True)
    for d in ckpt_dir.glob("*.tmp"):
        shutil.rmtree(d, ignore_errors=True)


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(d.name.split("_")[1]) for d in _committed_dirs(ckpt_dir)]
    return max(steps) if steps else None


def read_manifest(ckpt_dir, step: int) -> dict:
    """The manifest of a committed step (metadata only, no array I/O)."""
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    if not (d / "COMMITTED").exists():
        raise FileNotFoundError(f"step {step} in {ckpt_dir} is not a "
                                f"committed checkpoint")
    try:
        return json.loads((d / "manifest.json").read_text())
    except (OSError, ValueError) as e:
        # committed marker present but payload unreadable: the commit
        # protocol's invariant was violated after the marker
        from repro.runtime.errors import CheckpointCorrupt
        raise CheckpointCorrupt(
            f"committed step {step} has an unreadable manifest: {e}",
            path=d, step=step) from e


def restore(ckpt_dir, step: int, example_tree: Any,
            host_id: int = 0) -> Tuple[Any, dict]:
    """Restore into the *structure and shardings* of example_tree — the
    elastic-rescale path: leaves are re-device_put with whatever sharding
    the (possibly different-sized) current mesh dictates."""
    from repro.runtime.errors import CheckpointCorrupt
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    try:
        manifest = json.loads((d / "manifest.json").read_text())
    except (OSError, ValueError) as e:
        raise CheckpointCorrupt(
            f"step {step} has an unreadable manifest: {e}",
            path=d, step=step) from e
    leaves, treedef = _flatten(example_tree)
    if manifest["n_leaves"] != len(leaves):
        raise CheckpointCorrupt(
            f"checkpoint has {manifest['n_leaves']} leaves, "
            f"restore tree has {len(leaves)}", path=d, step=step)
    new = []
    try:
        shard = np.load(d / f"shard_{host_id}.npz")
    except (OSError, ValueError) as e:
        raise CheckpointCorrupt(
            f"step {step} shard {host_id} is unreadable: {e}",
            path=d, step=step) from e
    # context-manage the NpzFile: a leaked zip fd per restore starves a
    # long-lived session pool of descriptors
    with shard as data:
        for i, ex in enumerate(leaves):
            try:
                arr = data[f"leaf_{i}"]
            except KeyError as e:
                raise CheckpointCorrupt(
                    f"step {step} shard {host_id} is missing leaf_{i}",
                    path=d, step=step) from e
            if hasattr(ex, "sharding") and ex.sharding is not None:
                try:
                    new.append(jax.device_put(arr.astype(ex.dtype),
                                              ex.sharding))
                    continue
                except Exception:
                    pass
            new.append(jax.numpy.asarray(arr, dtype=getattr(ex, "dtype",
                                                            None)))
    return jax.tree_util.tree_unflatten(treedef, new), manifest["extra"]
