"""Sharded, preemption-safe checkpointing (no orbax in this environment).

Layout:  <dir>/step_<k>/
           manifest.json        — step, leaf index, data-pipeline state
           shard_<i>.npz        — flattened leaves, one file per host
           COMMITTED            — atomic-rename commit marker

Fault-tolerance contract (DESIGN.md §5):
  * writes go to step_<k>.tmp and are renamed only after fsync — a
    preempted save can never corrupt the latest restorable step;
  * ``latest_step`` ignores uncommitted directories, so restart always
    resumes from the newest complete checkpoint;
  * per-host shard files: on a real cluster each host serializes only its
    addressable shards (here: host 0 writes everything it owns);
  * the manifest stores the data-pipeline step so the input stream
    replays deterministically after restore.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
from typing import Any, Optional, Tuple

import numpy as np
import jax


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir, step: int, tree: Any, extra: Optional[dict] = None,
         host_id: int = 0, keep: int = 3):
    ckpt_dir = pathlib.Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(tmp / f"shard_{host_id}.npz", **arrays)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "extra": extra or {},
    }
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    (tmp / "COMMITTED").touch()
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: pathlib.Path, keep: int):
    steps = sorted(d for d in ckpt_dir.glob("step_*")
                   if d.is_dir() and (d / "COMMITTED").exists())
    for d in steps[:-keep]:
        shutil.rmtree(d, ignore_errors=True)
    for d in ckpt_dir.glob("*.tmp"):
        shutil.rmtree(d, ignore_errors=True)


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(d.name.split("_")[1]) for d in ckpt_dir.glob("step_*")
             if d.is_dir() and (d / "COMMITTED").exists()]
    return max(steps) if steps else None


def restore(ckpt_dir, step: int, example_tree: Any,
            host_id: int = 0) -> Tuple[Any, dict]:
    """Restore into the *structure and shardings* of example_tree — the
    elastic-rescale path: leaves are re-device_put with whatever sharding
    the (possibly different-sized) current mesh dictates."""
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / f"shard_{host_id}.npz")
    leaves, treedef = _flatten(example_tree)
    assert manifest["n_leaves"] == len(leaves), \
        f"checkpoint has {manifest['n_leaves']} leaves, tree has {len(leaves)}"
    new = []
    for i, ex in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        if hasattr(ex, "sharding") and ex.sharding is not None:
            try:
                new.append(jax.device_put(arr.astype(ex.dtype), ex.sharding))
                continue
            except Exception:
                pass
        new.append(jax.numpy.asarray(arr, dtype=getattr(ex, "dtype", None)))
    return jax.tree_util.tree_unflatten(treedef, new), manifest["extra"]
