"""Dynamic Triangle Counting — paper Fig. 19, staged against the engine.

TC assumes a symmetrized (undirected) graph, as in the paper's evaluation.

staticTC   : node-iterator  Σ_v Σ_{u∈N(v),u<v} Σ_{w∈N(v),w>v} edge(u,w)
Incremental: per added edge (v1,v2), wedges through v3∈N(v1), with the
             count1/2 + count2/4 + count3/6 multiplicity dedup.
Decremental: same enumeration on the *pre-deletion* graph, subtracted.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine import Engine
from repro.graph.csr import INT
from repro.graph.updates import UpdateStream

I64 = jnp.int32


def static_tc(engine: Engine, g) -> jax.Array:
    def pair_fn(x, y, z, z_ok, ctx):
        # lane edge is (v=x, u=y); z=w enumerates N(v).
        valid = z_ok & (y < x) & (z > x)
        tri = valid & ctx.is_edge(y, z)
        return tri.astype(I64)

    return engine.count_wedges(g, pair_fn, lane_flags={},
                               out_example=jnp.zeros((), I64))


def _delta_counts(engine: Engine, g, flag_name: str, lane_flags):
    """Shared incremental/decremental wedge count (paper's count1/2/3)."""
    def pair_fn(x, y, z, z_ok, ctx):
        lane_new = ctx.lane_flag(flag_name)          # (v1,v2) is an update edge
        valid = z_ok & lane_new & (z != x) & (z != y)
        e1_new = ctx.nbr_flag(flag_name)             # (v1,v3) modified?
        tri = valid & ctx.is_edge(y, z)
        e2_new = ctx.edge_flag(flag_name, y, z)      # (v2,v3) modified?
        new_edges = 1 + e1_new.astype(I64) + e2_new.astype(I64)
        c1 = (tri & (new_edges == 1)).astype(I64)
        c2 = (tri & (new_edges == 2)).astype(I64)
        c3 = (tri & (new_edges == 3)).astype(I64)
        return (c1, c2, c3)

    zeros = (jnp.zeros((), I64),) * 3
    c1, c2, c3 = engine.count_wedges(g, pair_fn, lane_flags=lane_flags,
                                     out_example=zeros)
    return c1 // 2 + c2 // 4 + c3 // 6


def stream_step(engine: Engine, g, batch, count):
    """One ΔG batch of dynamic TC; the carry is the running count.
    Inside ``run_stream`` the engine view supplies static wedge bounds,
    so the enumeration never syncs to host mid-scan."""
    # --- decremental: count on the pre-deletion graph, then delete --------
    del_flags = engine.batch_edge_flags(g, batch.del_src, batch.del_dst,
                                        batch.del_mask)
    count = count - _delta_counts(engine, g, "mod", {"mod": del_flags})
    g = engine.update_del(g, batch)

    # --- incremental: add edges, flag them, count on the new graph --------
    g = engine.update_add(g, batch)
    add_flags = engine.batch_edge_flags(g, batch.add_src, batch.add_dst,
                                        batch.add_mask)
    count = count + _delta_counts(engine, g, "mod", {"mod": add_flags})
    return g, count


def dyn_tc(engine: Engine, g, stream: UpdateStream, batch_size: int,
           count=None):
    if count is None:
        count = static_tc(engine, g)
    for batch in stream.batches(batch_size):
        g, count = stream_step(engine, g, batch, count)
    return g, count


def dyn_tc_stream(engine: Engine, g, stream: UpdateStream, batch_size: int,
                  count=None, **kw):
    """dyn_tc through the device-resident streaming executor."""
    if count is None:
        count = static_tc(engine, g)
    count = jnp.asarray(count, I64)
    return engine.run_stream(g, stream, batch_size, stream_step, count, **kw)
