"""Dynamic PageRank — paper Fig. 20, staged against the engine interface.

The dynamic variant re-iterates PR only over the ``modified`` set, where
``modified`` is BFS-propagated (propagateNodeFlags) from the endpoints of
the update batch to everything reachable — the paper's affected-subgraph
detection.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.ir import EdgeSweep, Reduce
from repro.core.engine import Engine, Props
from repro.graph.csr import INT
from repro.graph.diffcsr import BOOL
from repro.graph.updates import UpdateStream

F32 = jnp.float32


def _pr_sweep(n_real: int, delta: float) -> EdgeSweep:
    def edge_fn(s, d, w):
        contrib = s["pr"] * s["inv_outdeg"]
        elig = d["modified"]
        return {"acc": (contrib, elig)}

    def post_fn(p, red, hit):
        val = (1.0 - delta) / n_real + delta * red["acc"]
        active = p["modified"] & p["real"]
        return {
            **p,
            "pr": jnp.where(active, val, p["pr"]),
            "_absdiff": jnp.where(active, jnp.abs(val - p["pr"]), 0.0),
        }

    return EdgeSweep(edge_fn=edge_fn, reduces={"acc": Reduce("sum")},
                     post_fn=post_fn,
                     gather_form={"acc": (
                         lambda p: p["pr"] * p["inv_outdeg"], False)})


def _iterate(engine: Engine, g, props: Props, beta: float, delta: float,
             max_iter: int) -> Props:
    sw = _pr_sweep(engine.n_real, delta)
    props = dict(props)
    props["_absdiff"] = engine.full(0.0, F32)

    def cond_fn(p, it, col):
        diff = col.sum(p["_absdiff"])
        return (it == 0) | (diff > beta)

    props = engine.fixed_point(g, sw, props, cond_fn, max_iter)
    props.pop("_absdiff")
    return props


def init_props(engine: Engine) -> Props:
    n = engine.n_real
    real = jnp.arange(engine.n_pad, dtype=INT) < n
    return {
        "pr": jnp.where(real, 1.0 / n, 0.0).astype(F32),
        "real": real,
        "modified": real,
        "inv_outdeg": jnp.zeros((engine.n_pad,), F32),
    }


def _with_degrees(engine: Engine, g, props: Props) -> Props:
    deg = engine.out_degrees(g).astype(F32)
    return {**props, "inv_outdeg": jnp.where(deg > 0, 1.0 / deg, 0.0)}


def static_pr(engine: Engine, g, beta: float = 1e-3, delta: float = 0.85,
              max_iter: int = 100) -> Props:
    props = init_props(engine)
    props = _with_degrees(engine, g, props)
    return _iterate(engine, g, props, beta, delta, max_iter)


@functools.lru_cache(maxsize=None)
def make_stream_step(beta: float = 1e-3, delta: float = 0.85,
                     max_iter: int = 100):
    """The per-ΔG-batch body with the PR knobs bound — jit-compatible,
    lax.scanned by ``Engine.run_stream``.  lru_cached so repeated calls
    with the same knobs reuse one step object (and its jitted scan)."""

    def stream_step(engine: Engine, g, batch, props: Props):
        # Both endpoints seed the affected set: the destination's in-edge
        # set changed, and the source's out-degree changed (which rescales
        # its contribution to *all* of its out-neighbors).
        # --- decremental half ----------------------------------------------
        def on_delete(p: Props) -> Props:
            tgt = jnp.where(batch.del_mask, batch.del_dst, engine.n_pad)
            tgs = jnp.where(batch.del_mask, batch.del_src, engine.n_pad)
            m = jnp.zeros_like(p["modified"]).at[tgt].set(True, mode="drop")
            return {**p, "modified": m.at[tgs].set(True, mode="drop")}

        props = engine.vertex_map(g, on_delete, props)
        props = engine.propagate_flags(g, props, "modified")
        g = engine.update_del(g, batch)
        props = _with_degrees(engine, g, props)
        props = _iterate(engine, g, props, beta, delta, max_iter)

        # --- incremental half ----------------------------------------------
        def on_add(p: Props) -> Props:
            tgt = jnp.where(batch.add_mask, batch.add_dst, engine.n_pad)
            tgs = jnp.where(batch.add_mask, batch.add_src, engine.n_pad)
            m = jnp.zeros_like(p["modified"]).at[tgt].set(True, mode="drop")
            return {**p, "modified": m.at[tgs].set(True, mode="drop")}

        props = engine.vertex_map(g, on_add, props)
        props = engine.propagate_flags(g, props, "modified")  # paper order:
        g = engine.update_add(g, batch)                       # flags first,
        props = _with_degrees(engine, g, props)               # then CSR add
        props = _iterate(engine, g, props, beta, delta, max_iter)
        return g, props

    return stream_step


def dyn_pr(engine: Engine, g, stream: UpdateStream, batch_size: int,
           beta: float = 1e-3, delta: float = 0.85, max_iter: int = 100,
           props: Props | None = None):
    if props is None:
        props = static_pr(engine, g, beta, delta, max_iter)
    step = make_stream_step(beta, delta, max_iter)
    for batch in stream.batches(batch_size):
        g, props = step(engine, g, batch, props)
    return g, props


def dyn_pr_stream(engine: Engine, g, stream: UpdateStream, batch_size: int,
                  beta: float = 1e-3, delta: float = 0.85,
                  max_iter: int = 100, props: Props | None = None, **kw):
    """dyn_pr through the device-resident streaming executor."""
    if props is None:
        props = static_pr(engine, g, beta, delta, max_iter)
    step = make_stream_step(beta, delta, max_iter)
    return engine.run_stream(g, stream, batch_size, step, props, **kw)
