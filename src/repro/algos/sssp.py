"""Dynamic SSSP — paper Fig. 21, staged against the engine interface.

staticSSSP   : Bellman-Ford-style fixedPoint over modified frontier.
Incremental  : same sweep seeded from activeOnAdd vertices.
Decremental  : phase 1 parent-subtree invalidation, phase 2 pull-repair.
dyn_sssp     : the Batch / OnDelete / OnAdd driver (paper Fig. 3).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.ir import EdgeSweep, Reduce
from repro.core.engine import Engine, Props
from repro.graph.csr import INT, INF_W
from repro.graph.diffcsr import BOOL
from repro.graph.updates import UpdateStream

NO_PARENT = jnp.asarray(-1, INT)


def _relax_sweep() -> EdgeSweep:
    """forall v filter(modified): forall nbr:
       <nbr.dist, nbr.modified_nxt, nbr.parent> = <Min(nbr.dist, v.dist+w), True, v>"""
    def edge_fn(s, d, w):
        cand = s["dist"] + w
        elig = s["modified"] & (s["dist"] < INF_W)
        return {"dist": (cand, elig)}

    def post_fn(p, red, hit):
        better = hit["dist"] & (red["dist"] < p["dist"])
        return {
            **p,
            "dist": jnp.where(better, red["dist"], p["dist"]),
            "parent": jnp.where(better, red["parent"], p["parent"]),
            "modified": better,           # modified = modified_nxt
        }

    return EdgeSweep(edge_fn=edge_fn,
                     reduces={"dist": Reduce("min"),
                              "parent": Reduce("argmin", of="dist")},
                     post_fn=post_fn,
                     gather_form={"dist": (
                         lambda p: jnp.where(
                             p["modified"] & (p["dist"] < INF_W),
                             p["dist"], INF_W).astype(INT), True)},
                     frontier="modified")


def init_props(engine: Engine, source: int) -> Props:
    iota = jnp.arange(engine.n_pad, dtype=INT)
    return {
        "dist": jnp.where(iota == source, 0, INF_W).astype(INT),
        "parent": engine.full(-1, INT),
        "modified": (iota == source),
    }


def static_sssp(engine: Engine, g, source: int, max_iter: int = 1 << 30) -> Props:
    props = init_props(engine, source)
    return engine.fixed_point(
        g, _relax_sweep(), props,
        cond_fn=lambda p, it, col: col.any(p["modified"]), max_iter=max_iter)


def incremental(engine: Engine, g, props: Props, max_iter: int = 1 << 30) -> Props:
    """props['modified'] seeds the affected frontier (activeOnAdd)."""
    return engine.fixed_point(
        g, _relax_sweep(), props,
        cond_fn=lambda p, it, col: col.any(p["modified"]), max_iter=max_iter)


def _phase1(p: Props) -> Props:
    """Invalidate the shortest-path subtree below deleted tree edges by
    chasing parent pointers to a fixed point (paper's decremental
    pre-phase).  Module-level + jitted so the trace caches across
    batches of a stream."""
    def cond(state):
        changed, pp = state
        return changed

    def body(state):
        _, pp = state
        par = jnp.clip(pp["parent"], 0, pp["parent"].shape[0] - 1)
        hitp = (pp["parent"] >= 0) & pp["modified"][par] & ~pp["modified"]
        new = {
            **pp,
            "dist": jnp.where(hitp, INF_W, pp["dist"]),
            "parent": jnp.where(hitp, NO_PARENT, pp["parent"]),
            "modified": pp["modified"] | hitp,
        }
        return jnp.any(hitp), new

    _, out = jax.lax.while_loop(cond, body, (jnp.asarray(True), p))
    return out


_phase1_jit = jax.jit(_phase1)


def decremental(engine: Engine, g, props: Props, max_iter: int = 1 << 30) -> Props:
    props = engine.vertex_map(g, _phase1_jit, props)

    # Phase 2: repair the invalidated region.  The paper's listing pulls
    # over in-edges of modified vertices; its §6.2 notes a push-based
    # variant "has the potential to be more efficient" — we use it: the
    # surviving labels are valid upper bounds (deletions only increase
    # distances; unaffected vertices keep intact shortest-path trees), so
    # push relaxation seeded at the repair BOUNDARY (finite-dist vertices
    # with an edge into the invalidated set) converges to the true
    # distances — and starts sparse, where the FrontierEngine wins.
    finite = props["dist"] < INF_W
    if hasattr(engine, "src_flags_from_dst"):
        boundary = engine.src_flags_from_dst(
            g.g if hasattr(g, "g") else g, props["modified"]) & finite
    else:
        boundary = finite            # dense seed (still correct)
    props = {**props, "modified": boundary}
    props = engine.fixed_point(
        g, _relax_sweep(), props,
        cond_fn=lambda p, it, col: col.any(p["modified"]),
        max_iter=max_iter)
    return props


# ---------------------------------------------------------------------------
# Dynamic driver (paper Fig. 3): Batch { OnDelete; updateCSRDel; Decremental;
#                                        OnAdd; updateCSRAdd; Incremental }
# ---------------------------------------------------------------------------

def stream_step(engine: Engine, g, batch, props: Props):
    """One ΔG batch: the paper's Fig. 3 loop body, engine-neutral and
    jit-compatible — ``Engine.run_stream`` lax.scans this."""
    # --- OnDelete pre-processing ------------------------------------------
    def on_delete(p: Props) -> Props:
        tree_edge = (p["parent"][jnp.clip(batch.del_dst, 0, engine.n_pad - 1)]
                     == batch.del_src) & batch.del_mask
        tgt = jnp.where(tree_edge, batch.del_dst, engine.n_pad)
        dist = p["dist"].at[tgt].set(INF_W, mode="drop")
        parent = p["parent"].at[tgt].set(NO_PARENT, mode="drop")
        modified = p["modified"].at[tgt].set(True, mode="drop")
        return {**p, "dist": dist, "parent": parent, "modified": modified}

    props = {**props, "modified": jnp.zeros_like(props["modified"])}
    props = engine.vertex_map(g, on_delete, props)
    g = engine.update_del(g, batch)
    props = decremental(engine, g, props)

    # --- OnAdd pre-processing ----------------------------------------------
    g = engine.update_add(g, batch)

    def on_add(p: Props) -> Props:
        src_d = p["dist"][jnp.clip(batch.add_src, 0, engine.n_pad - 1)]
        dst_d = p["dist"][jnp.clip(batch.add_dst, 0, engine.n_pad - 1)]
        improves = (dst_d > src_d + batch.add_w) & batch.add_mask
        tgt = jnp.where(improves, batch.add_src, engine.n_pad)
        modified = p["modified"].at[tgt].set(True, mode="drop")
        return {**p, "modified": modified}

    props = {**props, "modified": jnp.zeros_like(props["modified"])}
    props = engine.vertex_map(g, on_add, props)
    props = incremental(engine, g, props)
    return g, props


def dyn_sssp(engine: Engine, g, source: int, stream: UpdateStream,
             batch_size: int, props: Props | None = None):
    if props is None:
        props = static_sssp(engine, g, source)
    for batch in stream.batches(batch_size):
        g, props = stream_step(engine, g, batch, props)
    return g, props


def dyn_sssp_stream(engine: Engine, g, source: int, stream: UpdateStream,
                    batch_size: int, props: Props | None = None, **kw):
    """dyn_sssp through the device-resident streaming executor."""
    if props is None:
        props = static_sssp(engine, g, source)
    return engine.run_stream(g, stream, batch_size, stream_step, props, **kw)
