"""Pure-python/numpy oracles for the three paper algorithms.

These implement the textbook *static* algorithms from scratch; every
dynamic result must equal the oracle run on the post-update edge set
(the paper's own correctness criterion: dynamic == static-on-new-graph).
"""
from __future__ import annotations

from typing import Dict, List, Set, Tuple

import numpy as np

INF = np.int64(np.iinfo(np.int32).max // 2)


def edges_after_updates(n: int, edges: np.ndarray, weights: np.ndarray,
                        adds: np.ndarray, dels: np.ndarray):
    """Apply Δ to an edge set host-side (dedup, delete-then-add per batch
    order is irrelevant for the final set as adds are fresh edges)."""
    ew: Dict[Tuple[int, int], int] = {}
    for (u, v), w in zip(edges.tolist(), weights.tolist()):
        ew[(u, v)] = w
    for u, v in dels.tolist():
        ew.pop((u, v), None)
    for u, v, w in adds.tolist():
        ew[(u, v)] = w
    if not ew:
        return np.zeros((0, 2), np.int64), np.zeros((0,), np.int32)
    e = np.array(sorted(ew), dtype=np.int64)
    w = np.array([ew[tuple(x)] for x in e.tolist()], dtype=np.int32)
    return e, w


def sssp_oracle(n: int, edges: np.ndarray, weights: np.ndarray,
                source: int) -> np.ndarray:
    """Bellman-Ford (no negative weights here, so it converges)."""
    dist = np.full(n, INF, dtype=np.int64)
    dist[source] = 0
    src = edges[:, 0] if len(edges) else np.zeros(0, np.int64)
    dst = edges[:, 1] if len(edges) else np.zeros(0, np.int64)
    w = weights.astype(np.int64)
    for _ in range(n):
        cand = dist[src] + w
        nd = dist.copy()
        np.minimum.at(nd, dst, np.where(dist[src] < INF, cand, INF))
        if np.array_equal(nd, dist):
            break
        dist = nd
    return np.minimum(dist, INF)


def pagerank_oracle(n: int, edges: np.ndarray, beta: float = 1e-3,
                    delta: float = 0.85, max_iter: int = 100) -> np.ndarray:
    pr = np.full(n, 1.0 / n, dtype=np.float64)
    src = edges[:, 0] if len(edges) else np.zeros(0, np.int64)
    dst = edges[:, 1] if len(edges) else np.zeros(0, np.int64)
    outdeg = np.zeros(n, dtype=np.int64)
    np.add.at(outdeg, src, 1)
    inv = np.where(outdeg > 0, 1.0 / np.maximum(outdeg, 1), 0.0)
    for _ in range(max_iter):
        acc = np.zeros(n, dtype=np.float64)
        np.add.at(acc, dst, pr[src] * inv[src])
        val = (1.0 - delta) / n + delta * acc
        diff = np.abs(val - pr).sum()
        pr = val
        if diff <= beta:
            break
    return pr


def tc_oracle(n: int, edges: np.ndarray) -> int:
    """Paper's node-iterator count on a symmetrized edge set."""
    nbrs: List[Set[int]] = [set() for _ in range(n)]
    eset = set(map(tuple, edges.tolist()))
    for u, v in edges.tolist():
        nbrs[u].add(v)
    count = 0
    for v in range(n):
        for u in nbrs[v]:
            if u >= v:
                continue
            for w in nbrs[v]:
                if w <= v:
                    continue
                if (u, w) in eset:
                    count += 1
    return count


def symmetrize(edges: np.ndarray, weights: np.ndarray):
    e2 = np.concatenate([edges, edges[:, ::-1]], axis=0)
    w2 = np.concatenate([weights, weights], axis=0)
    key = e2[:, 0] * (e2.max() + 1) + e2[:, 1]
    _, idx = np.unique(key, return_index=True)
    return e2[idx], w2[idx]
