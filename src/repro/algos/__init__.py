"""Hand-staged dynamic graph algorithms (paper Figs. 19-21), written
against the backend-neutral ``Engine`` interface.

Every module follows one convention — drivers are
``fn(engine, handle, ...)`` and return ``(new_handle, result)`` — which
is exactly what ``repro.api.GraphSession.call`` adapts, so sessions
keep the handle device-resident across hand-staged calls too:

    sess = repro.bind_graph(csr, backend="jnp")
    props = sess.call(sssp.dyn_sssp, 0, stream, batch_size=16)

``STREAM_STEPS`` maps algorithm names to their engine-neutral per-batch
stream steps (what ``Engine.run_stream`` lax.scans).
"""
from repro.algos import oracles, pagerank, sssp, triangles

STREAM_STEPS = {
    "sssp": sssp.stream_step,
    "pagerank": pagerank.make_stream_step,   # factory: knobs -> step
    "tc": triangles.stream_step,
}

__all__ = ["oracles", "pagerank", "sssp", "triangles", "STREAM_STEPS"]
