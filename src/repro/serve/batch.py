"""The batched multi-graph execution path: N sessions, one launch.

A pool of same-shape sessions is, to XLA, one stacked pytree: every
handle leaf gains a leading session axis and ONE vmapped (or scanned)
program applies every tenant's ΔG batch in a single device call.  The
per-tenant semantics are untouched — ``vmap`` runs the exact
deletes-then-adds program :meth:`GraphSession.apply` runs, just over a
batch axis — so the contract this module is tested against is
**bit-exactness**: a mega-call must produce the same handle bits as N
sequential solo applies.

Two costs are managed here:

* **compile count** — groups are padded up to the next power-of-two
  bucket (the stream executor's padding trick, applied across sessions
  instead of across lanes), so a pool whose group sizes wander between
  drains compiles O(log N) programs, not one per size;
* **host syncs** — the pool-overflow counters for the whole group come
  back as one stacked ``(bucket, 3)`` array, read back in ONE host
  sync, preserving the one-sync-per-apply budget of the solo path.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

BATCH_MODES = ("vmap", "scan", "off")


def tree_stack(trees: List[Any]):
    """Stack a list of same-shape pytrees along a new leading axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def tree_index(tree: Any, i: int):
    """Slice one element back out of a stacked pytree."""
    return jax.tree_util.tree_map(lambda x: x[i], tree)


def next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length() if n > 1 else 1


def group_key(engine, handle, batch) -> Tuple:
    """What must match for sessions to share one mega-call: the engine
    instance (its program AND its host-side padding state), the handle's
    tree structure and every leaf's shape/dtype (stackability), and the
    ΔG batch's lane width."""
    leaves, treedef = jax.tree_util.tree_flatten(handle)
    return (id(engine), treedef,
            tuple((tuple(l.shape), str(l.dtype)) for l in leaves),
            batch.size)


class MegaBatcher:
    """Owns the jitted mega-call runners, one per ``(engine, mode)``.

    ``mode="vmap"`` vectorizes across sessions (one fused launch);
    ``"scan"`` runs them as a compiled sequential loop (no batch-axis
    memory amplification — the fallback for groups too large to hold
    stacked); ``"off"`` is handled by the pool (never calls here).
    jit's own shape cache specializes each runner per (leaf shapes,
    bucket), so this layer only caches the python closure.
    """

    def __init__(self, mode: str = "vmap"):
        if mode not in BATCH_MODES:
            raise ValueError(f"batch_mode must be one of {BATCH_MODES}, "
                             f"got {mode!r}")
        self.mode = mode
        self._runners: Dict[Tuple[int, str], Any] = {}

    def _runner(self, engine):
        """The whole mega-call — session stacking, the vectorized
        del+add program, and per-session unstacking — is ONE jitted
        function over a tuple of handles, so a drain round costs one
        dispatch regardless of group size.  Stacking eagerly instead
        (jnp.stack per leaf, then x[i] per session to unstack) costs
        ~2·bucket·leaves tiny device calls per round, which on small
        graphs swamps the fused launch it was supposed to amortize."""
        key = (id(engine), self.mode)
        fn = self._runners.get(key)
        if fn is not None:
            return fn

        def one(h, b):
            h = engine.update_del(h, b)
            h = engine.update_add(h, b)
            return h, engine.handle_counters(h)

        def mega(hs, bs):
            sh, sb = tree_stack(list(hs)), tree_stack(list(bs))
            if self.mode == "vmap":
                out_h, out_c = jax.vmap(one)(sh, sb)
            else:  # scan: compiled sequential loop, no batch-axis
                   # memory amplification
                def body(_, hb):
                    return None, one(*hb)
                _, (out_h, out_c) = jax.lax.scan(body, None, (sh, sb))
            return tuple(tree_index(out_h, i)
                         for i in range(len(hs))), out_c

        fn = self._runners[key] = jax.jit(mega)
        return fn

    def run(self, engine, handles: List[Any], batches: List[Any]
            ) -> Tuple[List[Any], np.ndarray]:
        """Apply ``batches[i]`` to ``handles[i]`` for all i in ONE
        compiled launch.  Returns the new handles and the host-side
        ``(len(handles), 3)`` pool-counter array — the single sync.
        Pad slots (group size up to the bucket) replay slot 0 and are
        dropped before returning; the jit cache specializes one program
        per (leaf shapes, bucket), so compile count stays logarithmic
        in the largest group ever drained."""
        real = len(handles)
        bucket = next_pow2(real)
        hs = tuple(handles) + (handles[0],) * (bucket - real)
        bs = tuple(batches) + (batches[0],) * (bucket - real)
        out_h, out_c = self._runner(engine)(hs, bs)
        return list(out_h[:real]), np.asarray(out_c)[:real]
