"""SessionPool: thousands of live graphs multiplexed over one process.

The serving story the paper's Batch-loop driver implies but never
builds: one compiled program × one backend, N independent tenant graphs
resident at once.  The pool owns

* **binding** — tenants bind through
  :func:`repro.core.registry.shared_engine`, so every same-scope tenant
  shares ONE engine instance and its compiled executables: the first
  tenant's compile warms all later ones;
* **the batched execution path** — queued ΔG batches from same-shape
  sessions are stacked and applied in one vmapped mega-call
  (:mod:`repro.serve.batch`), bit-exact vs per-session ``apply``;
* **backpressure** — a bounded request queue with per-tenant FIFOs and
  round-robin fairness; at the bound, ``overload="reject"`` raises the
  typed :class:`~repro.runtime.errors.PoolSaturatedError` and
  ``"shed"`` drops the oldest request of the deepest queue into a
  dead-letter buffer of QuarantineRecords (the PR 8 admission taxonomy,
  reused one level up);
* **eviction** — beyond ``max_resident`` live sessions, the
  least-recently-used idle tenant is spilled via ``Session.save`` and
  transparently revived on next touch by ``restore_session`` onto the
  SAME shared engine (``engine=``), so a revived tenant rejoins its
  batching group with no recompile.

Per-tenant fault counters stay in each session's ``SessionHealth``;
``pool.health`` adds the queue/batching/eviction counters only the pool
can see.
"""
from __future__ import annotations

import collections
import os
import tempfile
import threading
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.registry import shared_engine
from repro.graph.csr import CSR
from repro.graph.updates import UpdateBatch
from repro.runtime import faults as _faults
from repro.runtime.admission import (DEFAULT_MAX_BATCH, AdmissionGuard,
                                     DeadLetterBuffer, QuarantineRecord,
                                     Violation)
from repro.runtime.errors import PoolSaturatedError
from repro.runtime.health import PoolHealth
from repro.serve.batch import BATCH_MODES, MegaBatcher, group_key

OVERLOAD_POLICIES = ("reject", "shed")


class SessionPool:
    """Serve many independent graph sessions from one compiled program.

    ``program=None`` pools algorithm-agnostic ``bind_graph``-style
    sessions (hand-staged drivers); passing a
    :class:`~repro.api.CompiledProgram` pools DSL sessions, including
    armed Batch loops (armed applies run per-session — the armed frame
    is host-side state — but still share the engine's executables).

    The request path is ``submit(tenant, batch)`` → ``drain()``; the
    blocking convenience ``apply(tenant, batch)`` does both.  All entry
    points are thread-safe behind one reentrant lock: device work is
    serialized (sessions share engines and XLA is happiest that way),
    threads only ever wait, never corrupt.
    """

    def __init__(self, program=None, backend: str = "jnp", *,
                 batch_mode: str = "vmap",
                 max_pending: int = 256,
                 overload: str = "reject",
                 max_resident: Optional[int] = None,
                 spill_dir: Optional[str] = None,
                 admission: Optional[str] = "clamp",
                 max_batch: int = DEFAULT_MAX_BATCH,
                 dead_letter: int = 64,
                 shed_letter: int = 64,
                 **engine_opts):
        if batch_mode not in BATCH_MODES:
            raise ValueError(f"batch_mode must be one of {BATCH_MODES}, "
                             f"got {batch_mode!r}")
        if overload not in OVERLOAD_POLICIES:
            raise ValueError(f"overload must be one of "
                             f"{OVERLOAD_POLICIES}, got {overload!r}")
        if max_pending < 1:
            raise ValueError("max_pending must be >= 1")
        self.program = program
        self.backend = backend
        self.batch_mode = batch_mode
        self.max_pending = int(max_pending)
        self.overload = overload
        self.max_resident = max_resident
        self._spill_root = spill_dir
        self._admission = admission
        self._max_batch = int(max_batch)
        self._dead_letter = int(dead_letter)
        self._engine_opts = dict(engine_opts)

        self._lock = threading.RLock()
        self._sessions: "collections.OrderedDict[str, Any]" = \
            collections.OrderedDict()          # LRU order: oldest first
        self._evicted: Dict[str, str] = {}     # tenant -> spill dir
        self._queues: Dict[str, collections.deque] = {}
        self._order: List[str] = []            # round-robin cursor basis
        self._rr = 0
        self._pending = 0
        self._batcher = MegaBatcher(batch_mode if batch_mode != "off"
                                    else "vmap")
        # tenants in the currently-executing round: restoring one round
        # member must never evict another (its admitted-but-unapplied
        # session would be spilled pre-apply and the apply lost)
        self._pinned: frozenset = frozenset()
        self.shed_records = DeadLetterBuffer(shed_letter)
        self.health = PoolHealth()

    # -- binding -------------------------------------------------------------
    def bind(self, tenant: str, csr: CSR, **session_kw):
        """Bind ``tenant`` to its own graph on the pool's shared engine.
        ``session_kw`` overrides the pool-level session defaults
        (``capacity``, ``admission``, ``max_batch``, ``dead_letter``)."""
        from repro.api import GraphSession, Session   # circular at import
        with self._lock:
            if tenant in self._sessions or tenant in self._evicted:
                raise ValueError(f"tenant {tenant!r} is already bound")
            engine = self._shared_engine(csr.n)
            kw = {"admission": self._admission,
                  "max_batch": self._max_batch,
                  "dead_letter": self._dead_letter}
            kw.update(session_kw)
            capacity = kw.pop("capacity", "auto")
            if self.program is not None:
                sess = Session(self.program, engine, csr, capacity,
                               backend_name=self.backend, **kw)
            else:
                sess = GraphSession(engine, csr, capacity,
                                    backend_name=self.backend, **kw)
            self._sessions[tenant] = sess
            self._queues[tenant] = collections.deque()
            self._order.append(tenant)
            self.health.tenants += 1
            self.health.resident += 1
            self._maybe_evict(keep=(tenant,))
            return sess

    def _shared_engine(self, n: int):
        """The pool's one engine per graph scale.  ``scope`` carries the
        vertex count because engines keep per-graph host state (``_n``);
        see :func:`repro.core.registry.shared_engine`."""
        return shared_engine(self.backend, scope=(self.program, n),
                             **self._engine_opts)

    def session(self, tenant: str):
        """The tenant's live session, transparently restoring it from
        its spill checkpoint if it was evicted."""
        with self._lock:
            sess = self._sessions.get(tenant)
            if sess is not None:
                self._sessions.move_to_end(tenant)      # LRU touch
                return sess
            spill = self._evicted.get(tenant)
            if spill is None:
                raise KeyError(f"unknown tenant {tenant!r}")
            return self._restore(tenant, spill)

    def tenants(self) -> List[str]:
        with self._lock:
            return list(self._order)

    # -- request queue -------------------------------------------------------
    def submit(self, tenant: str, batch: UpdateBatch) -> None:
        """Enqueue one ΔG batch for ``tenant``.  At ``max_pending`` the
        overload policy decides: ``reject`` raises
        :class:`PoolSaturatedError` (the submit is refused, no state
        touched); ``shed`` drops the oldest request of the deepest
        queue into ``shed_records`` and accepts this one."""
        with self._lock:
            if tenant not in self._queues:
                raise KeyError(f"unknown tenant {tenant!r}")
            if self._pending >= self.max_pending:
                if self.overload == "reject":
                    self.health.rejected += 1
                    raise PoolSaturatedError(
                        f"pool queue full ({self._pending}/"
                        f"{self.max_pending} pending); submit for "
                        f"{tenant!r} refused", tenant=tenant,
                        pending=self._pending,
                        max_pending=self.max_pending, policy="reject",
                        depths=self._depths())
                self._shed_one(tenant)
            self._queues[tenant].append(batch)
            self._pending += 1
            self.health.submitted += 1
            self.health.queue_peak = max(self.health.queue_peak,
                                         self._pending)

    def _depths(self) -> Dict[str, int]:
        return {t: len(q) for t, q in self._queues.items() if q}

    def _shed_one(self, incoming: str) -> None:
        """Drop the oldest request of the deepest queue — pressure comes
        off the tenant most responsible for it, and the victim keeps its
        FIFO order.  The dropped request lands in ``shed_records`` as a
        QuarantineRecord so a client can replay it later."""
        victim = max(self._queues, key=lambda t: len(self._queues[t]))
        dropped = self._queues[victim].popleft()
        self._pending -= 1
        self.health.shed += 1
        sess = self._sessions.get(victim)
        cursor = sess.stream_cursor if sess is not None else -1
        self.shed_records.push(QuarantineRecord(
            reasons=(Violation("pool_saturated", 1,
                               f"queue full ({self.max_pending}); shed "
                               f"oldest of {victim!r} on submit from "
                               f"{incoming!r}"),),
            cursor=cursor, index=None,
            n_adds=int(np.asarray(dropped.add_mask).sum()),
            n_dels=int(np.asarray(dropped.del_mask).sum()),
            batch=dropped))

    def pending(self) -> int:
        with self._lock:
            return self._pending

    # -- execution -----------------------------------------------------------
    def apply(self, tenant: str, batch: UpdateBatch):
        """Submit one batch and drain the queue: the blocking
        single-tenant path.  Returns the tenant's session."""
        self.submit(tenant, batch)
        self.drain()
        return self.session(tenant)

    def apply_many(self, requests) -> int:
        """Submit ``(tenant, batch)`` pairs, then drain — the batched
        ingest path a front-end uses once per service tick."""
        for tenant, batch in requests:
            self.submit(tenant, batch)
        return self.drain()

    def drain(self) -> int:
        """Apply every queued request.  Each round takes at most one
        request per tenant (round-robin starting after last round's
        first server, so no tenant owns the front of every round), then
        executes the round with same-shape sessions grouped into one
        mega-call each.  Returns the number of batches executed."""
        applied = 0
        with self._lock:
            while self._pending:
                round_ = []
                order = self._order[self._rr:] + self._order[:self._rr]
                if self._order:
                    self._rr = (self._rr + 1) % len(self._order)
                for tenant in order:
                    q = self._queues.get(tenant)
                    if q:
                        round_.append((tenant, q.popleft()))
                        self._pending -= 1
                applied += self._run_round(round_)
        return applied

    def _run_round(self, round_: List[Tuple[str, UpdateBatch]]) -> int:
        """One fairness round: admit every request through its session's
        own guard (exactly the solo-``apply`` admission code), group the
        admitted survivors by stackability, and run each group through
        the mega-call — falling back per-session on armed loops,
        singleton groups, ``batch_mode="off"``, and pool overflow."""
        applied = 0
        groups: Dict[Tuple, List[Tuple[Any, UpdateBatch]]] = {}
        self._pinned = frozenset(t for t, _ in round_)
        try:
            applied = self._run_round_pinned(round_, groups)
        finally:
            self._pinned = frozenset()
        # the round may have restored more tenants than max_resident
        # allows to coexist; with the pins lifted, re-enforce the bound
        self._maybe_evict()
        return applied

    def _run_round_pinned(self, round_, groups) -> int:
        applied = 0
        for tenant, batch in round_:
            sess = self.session(tenant)
            if getattr(sess, "_armed", None) is not None:
                # armed Batch loops interpret the batch through a paused
                # host-side frame — per-session by construction
                sess.apply(batch)
                self.health.sequential_fallbacks += 1
                self.health.applied += 1
                applied += 1
                continue
            admitted = sess._admit_for_apply(batch)
            if admitted is None:       # quarantined / empty: consumed
                self.health.applied += 1
                applied += 1
                continue
            if self.batch_mode == "off":
                sess._apply_admitted(admitted)
                self.health.sequential_fallbacks += 1
                self.health.applied += 1
                applied += 1
                continue
            key = group_key(sess._engine, sess._handle, admitted)
            groups.setdefault(key, []).append((sess, admitted))
        for members in groups.values():
            applied += self._run_group(members)
        return applied

    def _run_group(self, members: List[Tuple[Any, UpdateBatch]]) -> int:
        """Run one stackable group.  The mega-call is adopted per
        session only when its pool did NOT overflow; an overflowing
        session discards its slot and replays through the solo
        grow-and-replay path (``_apply_admitted``), which the other
        sessions never see."""
        if len(members) == 1:
            sess, admitted = members[0]
            sess._apply_admitted(admitted)
            self.health.sequential_fallbacks += 1
            self.health.applied += 1
            return 1
        engine = members[0][0]._engine
        handles = [s._handle for s, _ in members]
        batches = [b for _, b in members]
        new_handles, counters = self._batcher.run(engine, handles, batches)
        _faults.fire("counter_sync", engine=self.backend)
        self.health.mega_calls += 1
        for (sess, admitted), handle, (of, _, _) in zip(members,
                                                        new_handles,
                                                        counters):
            if int(of) > sess._of_base:
                # this tenant's diff pool overflowed inside the
                # mega-call: its stacked result silently dropped adds.
                # Its own handle is untouched (the mega-call is
                # functional), so replay solo with grow-and-replay.
                self.health.sequential_fallbacks += 1
                sess._apply_admitted(admitted)
            else:
                sess._handle = handle
                sess._of_base = int(of)
                sess._cursor += 1
                self.health.mega_sessions += 1
            self.health.applied += 1
        return len(members)

    # -- eviction ------------------------------------------------------------
    def evict(self, tenant: str) -> str:
        """Spill ``tenant`` to its checkpoint directory (``Session.save``
        — atomic-commit protocol) and free its device state.  Returns
        the spill path; the next ``session()``/``submit``+``drain``
        touch restores it transparently."""
        with self._lock:
            sess = self._sessions.get(tenant)
            if sess is None:
                if tenant in self._evicted:
                    return self._evicted[tenant]   # already spilled
                raise KeyError(f"unknown tenant {tenant!r}")
            if self._queues[tenant]:
                raise ValueError(f"tenant {tenant!r} has queued requests; "
                                 f"drain before evicting")
            path = os.path.join(self._spill_dir(), tenant)
            sess.save(path)
            del self._sessions[tenant]
            self._evicted[tenant] = path
            self.health.evictions += 1
            self.health.resident -= 1
            return path

    def _spill_dir(self) -> str:
        if self._spill_root is None:
            self._spill_root = tempfile.mkdtemp(prefix="repro-pool-")
        os.makedirs(self._spill_root, exist_ok=True)
        return self._spill_root

    def _maybe_evict(self, keep: Tuple[str, ...] = ()) -> None:
        """Enforce ``max_resident`` by spilling least-recently-used
        tenants (skipping ``keep`` and anyone with queued work)."""
        if self.max_resident is None:
            return
        while self.health.resident > self.max_resident:
            victim = next((t for t in self._sessions
                           if t not in keep and t not in self._pinned
                           and not self._queues[t]),
                          None)
            if victim is None:
                return
            self.evict(victim)

    def _restore(self, tenant: str, spill: str):
        """Revive an evicted tenant onto the SAME shared engine (the
        ``engine=`` restore path), so it rejoins its executable-sharing
        group; then re-arm the pool's admission guard — guard config is
        pool policy, not checkpointed state (dead-letter records do not
        survive eviction; ``shed_records`` is the pool-level ledger)."""
        from repro.api import restore_session
        from repro.ckpt import checkpoint as ckpt
        step = ckpt.latest_step(spill)
        meta = ckpt.read_manifest(spill, step)["extra"]
        engine = self._shared_engine(int(meta["n"]))
        sess = restore_session(spill, engine=engine)
        sess._backend_name = self.backend
        sess._health.backend = self.backend
        sess._health.preferred_backend = self.backend
        sess._guard = AdmissionGuard(self._admission,
                                     max_batch=self._max_batch,
                                     dead_letter=self._dead_letter,
                                     health=sess._health)
        sess._health.dead_letter = sess._guard.buffer
        self._sessions[tenant] = sess
        del self._evicted[tenant]
        self.health.restores += 1
        self.health.resident += 1
        self._maybe_evict(keep=(tenant,))
        return sess

    # -- observability -------------------------------------------------------
    def tenant_health(self, tenant: str):
        """The tenant's live ``SessionHealth`` (restores it if evicted)."""
        return self.session(tenant).health

    def stats(self) -> Dict[str, Any]:
        """One JSON-able snapshot: pool counters + queue depths + shed
        ledger summary."""
        with self._lock:
            d = self.health.as_dict()
            d["pending"] = self._pending
            d["depths"] = self._depths()
            d["evicted"] = sorted(self._evicted)
            d["shed_records"] = {"held": len(self.shed_records),
                                 "total": self.shed_records.total,
                                 "evicted": self.shed_records.evicted}
            return d
