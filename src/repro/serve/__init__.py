"""repro.serve — the multi-tenant serving layer (DESIGN.md §7).

One process, one compiled program per backend, thousands of live graph
sessions: :class:`SessionPool` multiplexes independent tenants over
shared engine executables, batches same-shape ΔG applies into single
vmapped mega-calls (bit-exact vs solo ``apply``), bounds its request
queue with typed backpressure, and spills idle sessions to disk through
the PR 7 durability path.
"""
from repro.serve.batch import (BATCH_MODES, MegaBatcher, group_key,
                               next_pow2, tree_index, tree_stack)
from repro.serve.pool import OVERLOAD_POLICIES, SessionPool

__all__ = [
    "SessionPool", "MegaBatcher", "group_key", "tree_stack", "tree_index",
    "next_pow2", "BATCH_MODES", "OVERLOAD_POLICIES",
]
