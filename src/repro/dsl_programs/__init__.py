"""The paper's appendix DSL programs (Figs. 19-21), shipped as data.

Each ``.sp`` file holds the ``static*`` and ``Dyn*`` functions of one
algorithm in the StarPlat-Dynamic appendix syntax; they compile through
``repro.core.dsl.compile_source`` and run on any engine.  See
README.md ("The .sp program format") for the syntax and for how to add
a new algorithm to the conformance matrix.
"""
import pathlib

_HERE = pathlib.Path(__file__).resolve().parent

PROGRAMS = ("sssp", "pagerank", "tc")


def path(name: str) -> str:
    """Absolute path of a shipped program, e.g. ``path('sssp')``."""
    p = _HERE / f"{name}.sp"
    if not p.exists():
        raise KeyError(f"no such DSL program: {name!r} "
                       f"(have {', '.join(PROGRAMS)})")
    return str(p)
