// Dynamic Triangle Counting — the paper's appendix Fig. 19 in the
// StarPlat-Dynamic appendix syntax, on a symmetrized (undirected) graph.
// staticTC is the node-iterator count; incrementalTC/decrementalTC
// enumerate wedges through the endpoints of flagged update edges with
// the count1/2 + count2/4 + count3/6 multiplicity dedup (a triangle with
// k flagged edges is discovered 2k times); DynTC subtracts the deleted
// triangles on the pre-deletion graph, applies the batch, and adds the
// created triangles on the post-addition graph.

Static staticTC(Graph g) {
  int triangle_count = 0;
  forall (v in g.nodes()) {
    forall (u in g.neighbors(v).filter(u < v)) {
      forall (w in g.neighbors(v).filter(w > v)) {
        if (g.is_an_edge(u, w)) {
          triangle_count += 1;
        }
      }
    }
  }
  return triangle_count;
}

Incremental incrementalTC(Graph g, updates<g> updateBatch,
                          propEdge<bool> modified) {
  int count1 = 0;
  int count2 = 0;
  int count3 = 0;
  forall (u in updateBatch.currentBatch(1)) {
    node v1 = u.source;
    node v2 = u.destination;
    forall (v3 in g.neighbors(v1).filter(v3 != v1 && v3 != v2)) {
      if (g.is_an_edge(v2, v3)) {
        edge e1 = g.get_edge(v1, v3);
        edge e2 = g.get_edge(v2, v3);
        int numNew = 1;
        if (e1.modified == True) { numNew = numNew + 1; }
        if (e2.modified == True) { numNew = numNew + 1; }
        if (numNew == 1) { count1 += 1; }
        if (numNew == 2) { count2 += 1; }
        if (numNew == 3) { count3 += 1; }
      }
    }
  }
  return count1 / 2 + count2 / 4 + count3 / 6;
}

Decremental decrementalTC(Graph g, updates<g> updateBatch,
                          propEdge<bool> modified) {
  int count1 = 0;
  int count2 = 0;
  int count3 = 0;
  forall (u in updateBatch.currentBatch(0)) {
    node v1 = u.source;
    node v2 = u.destination;
    forall (v3 in g.neighbors(v1).filter(v3 != v1 && v3 != v2)) {
      if (g.is_an_edge(v2, v3)) {
        edge e1 = g.get_edge(v1, v3);
        edge e2 = g.get_edge(v2, v3);
        int numNew = 1;
        if (e1.modified == True) { numNew = numNew + 1; }
        if (e2.modified == True) { numNew = numNew + 1; }
        if (numNew == 1) { count1 += 1; }
        if (numNew == 2) { count2 += 1; }
        if (numNew == 3) { count3 += 1; }
      }
    }
  }
  return count1 / 2 + count2 / 4 + count3 / 6;
}

Dynamic DynTC(Graph g, updates<g> updateBatch, int batchSize) {
  propEdge<bool> modified_add;
  propEdge<bool> modified_del;
  int triangle_count = staticTC(g);
  Batch(updateBatch : batchSize) {
    g.attachEdgeProperty(modified_del = False);
    OnDelete(u in updateBatch.currentBatch()) : {
      node s = u.source;
      node d = u.destination;
      edge e = g.get_edge(s, d);
      e.modified_del = True;
    }
    triangle_count = triangle_count -
        decrementalTC(g, updateBatch, modified_del);
    g.updateCSRDel(updateBatch);
    g.updateCSRAdd(updateBatch);
    g.attachEdgeProperty(modified_add = False);
    OnAdd(u in updateBatch.currentBatch()) : {
      node s = u.source;
      node d = u.destination;
      edge e = g.get_edge(s, d);
      e.modified_add = True;
    }
    triangle_count = triangle_count +
        incrementalTC(g, updateBatch, modified_add);
  }
  return triangle_count;
}
