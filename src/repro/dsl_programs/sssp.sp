// Dynamic SSSP — the paper's appendix Fig. 21 in the StarPlat-Dynamic
// appendix syntax.  staticSSSP is the Bellman-Ford-style fixedPoint over
// the modified frontier; Incremental re-runs it from a seeded frontier;
// Decremental invalidates the shortest-path subtree below deleted tree
// edges (phase 1) and repairs from the surviving labels (phase 2);
// DynSSSP is the Batch { OnDelete; updateCSRDel; Decremental; OnAdd;
// updateCSRAdd; Incremental } driver of the paper's Fig. 3.

Static staticSSSP(Graph g, node src, propNode<int> dist,
                  propNode<int> parent, propNode<bool> modified) {
  propNode<bool> modified_nxt;
  g.attachNodeProperty(dist = INF, parent = -1, modified = False,
                       modified_nxt = False);
  src.dist = 0;
  src.modified = True;
  bool finished = False;
  fixedPoint until (finished : !modified) {
    forall (v in g.nodes().filter(modified == True)) {
      forall (nbr in g.neighbors(v)) {
        edge e = g.get_edge(v, nbr);
        <nbr.dist, nbr.modified_nxt, nbr.parent> =
            <Min(nbr.dist, v.dist + e.weight), True, v>;
      }
    }
    modified = modified_nxt;
    g.attachNodeProperty(modified_nxt = False);
  }
}

// Re-relax from whatever frontier the caller seeded in `modified`
// (the activeOnAdd vertices), to a fixed point.
Incremental(Graph g, propNode<int> dist, propNode<int> parent,
            propNode<bool> modified) {
  propNode<bool> modified_nxt;
  g.attachNodeProperty(modified_nxt = False);
  bool finished = False;
  fixedPoint until (finished : !modified) {
    forall (v in g.nodes().filter(modified == True)) {
      forall (nbr in g.neighbors(v)) {
        edge e = g.get_edge(v, nbr);
        <nbr.dist, nbr.modified_nxt, nbr.parent> =
            <Min(nbr.dist, v.dist + e.weight), True, v>;
      }
    }
    modified = modified_nxt;
    g.attachNodeProperty(modified_nxt = False);
  }
}

Decremental(Graph g, propNode<int> dist, propNode<int> parent,
            propNode<bool> modified) {
  // Phase 1: chase parent pointers — every vertex whose shortest-path
  // parent got invalidated is invalidated too, to a fixed point.
  bool finished = False;
  while (!finished) {
    finished = True;
    forall (v in g.nodes().filter(modified == False)) {
      node par = v.parent;
      if (par >= 0 && par.modified == True) {
        v.dist = INF;
        v.parent = -1;
        v.modified = True;
        finished = False;
      }
    }
  }
  // Phase 2: the surviving labels are valid upper bounds (deletions only
  // lengthen paths), so re-relax seeded from every still-reachable vertex.
  forall (v in g.nodes()) {
    v.modified = v.dist < INF;
  }
  Incremental(g, dist, parent, modified);
}

Dynamic DynSSSP(Graph g, updates<g> updateBatch, int batchSize, node src,
                propNode<int> dist, propNode<int> parent,
                propNode<bool> modified) {
  staticSSSP(g, src, dist, parent, modified);
  Batch(updateBatch : batchSize) {
    g.attachNodeProperty(modified = False);
    OnDelete(u in updateBatch.currentBatch()) : {
      node s = u.source;
      node d = u.destination;
      if (d.parent == s) {
        d.dist = INF;
        d.parent = -1;
        d.modified = True;
      }
    }
    g.updateCSRDel(updateBatch);
    Decremental(g, dist, parent, modified);
    g.attachNodeProperty(modified = False);
    OnAdd(u in updateBatch.currentBatch()) : {
      node s = u.source;
      node d = u.destination;
      edge e = g.get_edge(s, d);
      if (s.dist + e.weight < d.dist) {
        s.modified = True;
      }
    }
    g.updateCSRAdd(updateBatch);
    Incremental(g, dist, parent, modified);
  }
}
