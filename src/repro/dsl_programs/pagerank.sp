// Dynamic PageRank — the paper's appendix Fig. 20 in the StarPlat-Dynamic
// appendix syntax.  staticPR is the pull-based power iteration with the
// L1 convergence test; recomputePR is the same iteration restricted to
// the `modified` (affected) vertices; DynPR marks the endpoints of each
// update batch, BFS-spreads the mark to everything reachable
// (propagateNodeFlags — the paper's affected-subgraph detection), and
// re-iterates only there.

Static staticPR(Graph g, float beta, float delta, int maxIter,
                propNode<float> pageRank) {
  propNode<float> pageRank_nxt;
  float num_nodes = g.num_nodes();
  g.attachNodeProperty(pageRank = 1.0 / num_nodes, pageRank_nxt = 0.0);
  int iterCount = 0;
  float diff = 0.0;
  do {
    diff = 0;
    forall (v in g.nodes()) {
      float sum = 0.0;
      for (nbr in g.nodes_to(v)) {
        sum = sum + nbr.pageRank / g.count_outNbrs(nbr);
      }
      float val = (1 - delta) / num_nodes + delta * sum;
      diff = diff + abs(val - v.pageRank);
      v.pageRank_nxt = val;
    }
    pageRank = pageRank_nxt;
    iterCount = iterCount + 1;
  } while ((diff > beta) && (iterCount < maxIter));
}

// Same power iteration, gated to the affected set: only modified
// vertices recompute their rank (their in-neighbors' ranks are read
// whether modified or not), so the L1 test runs over the affected set.
Incremental recomputePR(Graph g, float beta, float delta, int maxIter,
                        propNode<float> pageRank,
                        propNode<bool> modified) {
  float num_nodes = g.num_nodes();
  int iterCount = 0;
  float diff = 0.0;
  do {
    diff = 0;
    forall (v in g.nodes().filter(modified == True)) {
      float sum = 0.0;
      for (nbr in g.nodes_to(v)) {
        sum = sum + nbr.pageRank / g.count_outNbrs(nbr);
      }
      float val = (1 - delta) / num_nodes + delta * sum;
      diff = diff + abs(val - v.pageRank);
      v.pageRank = val;
    }
    iterCount = iterCount + 1;
  } while ((diff > beta) && (iterCount < maxIter));
}

Dynamic DynPR(Graph g, updates<g> updateBatch, int batchSize, float beta,
              float delta, int maxIter, propNode<float> pageRank) {
  propNode<bool> modified;
  staticPR(g, beta, delta, maxIter, pageRank);
  Batch(updateBatch : batchSize) {
    g.attachNodeProperty(modified = False);
    OnDelete(u in updateBatch.currentBatch()) : {
      node s = u.source;
      node d = u.destination;
      s.modified = True;       // source out-degree changes: its whole
      d.modified = True;       // contribution shifts, not just this edge
    }
    g.propagateNodeFlags(modified);
    g.updateCSRDel(updateBatch);
    recomputePR(g, beta, delta, maxIter, pageRank, modified);
    g.attachNodeProperty(modified = False);
    OnAdd(u in updateBatch.currentBatch()) : {
      node s = u.source;
      node d = u.destination;
      s.modified = True;
      d.modified = True;
    }
    g.propagateNodeFlags(modified);
    g.updateCSRAdd(updateBatch);
    recomputePR(g, beta, delta, maxIter, pageRank, modified);
  }
}
