"""Fault-tolerant training driver.

PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --reduced \
    --steps 50 --batch 8 --seq 128 --ckpt /tmp/ckpt

Production posture (1000+ nodes), all exercised here at host scale:
  * deterministic resumable data pipeline (repro.data.pipeline),
  * step-granular atomic checkpoints + resume-from-latest,
  * donated buffers (no double-residency of params/opt state),
  * elastic restart loop (repro.launch.elastic) around transient faults,
  * async dispatch: the host thread stays ≥1 step ahead of the device.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.archs import REGISTRY, get_arch
from repro.configs.reduced import reduced
from repro.data.pipeline import DataConfig, SyntheticSource, iterate
from repro.ckpt import checkpoint as ckpt
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model


def build(args):
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = make_host_mesh(args.model_parallel) if args.mesh else None
    model = Model(cfg=cfg, mesh=mesh,
                  dtype=jnp.float32 if args.f32 else jnp.bfloat16,
                  lr=args.lr)
    return cfg, model


def train(args) -> dict:
    cfg, model = build(args)
    key = jax.random.PRNGKey(args.seed)
    data = SyntheticSource(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed))

    start = ckpt.latest_step(args.ckpt) if args.ckpt else None
    if start is not None:
        params = model.init(key)            # structure donor
        state = model.init_opt(params)
        (params, state), extra = ckpt.restore(
            args.ckpt, start, (params, state))
        step0 = int(extra.get("step", start))
        print(f"[train] resumed from step {step0}")
    else:
        params = model.init(key)
        state = model.init_opt(params)
        step0 = 0

    @jax.jit
    def step_fn(params, state, step, batch):
        return model.train_step(params, state, step, batch)

    losses = []
    t0 = time.time()
    it = iterate(data, start_step=step0)
    for step, batch in it:
        if step >= args.steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, state, metrics = step_fn(
            params, state, jnp.asarray(step, jnp.int32), batch)
        if args.fail_at is not None and step == args.fail_at:
            raise RuntimeError("injected failure (elastic test)")
        if step % args.log_every == 0:
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            print(f"[train] step={step} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({dt:.1f}s)", flush=True)
        if args.ckpt and step > 0 and step % args.ckpt_every == 0:
            ckpt.save(args.ckpt, step, (params, state),
                      extra={"step": step + 1})
    if args.ckpt:
        ckpt.save(args.ckpt, args.steps, (params, state),
                  extra={"step": args.steps})
    return {"losses": losses, "params": params}


def parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m", choices=list(REGISTRY))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh", action="store_true",
                    help="shard over all host devices")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--f32", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (elastic test)")
    ap.add_argument("--elastic", action="store_true")
    return ap


def main():
    args = parser().parse_args()
    if args.elastic:
        from repro.launch.elastic import run_elastic
        run_elastic(train, args)
    else:
        train(args)


if __name__ == "__main__":
    main()
