"""Batched decode driver (the serving-side end-to-end path).

PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m --reduced \
    --batch 4 --prompt-len 16 --gen 32

Serving here is the LM-side analogue of the paper's dynamic-vs-static
trade: ``prefill`` is the static full recomputation, each ``serve_step``
is an *incremental* update that touches only the new token's row of the
attention "graph" (DESIGN.md §4) — dynamic processing wins exactly when
the update fraction (1 token vs the 32k context) is small, which is the
paper's headline observation transplanted to inference.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.archs import REGISTRY, get_arch
from repro.configs.reduced import reduced
from repro.models import transformer as T
from repro.models.model import Model


def serve(args) -> dict:
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = Model(cfg=cfg, dtype=jnp.float32 if args.f32 else jnp.bfloat16)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)

    B, P, G = args.batch, args.prompt_len, args.gen
    S = P + G
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab)
    src = None
    if cfg.family == "vlm":
        src = jnp.ones((B, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    elif cfg.family == "audio":
        src = jnp.ones((B, cfg.enc_seq, cfg.d_model), jnp.float32)

    # ---- prefill (static recomputation over the prompt) -------------------
    t0 = time.time()
    batch = {"tokens": prompts}
    if src is not None:
        batch["src"] = src
    logits, caches = model.prefill_step(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    # pad the prefill cache out to the full decode length
    def pad(x):
        if x.ndim == 5 and x.shape[3] == P:        # (R,B,kv,P,dh)
            return jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, S - P), (0, 0)))
        return x
    caches = jax.tree_util.tree_map(pad, caches)

    step_fn = jax.jit(
        lambda p, c, t, pos: model.serve_step(p, c, t, pos, src=src))

    # ---- incremental decode ------------------------------------------------
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [np.asarray(tok)]
    t0 = time.time()
    for i in range(G - 1):
        logits, caches = step_fn(params, caches, tok, jnp.asarray(P + i))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    toks = np.concatenate(out, axis=1)
    tps = B * (G - 1) / max(t_decode, 1e-9)
    print(f"[serve] arch={cfg.name} B={B} prompt={P} gen={G}")
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms "
          f"({B * P / max(t_prefill, 1e-9):.0f} tok/s)")
    print(f"[serve] decode  {t_decode*1e3:.1f} ms "
          f"({tps:.0f} tok/s, {t_decode / max(G - 1, 1) * 1e3:.1f} ms/step)")
    assert np.isfinite(toks).all()
    return {"tokens": toks, "prefill_s": t_prefill, "decode_s": t_decode}


def parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m", choices=list(REGISTRY))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--f32", action="store_true")
    return ap


def main():
    serve(parser().parse_args())


if __name__ == "__main__":
    main()
