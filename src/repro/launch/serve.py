"""Batched decode driver (the serving-side end-to-end path).

PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m --reduced \
    --batch 4 --prompt-len 16 --gen 32

Serving here is the LM-side analogue of the paper's dynamic-vs-static
trade: ``prefill`` is the static full recomputation, each ``serve_step``
is an *incremental* update that touches only the new token's row of the
attention "graph" (DESIGN.md §4) — dynamic processing wins exactly when
the update fraction (1 token vs the 32k context) is small, which is the
paper's headline observation transplanted to inference.

``--graph`` lifts the same driver shape onto graph sessions: a
:class:`repro.serve.SessionPool` of N tenants, each ingesting one ΔG
batch per service tick through the pool's batched mega-call, with
per-tick p50/p99 latency reported the way the decode path reports
tok/s.

PYTHONPATH=src python -m repro.launch.serve --graph --tenants 16 \
    --ticks 12 --batch-size 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.archs import REGISTRY, get_arch
from repro.configs.reduced import reduced
from repro.models import transformer as T
from repro.models.model import Model


def serve(args) -> dict:
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = Model(cfg=cfg, dtype=jnp.float32 if args.f32 else jnp.bfloat16)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)

    B, P, G = args.batch, args.prompt_len, args.gen
    S = P + G
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab)
    src = None
    if cfg.family == "vlm":
        src = jnp.ones((B, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    elif cfg.family == "audio":
        src = jnp.ones((B, cfg.enc_seq, cfg.d_model), jnp.float32)

    # ---- prefill (static recomputation over the prompt) -------------------
    t0 = time.time()
    batch = {"tokens": prompts}
    if src is not None:
        batch["src"] = src
    logits, caches = model.prefill_step(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    # pad the prefill cache out to the full decode length
    def pad(x):
        if x.ndim == 5 and x.shape[3] == P:        # (R,B,kv,P,dh)
            return jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, S - P), (0, 0)))
        return x
    caches = jax.tree_util.tree_map(pad, caches)

    step_fn = jax.jit(
        lambda p, c, t, pos: model.serve_step(p, c, t, pos, src=src))

    # ---- incremental decode ------------------------------------------------
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [np.asarray(tok)]
    t0 = time.time()
    for i in range(G - 1):
        logits, caches = step_fn(params, caches, tok, jnp.asarray(P + i))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    toks = np.concatenate(out, axis=1)
    tps = B * (G - 1) / max(t_decode, 1e-9)
    print(f"[serve] arch={cfg.name} B={B} prompt={P} gen={G}")
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms "
          f"({B * P / max(t_prefill, 1e-9):.0f} tok/s)")
    print(f"[serve] decode  {t_decode*1e3:.1f} ms "
          f"({tps:.0f} tok/s, {t_decode / max(G - 1, 1) * 1e3:.1f} ms/step)")
    assert np.isfinite(toks).all()
    return {"tokens": toks, "prefill_s": t_prefill, "decode_s": t_decode}


def serve_graphs(args) -> dict:
    """The graph-session serving loop: one pool, ``--tenants`` live
    graphs, one ΔG batch per tenant per tick, drained through the
    batched mega-call.  Prints per-tick p50/p99 and the pool's health
    counters; returns the stats snapshot for callers/tests."""
    from repro.core import registry
    from repro.graph.csr import build_csr, rmat_graph
    from repro.graph.updates import random_updates
    from repro.serve import SessionPool

    n, edges, w = rmat_graph(args.scale, 8, seed=args.seed)
    keep = edges[:, 0] != edges[:, 1]
    csr = build_csr(n, edges[keep], w[keep])
    pool = SessionPool(backend=args.backend, batch_mode=args.batch_mode,
                       max_pending=4 * args.tenants)
    streams = [random_updates(csr, 30, seed=args.seed + 1 + t)
               for t in range(args.tenants)]
    for t in range(args.tenants):
        pool.bind(f"t{t}", csr)
    print(f"[serve] graph pool: backend={args.backend} "
          f"mode={args.batch_mode} tenants={args.tenants} "
          f"n={csr.n} edges={csr.num_edges}")

    ticks = []
    for i in range(args.ticks):
        reqs = [(f"t{t}",
                 streams[t].batch(i % streams[t].num_batches(args.batch_size),
                                  args.batch_size))
                for t in range(args.tenants)]
        t0 = time.time()
        pool.apply_many(reqs)
        jax.block_until_ready([pool.session(f"t{t}")._handle
                               for t in range(args.tenants)])
        ticks.append(time.time() - t0)
    warm = np.asarray(ticks[1:]) if len(ticks) > 1 else np.asarray(ticks)
    p50, p99 = np.percentile(warm, [50, 99])
    stats = pool.stats()
    print(f"[serve] tick p50 {p50 * 1e3:.2f} ms  p99 {p99 * 1e3:.2f} ms  "
          f"({p50 / args.tenants * 1e6:.0f} us/session)")
    print(f"[serve] mega_calls={stats['mega_calls']} "
          f"mega_sessions={stats['mega_sessions']} "
          f"sequential_fallbacks={stats['sequential_fallbacks']} "
          f"applied={stats['applied']}")
    registry.clear_shared_engines()
    return {"p50_s": float(p50), "p99_s": float(p99), "stats": stats}


def parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m", choices=list(REGISTRY))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--f32", action="store_true")
    # --graph mode: multi-tenant graph-session pool instead of LM decode
    ap.add_argument("--graph", action="store_true",
                    help="serve a pool of graph sessions instead of decode")
    ap.add_argument("--backend", default="jnp")
    ap.add_argument("--batch-mode", default="vmap",
                    choices=("vmap", "scan", "off"))
    ap.add_argument("--tenants", type=int, default=16)
    ap.add_argument("--ticks", type=int, default=12)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--scale", type=int, default=9,
                    help="rmat graph scale (log2 nodes)")
    return ap


def main():
    args = parser().parse_args()
    if args.graph:
        serve_graphs(args)
    else:
        serve(args)


if __name__ == "__main__":
    main()
