"""Elastic execution + straggler policy.

Failure model at 1000+ nodes (DESIGN.md §5):

  * **Node loss.**  A dead host surfaces as a collective timeout /
    RuntimeError in the step function.  Policy: tear down, rebuild the
    mesh from surviving hosts (shrinking the ``data`` axis — parameter
    specs are *named*, so restore re-shards onto the new mesh without any
    per-device bookkeeping), resume from the latest committed checkpoint.
    ``run_elastic`` implements exactly this loop; at host scale the
    "re-mesh" is a no-op but the restart/restore path is fully real.

  * **Stragglers.**  Synchronous SPMD means the step time is the max over
    hosts.  Mitigations wired into the launcher:
      - deterministic host-sharded input pipeline (no data-server tail),
      - async dispatch (host k+1 work is enqueued before step k ends),
      - checkpoint writes on a background thread (no step-time spike),
      - the cross-pod gradient reduction is hierarchical
        (reduce-scatter intra-pod → all-reduce inter-pod → all-gather),
        so one slow DCI link only serializes its own pod's shard.
    For persistent stragglers the policy is eviction-and-rebalance:
    identical to node loss above, triggered by a step-time SLO.

  * **Preemption.**  SIGTERM → final checkpoint save → clean exit;
    the atomic-rename commit protocol guarantees a restartable state
    even if the save itself is interrupted.
"""
from __future__ import annotations

import time

from repro.runtime.failover import backoff_delay


def run_elastic(train_fn, args, max_restarts: int = 3,
                backoff_s: float = 0.5, backoff_cap_s: float = 30.0):
    """Retry loop: restart `train_fn` from the latest checkpoint after a
    transient failure, rebuilding device state each attempt.  Restarts
    back off exponentially with jitter (``backoff_delay``, shared with
    the failover re-probe timer) so a cluster of restarting hosts does
    not stampede the coordinator in lockstep."""
    attempt = 0
    while True:
        try:
            return train_fn(args)
        except (RuntimeError, OSError) as e:
            attempt += 1
            if attempt > max_restarts:
                raise
            print(f"[elastic] failure: {e!r}; restart {attempt}/"
                  f"{max_restarts} from latest checkpoint")
            # A real cluster would re-query the coordinator for surviving
            # hosts here and rebuild the mesh with a smaller 'data' axis.
            if getattr(args, "fail_at", None) is not None:
                args.fail_at = None          # injected faults fire once
            time.sleep(backoff_delay(attempt - 1, base=backoff_s,
                                     cap=backoff_cap_s))


def run_elastic_session(make_session, work_fn, max_restarts: int = 3,
                        backoff_s: float = 0.5, backoff_cap_s: float = 30.0):
    """Tear-down → re-mesh → restore loop for ``repro.api`` sessions.

    ``make_session(attempt)`` builds the session for the given attempt —
    typically ``attempt == 0`` binds fresh and every retry calls
    ``repro.api.restore_session(ckpt_dir, ...)``, which re-partitions
    dist state onto whatever devices survived (the re-mesh).
    ``work_fn(session)`` must be resumable: consult
    ``session.stream_cursor`` to skip already-applied ΔG batches.  On a
    transient failure (RuntimeError/OSError — collective timeout, lost
    host) the session is dropped and rebuilt from the latest committed
    checkpoint; the atomic-rename commit protocol guarantees one exists.

    Both elastic loops share the exponential-backoff-with-jitter policy
    (the old ``backoff_s=0.0`` default here was a hot restart loop: a
    persistent fault re-bound the session as fast as the device could
    re-prepare it).
    """
    attempt = 0
    while True:
        sess = make_session(attempt)
        try:
            return work_fn(sess)
        except (RuntimeError, OSError) as e:
            attempt += 1
            if attempt > max_restarts:
                raise
            print(f"[elastic] failure: {e!r}; rebuilding session "
                  f"{attempt}/{max_restarts} from latest checkpoint")
            time.sleep(backoff_delay(attempt - 1, base=backoff_s,
                                     cap=backoff_cap_s))
