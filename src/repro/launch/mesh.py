"""Production mesh construction.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; ×2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever this host actually has — used by tests and examples."""
    n = len(jax.devices())
    mp = model_parallel if n % model_parallel == 0 else 1
    return jax.make_mesh((n // mp, mp), ("data", "model"))
