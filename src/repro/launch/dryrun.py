import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, without allocating a single parameter:
  * proof the sharding config lowers and compiles on the production mesh
    (16×16 single-pod AND 2×16×16 multi-pod),
  * ``memory_analysis()`` — per-device bytes (does it fit HBM),
  * ``cost_analysis()``   — HLO FLOPs / bytes for the roofline,
  * collective-bytes by op kind, parsed from the compiled HLO.

Results are appended to benchmarks/results/dryrun.json so interrupted
sweeps resume where they stopped.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--force]
"""
import argparse
import functools
import json
import pathlib
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.archs import REGISTRY, SHAPES, cells
from repro.launch.mesh import make_production_mesh

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / \
    "results" / "dryrun.json"

_SHAPE_RE = re.compile(r"(f8e4m3fn|f8e5m2|bf16|f16|f32|f64|s8|u8|s16|u16|"
                       r"s32|u32|s64|u64|pred)\[([0-9,]*)\]")
_BYTES = {"pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
          "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8, "u64": 8}
_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")


def shape_bytes(m: re.Match) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _BYTES[dt]


def collective_bytes(hlo: str) -> dict:
    """Sum result-shape bytes of every collective op, by kind.

    cost_analysis() has no collective term, so we parse the compiled HLO
    (brief §ROOFLINE).  Result shape is used as the volume proxy: for
    all-gather it's the post-gather size (what actually crosses ICI,
    counted once), for reduce-scatter the reduced shard.
    """
    out = {k: 0 for k in _COLL}
    out["count"] = 0
    for line in hlo.splitlines():
        s = line.strip()
        # result-defining lines look like: %x = TYPE[...] op-name(...)
        for kind in _COLL:
            if f" {kind}(" in s or f"= {kind}(" in s:
                # take the shape(s) before the op name (the result tuple)
                head = s.split(kind + "(")[0]
                ms = list(_SHAPE_RE.finditer(head))
                if ms:
                    out[kind] += sum(shape_bytes(m) for m in ms)
                    out["count"] += 1
                break
    out["total"] = sum(out[k] for k in _COLL)
    return out


def lower_cell(arch: str, shape_name: str, mesh, multi_pod: bool,
               tuning: str = "baseline"):
    from repro.models.model import Model, input_specs
    from repro.models.tuning import BASELINE, OPTIMIZED
    cfg = REGISTRY[arch]
    shape = SHAPES[shape_name]
    model = Model(cfg=cfg, mesh=mesh,
                  tuning=BASELINE if tuning == "baseline" else OPTIMIZED)
    specs = input_specs(model, shape)
    if shape.kind == "train":
        fn = lambda params, opt_state, step, batch: model.train_step(
            params, opt_state, step, batch)
        args = (specs["params"], specs["opt_state"], specs["step"],
                specs["batch"])
    elif shape.kind == "prefill":
        fn = lambda params, batch: model.prefill_step(params, batch)
        args = (specs["params"], specs["batch"])
    else:
        long_mode = shape_name == "long_500k"
        if "src" in specs:
            fn = lambda params, cache, token, pos, src: model.serve_step(
                params, cache, token, pos, src=src, long_mode=long_mode)
            args = (specs["params"], specs["cache"], specs["token"],
                    specs["pos"], specs["src"])
        else:
            fn = lambda params, cache, token, pos: model.serve_step(
                params, cache, token, pos, long_mode=long_mode)
            args = (specs["params"], specs["cache"], specs["token"],
                    specs["pos"])
    with jax.set_mesh(mesh):
        lowered = jax.jit(fn).lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def analyze(compiled, n_chips: int) -> dict:
    from repro.launch.hlo_cost import cost_record
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    rec = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "collectives": coll,
        "n_chips": n_chips,
    }
    # trip-count-aware per-device costs (cost_analysis counts while/scan
    # bodies once and XLA's numbers exclude loop trip counts — see
    # repro/launch/hlo_cost.py)
    rec["hlo_cost"] = cost_record(hlo)
    if ma is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            try:
                rec[k] = int(getattr(ma, k))
            except Exception:
                pass
    return rec


def load_results() -> dict:
    if RESULTS.exists():
        return json.loads(RESULTS.read_text())
    return {}


def save_results(res: dict):
    RESULTS.parent.mkdir(parents=True, exist_ok=True)
    RESULTS.write_text(json.dumps(res, indent=1, sort_keys=True))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--tuning", default="baseline",
                    choices=["baseline", "opt"],
                    help="baseline = paper-faithful lowering; opt = the "
                         "§Perf-optimized paths (tuning.py)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", False, 256))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", True, 512))

    res = load_results()
    todo = [(a, s) for (a, s) in cells()
            if (args.arch in (None, a)) and (args.shape in (None, s))]
    print(f"dry-run: {len(todo)} cells × {len(meshes)} meshes")
    for mesh_name, multi, chips in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        for arch, shape_name in todo:
            key = f"{arch}|{shape_name}|{mesh_name}"
            if args.tuning != "baseline":
                key += f"|{args.tuning}"
            if key in res and res[key].get("ok") and not args.force:
                print(f"[skip] {key}")
                continue
            t0 = time.time()
            try:
                lowered, compiled = lower_cell(arch, shape_name, mesh, multi,
                                               tuning=args.tuning)
                rec = analyze(compiled, chips)
                rec["ok"] = True
                rec["compile_s"] = round(time.time() - t0, 1)
                print(f"[ok]   {key}  flops={rec['flops']:.3e} "
                      f"coll={rec['collectives']['total']:.3e}B "
                      f"temp={rec.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
                      f"({rec['compile_s']}s)")
                del lowered, compiled
            except Exception as e:
                rec = {"ok": False, "error": f"{type(e).__name__}: {e}",
                       "compile_s": round(time.time() - t0, 1)}
                print(f"[FAIL] {key}: {rec['error'][:300]}")
                traceback.print_exc(limit=3)
            res[key] = rec
            save_results(res)
    bad = [k for k, v in res.items() if not v.get("ok")]
    print(f"done: {sum(1 for v in res.values() if v.get('ok'))} ok, "
          f"{len(bad)} failed")
    for k in bad:
        print("  FAILED:", k)


if __name__ == "__main__":
    main()
