"""Trip-count-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` has two blind spots that matter for a
roofline on scanned-layer models:

  1. while-loop bodies (lax.scan over layer units) are counted ONCE,
     not × trip-count — a 94-layer model reports ~1 layer of FLOPs;
  2. the reported numbers are for the per-device (post-SPMD) module.

This module re-derives FLOPs / HBM bytes / collective bytes by parsing
the optimized HLO, building the computation call graph, and weighting
every computation by its execution multiplicity (while bodies get their
trip count, extracted from the loop-condition constant).

The numbers are per-device, which is what the roofline terms need.

Cost model:
  * FLOPs: 2·prod(result)·prod(contracting dims) per ``dot``; elementwise
    flops are ignored (MXU-roofline convention).
  * HBM bytes: Σ (operands + results) over top-level ops, skipping pure
    data-movement/metadata ops (tuple plumbing, bitcasts, parameters).
    Fusions count their boundary, matching XLA's bytes-accessed notion.
  * collective bytes: result-shape bytes per collective op.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1,
    "u4": 1, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8, "u64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that don't touch HBM on their own (metadata / layout plumbing)
_FREE_OPS = {"tuple", "get-tuple-element", "parameter", "bitcast",
             "constant", "after-all", "opt-barrier", "partition-id",
             "replica-id", "iota"}

# ops whose operand/result traffic is real HBM traffic on TPU.  The CPU
# backend leaves elementwise chains unfused that the TPU compiler fuses
# into their producing/consuming matmuls, so counting *every* op's
# operands wildly over-states TPU HBM bytes; this set is the
# fusion-realistic view (matmuls, cache updates, gathers/scatters,
# reductions, copies that survive fusion, and collectives).
_MEM_OPS = {"dot", "convolution", "dynamic-slice", "dynamic-update-slice",
            "gather", "scatter", "reduce", "reduce-window", "sort",
            "select-and-scatter", "copy", "custom-call",
            *COLLECTIVES, *{c + "-start" for c in COLLECTIVES}}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    shape: str                  # result shape text (may be a tuple)
    line: str
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    ops: Dict[str, Op]
    order: List[str]


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        hdr = _COMP_HDR.match(s)
        if hdr and s.endswith("{"):
            cur = Computation(name=hdr.group(1), ops={}, order=[])
            comps[cur.name] = cur
            continue
        if s == "}" or s.startswith("}"):
            continue
        m = _OP_RE.match(s)
        if m and cur is not None:
            name, shape, opcode = m.group(1), m.group(2), m.group(3)
            paren = s.find(opcode + "(") + len(opcode) + 1
            depth, i = 1, paren
            while i < len(s) and depth:
                if s[i] == "(":
                    depth += 1
                elif s[i] == ")":
                    depth -= 1
                i += 1
            args = s[paren:i - 1]
            operands = _OPERAND_RE.findall(args)
            cur.ops[name] = Op(name=name, opcode=opcode, shape=shape,
                               line=s, operands=operands)
            cur.order.append(name)
    return comps


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the loop condition ≈ the trip count
    (canonical scan: compare(i, constant(R)) direction=LT, i from 0)."""
    best = 1
    for op in cond.ops.values():
        for m in _CONST_RE.finditer(op.line):
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = _shape_elems(op.shape)
    cdims = _LHS_CONTRACT.search(op.line)
    k = 1
    if cdims and op.operands:
        lhs = comp.ops.get(op.operands[0])
        if lhs is not None:
            m = _SHAPE_RE.search(lhs.shape)
            if m:
                dims = [int(d) for d in m.group(2).split(",") if d]
                for ci in cdims.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        k *= dims[int(ci)]
    return 2.0 * out_elems * k


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0          # all-ops upper bound (CPU-fusion view)
    bytes_fused: float = 0.0    # _MEM_OPS only (TPU-fusion-realistic view)
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: int = 0
    bytes_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_fused += other.bytes_fused * mult
        self.coll_bytes += other.coll_bytes * mult
        self.coll_count += int(other.coll_count * mult)
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * mult
        for k, v in other.bytes_by_op.items():
            self.bytes_by_op[k] = self.bytes_by_op.get(k, 0.0) + v * mult


# fusion computations containing only elementwise ops melt into their
# producers on TPU; ones containing these ops still touch HBM
_CORE_MEM = {"dot", "convolution", "reduce", "reduce-window", "scatter",
             "gather", "sort", "select-and-scatter", "dynamic-update-slice",
             "dynamic-slice"}


def _has_mem_op(name: str, comps: Dict[str, "Computation"],
                memo: Dict[str, bool], depth: int = 0) -> bool:
    if name in memo:
        return memo[name]
    comp = comps.get(name)
    if comp is None or depth > 64:
        return False
    memo[name] = False
    out = False
    for op in comp.ops.values():
        if op.opcode in _CORE_MEM:
            out = True
            break
        m = _CALLS_RE.search(op.line)
        if m and _has_mem_op(m.group(1), comps, memo, depth + 1):
            out = True
            break
    memo[name] = out
    return out


def _local_cost(comp: Computation, comps: Dict[str, "Computation"],
                in_fusion: bool, mem_memo: Dict[str, bool]) -> Cost:
    """Cost of ops defined directly in this computation (no callees).

    Inside fusion computations only FLOPs and collectives count — the
    intermediate values live in registers/VMEM; the fusion's HBM traffic
    is its boundary, counted at the caller's ``fusion`` op.
    """
    c = Cost()
    for op in comp.ops.values():
        if op.opcode in ("dot", "convolution"):
            c.flops += _dot_flops(op, comp)
        if op.opcode in COLLECTIVES or \
                (op.opcode.endswith("-start") and
                 op.opcode[:-6] in COLLECTIVES):
            kind = op.opcode[:-6] if op.opcode.endswith("-start") \
                else op.opcode
            b = _shape_bytes(op.shape)
            c.coll_bytes += b
            c.coll_by_kind[kind] = c.coll_by_kind.get(kind, 0.0) + b
            c.coll_count += 1
        if in_fusion:
            continue
        if op.opcode == "fusion":
            b = _op_hbm_bytes(op, comp)
            c.bytes += b
            m = _CALLS_RE.search(op.line)
            if m and _has_mem_op(m.group(1), comps, mem_memo):
                c.bytes_fused += b
                c.bytes_by_op["fusion"] = \
                    c.bytes_by_op.get("fusion", 0.0) + b
            continue
        if op.opcode not in _FREE_OPS and not op.opcode.endswith("-done"):
            b = _op_hbm_bytes(op, comp)
            c.bytes += b
            if op.opcode in _MEM_OPS:
                c.bytes_fused += b
                c.bytes_by_op[op.opcode] = \
                    c.bytes_by_op.get(op.opcode, 0.0) + b
    return c


def _operand_bytes(op: Op, comp: Computation, idx: int) -> int:
    if idx >= len(op.operands):
        return 0
    src = comp.ops.get(op.operands[idx])
    return _shape_bytes(src.shape) if src is not None else 0


def _op_hbm_bytes(op: Op, comp: Computation) -> int:
    """HBM traffic for one op.  Slice-family ops move only the slice —
    counting the full operand would charge a scan step for reading the
    entire stacked (R,…) weight tensor instead of its own layer."""
    res = _shape_bytes(op.shape)
    if op.opcode == "dynamic-slice":
        return 2 * res                       # read slice + write result
    if op.opcode == "dynamic-update-slice":
        upd = _operand_bytes(op, comp, 1)
        return 2 * upd                       # read update + write window
    if op.opcode == "gather":
        return 2 * res + _operand_bytes(op, comp, 1)   # rows + indices
    if op.opcode == "scatter":
        upd = _operand_bytes(op, comp, 2)
        return 2 * upd + _operand_bytes(op, comp, 1)
    if op.opcode in COLLECTIVES or op.opcode.endswith("-start"):
        return 2 * res                       # HBM in + out around the wire
    # default (dot, custom-call, copy, reduce, …): operands + result
    b = res
    for o in op.operands:
        src = comp.ops.get(o)
        if src is not None and src.opcode not in ("tuple",):
            b += _shape_bytes(src.shape)
    return b


def _callees(comp: Computation) -> List[Tuple[str, float]]:
    """(callee name, multiplier) pairs for fusions/calls/whiles/etc."""
    out: List[Tuple[str, float]] = []
    for op in comp.ops.values():
        if op.opcode == "while":
            m = _COND_BODY_RE.search(op.line)
            if m:
                out.append((m.group(1), 1.0))     # cond: ≈ trips, cheap
                out.append((m.group(2), -1.0))    # body: resolved later
            continue
        m = _CALLS_RE.search(op.line)
        if m:
            out.append((m.group(1), 1.0))
        m = _TO_APPLY_RE.search(op.line)
        if m:
            out.append((m.group(1), 1.0))
    return out


def analyze_hlo(text: str, entry: Optional[str] = None) -> Cost:
    comps = parse_hlo(text)
    if not comps:
        return Cost()
    if entry is None:
        # entry is usually 'main...'; fall back to the last computation
        entry = next((n for n in comps if n.startswith("main")),
                     list(comps)[-1])

    memo: Dict[str, Cost] = {}
    mem_memo: Dict[str, bool] = {}

    def total(name: str, depth=0, in_fusion=False) -> Cost:
        key = f"{name}|{in_fusion}"
        if key in memo:
            return memo[key]
        comp = comps.get(name)
        out = Cost()
        if comp is None or depth > 64:
            return out
        out.add(_local_cost(comp, comps, in_fusion, mem_memo))
        for op in comp.ops.values():
            if op.opcode == "while":
                m = _COND_BODY_RE.search(op.line)
                if not m:
                    continue
                cond_name, body_name = m.group(1), m.group(2)
                trips = _trip_count(comps[cond_name]) \
                    if cond_name in comps else 1
                out.add(total(body_name, depth + 1, in_fusion), mult=trips)
                out.add(total(cond_name, depth + 1, in_fusion), mult=trips)
            else:
                fus = op.opcode == "fusion"
                mm = _CALLS_RE.search(op.line)
                if mm:
                    out.add(total(mm.group(1), depth + 1,
                                  in_fusion or fus))
                mm = _TO_APPLY_RE.search(op.line)
                if mm and mm.group(1) in comps:
                    out.add(total(mm.group(1), depth + 1, True))
        memo[key] = out
        return out

    return total(entry)


def cost_record(text: str) -> Dict[str, float]:
    c = analyze_hlo(text)
    rec = {
        "flops_per_device": c.flops,
        "bytes_per_device": c.bytes,
        "bytes_fused_per_device": c.bytes_fused,
        "collective_bytes_per_device": c.coll_bytes,
        "collective_count": c.coll_count,
    }
    for k, v in c.coll_by_kind.items():
        rec[f"coll_{k}"] = v
    for k, v in sorted(c.bytes_by_op.items(), key=lambda kv: -kv[1])[:8]:
        rec[f"bytes_{k}"] = v
    return rec
