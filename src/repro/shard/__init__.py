"""Sharded DynGraph backend: the graph *itself* partitioned across
devices, with halo (ghost-region) exchange of boundary property values
(DESIGN.md §5).  Registered as backend ``"dist_sharded"``."""
from repro.shard.engine import ShardedEngine, ShardGraph, LocalShard

__all__ = ["ShardedEngine", "ShardGraph", "LocalShard"]
