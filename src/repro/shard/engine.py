"""ShardedEngine — the graph ITSELF distributed, not just the work.

``DistEngine`` (core/dist.py) reproduces the paper's MPI backend but
replicates the diff-CSR: every device holds the full edge set, so the
largest servable graph is bounded by ONE device's memory.  This engine
partitions the data structure (ROADMAP item 1):

  * **row ownership**: each shard stores only the out-edge rows of its
    partition range (``graph/partition.py`` — ``block`` or
    degree-balanced, a schedule knob in the GraphIt sense); property
    ownership stays block-identity so the single-device algorithm text
    (global-id-indexed vertex properties) remains valid unchanged;
  * **halo region**: each shard keeps a replicated strip of *ghost*
    property slots for the foreign endpoints of its rows — the
    pyop2/firedrake diagonal-vs-off-process split (``graph/halo.py``);
  * **halo exchange**: a repair sweep does ONE packed ``all_to_all``
    per direction per dtype group — owners push boundary property
    values into ghosts (forward), ghost-side partial reductions fold
    back into owners (reverse).  Only boundary values cross shards, in
    static-shape send buffers, so the whole update→seed→repair segment
    stays inside one jitted ``shard_map`` scan;
  * **halo misses ride the overflow channel**: a ΔG insert whose
    endpoint is not yet in the halo tables records the id in a
    per-shard miss buffer and bumps a miss counter that is folded into
    the overflow counter the stream driver already polls — the stock
    rollback → rebuild → replay loop then rebuilds the partition with
    the missed ids as ghost hints, exactly like a pool overflow grows
    capacity.  Sweeps may drop unresolved edges *only* inside a
    segment that is guaranteed to be rolled back and replayed, so
    delivered results always come from a fully-resolved replay.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.core.ir import EdgeSweep
from repro.core.engine import Engine, Props, edge_lane_flags, \
    _STREAM_CACHE_LOCK
from repro.core.dist import DistEngine, DistGraph, DistCollectives, \
    _DistStreamView, _DView, shard_map
from repro.core import dist as _dist
from repro.graph.csr import CSR, INT, build_csr
from repro.graph import diffcsr
from repro.graph.diffcsr import DynGraph, BOOL
from repro.graph.updates import UpdateBatch
from repro.graph.partition import PARTITIONERS, make_partition
from repro.graph.halo import build_plan, ghost_sets

_DYN = tuple(f.name for f in dataclasses.fields(DynGraph) if f.name != "n")
_HALO = ("row_starts", "ghosts", "send_idx", "recv_tgt", "hmiss", "miss_buf")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardGraph(DistGraph):
    """A DistGraph plus the static halo-exchange tables and the
    per-shard miss channel, all stacked on the sharded axis."""

    row_starts: jax.Array   # (P, P+1) row-ownership boundaries (replicated)
    ghosts: jax.Array       # (P, H)   sorted ghost ids, pad n_pad
    send_idx: jax.Array     # (P, P, Hs) owner-local slots per reader, pad blk
    recv_tgt: jax.Array     # (P, P, Hs) halo slots per owner packet, pad H
    hmiss: jax.Array        # (P,)     cumulative halo-miss counter
    miss_buf: jax.Array     # (P, K)   missed global ids (ghost hints)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LocalShard:
    """One shard's view inside shard_map: its DynGraph slice plus its
    halo tables.  Reads of DynGraph attributes fall through to ``g`` so
    graph-shaped helpers keep working on it."""

    g: DynGraph
    row_starts: jax.Array   # (P+1,)
    ghosts: jax.Array       # (H,)
    send_idx: jax.Array     # (P, Hs)  what I (owner) send to each reader
    recv_tgt: jax.Array     # (P, Hs)  where each owner's packet lands in my halo
    hmiss: jax.Array        # ()
    miss_buf: jax.Array     # (K,)

    def __getattr__(self, name):
        return getattr(object.__getattribute__(self, "g"), name)


def _slocal(sg: ShardGraph) -> LocalShard:
    g = DynGraph(**{f: getattr(sg, f)[0] for f in _DYN}, n=sg.n)
    return LocalShard(g=g, **{f: getattr(sg, f)[0] for f in _HALO})


def _srestack(ls: LocalShard) -> ShardGraph:
    g = ls.g
    return ShardGraph(**{f: getattr(g, f)[None] for f in _DYN},
                      **{f: getattr(ls, f)[None] for f in _HALO}, n=g.n)


def _pack_dtype(dt):
    """Exchange-buffer dtype: ints and bools pack as int32; floats keep
    their exact dtype (int32 weights like INF_W exceed float32's exact
    integer range, so cross-casting is never safe)."""
    dt = np.dtype(dt)
    return dt if dt.kind == "f" else np.dtype(np.int32)


def _dtype_groups(vals: Dict[str, jax.Array]):
    groups: Dict[np.dtype, list] = {}
    for k in sorted(vals):
        groups.setdefault(_pack_dtype(vals[k].dtype), []).append(k)
    return sorted(groups.items(), key=lambda kv: kv[0].str)


class _ShardStreamView(_DistStreamView):
    """In-scan facade for the sharded engine: graph state inside the
    fused stream scan is a LocalShard, updates are row-ownership-masked
    with halo-miss recording, and wedge enumeration (TC) works — the
    halo'd shards reuse the distributed wedge body with segment-static
    bounds, which plain DistEngine's view refuses."""

    name = "dist_sharded-stream"

    def __init__(self, outer: "ShardedEngine", bounds=None):
        super().__init__(outer)
        self._bounds = bounds

    def update_del(self, ls: LocalShard, batch: UpdateBatch) -> LocalShard:
        return self._o._update_del_local(ls, batch)

    def update_add(self, ls: LocalShard, batch: UpdateBatch) -> LocalShard:
        return self._o._update_add_local(ls, batch)

    def batch_edge_flags(self, ls: LocalShard, qs, qd, mask) -> jax.Array:
        return edge_lane_flags(ls.g, qs, qd, mask)

    def count_wedges(self, ls: LocalShard, pair_fn, lane_flags, out_example,
                     bounds=None):
        b = bounds if bounds is not None else self._bounds
        if b is None:
            raise NotImplementedError(
                "wedge enumeration inside the sharded stream scan needs "
                "segment-static degree bounds")
        return self._o._count_wedges_local(ls.g, lane_flags, pair_fn,
                                           out_example, b[0], b[1])


class ShardedEngine(DistEngine):
    """Backend ``dist_sharded``: partitioned diff-CSR + halo exchange."""

    name = "dist_sharded"
    MISS_SLOTS = 256

    def __init__(self, num_shards: int | None = None, axis: str = "data",
                 devices=None, partitioner: str = "block"):
        super().__init__(num_shards=num_shards, axis=axis, devices=devices)
        if partitioner not in PARTITIONERS:
            raise ValueError(
                f"unknown partitioner {partitioner!r}; "
                f"expected one of {PARTITIONERS}")
        self.partitioner = partitioner
        self._partition = None
        self._plan = None
        # ghost hints accumulate across halo-miss rebuilds: a rebuild of
        # the ROLLED-BACK snapshot cannot see the edges whose insert
        # triggered the miss, so the missed ids must be force-added as
        # ghosts everywhere (and kept — consecutive rebuild rounds must
        # not forget each other's ids, or >MISS_SLOTS distinct misses
        # could livelock the replay loop).
        self._ghost_hints: np.ndarray | None = None
        self._last_miss = None

    # -- construction ------------------------------------------------------
    def prepare(self, csr: CSR, diff_capacity: int) -> ShardGraph:
        self._n = csr.n
        self._block = -(-csr.n // self.P)
        n, blk = csr.n, self._block
        src = np.asarray(csr.src)
        dst = np.asarray(csr.dst)
        w = np.asarray(csr.w)
        part = make_partition(self.partitioner, n, self.P, src)
        self._partition = part
        owner = part.owner_of(src) if src.size else np.zeros(0, np.int64)
        sels = [owner == p for p in range(self.P)]
        emax = max([1] + [int(s.sum()) for s in sels])
        shards = []
        for p, sel in enumerate(sels):
            e = np.stack([src[sel], dst[sel]], axis=1)
            sub = build_csr(n, e, w[sel], dedupe=False)
            k = sub.num_edges
            pad = emax - k
            shards.append(DynGraph(
                offsets=sub.offsets,
                src=jnp.concatenate([sub.src, jnp.zeros(pad, INT)]),
                dst=jnp.concatenate([sub.dst, jnp.zeros(pad, INT)]),
                w=jnp.concatenate([sub.w, jnp.ones(pad, INT)]),
                alive=jnp.concatenate([jnp.ones(k, BOOL),
                                       jnp.zeros(pad, BOOL)]),
                d_offsets=jnp.zeros((n + 1,), INT),
                d_src=jnp.full((diff_capacity,), n, INT),
                d_dst=jnp.zeros((diff_capacity,), INT),
                d_w=jnp.zeros((diff_capacity,), INT),
                d_alive=jnp.zeros((diff_capacity,), BOOL),
                overflow=jnp.zeros((), INT),
                n=n))
        gsets = ghost_sets(src, dst, owner, blk, self.P,
                           hints=self._ghost_hints)
        plan = build_plan(gsets, self.P, blk, self.n_pad)
        self._plan = plan
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *shards)
        sg = ShardGraph(
            **{f: getattr(stacked, f) for f in _DYN},
            row_starts=jnp.asarray(
                np.tile(part.starts.astype(np.int32)[None], (self.P, 1))),
            ghosts=jnp.asarray(plan.ghosts),
            send_idx=jnp.asarray(plan.send_idx),
            recv_tgt=jnp.asarray(plan.recv_tgt),
            hmiss=jnp.zeros((self.P,), INT),
            miss_buf=jnp.full((self.P, self.MISS_SLOTS), self.n_pad, INT),
            n=n)
        sh = NamedSharding(self.mesh, P(self.axis))
        return jax.tree_util.tree_map(lambda x: jax.device_put(x, sh), sg)

    def per_shard_bytes(self, sg: ShardGraph) -> int:
        """Resident bytes on ONE shard (the memory-scaling metric: a
        single-device DynGraph holds the whole edge set, a shard holds
        its rows plus the halo tables)."""
        total = 0
        for f in dataclasses.fields(ShardGraph):
            if f.name == "n":
                continue
            a = getattr(sg, f.name)
            total += int(np.prod(a.shape[1:], dtype=np.int64)
                         if a.ndim > 1 else 1) * a.dtype.itemsize
        return total

    # -- durable state -----------------------------------------------------
    # Inherits the shard-count-independent global-edge-list snapshot
    # ("dist" kind): saving on N shards and restoring onto M is the same
    # elastic path DistEngine has; the restoring engine re-partitions
    # with ITS partitioner knob (a schedule choice, not graph state).
    def pack_state(self, sg: ShardGraph):
        tree, meta = super().pack_state(sg)
        meta["partitioner"] = self.partitioner
        return tree, meta

    # -- streaming executor hooks ------------------------------------------
    def handle_counters(self, sg: ShardGraph) -> jax.Array:
        """(overflow + halo misses, used, dead): folding the miss count
        into the overflow lane makes the stock drivers' rollback-grow-
        replay loop service halo rebuilds with zero driver changes."""
        mat = sg.d_src < sg.n
        used = jnp.max(jnp.sum(mat.astype(INT), axis=1))
        dead = jnp.max(jnp.sum((mat & ~sg.d_alive).astype(INT), axis=1))
        if not isinstance(sg.hmiss, jax.core.Tracer):
            self._last_miss = (sg.hmiss, sg.miss_buf, sg.overflow)
        return jnp.stack([jnp.sum(sg.overflow) + jnp.sum(sg.hmiss),
                          used, dead])

    def grow(self, sg: ShardGraph, factor: float = 2.0) -> ShardGraph:
        """Rollback servicing: distinguish a true pool overflow (grow
        diff capacity) from a pure halo miss (rebuild the partition at
        the SAME capacity, with the missed ids as ghost hints).  One
        fused host transfer reads the stashed post-run counters."""
        from repro.runtime import faults as _faults
        cap = int(sg.d_src.shape[1])
        _faults.fire("pool_merge", engine=self.name, diff_capacity=cap)
        self._evict_stream_cache(self._handle_shape_key(sg))
        stash, self._last_miss = self._last_miss, None
        halo_only = False
        if stash is not None:
            (post_hm, post_buf, post_of), (pre_hm, pre_of) = jax.device_get(
                (stash, (sg.hmiss, sg.overflow)))
            ids = np.asarray(post_buf).ravel()
            ids = ids[(ids >= 0) & (ids < self.n_pad)]
            if ids.size:
                prev = (self._ghost_hints
                        if self._ghost_hints is not None else ids[:0])
                self._ghost_hints = np.union1d(prev, ids)
            halo_only = (
                int(np.sum(np.asarray(post_hm))) >
                int(np.sum(np.asarray(pre_hm)))
                and int(np.sum(np.asarray(post_of))) <=
                int(np.sum(np.asarray(pre_of))))
        new_cap = cap if halo_only else max(int(cap * factor), cap + 16)
        return self.merge(sg, diff_capacity=new_cap)

    def compact_handle(self, sg: ShardGraph) -> ShardGraph:
        def fn(sgl):
            ls = _slocal(sgl)
            return _srestack(dataclasses.replace(ls, g=diffcsr.compact(ls.g)))
        return self._shmap(fn, in_specs=(self._gspec(),),
                           out_specs=self._gspec())(sg)

    def _handle_shape_key(self, sg: ShardGraph) -> tuple:
        return (int(sg.src.shape[1]), int(sg.d_src.shape[1]),
                int(sg.ghosts.shape[1]), int(sg.send_idx.shape[2]))

    def static_wedge_bounds(self, sg: ShardGraph):
        offs = np.asarray(sg.offsets)
        max_main = int((offs[:, 1:] - offs[:, :-1]).max()) if offs.size else 0
        return max_main, int(sg.d_src.shape[1])

    def _segment_runner(self, step_fn, sg: ShardGraph, batch_size: int):
        bounds = self.static_wedge_bounds(sg)
        key = (step_fn, bounds, self._handle_shape_key(sg), batch_size)
        with _STREAM_CACHE_LOCK:
            fn = self._stream_cache.get(key)
            if fn is None:
                view = _ShardStreamView(self, bounds)
                ax = self.axis
                compiled = {}

                def seg_run(sgl, c0, batches):
                    ls = _slocal(sgl)

                    def body(state, batch):
                        ls, c = step_fn(view, state[0], batch, state[1])
                        return (ls, c), None

                    (ls, c), _ = jax.lax.scan(body, (ls, c0), batches)
                    cnt = diffcsr.pool_counters(ls.g)
                    cnt = jnp.stack([
                        jax.lax.psum(cnt[0], ax) + jax.lax.psum(ls.hmiss, ax),
                        jax.lax.pmax(cnt[1], ax),
                        jax.lax.pmax(cnt[2], ax)])
                    return _srestack(ls), c, cnt[None]

                def fn(sg, carry, stacked):
                    # carry specs are per-leaf: vertex-property carries
                    # shard over the axis, scalar carries (TC's count)
                    # stay replicated — DistEngine's blanket P(axis)
                    # carry spec cannot express the latter.
                    cid = tuple(jnp.ndim(l) == 0
                                for l in jax.tree_util.tree_leaves(carry))
                    run = compiled.get(cid)
                    if run is None:
                        cspec = jax.tree_util.tree_map(
                            lambda l: P() if jnp.ndim(l) == 0 else P(ax),
                            carry)
                        run = jax.jit(self._shmap(
                            seg_run,
                            in_specs=(self._gspec(), cspec, P()),
                            out_specs=(self._gspec(), cspec, P(ax))))
                        compiled[cid] = run
                    sg, carry, counters = run(sg, carry, stacked)
                    self._last_miss = (sg.hmiss, sg.miss_buf, sg.overflow)
                    return sg, carry, counters[0]

                self._stream_cache[key] = fn
        return fn

    # -- halo exchange -----------------------------------------------------
    def _halo_forward(self, ls: LocalShard,
                      vals: Props) -> Props:
        """Owner → ghost refresh: for each dtype group, pack the boundary
        values each reader needs into one (P, Hs, C) buffer, one
        ``all_to_all``, scatter into the (H,)-halo strip.  Pad lanes
        carry garbage but land on ``recv_tgt`` pads (== H) and drop."""
        if not vals:
            return {}
        H = int(ls.ghosts.shape[0])
        blk = self.block
        idx = jnp.clip(ls.send_idx, 0, max(blk - 1, 0))     # (P, Hs)
        out = {}
        for dt, names in _dtype_groups(vals):
            sbuf = jnp.stack([vals[k][idx].astype(dt) for k in names],
                             axis=-1)                       # (P, Hs, C)
            rbuf = jax.lax.all_to_all(sbuf, self.axis, 0, 0, tiled=True)
            hbuf = jnp.zeros((H, len(names)), dt).at[ls.recv_tgt].set(
                rbuf, mode="drop")
            for c, k in enumerate(names):
                out[k] = hbuf[:, c].astype(vals[k].dtype)
        return out

    def _halo_reverse(self, ls: LocalShard, items: dict) -> dict:
        """Ghost partials → owner fold.  ``items`` maps name to
        ``(ghost (H,), base (blk,), fold, ident)``; returns the folded
        (blk,) owner values.  The same plan runs backwards: readers
        gather their ghost partials at ``recv_tgt``, owners fold the
        returning packets into their block at ``send_idx``."""
        if not items:
            return {}
        H = int(ls.ghosts.shape[0])
        safe = jnp.clip(ls.recv_tgt, 0, max(H - 1, 0))
        valid = ls.recv_tgt < H
        si = ls.send_idx
        out = {}
        groups: Dict[np.dtype, list] = {}
        for k in sorted(items):
            groups.setdefault(_pack_dtype(items[k][1].dtype), []).append(k)
        for dt, names in sorted(groups.items(), key=lambda kv: kv[0].str):
            cols = []
            for k in names:
                ghost, base, fold, ident = items[k]
                cols.append(jnp.where(valid, ghost[safe].astype(dt),
                                      jnp.asarray(ident, dt)))
            sbuf = jnp.stack(cols, axis=-1)                 # (P, Hs, C)
            rbuf = jax.lax.all_to_all(sbuf, self.axis, 0, 0, tiled=True)
            for c, k in enumerate(names):
                ghost, base, fold, ident = items[k]
                col = rbuf[..., c].astype(base.dtype)
                if fold == "min":
                    out[k] = base.at[si].min(col, mode="drop")
                elif fold == "max":
                    out[k] = base.at[si].max(col, mode="drop")
                else:
                    out[k] = base.at[si].add(col, mode="drop")
        return out

    # -- core sweep --------------------------------------------------------
    def _sweep_local(self, ls: LocalShard, sw: EdgeSweep, lp: Props,
                     read_set) -> Props:
        """One repair sweep on one shard.  Edge endpoints resolve to the
        (block + halo) concatenated property strip — owned ids map into
        the block, foreign ids binary-search the sorted ghost table.
        Reductions land in a (block + H + 1) dense buffer whose ghost
        strip folds back to owners through the reverse exchange.
        Unresolved endpoints (possible only for edges inserted after the
        tables were built) drop out of the sweep — their inserts already
        bumped the miss counter, so the driver is guaranteed to roll the
        segment back and replay it on rebuilt tables."""
        g = ls.g
        blk = self.block
        H = int(ls.ghosts.shape[0])
        drop = blk + H
        i = jax.lax.axis_index(self.axis)
        lo = i * blk
        esrc, edst, ew, ealive = g.edge_arrays()

        def resolve(v):
            owned = (v // blk) == i
            slot = jnp.clip(jnp.searchsorted(ls.ghosts, v), 0,
                            max(H - 1, 0))
            found = ls.ghosts[slot] == v
            ref = jnp.where(owned, v - lo,
                            jnp.where(found, blk + slot, drop))
            return ref, owned | found

        sref, s_ok = resolve(esrc)
        dref, d_ok = resolve(edst)
        ok = s_ok & d_ok & ealive
        gs = jnp.clip(sref, 0, drop - 1)
        gd = jnp.clip(dref, 0, drop - 1)

        halo = self._halo_forward(ls, {k: lp[k] for k in read_set})
        comb = {k: jnp.concatenate([lp[k], halo[k]]) for k in halo}
        out = sw.edge_fn(_DView(comb, gs), _DView(comb, gd), ew)

        tgt = jnp.where(ok, dref, drop)
        items, post_or = {}, set()
        for target, red in sw.reduces.items():
            if red.kind == "argmin":
                continue
            val, elig = out[target]
            elig = elig & ok
            ident = red.identity(val.dtype)
            v = jnp.where(elig, val, ident)
            dense = red.segment(v, tgt, drop + 1)
            if red.kind == "or":
                dense = dense.astype(INT)
                items["v:" + target] = (dense[blk:drop], dense[:blk],
                                        "max", jnp.zeros((), INT))
                post_or.add(target)
            elif red.kind == "sum":
                items["v:" + target] = (dense[blk:drop], dense[:blk],
                                        "add", jnp.zeros((), dense.dtype))
            else:
                fold = "min" if red.kind == "min" else "max"
                items["v:" + target] = (dense[blk:drop], dense[:blk],
                                        fold, ident)
            h = jax.ops.segment_max(elig.astype(INT), tgt,
                                    num_segments=drop + 1)
            items["h:" + target] = (h[blk:drop], h[:blk], "max",
                                    jnp.zeros((), INT))
        folded = self._halo_reverse(ls, items)
        reduced, hit = {}, {}
        for target, red in sw.reduces.items():
            if red.kind == "argmin":
                continue
            r = folded["v:" + target]
            reduced[target] = (r > 0) if target in post_or else r
            hit[target] = folded["h:" + target] > 0

        amins = {t: r for t, r in sw.reduces.items() if r.kind == "argmin"}
        if amins:
            # second pass: forward the folded minima so every shard can
            # test achievement, then min-fold the achieving GLOBAL
            # source ids — reproducing the deterministic smallest-source
            # tie-break of the single-device argmin bit-exactly.
            ofs = sorted({r.of for r in amins.values()})
            fwd = self._halo_forward(ls, {of: reduced[of] for of in ofs})
            cof = {of: jnp.concatenate([reduced[of], fwd[of]]) for of in ofs}
            aitems = {}
            for target, red in amins.items():
                val, elig = out[red.of]
                elig = elig & ok
                achieved = elig & (val == cof[red.of][gd])
                v = jnp.where(achieved, esrc, jnp.asarray(self.n_pad, INT))
                dense = jax.ops.segment_min(v, tgt, num_segments=drop + 1)
                aitems["a:" + target] = (dense[blk:drop], dense[:blk],
                                         "min", jnp.asarray(self.n_pad, INT))
            afold = self._halo_reverse(ls, aitems)
            for target, red in amins.items():
                reduced[target] = afold["a:" + target]
                hit[target] = hit[red.of]
        return sw.post_fn(lp, reduced, hit)

    def sweep(self, sg: ShardGraph, sw: EdgeSweep, props: Props) -> Props:
        read_set = frozenset(sw.read_set(props))

        def fn(sgl, p):
            return self._sweep_local(_slocal(sgl), sw, p, read_set)

        return self._shmap(fn, in_specs=(self._gspec(), self._pspec()),
                           out_specs=self._pspec())(sg, props)

    def fixed_point(self, sg: ShardGraph, sw: EdgeSweep, props: Props,
                    cond_fn: Callable, max_iter: int) -> Props:
        read_set = frozenset(sw.read_set(props))
        col = DistCollectives(self.axis)

        def fn(sgl, p0):
            ls = _slocal(sgl)

            def cond(state):
                it, p = state
                return (it < max_iter) & cond_fn(p, it, col)

            def body(state):
                it, p = state
                return it + 1, self._sweep_local(ls, sw, p, read_set)

            _, out = jax.lax.while_loop(cond, body,
                                        (jnp.zeros((), INT), p0))
            return out

        return self._shmap(fn, in_specs=(self._gspec(), self._pspec()),
                           out_specs=self._pspec())(sg, props)

    # -- dynamic updates (row-ownership-masked, miss-recording) ------------
    def _row_owner(self, ls: LocalShard, v):
        return jnp.searchsorted(ls.row_starts, jnp.asarray(v, INT),
                                side="right") - 1

    def _covered(self, ls: LocalShard, v):
        i = jax.lax.axis_index(self.axis)
        H = int(ls.ghosts.shape[0])
        owned = (v // self.block) == i
        slot = jnp.clip(jnp.searchsorted(ls.ghosts, v), 0, max(H - 1, 0))
        return owned | (ls.ghosts[slot] == v)

    def _note_misses(self, ls: LocalShard, ids, mask) -> LocalShard:
        """Record endpoints the halo tables cannot resolve.  The counter
        is cumulative (rollback-safe: a replayed segment re-counts from
        the snapshot's value) and rides the overflow channel; the buffer
        keeps the earliest MISS_SLOTS distinct-ish ids as ghost hints
        for the rebuild."""
        K = int(ls.miss_buf.shape[0])
        miss = mask & ~self._covered(ls, ids)
        cnt = jnp.sum(miss.astype(INT))
        pos = ls.hmiss + jnp.cumsum(miss.astype(INT)) - 1
        pos = jnp.where(miss & (pos < K), pos, K)
        buf = ls.miss_buf.at[pos].set(jnp.asarray(ids, INT), mode="drop")
        return dataclasses.replace(ls, hmiss=ls.hmiss + cnt, miss_buf=buf)

    def _update_del_local(self, ls: LocalShard, b: UpdateBatch) -> LocalShard:
        i = jax.lax.axis_index(self.axis)
        own = self._row_owner(ls, b.del_src) == i
        g2 = diffcsr.update_csr_del(ls.g, b.del_src, b.del_dst,
                                    b.del_mask & own)
        # deletes tombstone rows already resident — no new endpoints,
        # no halo growth
        return dataclasses.replace(ls, g=g2)

    def _update_add_local(self, ls: LocalShard, b: UpdateBatch) -> LocalShard:
        i = jax.lax.axis_index(self.axis)
        own = self._row_owner(ls, b.add_src) == i
        m = b.add_mask & own
        g2 = diffcsr.update_csr_add(ls.g, b.add_src, b.add_dst, b.add_w, m)
        ls = dataclasses.replace(ls, g=g2)
        ids = jnp.concatenate([jnp.asarray(b.add_src, INT),
                               jnp.asarray(b.add_dst, INT)])
        return self._note_misses(ls, ids, jnp.concatenate([m, m]))

    def update_del(self, sg: ShardGraph, batch: UpdateBatch) -> ShardGraph:
        def fn(sgl, b):
            return _srestack(self._update_del_local(_slocal(sgl), b))
        return self._shmap(fn, in_specs=(self._gspec(), P()),
                           out_specs=self._gspec())(sg, batch)

    def update_add(self, sg: ShardGraph, batch: UpdateBatch) -> ShardGraph:
        def fn(sgl, b):
            return _srestack(self._update_add_local(_slocal(sgl), b))
        return self._shmap(fn, in_specs=(self._gspec(), P()),
                           out_specs=self._gspec())(sg, batch)
