"""Gradient compression for cross-pod all-reduce (distributed-optimization
trick for the 1000+-node posture).

Intra-pod ICI is ~50 GB/s/link; cross-pod DCI is an order of magnitude
scarcer, so the hierarchical gradient reduction (reduce-scatter intra-pod
→ all-reduce across pods → all-gather intra-pod) compresses the cross-pod
leg to int8 with per-block scales and stochastic rounding:

  * blockwise max-abs scaling (block = trailing 256 lanes) keeps the
    quantization error proportional to the local dynamic range;
  * stochastic rounding makes the quantizer unbiased: E[q] = x, so SGD's
    convergence guarantees survive (standard result for unbiased
    compressors);
  * the all-reduce sums int32-accumulated int8 payloads, then rescales.

`compressed_psum` is mesh-aware: it applies only over the named cross-pod
axis and is a no-op when that axis is absent (single-pod runs).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x: jax.Array) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), pad


def quantize(x: jax.Array, key: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (any shape) → (int8 blocks, f32 per-block scales); unbiased."""
    blocks, _ = _pad_to_block(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    y = blocks / scale
    # stochastic rounding: floor(y + u), u ~ U[0,1)
    u = jax.random.uniform(key, y.shape)
    q = jnp.clip(jnp.floor(y + u), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    blocks = q.astype(jnp.float32) * scale[:, None]
    flat = blocks.reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compressed_psum(x: jax.Array, axis: Optional[str], key: jax.Array,
                    group_size: int) -> jax.Array:
    """Sum ``x`` over the (cross-pod) mesh axis with int8 payloads.

    Inside shard_map only.  int8 values are widened to int32 for the wire
    sum (no overflow for group_size ≤ 2^24/127) and rescaled by the mean
    of the per-pod scales — unbiased because quantization is unbiased.
    """
    if axis is None:
        return x
    q, scale = quantize(x, key)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis)
    ssum = jax.lax.psum(scale, axis)
    # Σ_p q_p·s_p ≈ (Σ q_p)·(Σ s_p)/P when scales are similar; exact when
    # all pods share a scale.  The residual bias is second-order in the
    # scale spread; acceptable for gradients (documented trade-off).
    mean_scale = ssum / group_size
    blocks = qsum.astype(jnp.float32) * mean_scale[:, None]
    flat = blocks.reshape(-1)
    n = 1
    for d in x.shape:
        n *= d
    return flat[:n].reshape(x.shape).astype(x.dtype)


def compression_ratio(shape, dtype=jnp.bfloat16) -> float:
    """Wire-bytes ratio vs an uncompressed all-reduce of the same tensor."""
    n = 1
    for d in shape:
        n *= d
    raw = n * jnp.dtype(dtype).itemsize
    comp = n * 1 + (n // BLOCK + 1) * 4
    return comp / raw
