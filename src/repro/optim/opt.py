"""Optimizers: AdamW and Adafactor, pytree-native, sharding-aware.

Adafactor (factored second moment) is the default for the ≥50B archs so
that optimizer state fits v5e HBM at 256 chips (DESIGN.md §5); AdamW for
the rest.  ``state_specs`` mirrors parameter PartitionSpecs onto the
state pytree so the dry-run can hand fully-specified ShapeDtypeStructs
to ``jit(...).lower``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

F32 = jnp.float32


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(F32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(tree, max_norm):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree_util.tree_map(
        lambda x: (x.astype(F32) * scale).astype(x.dtype), tree), g


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable          # (grads, params, state, step) -> (params, state)
    state_specs: Callable     # param_specs -> state specs


def adamw(lr: float = 3e-4, b1=0.9, b2=0.95, eps=1e-8, wd=0.1,
          max_grad_norm=1.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, F32)
        return {"m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params)}

    def update(grads, params, state, step):
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        t = step.astype(F32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(g, p, m, v):
            g = g.astype(F32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / c1) / (jnp.sqrt(v / c2) + eps) + wd * p.astype(F32)
            return (p.astype(F32) - lr * u).astype(p.dtype), m, v

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        outs = [upd(g, p, m, v)
                for g, p, m, v in zip(flat_g, flat_p, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in outs])
        new_m = tdef.unflatten([o[1] for o in outs])
        new_v = tdef.unflatten([o[2] for o in outs])
        return new_p, {"m": new_m, "v": new_v}, gnorm

    def state_specs(pspecs):
        return {"m": pspecs, "v": pspecs}

    return Optimizer(init, update, state_specs)


def adafactor(lr: float = 1e-3, eps=1e-30, clip_thresh=1.0, wd=0.0,
              max_grad_norm=1.0, min_dim_factored=128) -> Optimizer:
    """Factored second moment over the trailing two dims (≥2-D leaves)."""

    def factored(p):
        return p.ndim >= 2 and p.shape[-1] >= min_dim_factored \
            and p.shape[-2] >= min_dim_factored

    def init(params):
        def st(p):
            if factored(p):
                return {"r": jnp.zeros(p.shape[:-1], F32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], F32)}
            return {"v": jnp.zeros(p.shape, F32)}
        return jax.tree_util.tree_map(st, params)

    def update(grads, params, state, step):
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        t = step.astype(F32) + 1.0
        beta = 1.0 - t ** -0.8

        def upd(g, p, s):
            g = g.astype(F32)
            g2 = g * g + eps
            if "r" in s:
                r = beta * s["r"] + (1 - beta) * jnp.mean(g2, axis=-1)
                c = beta * s["c"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    r[..., None] / jnp.mean(r, axis=-1, keepdims=True)[..., None]
                    * c[..., None, :])
                u = g / jnp.maximum(denom, 1e-30)
                ns = {"r": r, "c": c}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g / jnp.sqrt(v)
                ns = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / clip_thresh)
            newp = p.astype(F32) - lr * (u + wd * p.astype(F32))
            return newp.astype(p.dtype), ns

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state)
        outs = [upd(g, p, s) for g, p, s in zip(flat_g, flat_p, flat_s)]
        new_p = tdef.unflatten([o[0] for o in outs])
        new_s = tdef.unflatten([o[1] for o in outs])
        return new_p, new_s, gnorm

    def state_specs(pspecs):
        def st(spec, is_factored_hint=None):
            # spec is a PartitionSpec for the parameter; derive for r/c/v.
            return spec
        # Shapes differ between r/c/v and the param, so derive per leaf at
        # the call site where shapes are known; here we return a callable
        # marker handled by model.opt_state_specs.
        raise NotImplementedError("use model.opt_state_specs for adafactor")

    return Optimizer(init, update, state_specs)


def make_optimizer(name: str, lr: float | None = None) -> Optimizer:
    if name == "adamw":
        return adamw(lr or 3e-4)
    if name == "adafactor":
        return adafactor(lr or 1e-3)
    raise ValueError(name)
