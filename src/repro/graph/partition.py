"""Row partitioners for the sharded DynGraph (``repro.shard``).

The sharded engine keeps TWO ownership notions deliberately separate:

* **property ownership** is always block-identity — vertex ``v``'s
  property slot lives on shard ``v // block`` (``block = ceil(n / P)``).
  This is forced by ``shard_map``: an ``(n_pad,)`` vertex array shards
  into equal ``(block,)`` slices, and every algorithm in
  ``repro.algos`` indexes properties by *global* vertex id (SSSP's
  ``parent`` values are global ids), so the identity layout is the only
  one that keeps the single-device algorithm text valid.

* **row ownership** — which shard stores and processes the out-edges of
  vertex ``u`` — is the schedule knob this module provides (GraphIt's
  algorithm/schedule split: the partitioner is a schedule choice, not a
  DSL change).  Both partitioners emit CONTIGUOUS vertex ranges, so the
  in-kernel owner test is a ``searchsorted`` over a tiny ``(P+1,)``
  boundary table.

``block`` reproduces DistEngine's layout (row owner == property owner:
only destination endpoints ever need ghost slots); ``degree`` balances
out-degree mass across shards, which skew-heavy graphs need — its cost
is that source endpoints of displaced rows become ghosts too.
"""
from __future__ import annotations

import dataclasses

import numpy as np

PARTITIONERS = ("block", "degree")


@dataclasses.dataclass(frozen=True)
class RowPartition:
    """Contiguous row-ownership ranges: shard ``p`` owns the out-rows of
    vertices ``[starts[p], starts[p+1])``."""

    kind: str
    n: int
    P: int
    block: int                 # property-block width ceil(n / P)
    starts: np.ndarray         # (P+1,) int64, starts[0] == 0, starts[P] == n

    def owner_of(self, v) -> np.ndarray:
        """Row-owner shard of each vertex in ``v`` (host-side)."""
        v = np.asarray(v)
        return np.searchsorted(self.starts, v, side="right") - 1

    @property
    def assign(self) -> np.ndarray:
        """Dense (n,) row-owner table — test/debug surface."""
        return self.owner_of(np.arange(self.n))


def _prop_block(n: int, P: int) -> int:
    return -(-max(n, 1) // P)


def block_partition(n: int, P: int) -> RowPartition:
    """Equal vertex ranges — DistEngine's layout (row owner == property
    owner), so only cut destinations become ghosts."""
    block = _prop_block(n, P)
    starts = np.minimum(np.arange(P + 1, dtype=np.int64) * block, n)
    return RowPartition("block", n, P, block, starts)


def degree_partition(n: int, P: int, src) -> RowPartition:
    """Contiguous ranges balancing out-degree mass: boundary ``p`` is
    placed where the degree prefix sum first reaches ``p/P`` of the
    total.  Each shard's mass overshoots the ideal ``total/P`` by at
    most one vertex's degree.  Falls back to ``block`` on an edgeless
    graph (every prefix target is zero)."""
    src = np.asarray(src)
    deg = np.bincount(src, minlength=n) if n else np.zeros(0, np.int64)
    total = int(deg.sum())
    if total == 0:
        part = block_partition(n, P)
        return dataclasses.replace(part, kind="degree")
    cum = np.cumsum(deg)
    targets = (total * np.arange(1, P, dtype=np.float64)) / P
    cuts = np.searchsorted(cum, targets, side="left") + 1
    starts = np.concatenate([[0], np.minimum(cuts, n), [n]]).astype(np.int64)
    starts = np.maximum.accumulate(starts)
    return RowPartition("degree", n, P, _prop_block(n, P), starts)


def make_partition(kind: str, n: int, P: int, src=None) -> RowPartition:
    if kind == "block":
        return block_partition(n, P)
    if kind == "degree":
        return degree_partition(n, P, src if src is not None else ())
    raise ValueError(
        f"unknown partitioner {kind!r}; expected one of {PARTITIONERS}")
