from repro.graph.csr import CSR, build_csr, rmat_graph, uniform_graph, grid_graph, INF_W
from repro.graph.diffcsr import DynGraph, from_csr, update_csr_add, update_csr_del, merge, is_edge, edge_weight
from repro.graph.updates import UpdateStream, UpdateBatch, random_updates
