"""Update batches: the ``updates<g>`` / ``Batch(allUpdates:batchSize)`` DSL
objects from the paper, as static-shape arrays.

An :class:`UpdateStream` is the full Δ (the paper generates these as a
percentage of |E|: half deletions sampled from existing edges, half
additions of fresh random edges, matching the paper's "percentage of
updates ... includes both incremental and decremental ones").

``batches()`` sweeps through it ``batch_size`` at a time — each
:class:`UpdateBatch` carries padded add/del arrays with validity masks so
every batch has the same static shape (XLA-friendly; the last partial
batch is padded).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np
import jax
import jax.numpy as jnp

from repro.graph.csr import CSR, INT
from repro.graph.diffcsr import BOOL


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class UpdateBatch:
    add_src: jax.Array   # (B,) int32
    add_dst: jax.Array
    add_w: jax.Array
    add_mask: jax.Array  # (B,) bool
    del_src: jax.Array   # (B,) int32
    del_dst: jax.Array
    del_mask: jax.Array

    @property
    def size(self) -> int:
        return int(self.add_src.shape[0])


@dataclasses.dataclass(frozen=True)
class UpdateStream:
    """Host-side container for the whole Δ; slices into UpdateBatches."""

    adds: np.ndarray      # (A, 3) src, dst, w
    dels: np.ndarray      # (Dl, 2) src, dst

    @property
    def num_adds(self) -> int:
        return int(self.adds.shape[0])

    @property
    def num_dels(self) -> int:
        return int(self.dels.shape[0])

    def num_batches(self, batch_size: int) -> int:
        longest = max(self.num_adds, self.num_dels, 1)
        return -(-longest // batch_size)

    def batch(self, i: int, batch_size: int) -> UpdateBatch:
        def pad_slice(arr: np.ndarray, width: int):
            lo = i * batch_size
            chunk = arr[lo:lo + batch_size]
            k = chunk.shape[0]
            out = np.zeros((batch_size, width), dtype=np.int32)
            # NaN/Inf rows int-cast silently here BY DESIGN: batch views
            # are device-bound int lanes, and admission validates the
            # raw host arrays before any batch view is trusted
            with np.errstate(invalid="ignore"):
                out[:k] = chunk
            mask = np.zeros((batch_size,), dtype=bool)
            mask[:k] = True
            return out, mask

        a, am = pad_slice(self.adds, 3)
        d, dm = pad_slice(self.dels, 2)
        return UpdateBatch(
            add_src=jnp.asarray(a[:, 0]), add_dst=jnp.asarray(a[:, 1]),
            add_w=jnp.asarray(np.maximum(a[:, 2], 1)),
            add_mask=jnp.asarray(am),
            del_src=jnp.asarray(d[:, 0]), del_dst=jnp.asarray(d[:, 1]),
            del_mask=jnp.asarray(dm),
        )

    def batches(self, batch_size: int) -> Iterator[UpdateBatch]:
        for i in range(self.num_batches(batch_size)):
            yield self.batch(i, batch_size)

    def window(self, batch_size: int, start: int,
               count: int) -> "UpdateStream":
        """A sub-stream covering batches ``[start, start+count)`` at this
        ``batch_size``.  Because ``batch()`` slices adds and dels with the
        same row arithmetic, ``window(bs, i, k).batch(j, bs)`` is
        lane-identical to ``self.batch(i + j, bs)`` — which is what lets
        the admission guard splice quarantined batches out of a stream
        and run the surviving contiguous ranges through the fused
        executor unchanged."""
        lo = start * batch_size
        hi = (start + count) * batch_size
        return UpdateStream(adds=self.adds[lo:hi], dels=self.dels[lo:hi])

    def stacked(self, batch_size: int, start: int = 0,
                count: int | None = None) -> UpdateBatch:
        """A (count, B)-leaved UpdateBatch pytree — the padded batch
        stream segment that ``Engine.run_stream`` lax.scans over."""
        nb = self.num_batches(batch_size)
        if count is None:
            count = nb - start
        bs = [self.batch(start + j, batch_size) for j in range(count)]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *bs)


def random_updates(csr: CSR, percent: float, seed: int = 0,
                   max_w: int = 100, add_frac: float = 0.5) -> UpdateStream:
    """Sample Δ as the paper does: ``percent`` of |E| updates, split between
    deletions of existing edges and additions of fresh edges."""
    rng = np.random.default_rng(seed)
    n = csr.n
    e = csr.num_edges
    total = max(int(e * percent / 100.0), 1)
    n_add = int(total * add_frac)
    n_del = total - n_add

    src = np.asarray(csr.src)
    dst = np.asarray(csr.dst)
    del_idx = rng.choice(e, size=min(n_del, e), replace=False)
    dels = np.stack([src[del_idx], dst[del_idx]], axis=1).astype(np.int32)

    # Fresh edges: sample, then drop collisions with existing edges.
    existing = set(zip(src.tolist(), dst.tolist()))
    adds = []
    while len(adds) < n_add:
        cand = rng.integers(0, n, size=(2 * (n_add - len(adds)) + 8, 2))
        for u, v in cand:
            if (u, v) not in existing and u != v:
                adds.append((int(u), int(v), int(rng.integers(1, max_w))))
                existing.add((int(u), int(v)))
                if len(adds) >= n_add:
                    break
    adds = np.asarray(adds, dtype=np.int32).reshape(-1, 3)
    return UpdateStream(adds=adds, dels=dels)
