"""diff-CSR: the paper's dynamic graph representation, TPU-adapted.

Paper semantics (§3.5):
  * deletions tombstone the CSR ``coordinates`` slot (sentinel ∞);
  * additions first re-use a vacant slot, else go to a secondary
    *diff-CSR* (own offsets/coords/weights sized by the update batch);
  * after a configurable number of batches the chain is merged back
    into a clean CSR.

TPU adaptation (XLA needs static shapes; scatter-atomics become masks):
  * the main region keeps its allocation forever; a tombstone is an
    ``alive=False`` bit rather than an in-place ∞ write, which *preserves
    row sortedness* and therefore O(log deg) edge membership — a strict
    improvement over the paper's sentinel (recorded in DESIGN.md §2);
  * "vacant-slot reuse" becomes *revival*: re-adding a previously deleted
    edge flips its alive bit in place (same slot, no data movement);
  * the diff region is a fixed-capacity sorted edge pool with its own
    offsets, rebuilt per batch (cheap: capacity == max batch adds);
  * capacity overflow cannot raise inside jit, so it increments an
    ``overflow`` counter that the host checks between batches and
    responds to with ``merge()`` — the paper's merge policy, made
    explicit and fault-tolerant.

Everything here is pure-functional and jit-compatible; ``merge`` is the
one host-side (numpy) op, reserved for *capacity growth*.  Routine
maintenance stays on device: ``update_csr_add`` keeps the diff pool
sorted with an O(B log D) sorted-merge insert (no full-pool re-sort),
and ``compact`` reclaims tombstoned diff slots under jit without
changing shapes (DESIGN.md §2/§3).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.graph.csr import CSR, INT, build_csr, row_searchsorted

BOOL = jnp.bool_


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DynGraph:
    """Dynamic graph = main CSR region + diff region, both static-shape."""

    # --- main region (rows sorted by dst within each src row) ---
    offsets: jax.Array      # (n+1,) int32
    src: jax.Array          # (E,) int32
    dst: jax.Array          # (E,) int32
    w: jax.Array            # (E,) int32
    alive: jax.Array        # (E,) bool
    # --- diff region (globally sorted by (src,dst); empty slots src=n) ---
    d_offsets: jax.Array    # (n+1,) int32
    d_src: jax.Array        # (D,) int32
    d_dst: jax.Array        # (D,) int32
    d_w: jax.Array          # (D,) int32
    d_alive: jax.Array      # (D,) bool
    # --- bookkeeping ---
    overflow: jax.Array     # () int32 — adds dropped for lack of capacity
    n: int = dataclasses.field(metadata=dict(static=True))

    @property
    def main_capacity(self) -> int:
        return int(self.src.shape[0])

    @property
    def diff_capacity(self) -> int:
        return int(self.d_src.shape[0])

    # Flat edge view used by every ``forall (e in g.edges)`` lowering.
    def edge_arrays(self) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
        esrc = jnp.concatenate([self.src, jnp.minimum(self.d_src, self.n - 1)])
        edst = jnp.concatenate([self.dst, self.d_dst])
        ew = jnp.concatenate([self.w, self.d_w])
        ealive = jnp.concatenate([self.alive,
                                  self.d_alive & (self.d_src < self.n)])
        return esrc, edst, ew, ealive

    def out_degrees(self) -> jax.Array:
        esrc, _, _, ealive = self.edge_arrays()
        return jax.ops.segment_sum(ealive.astype(INT), esrc,
                                   num_segments=self.n)


def from_csr(csr: CSR, diff_capacity: int) -> DynGraph:
    d = max(int(diff_capacity), 1)
    n = csr.n
    e = csr.num_edges
    if e == 0:
        # keep ≥1 (dead) lane so gathers stay well-formed on empty graphs
        return DynGraph(
            offsets=csr.offsets,
            src=jnp.zeros((1,), INT), dst=jnp.zeros((1,), INT),
            w=jnp.ones((1,), INT), alive=jnp.zeros((1,), BOOL),
            d_offsets=jnp.zeros((n + 1,), INT),
            d_src=jnp.full((d,), n, INT), d_dst=jnp.zeros((d,), INT),
            d_w=jnp.zeros((d,), INT), d_alive=jnp.zeros((d,), BOOL),
            overflow=jnp.zeros((), INT), n=n)
    return DynGraph(
        offsets=csr.offsets, src=csr.src, dst=csr.dst, w=csr.w,
        alive=jnp.ones((csr.num_edges,), dtype=BOOL),
        d_offsets=jnp.zeros((n + 1,), dtype=INT),
        d_src=jnp.full((d,), n, dtype=INT),
        d_dst=jnp.zeros((d,), dtype=INT),
        d_w=jnp.zeros((d,), dtype=INT),
        d_alive=jnp.zeros((d,), dtype=BOOL),
        overflow=jnp.zeros((), dtype=INT),
        n=n,
    )


# ---------------------------------------------------------------------------
# Lookup helpers
# ---------------------------------------------------------------------------

def _locate_main(g: DynGraph, qs: jax.Array, qd: jax.Array):
    """(pos, found) of (qs->qd) in the main region, ignoring alive bit."""
    lo = g.offsets[qs]
    hi = g.offsets[qs + 1]
    pos = row_searchsorted(g.dst, lo, hi, qd)
    safe = jnp.clip(pos, 0, g.main_capacity - 1) if g.main_capacity else pos
    found = (pos < hi) & (g.dst[safe] == qd) if g.main_capacity else jnp.zeros_like(qs, BOOL)
    return safe, found


def _locate_diff(g: DynGraph, qs: jax.Array, qd: jax.Array):
    lo = g.d_offsets[qs]
    hi = g.d_offsets[qs + 1]
    pos = row_searchsorted(g.d_dst, lo, hi, qd)
    safe = jnp.clip(pos, 0, g.diff_capacity - 1) if g.diff_capacity else pos
    found = (pos < hi) & (g.d_dst[safe] == qd) if g.diff_capacity else jnp.zeros_like(qs, BOOL)
    return safe, found


def update_lanes(g: DynGraph, qs, qd, mask):
    """(lane, active) of batch edges in the E+D lane space of ``g`` —
    the addressing used to patch ELL packs in place."""
    E, D = g.main_capacity, g.diff_capacity
    p1, f1 = _locate_main(g, qs, qd)
    p2, f2 = _locate_diff(g, qs, qd)
    in_main = f1 & mask
    in_diff = f2 & mask & ~f1
    lane = jnp.where(in_main, p1, jnp.where(in_diff, E + p2, E + D))
    return lane, in_main | in_diff


def _pair_searchsorted(a_src: jax.Array, a_dst: jax.Array,
                       q_src: jax.Array, q_dst: jax.Array,
                       iters: int) -> jax.Array:
    """Branchless lexicographic searchsorted: for each query pair, the
    first index i with (a_src[i], a_dst[i]) >= (q_src, q_dst).  The key
    arrays must be sorted by (src, dst); avoids int64 combined keys."""
    lo = jnp.zeros(q_src.shape, INT)
    hi = jnp.full(q_src.shape, a_src.shape[0], INT)
    cap = max(int(a_src.shape[0]) - 1, 0)

    def body(_, carry):
        lo, hi = carry
        active = lo < hi
        mid = (lo + hi) // 2
        safe = jnp.clip(mid, 0, cap)
        ms, md = a_src[safe], a_dst[safe]
        pred = (ms < q_src) | ((ms == q_src) & (md < q_dst))
        lo = jnp.where(active & pred, mid + 1, lo)
        hi = jnp.where(active & ~pred, mid, hi)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return lo


def _log2_iters(length: int) -> int:
    it = 1
    while (1 << it) < length + 1:
        it += 1
    return it + 1


def is_edge(g: DynGraph, qs: jax.Array, qd: jax.Array) -> jax.Array:
    """Vectorized alive-edge membership (u->v). qs/qd any broadcastable shape."""
    qs = jnp.asarray(qs, INT)
    qd = jnp.asarray(qd, INT)
    p1, f1 = _locate_main(g, qs, qd)
    p2, f2 = _locate_diff(g, qs, qd)
    return (f1 & g.alive[p1]) | (f2 & g.d_alive[p2])


def edge_weight(g: DynGraph, qs: jax.Array, qd: jax.Array) -> jax.Array:
    """Weight of alive edge u->v, or INF_W//1 semantics left to caller."""
    from repro.graph.csr import INF_W
    p1, f1 = _locate_main(g, qs, qd)
    p2, f2 = _locate_diff(g, qs, qd)
    w = jnp.full_like(qs, INF_W)
    w = jnp.where(f2 & g.d_alive[p2], g.d_w[p2], w)
    w = jnp.where(f1 & g.alive[p1], g.w[p1], w)
    return w


# ---------------------------------------------------------------------------
# updateCSRDel — tombstone deletions (paper §3.5)
# ---------------------------------------------------------------------------

def update_csr_del(g: DynGraph, del_src: jax.Array, del_dst: jax.Array,
                   mask: jax.Array | None = None) -> DynGraph:
    del_src = jnp.asarray(del_src, INT)
    del_dst = jnp.asarray(del_dst, INT)
    if mask is None:
        mask = jnp.ones(del_src.shape, BOOL)
    p1, f1 = _locate_main(g, del_src, del_dst)
    p2, f2 = _locate_diff(g, del_src, del_dst)
    kill1 = f1 & mask
    kill2 = f2 & mask & ~f1
    # Scatter False into alive bits via OOB-drop: masked-out lanes aim past
    # the end of the array and are dropped.  Duplicates are idempotent.
    E, D = g.main_capacity, g.diff_capacity
    alive = g.alive.at[jnp.where(kill1, p1, E)].set(False, mode="drop")
    d_alive = g.d_alive.at[jnp.where(kill2, p2, D)].set(False, mode="drop")
    return dataclasses.replace(g, alive=alive, d_alive=d_alive)


# ---------------------------------------------------------------------------
# updateCSRAdd — revive vacant slots, overflow into diff-CSR (paper §3.5)
# ---------------------------------------------------------------------------

def update_csr_add(g: DynGraph, add_src: jax.Array, add_dst: jax.Array,
                   add_w: jax.Array | None = None,
                   mask: jax.Array | None = None, *,
                   pool_merge=None) -> DynGraph:
    """``pool_merge`` plugs a backend merge kernel into step 3: called as
    ``pool_merge(d_src, d_dst, d_w, d_alive, f_src, f_dst, f_w, f_alive,
    n=g.n)`` with both lists sorted by (src, dst) and sentinel rows
    (src == n) sunk to the end, it must return the merged
    ``(d_src, d_dst, d_w, d_alive)`` — bit-exact against the default
    scatter path (the Pallas backend passes its merge-path kernel)."""
    add_src = jnp.asarray(add_src, INT)
    add_dst = jnp.asarray(add_dst, INT)
    if add_w is None:
        add_w = jnp.ones(add_src.shape, INT)
    if mask is None:
        mask = jnp.ones(add_src.shape, BOOL)

    E, D = g.main_capacity, g.diff_capacity

    # 1) revive / update in the main region (vacant-slot reuse).
    p1, f1 = _locate_main(g, add_src, add_dst)
    rev1 = f1 & mask
    idx1 = jnp.where(rev1, p1, E)
    alive = g.alive.at[idx1].set(True, mode="drop")
    w = g.w.at[idx1].set(add_w, mode="drop")

    # 2) revive / update in the diff region.
    p2, f2 = _locate_diff(g, add_src, add_dst)
    rev2 = f2 & mask & ~f1
    idx2 = jnp.where(rev2, p2, D)
    d_alive = g.d_alive.at[idx2].set(True, mode="drop")
    d_w = g.d_w.at[idx2].set(add_w, mode="drop")

    # 3) append the rest to the diff pool (OOB-drop on overflow).
    fresh = mask & ~f1 & ~f2
    # de-duplicate repeated fresh edges within the batch: sort by (src,dst),
    # keep only the first fresh lane of each key group.
    B = add_src.shape[0]
    order = jnp.lexsort((add_dst, add_src))
    s_src, s_dst, s_w = add_src[order], add_dst[order], add_w[order]
    s_fresh = fresh[order]
    first = jnp.concatenate([
        jnp.ones((1,), BOOL),
        (s_src[1:] != s_src[:-1]) | (s_dst[1:] != s_dst[:-1])])
    grp = jnp.cumsum(first.astype(INT)) - 1
    idx = jnp.arange(B, dtype=INT)
    first_fresh = jax.ops.segment_min(
        jnp.where(s_fresh, idx, jnp.asarray(B, INT)), grp, num_segments=B)
    s_fresh = s_fresh & (idx == first_fresh[grp])

    d = g.diff_capacity
    used = jnp.sum((g.d_src < g.n).astype(INT))
    # Sorted-merge insert (replaces the old full-pool lexsort): the pool
    # is already sorted by (src, dst) with vacant rows (src = n) sunk to
    # the end, and the admitted fresh edges are sorted within the batch —
    # so every row's post-merge position is its own rank plus the count
    # of keys from the other sorted list below it.  O((B + D)·log) gather
    # rounds, no O(D log D) re-sort of the pool.
    fresh_rank = jnp.cumsum(s_fresh.astype(INT)) - 1
    fits = s_fresh & (used + fresh_rank < d)
    overflow = g.overflow + jnp.sum((s_fresh & ~fits).astype(INT))
    if d:
        # compact the admitted fresh edges into a sorted (B,)-padded list
        f_src = jnp.full((B,), g.n, INT)
        f_dst = jnp.zeros((B,), INT)
        f_w = jnp.zeros((B,), INT)
        ftgt = jnp.where(fits, fresh_rank, B)
        f_src = f_src.at[ftgt].set(s_src, mode="drop")
        f_dst = f_dst.at[ftgt].set(s_dst, mode="drop")
        f_w = f_w.at[ftgt].set(s_w, mode="drop")
        if pool_merge is not None:
            # admitted ranks are a dense prefix, so alive = prefix mask
            f_alive = jnp.arange(B, dtype=INT) < jnp.sum(fits.astype(INT))
            d_src, d_dst, d_wn, d_al = pool_merge(
                g.d_src, g.d_dst, d_w, d_alive, f_src, f_dst, f_w,
                f_alive, n=g.n)
        else:
            # merged position of each existing pool row / each admitted
            # edge.  Fresh edges are never equal to a materialized pool
            # key (they would have been revivals), so ties cannot occur.
            cnt_f = _pair_searchsorted(f_src, f_dst, g.d_src, g.d_dst,
                                       _log2_iters(B))
            cnt_p = _pair_searchsorted(g.d_src, g.d_dst, s_src, s_dst,
                                       _log2_iters(d))
            pool_rows = (g.d_src < g.n)
            pool_pos = jnp.where(pool_rows,
                                 jnp.arange(d, dtype=INT) + cnt_f, d)
            fresh_pos = jnp.where(fits, fresh_rank + cnt_p, d)
            d_src = jnp.full((d,), g.n, INT).at[pool_pos].set(
                g.d_src, mode="drop").at[fresh_pos].set(s_src, mode="drop")
            d_dst = jnp.zeros((d,), INT).at[pool_pos].set(
                g.d_dst, mode="drop").at[fresh_pos].set(s_dst, mode="drop")
            d_wn = jnp.zeros((d,), INT).at[pool_pos].set(
                d_w, mode="drop").at[fresh_pos].set(s_w, mode="drop")
            d_al = jnp.zeros((d,), BOOL).at[pool_pos].set(
                d_alive, mode="drop").at[fresh_pos].set(True, mode="drop")
        d_offsets = jnp.searchsorted(d_src, jnp.arange(g.n + 1, dtype=INT),
                                     side="left").astype(INT)
    else:
        d_src, d_dst, d_wn, d_al, d_offsets = (g.d_src, g.d_dst, d_w,
                                               d_alive, g.d_offsets)
    return dataclasses.replace(
        g, alive=alive, w=w, d_src=d_src, d_dst=d_dst, d_w=d_wn,
        d_alive=d_al, d_offsets=d_offsets, overflow=overflow)


# ---------------------------------------------------------------------------
# compact — on-device reclamation of tombstoned diff slots (jit-compatible)
# ---------------------------------------------------------------------------

def compact(g: DynGraph) -> DynGraph:
    """Drop dead diff-pool rows in place, keeping shapes static.

    A tombstoned diff edge (materialized but ``d_alive == False``) holds a
    pool slot it no longer needs.  This stable left-compaction of the
    alive rows reclaims those slots without leaving jit — the routine
    merge of the paper's merge policy.  Host-side :func:`merge` remains
    only for capacity growth (true overflow).  Row order is preserved, so
    the pool stays sorted by (src, dst) and ``d_offsets`` stays exact.
    """
    d = g.diff_capacity
    if not d:
        return g
    keep = g.d_alive & (g.d_src < g.n)
    pos = jnp.cumsum(keep.astype(INT)) - 1
    tgt = jnp.where(keep, pos, d)
    d_src = jnp.full((d,), g.n, INT).at[tgt].set(g.d_src, mode="drop")
    d_dst = jnp.zeros((d,), INT).at[tgt].set(g.d_dst, mode="drop")
    d_w = jnp.zeros((d,), INT).at[tgt].set(g.d_w, mode="drop")
    d_alive = jnp.zeros((d,), BOOL).at[tgt].set(True, mode="drop")
    d_offsets = jnp.searchsorted(d_src, jnp.arange(g.n + 1, dtype=INT),
                                 side="left").astype(INT)
    return dataclasses.replace(g, d_src=d_src, d_dst=d_dst, d_w=d_w,
                               d_alive=d_alive, d_offsets=d_offsets)


def pool_counters(g: DynGraph) -> jax.Array:
    """(overflow, used, dead) int32 triple — the merge-pressure counters
    the streaming executor reads once per stream segment."""
    used = jnp.sum((g.d_src < g.n).astype(INT))
    dead = jnp.sum(((g.d_src < g.n) & ~g.d_alive).astype(INT))
    return jnp.stack([g.overflow, used, dead])


# ---------------------------------------------------------------------------
# merge — compaction of the diff chain back into a clean CSR (host-side)
# ---------------------------------------------------------------------------

def merge(g: DynGraph, diff_capacity: int | None = None,
          slack: float = 0.0) -> DynGraph:
    """Rebuild a clean CSR out of all alive edges (paper's periodic merge).

    Host-side numpy: this is the one shape-changing operation, so it sits
    at a jit boundary exactly like the paper's merge sits between batches.
    """
    esrc, edst, ew, ealive = (np.asarray(x) for x in g.edge_arrays())
    keep = ealive
    edges = np.stack([esrc[keep], edst[keep]], axis=1)
    csr = build_csr(g.n, edges, ew[keep], dedupe=True)
    if diff_capacity is None:
        diff_capacity = max(g.diff_capacity, 1)
    cap = int(diff_capacity * (1.0 + slack)) or 1
    return from_csr(csr, cap)
