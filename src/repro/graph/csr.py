"""Static CSR graph representation + generators.

The paper (StarPlat) stores static graphs in CSR: ``offsets`` (n+1) and
``coordinates`` (E) plus ``weights`` for weighted graphs.  We keep the same
layout but additionally keep the explicit ``src`` array (sorted-COO view of
the same ordering), because every TPU lowering of ``forall (e in edges)``
is a segment reduction that wants both endpoints as flat vectors.

Rows are kept sorted by destination.  This is a deliberate deviation from
the paper's unsorted adjacencies: sorted rows give O(log deg) edge
membership via branchless binary search (see ``row_searchsorted``), which
the CUDA backend of the paper obtains only optionally ("binary search if
the neighbors are sorted").
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

INT = jnp.int32
# Weight used for missing/invalid lookups.
INF_W = np.int32(np.iinfo(np.int32).max // 2)


@dataclasses.dataclass(frozen=True)
class CSR:
    """Immutable static CSR (host-built, device arrays)."""

    n: int                      # static vertex count
    offsets: jax.Array          # (n+1,) int32, row starts
    src: jax.Array              # (E,) int32 sorted by (src, dst)
    dst: jax.Array              # (E,) int32
    w: jax.Array                # (E,) int32 edge weights

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])


def build_csr(n: int, edges: np.ndarray, weights: np.ndarray | None = None,
              dedupe: bool = True) -> CSR:
    """Build a CSR from a (E, 2) int array of directed edges.

    Host-side (numpy): sorting and deduplication are one-off costs, the
    same way StarPlat's graph loader builds its CSR before processing.
    """
    edges = np.asarray(edges, dtype=np.int64)
    if edges.size == 0:
        edges = edges.reshape(0, 2)
    if weights is None:
        weights = np.ones((edges.shape[0],), dtype=np.int32)
    weights = np.asarray(weights, dtype=np.int32)
    key = edges[:, 0] * np.int64(n) + edges[:, 1]
    order = np.argsort(key, kind="stable")
    edges, weights, key = edges[order], weights[order], key[order]
    if dedupe and edges.shape[0]:
        keep = np.ones(edges.shape[0], dtype=bool)
        keep[1:] = key[1:] != key[:-1]
        edges, weights = edges[keep], weights[keep]
    src = edges[:, 0].astype(np.int32)
    dst = edges[:, 1].astype(np.int32)
    offsets = np.zeros(n + 1, dtype=np.int32)
    np.add.at(offsets, src + 1, 1)
    offsets = np.cumsum(offsets, dtype=np.int32)
    return CSR(n=n, offsets=jnp.asarray(offsets), src=jnp.asarray(src),
               dst=jnp.asarray(dst), w=jnp.asarray(weights))


# ---------------------------------------------------------------------------
# Branchless per-row binary search (vectorized over queries).
# ---------------------------------------------------------------------------

def row_searchsorted(sorted_vals: jax.Array, lo: jax.Array, hi: jax.Array,
                     queries: jax.Array) -> jax.Array:
    """For each query q_i, first index in sorted_vals[lo_i:hi_i] >= q_i.

    Branchless binary search over *row slices* of one flat array — avoids
    int64 combined keys (XLA default int width) and keeps rows independent.
    ~32 gather rounds; fully vectorized over the query batch.
    """
    lo = lo.astype(INT)
    hi = hi.astype(INT)
    cap = max(int(sorted_vals.shape[0]) - 1, 0)
    # Enough iterations for any row length up to 2^31.
    def body(_, carry):
        lo, hi = carry
        active = lo < hi
        mid = (lo + hi) // 2
        v = sorted_vals[jnp.clip(mid, 0, cap)] if cap or sorted_vals.shape[0] \
            else jnp.zeros_like(mid)
        pred = v < queries
        lo = jnp.where(active & pred, mid + 1, lo)
        hi = jnp.where(active & ~pred, mid, hi)
        return lo, hi
    lo, hi = jax.lax.fori_loop(0, 32, body, (lo, hi))
    return lo


# ---------------------------------------------------------------------------
# Generators (paper Table 1 mix: social/skew = RMAT, road = grid, uniform).
# ---------------------------------------------------------------------------

def rmat_graph(n_log2: int, avg_deg: int, seed: int = 0,
               a: float = 0.57, b: float = 0.19, c: float = 0.19,
               max_w: int = 100) -> Tuple[int, np.ndarray, np.ndarray]:
    """RMAT generator with the paper's SNAP parameters (a,b,c,d)."""
    rng = np.random.default_rng(seed)
    n = 1 << n_log2
    m = n * avg_deg
    srcs = np.zeros(m, dtype=np.int64)
    dsts = np.zeros(m, dtype=np.int64)
    for bit in range(n_log2):
        r = rng.random(m)
        # quadrant probabilities: a (0,0), b (0,1), c (1,0), d (1,1)
        src_bit = (r >= a + b).astype(np.int64)
        r2 = rng.random(m)
        dst_bit = np.where(src_bit == 0, (r2 >= a / (a + b)).astype(np.int64),
                           (r2 >= c / (1 - a - b)).astype(np.int64))
        srcs = (srcs << 1) | src_bit
        dsts = (dsts << 1) | dst_bit
    edges = np.stack([srcs, dsts], axis=1)
    w = rng.integers(1, max_w, size=m).astype(np.int32)
    return n, edges, w


def uniform_graph(n: int, avg_deg: int, seed: int = 0, max_w: int = 100):
    rng = np.random.default_rng(seed)
    m = n * avg_deg
    edges = rng.integers(0, n, size=(m, 2)).astype(np.int64)
    w = rng.integers(1, max_w, size=m).astype(np.int32)
    return n, edges, w


def grid_graph(side: int, seed: int = 0, max_w: int = 100):
    """Road-network-like: 2D grid, degree ~4, large diameter (paper US/GR)."""
    rng = np.random.default_rng(seed)
    n = side * side
    idx = np.arange(n).reshape(side, side)
    e = []
    e.append(np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], 1))
    e.append(np.stack([idx[:, 1:].ravel(), idx[:, :-1].ravel()], 1))
    e.append(np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], 1))
    e.append(np.stack([idx[1:, :].ravel(), idx[:-1, :].ravel()], 1))
    edges = np.concatenate(e, axis=0).astype(np.int64)
    w = rng.integers(1, max_w, size=edges.shape[0]).astype(np.int32)
    return n, edges, w
