"""Halo (ghost-region) plan for the sharded DynGraph.

A shard's **halo** is the set of vertices whose property values its
edge rows read or reduce into but whose property slots live on another
shard (the pyop2/firedrake diagonal-vs-off-process split, applied to
vertex properties instead of matrix nonzeros).  This module computes
the ghost sets host-side at ``prepare`` time and freezes them into
static-shape exchange tables, so that at run time one packed
``all_to_all`` per direction moves *only* boundary property values —
no dynamic shapes, no host round-trips.

Table layout (``P`` shards, ``H`` = padded ghosts/shard, ``Hs`` =
padded ghosts per (owner, reader) pair):

* ``ghosts``   (P, H)    — sorted global ids of shard ``p``'s ghosts,
                           padded with ``n_pad`` (sorted ⇒ in-kernel
                           resolution is a searchsorted).
* ``send_idx`` (P, P, Hs) — ``send_idx[q, p]``: local slots (offsets
                           into owner ``q``'s property block) that ``q``
                           sends to reader ``p``; pad ``block`` (folded
                           scatters use ``mode="drop"``).
* ``recv_tgt`` (P, P, Hs) — ``recv_tgt[p, q]``: halo slots on reader
                           ``p`` filled by owner ``q``'s packet; pad
                           ``H``.  Both tables describe the SAME
                           (owner q → reader p) packet, so the forward
                           refresh and the reverse fold reuse one plan.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class HaloPlan:
    P: int
    block: int                 # property-block width per shard
    n_pad: int                 # block * P — ghost-table pad value
    H: int                     # padded halo width per shard
    Hs: int                    # padded packet width per (owner, reader) pair
    ghosts: np.ndarray         # (P, H) int32, sorted, pad n_pad
    counts: np.ndarray         # (P,) real ghost count per shard
    send_idx: np.ndarray       # (P, P, Hs) int32, pad block
    recv_tgt: np.ndarray       # (P, P, Hs) int32, pad H


def ghost_sets(src, dst, row_owner, block: int, P: int,
               hints=None) -> list[np.ndarray]:
    """Per-shard sorted ghost ids: endpoints of a shard's rows whose
    property owner (``v // block``) is another shard.  ``hints`` (extra
    global ids, e.g. from a halo-miss replay) are added to every shard
    they are foreign to — host-side we cannot know which shard's future
    rows will touch them."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    row_owner = np.asarray(row_owner)
    out = []
    for p in range(P):
        sel = row_owner == p
        ends = np.concatenate([src[sel], dst[sel]])
        if hints is not None and len(hints):
            ends = np.concatenate([ends, np.asarray(hints, dtype=np.int64)])
        ends = np.unique(ends)
        out.append(ends[(ends // block) != p])
    return out


def build_plan(gsets: Sequence[np.ndarray], P: int, block: int,
               n_pad: int) -> HaloPlan:
    counts = np.array([len(g) for g in gsets], dtype=np.int64)
    H = max(1, int(counts.max()) if P else 1)
    ghosts = np.full((P, H), n_pad, dtype=np.int32)
    seg = np.zeros((P, P + 1), dtype=np.int64)
    bounds = np.arange(P + 1, dtype=np.int64) * block
    for p, gh in enumerate(gsets):
        gh = np.asarray(gh, dtype=np.int64)
        ghosts[p, : len(gh)] = gh
        # ghosts are sorted, so each owner's slice is contiguous
        seg[p] = np.searchsorted(gh, bounds)
    pair = seg[:, 1:] - seg[:, :-1]          # pair[p, q] = |ghosts of p owned by q|
    Hs = max(1, int(pair.max()) if P else 1)
    send_idx = np.full((P, P, Hs), block, dtype=np.int32)
    recv_tgt = np.full((P, P, Hs), H, dtype=np.int32)
    for p in range(P):
        gh = np.asarray(gsets[p], dtype=np.int64)
        for q in range(P):
            c = int(pair[p, q])
            if not c:
                continue
            s = int(seg[p, q])
            ids = gh[s : s + c]
            send_idx[q, p, :c] = (ids - q * block).astype(np.int32)
            recv_tgt[p, q, :c] = np.arange(s, s + c, dtype=np.int32)
    return HaloPlan(P=P, block=block, n_pad=n_pad, H=H, Hs=Hs,
                    ghosts=ghosts, counts=counts,
                    send_idx=send_idx, recv_tgt=recv_tgt)
