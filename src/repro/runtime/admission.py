"""ΔG admission guard: validate every update batch before device state.

The paper's runtime trusts its update stream; a serving runtime cannot.
Negative vertex ids index CSR offset arrays from the *end* (silent
corruption), ids ≥ n scatter into the pad region, NaN weights poison
every downstream float reduction, and an adversarial giant batch forces
unbounded diff-pool growth.  The guard runs one vectorized host pass
over the batch (or, for streams, one pass over the whole host-side
arrays — amortized to noise on the fused hot path) and applies a
per-session policy:

  * ``reject``     — raise :class:`AdmissionError` with machine-readable
                     reasons; session state untouched.
  * ``clamp``      — sanitize what is repairable (mask off out-of-range
                     lanes, repair NaN/Inf/negative weights to 1) and
                     admit the rest; unsanitizable batches (oversized)
                     are quarantined.  The default: valid batches pass
                     through *unchanged* (same object, bit-exact).
  * ``quarantine`` — divert the whole offending batch to the bounded
                     dead-letter buffer; the session skips it and keeps
                     serving.
  * ``off``        — no validation (the pre-PR-8 behavior; what the
                     guarded-vs-unguarded benchmark row compares against).

``add_del_conflict`` (the same edge added and deleted in one batch) is
*counted* but never blocks admission under ``clamp``: the engine's
delete-before-add batch order makes it deterministic (the edge ends
alive), and the paper's own delete-then-re-add streams rely on it.
Under ``reject``/``quarantine`` it is a violation like any other —
callers choosing the strict policies asked for unambiguous streams.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.runtime.errors import AdmissionError

ADMISSION_POLICIES = ("reject", "clamp", "quarantine", "off")

DEFAULT_MAX_BATCH = 1 << 16

# violation kinds and whether ``clamp`` can sanitize them
_CLAMPABLE = {
    "add_id_out_of_range": True,
    "del_id_out_of_range": True,
    "weight_invalid": True,
    "add_del_conflict": True,    # no-op under clamp: ordering is defined
    "batch_oversized": False,
    # pool-level finding: a queued request dropped under the shed
    # policy (repro.serve); never clampable — the batch was not applied
    "pool_saturated": False,
}


@dataclasses.dataclass(frozen=True)
class Violation:
    """One machine-readable admission finding."""

    kind: str        # key of _CLAMPABLE
    count: int       # offending lanes (1 for batch-level findings)
    detail: str = ""

    @property
    def clampable(self) -> bool:
        return _CLAMPABLE[self.kind]

    def as_dict(self) -> dict:
        return {"kind": self.kind, "count": int(self.count),
                "detail": self.detail}


@dataclasses.dataclass(frozen=True)
class QuarantineRecord:
    """A dead-lettered batch: the reasons, where in the stream it sat,
    and the batch itself (kept for offline repair/replay)."""

    reasons: Tuple[Violation, ...]
    cursor: int                    # session cursor when quarantined
    index: Optional[int] = None    # batch index within a stream, if any
    n_adds: int = 0                # active (masked-in) lanes
    n_dels: int = 0
    batch: object = None

    def as_dict(self) -> dict:
        return {"reasons": [r.as_dict() for r in self.reasons],
                "cursor": self.cursor, "index": self.index,
                "n_adds": self.n_adds, "n_dels": self.n_dels}


class DeadLetterBuffer:
    """Bounded FIFO of :class:`QuarantineRecord`; oldest records are
    evicted (and counted) when full, so a poison flood cannot OOM the
    process through its own quarantine log."""

    def __init__(self, capacity: int = 64):
        self.capacity = max(int(capacity), 1)
        self._q: collections.deque = collections.deque(maxlen=self.capacity)
        self.total = 0       # records ever pushed
        self.evicted = 0     # records dropped to stay bounded

    def push(self, rec: QuarantineRecord) -> None:
        if len(self._q) == self.capacity:
            self.evicted += 1
        self._q.append(rec)
        self.total += 1

    def records(self) -> List[QuarantineRecord]:
        return list(self._q)

    def __len__(self) -> int:
        return len(self._q)

    def as_dict(self) -> dict:
        return {"capacity": self.capacity, "held": len(self._q),
                "total": self.total, "evicted": self.evicted,
                "records": [r.as_dict() for r in self._q]}


# ---------------------------------------------------------------------------
# Vectorized violation detection
# ---------------------------------------------------------------------------

def _host(x) -> np.ndarray:
    return np.asarray(x)


def _bad_ids(src, dst, mask, n) -> np.ndarray:
    return mask & ((src < 0) | (src >= n) | (dst < 0) | (dst >= n))


def _bad_w(w, mask) -> np.ndarray:
    with np.errstate(invalid="ignore"):
        bad = ~np.isfinite(w.astype(np.float64, copy=False)) | (w < 0)
    return mask & bad


def _conflicts(a_src, a_dst, a_ok, d_src, d_dst, d_ok, n) -> int:
    """Count (src, dst) pairs both added and deleted in one batch
    (in-range active lanes only — out-of-range lanes are already their
    own violation)."""
    if not (a_ok.any() and d_ok.any()):
        return 0
    ak = a_src[a_ok].astype(np.int64) * n + a_dst[a_ok].astype(np.int64)
    dk = d_src[d_ok].astype(np.int64) * n + d_dst[d_ok].astype(np.int64)
    return int(np.isin(np.unique(ak), np.unique(dk)).sum())


def batch_violations(batch, n: int,
                     max_batch: int = DEFAULT_MAX_BATCH) -> List[Violation]:
    """One host pass over an :class:`UpdateBatch`; empty list = clean."""
    out: List[Violation] = []
    a_src, a_dst = _host(batch.add_src), _host(batch.add_dst)
    a_w, a_mask = _host(batch.add_w), _host(batch.add_mask)
    d_src, d_dst = _host(batch.del_src), _host(batch.del_dst)
    d_mask = _host(batch.del_mask)

    if batch.size > max_batch:
        out.append(Violation("batch_oversized", 1,
                             f"size {batch.size} > max_batch {max_batch}"))
    bad_a = _bad_ids(a_src, a_dst, a_mask, n)
    if bad_a.any():
        out.append(Violation("add_id_out_of_range", int(bad_a.sum()),
                             f"vertex ids outside [0, {n})"))
    bad_d = _bad_ids(d_src, d_dst, d_mask, n)
    if bad_d.any():
        out.append(Violation("del_id_out_of_range", int(bad_d.sum()),
                             f"vertex ids outside [0, {n})"))
    bad_w = _bad_w(a_w, a_mask & ~bad_a)
    if bad_w.any():
        out.append(Violation("weight_invalid", int(bad_w.sum()),
                             "NaN/Inf or negative add weight"))
    nc = _conflicts(a_src, a_dst, a_mask & ~bad_a,
                    d_src, d_dst, d_mask & ~bad_d, max(n, 1))
    if nc:
        out.append(Violation("add_del_conflict", nc,
                             "edge both added and deleted in one batch "
                             "(delete-before-add order applies)"))
    return out


def sanitize_batch(batch, n: int):
    """The ``clamp`` repair: mask off out-of-range lanes, repair invalid
    weights to 1, preserve everything valid bit-exactly.  Returns a new
    UpdateBatch (int32 lanes, the dtype every engine expects)."""
    import jax.numpy as jnp
    from repro.graph.csr import INT
    from repro.graph.diffcsr import BOOL
    from repro.graph.updates import UpdateBatch

    a_src, a_dst = _host(batch.add_src), _host(batch.add_dst)
    a_w, a_mask = _host(batch.add_w), _host(batch.add_mask)
    d_src, d_dst = _host(batch.del_src), _host(batch.del_dst)
    d_mask = _host(batch.del_mask)

    a_ok = a_mask & ~_bad_ids(a_src, a_dst, a_mask, n)
    d_ok = d_mask & ~_bad_ids(d_src, d_dst, d_mask, n)
    w = a_w.astype(np.float64, copy=True)
    with np.errstate(invalid="ignore"):
        w[~np.isfinite(w) | (w < 0)] = 1.0
    # dead lanes are zeroed so a sanitized batch is shape-stable and
    # never carries the poison values anywhere, even masked
    z = lambda arr, ok: np.where(ok, arr, 0).astype(np.int32)
    return UpdateBatch(
        add_src=jnp.asarray(z(a_src, a_ok), INT),
        add_dst=jnp.asarray(z(a_dst, a_ok), INT),
        add_w=jnp.asarray(np.where(a_ok, w, 0).astype(np.int32), INT),
        add_mask=jnp.asarray(a_ok, BOOL),
        del_src=jnp.asarray(z(d_src, d_ok), INT),
        del_dst=jnp.asarray(z(d_dst, d_ok), INT),
        del_mask=jnp.asarray(d_ok, BOOL),
    )


def stream_batch_violations(stream, batch_size: int, n: int,
                            max_batch: int = DEFAULT_MAX_BATCH
                            ) -> Dict[int, List[Violation]]:
    """Per-batch violation map for a whole :class:`UpdateStream`, from
    ONE vectorized pass over the raw host arrays (before the padded
    batch views are even built — ``UpdateStream.batch`` would silently
    int-cast NaN weights).  Keys are batch indices; clean streams return
    ``{}`` (the fast path the benchmark measures)."""
    bs = int(batch_size)
    adds, dels = stream.adds, stream.dels
    per: Dict[int, Dict[str, int]] = {}

    def note(idx_arr, kind):
        b, c = np.unique(idx_arr // bs, return_counts=True)
        for bi, ct in zip(b.tolist(), c.tolist()):
            per.setdefault(int(bi), {})[kind] = \
                per.get(int(bi), {}).get(kind, 0) + int(ct)

    a_rows = np.arange(adds.shape[0])
    d_rows = np.arange(dels.shape[0])
    bad_a = np.zeros(adds.shape[0], bool)
    bad_d = np.zeros(dels.shape[0], bool)
    if adds.shape[0]:
        a_src, a_dst, a_w = adds[:, 0], adds[:, 1], adds[:, 2]
        bad_a = (a_src < 0) | (a_src >= n) | (a_dst < 0) | (a_dst >= n)
        if bad_a.any():
            note(a_rows[bad_a], "add_id_out_of_range")
        bw = _bad_w(a_w, ~bad_a)
        if bw.any():
            note(a_rows[bw], "weight_invalid")
    if dels.shape[0]:
        d_src, d_dst = dels[:, 0], dels[:, 1]
        bad_d = (d_src < 0) | (d_src >= n) | (d_dst < 0) | (d_dst >= n)
        if bad_d.any():
            note(d_rows[bad_d], "del_id_out_of_range")
    # per-batch add∩del conflicts via (batch, src, dst) key encoding
    if adds.shape[0] and dels.shape[0]:
        nn = max(int(n), 1)
        ga = a_rows[~bad_a] // bs
        gd = d_rows[~bad_d] // bs
        ka = (ga.astype(np.int64) * nn + adds[~bad_a, 0]) * nn \
            + adds[~bad_a, 1]
        kd = (gd.astype(np.int64) * nn + dels[~bad_d, 0]) * nn \
            + dels[~bad_d, 1]
        hit = np.isin(ka, kd)
        if hit.any():
            note(ga[hit] * bs, "add_del_conflict")

    out: Dict[int, List[Violation]] = {}
    for bi, kinds in per.items():
        out[bi] = [Violation(k, c) for k, c in sorted(kinds.items())]
    if bs > max_batch:
        for bi in range(stream.num_batches(bs)):
            out.setdefault(bi, []).append(
                Violation("batch_oversized", 1,
                          f"batch_size {bs} > max_batch {max_batch}"))
    return out


# ---------------------------------------------------------------------------
# The guard
# ---------------------------------------------------------------------------

class AdmissionGuard:
    """Per-session admission state: policy + limits + dead-letter buffer.

    ``admit`` returns the batch to apply (possibly sanitized under
    ``clamp``), ``None`` when the batch was quarantined, and raises
    :class:`AdmissionError` under ``reject``.  Counters live in the
    session's :class:`~repro.runtime.health.SessionHealth`."""

    def __init__(self, policy: str = "clamp",
                 max_batch: int = DEFAULT_MAX_BATCH,
                 dead_letter: int = 64, health=None):
        if policy is None:
            policy = "off"
        if policy not in ADMISSION_POLICIES:
            raise ValueError(f"admission policy must be one of "
                             f"{ADMISSION_POLICIES}, got {policy!r}")
        self.policy = policy
        self.max_batch = int(max_batch)
        self.buffer = DeadLetterBuffer(dead_letter)
        self.health = health

    # -- single batch --------------------------------------------------------
    def admit(self, batch, n: int, cursor: int = 0,
              index: Optional[int] = None):
        if self.policy == "off":
            return batch
        reasons = batch_violations(batch, n, self.max_batch)
        if not reasons:
            if self.health is not None:
                self.health.admitted += 1
            return batch
        return self.resolve(batch, reasons, cursor, index, n)

    def resolve(self, batch, reasons: Sequence[Violation],
                cursor: int, index: Optional[int], n: int):
        """Apply the policy to a batch with known violations: returns
        the (sanitized) batch to apply, ``None`` if quarantined, raises
        under ``reject``."""
        if self.policy == "reject":
            if self.health is not None:
                self.health.rejected += 1
            err = AdmissionError(
                f"batch failed admission: "
                f"{', '.join(r.kind for r in reasons)}",
                reasons=reasons, batch_index=index)
            if self.health is not None:
                self.health.record_error(err)
            raise err
        if self.policy == "quarantine" or \
                not all(r.clampable for r in reasons):
            self.quarantine(batch, reasons, cursor, index)
            return None
        # clamp: a conflict-only batch is admitted UNTOUCHED (same
        # object, bit-exact — delete-before-add ordering is defined and
        # the paper's delete-then-re-add streams rely on it); anything
        # else admits the sanitized remainder
        if self.health is not None:
            self.health.conflicts += sum(
                r.count for r in reasons if r.kind == "add_del_conflict")
        if all(r.kind == "add_del_conflict" for r in reasons):
            if self.health is not None:
                self.health.admitted += 1
            return batch
        if self.health is not None:
            self.health.clamped += 1
            self.health.admitted += 1
        return sanitize_batch(batch, n)

    def quarantine(self, batch, reasons: Sequence[Violation],
                   cursor: int, index: Optional[int] = None) -> None:
        a = _host(batch.add_mask)
        d = _host(batch.del_mask)
        self.buffer.push(QuarantineRecord(
            reasons=tuple(reasons), cursor=cursor, index=index,
            n_adds=int(a.sum()), n_dels=int(d.sum()), batch=batch))
        if self.health is not None:
            self.health.quarantined += 1

    # -- whole stream --------------------------------------------------------
    def inspect_stream(self, stream, batch_size: int,
                       n: int) -> Dict[int, List[Violation]]:
        """Per-batch poison map for a stream.  Under ``clamp``,
        conflict-only batches are pre-filtered out (counted, admitted
        untouched) so the caller's fused fast path keeps them — the
        splice path is only for batches that actually need repair."""
        if self.policy == "off":
            return {}
        poison = stream_batch_violations(stream, batch_size, n,
                                         self.max_batch)
        if self.policy != "clamp" or not poison:
            return poison
        out: Dict[int, List[Violation]] = {}
        for bi, reasons in poison.items():
            if all(r.kind == "add_del_conflict" for r in reasons):
                if self.health is not None:
                    self.health.conflicts += sum(r.count for r in reasons)
            else:
                out[bi] = reasons
        return out
