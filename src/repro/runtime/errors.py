"""Typed fault taxonomy for the streaming runtime (DESIGN.md §6).

Every failure mode the runtime can survive — or must report — gets a
distinct type carrying machine-readable context, so callers (the elastic
loop, the failover chain, a serving layer's SLO logic) can branch on
*what* went wrong instead of parsing ``RuntimeError`` strings.

All types derive from :class:`RuntimeFault` (itself a ``RuntimeError``
so existing ``except RuntimeError`` retry loops keep working) and expose
``describe()`` — a JSON-able dict mirrored into ``session.health``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence


class RuntimeFault(RuntimeError):
    """Base of the typed fault taxonomy."""

    def describe(self) -> Dict[str, Any]:
        return {"kind": type(self).__name__, "message": str(self)}


class AdmissionError(RuntimeFault):
    """A ΔG batch failed admission under the ``reject`` policy.

    ``reasons`` is the machine-readable violation list (see
    :class:`repro.runtime.admission.Violation`)."""

    def __init__(self, message: str, reasons: Sequence = (),
                 batch_index: Optional[int] = None):
        super().__init__(message)
        self.reasons = tuple(reasons)
        self.batch_index = batch_index

    def describe(self) -> Dict[str, Any]:
        d = super().describe()
        d["reasons"] = [r.as_dict() for r in self.reasons]
        if self.batch_index is not None:
            d["batch_index"] = self.batch_index
        return d


class PoolOverflowError(RuntimeFault):
    """The grow-and-replay loop hit its attempt cap: a batch kept
    overflowing the diff pool even after bounded capacity doubling.
    Carries the offending batch and the pool stats at give-up time, so
    the batch can be quarantined or split instead of growing the pool
    until OOM."""

    def __init__(self, message: str, batch=None, attempts: int = 0,
                 diff_capacity: int = 0, counters=()):
        super().__init__(message)
        self.batch = batch
        self.attempts = attempts
        self.diff_capacity = diff_capacity
        self.counters = tuple(int(c) for c in counters)  # (overflow, used, dead)

    def describe(self) -> Dict[str, Any]:
        d = super().describe()
        d.update(attempts=self.attempts, diff_capacity=self.diff_capacity,
                 counters=list(self.counters),
                 batch_size=getattr(self.batch, "size", None))
        return d


class KernelFailure(RuntimeFault):
    """A backend kernel failed to compile or launch.  Raised by the
    chaos harness at the ``kernel_launch`` seam, and used to wrap the
    original backend exception when the failover chain is exhausted."""

    def __init__(self, message: str, backend: Optional[str] = None,
                 seam: Optional[str] = None,
                 cause: Optional[BaseException] = None):
        super().__init__(message)
        self.backend = backend
        self.seam = seam
        if cause is not None:
            self.__cause__ = cause

    def describe(self) -> Dict[str, Any]:
        d = super().describe()
        d.update(backend=self.backend, seam=self.seam,
                 cause=repr(self.__cause__) if self.__cause__ else None)
        return d


class CheckpointCorrupt(RuntimeFault):
    """A committed checkpoint failed to parse or restore — truncated
    manifest, leaf-count mismatch, unreadable shard.  Distinct from
    ``FileNotFoundError`` (no checkpoint at all): corrupt means the
    commit protocol's invariant was violated after the marker."""

    def __init__(self, message: str, path: Optional[str] = None,
                 step: Optional[int] = None):
        super().__init__(message)
        self.path = str(path) if path is not None else None
        self.step = step

    def describe(self) -> Dict[str, Any]:
        d = super().describe()
        d.update(path=self.path, step=self.step)
        return d


class PoolSaturatedError(RuntimeFault):
    """The session pool's bounded request queue is full and the overload
    policy is ``reject``: the submit was refused before touching any
    session state.  Carries the queue shape at refusal time so a client
    (or load balancer) can back off per tenant instead of parsing
    strings."""

    def __init__(self, message: str, tenant: Optional[str] = None,
                 pending: int = 0, max_pending: int = 0,
                 policy: str = "reject",
                 depths: Optional[Dict[str, int]] = None):
        super().__init__(message)
        self.tenant = tenant
        self.pending = int(pending)
        self.max_pending = int(max_pending)
        self.policy = policy
        self.depths = dict(depths or {})

    def describe(self) -> Dict[str, Any]:
        d = super().describe()
        d.update(tenant=self.tenant, pending=self.pending,
                 max_pending=self.max_pending, policy=self.policy,
                 depths=dict(self.depths))
        return d


class DivergenceError(RuntimeFault):
    """The on-device divergence probe found NaN/Inf in a property array
    after a stream segment — numerically diverged state that would
    otherwise propagate silently through every later batch."""

    def __init__(self, message: str, props: Sequence[str] = ()):
        super().__init__(message)
        self.props = tuple(props)

    def describe(self) -> Dict[str, Any]:
        d = super().describe()
        d["props"] = list(self.props)
        return d
