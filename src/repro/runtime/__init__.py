"""repro.runtime — the fault-tolerant streaming runtime (DESIGN.md §6).

Four cooperating pieces, all surfaced through ``repro.api`` sessions:

  * **admission** — every ΔG batch is validated *before* it touches
    device state (out-of-range ids, NaN/Inf/negative weights, oversized
    batches, add+del conflicts) under a per-session policy
    ``reject | clamp | quarantine``; quarantined batches land in a
    bounded dead-letter buffer with machine-readable reasons.
  * **errors** — the typed fault taxonomy (:class:`PoolOverflowError`,
    :class:`KernelFailure`, :class:`CheckpointCorrupt`,
    :class:`DivergenceError`, :class:`AdmissionError`) replacing the
    bare ``RuntimeError``s the runtime used to die with.
  * **failover** — a registry-level degradation chain
    (``pallas → pallas_chained → jnp``): kernel failures at bind time or
    mid-stream re-bind the session through the cross-backend
    ``state_to_csr`` conversion path, sticky with periodic re-probe.
  * **faults** — the chaos-injection harness: tests (and the
    ``chaos-smoke`` CI job) arm named seams (kernel launch, pool merge,
    checkpoint write, counter sync, segment scan) and assert sessions
    survive bit-exact vs the oracle.

Observability rides along as ``session.health`` (quarantine / retry /
grow / failover counters, last error, current backend).
"""
from repro.runtime.errors import (RuntimeFault, AdmissionError,
                                  PoolOverflowError, KernelFailure,
                                  CheckpointCorrupt, DivergenceError,
                                  PoolSaturatedError)
from repro.runtime.admission import (AdmissionGuard, DeadLetterBuffer,
                                     QuarantineRecord, Violation,
                                     ADMISSION_POLICIES)
from repro.runtime.health import SessionHealth, PoolHealth
from repro.runtime.failover import FailoverPolicy, backoff_delay
from repro.runtime import faults
from repro.runtime import watchdog

__all__ = [
    "RuntimeFault", "AdmissionError", "PoolOverflowError", "KernelFailure",
    "CheckpointCorrupt", "DivergenceError", "PoolSaturatedError",
    "AdmissionGuard", "DeadLetterBuffer", "QuarantineRecord", "Violation",
    "ADMISSION_POLICIES", "SessionHealth", "PoolHealth", "FailoverPolicy",
    "backoff_delay", "faults", "watchdog",
]
