"""Backend failover policy: sticky degradation with periodic re-probe.

When a backend's kernels fail to compile or launch, the session hops
down its failover chain (``pallas → pallas_chained → jnp`` by default;
see :func:`repro.core.registry.failover_chain`) carrying graph state
across via the cross-backend ``state_to_csr`` path.  Degradation is
*sticky* — the session keeps serving on the surviving backend — but a
re-probe timer (shared exponential backoff with the elastic launcher)
periodically attempts to convert back to the preferred backend; a
failed probe re-degrades and doubles the wait.

This module is pure policy/bookkeeping: no engine imports, no device
work.  The session layer owns the actual state migration.
"""
from __future__ import annotations

import random
import time
from typing import List, Optional, Sequence


def backoff_delay(attempt: int, base: float = 0.5, cap: float = 30.0,
                  jitter: float = 0.5,
                  rng: Optional[random.Random] = None) -> float:
    """Exponential backoff with decorrelating jitter: ``base * 2**attempt``
    capped at ``cap``, then scaled by a uniform factor in
    ``[1 - jitter, 1]``.  Shared by the elastic restart loop and the
    failover re-probe timer so both degrade pressure the same way."""
    if base <= 0:
        return 0.0
    d = min(float(base) * (2.0 ** max(int(attempt), 0)), float(cap))
    if jitter > 0:
        r = rng.random() if rng is not None else random.random()
        d *= 1.0 - float(jitter) * r
    return d


class FailoverPolicy:
    """Bookkeeping for one session's degradation state.

    * ``preferred`` — the registry name ``bind()`` originally asked for.
    * ``chain``     — remaining fallbacks, in order, *excluding* whatever
      is currently bound.
    * re-probe: ``should_probe(now)`` turns true once the backoff window
      since the last failure has elapsed; ``probe_failed(now)`` doubles
      the window, ``recovered()`` resets it.
    """

    def __init__(self, preferred: str, chain: Sequence[str],
                 probe_base_s: float = 0.5, probe_cap_s: float = 30.0,
                 rng: Optional[random.Random] = None):
        self.preferred = preferred
        self.chain: List[str] = [c for c in chain if c != preferred]
        self.probe_base_s = probe_base_s
        self.probe_cap_s = probe_cap_s
        self._rng = rng
        self._failures = 0         # consecutive preferred-backend failures
        self._next_probe_t: Optional[float] = None

    # -- degradation ---------------------------------------------------------
    def candidates(self, current: str) -> List[str]:
        """Backends left to try after ``current`` failed, preserving
        chain order."""
        if current == self.preferred:
            return list(self.chain)
        if current in self.chain:
            i = self.chain.index(current)
            return self.chain[i + 1:]
        return list(self.chain)

    def degraded_from(self, now: Optional[float] = None) -> None:
        """Record a failure of the preferred backend (or of a probe) and
        schedule the next re-probe."""
        now = time.monotonic() if now is None else now
        self._failures += 1
        self._next_probe_t = now + backoff_delay(
            self._failures - 1, self.probe_base_s, self.probe_cap_s,
            rng=self._rng)

    # -- re-probe ------------------------------------------------------------
    def should_probe(self, now: Optional[float] = None) -> bool:
        if self._next_probe_t is None:
            return False
        now = time.monotonic() if now is None else now
        return now >= self._next_probe_t

    def probe_failed(self, now: Optional[float] = None) -> None:
        self.degraded_from(now)

    def recovered(self) -> None:
        self._failures = 0
        self._next_probe_t = None
