"""Chaos-injection harness: named fault seams on the runtime hot path.

Generalizes the ``ckpt._crash_point`` test seam from the checkpoint
commit protocol into a registry any host-side boundary can consult.
Production cost is one truthiness check on an empty dict per seam
crossing; nothing fires unless a test armed an injector.

    from repro.runtime import faults

    with faults.inject("kernel_launch", KernelFailure("boom"),
                       match=lambda ctx: ctx.get("engine") == "pallas"):
        sess.apply(batch)          # raises at the pallas launch seam

Seams are *host-side* boundaries: a seam inside code that jit traces
fires at trace time (modeling a compile failure) and on every
interpreted/eager call (modeling a launch failure); it cannot fire from
inside an already-compiled executable.

Named seams (instrumented call sites):

  * ``kernel_launch``    — PallasEngine sweep/update kernel dispatch,
                           FrontierEngine sparse-step dispatch
  * ``pool_merge``       — host-side diff-pool grow/merge (Engine.grow)
  * ``checkpoint_write`` — every commit-protocol point in ckpt.save
                           (ctx: ``point`` in shard/manifest/committed/
                           renamed — the PR 7 ``_crash_point`` seam)
  * ``counter_sync``     — the per-attempt (overflow, used, dead) pool
                           counter readback in the session layer
  * ``segment_scan``     — per-segment dispatch in the fused stream
                           executor (and per-batch in the baseline)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Callable, Dict, List, Optional

from repro.runtime.errors import KernelFailure

SEAMS = ("kernel_launch", "pool_merge", "checkpoint_write",
         "counter_sync", "segment_scan")

_lock = threading.Lock()
_injectors: Dict[str, List["Injector"]] = {}


class Injector:
    """One armed fault: raises ``exc`` at a seam, ``times`` times, after
    skipping the first ``after`` matching crossings.  ``match`` filters
    on the seam's context kwargs (e.g. engine name, commit point)."""

    def __init__(self, seam: str, exc: Optional[BaseException] = None,
                 after: int = 0, times: Optional[int] = 1,
                 match: Optional[Callable[[dict], bool]] = None):
        if seam not in SEAMS:
            raise ValueError(f"unknown fault seam {seam!r}; "
                             f"known: {', '.join(SEAMS)}")
        self.seam = seam
        self.exc = exc
        self.after = after
        self.times = times          # None = every matching crossing
        self.match = match
        self.fired = 0              # observability for tests
        self.seen = 0

    def _consider(self, ctx: dict) -> Optional[BaseException]:
        if self.match is not None and not self.match(ctx):
            return None
        self.seen += 1
        if self.seen <= self.after:
            return None
        if self.times is not None and self.fired >= self.times:
            return None
        self.fired += 1
        if self.exc is not None:
            return self.exc
        return KernelFailure(f"injected fault at seam {self.seam!r}",
                             backend=ctx.get("engine"), seam=self.seam)


def fire(seam: str, **ctx) -> None:
    """Cross a named seam.  No-op (one dict truthiness check) unless a
    test armed an injector for it."""
    if not _injectors:
        return
    injs = _injectors.get(seam)
    if not injs:
        return
    with _lock:
        for inj in list(injs):
            exc = inj._consider(ctx)
            if exc is not None:
                raise exc


@contextlib.contextmanager
def inject(seam: str, exc: Optional[BaseException] = None, *,
           after: int = 0, times: Optional[int] = 1,
           match: Optional[Callable[[dict], bool]] = None):
    """Arm a fault at ``seam`` for the duration of the with-block and
    yield the :class:`Injector` (tests read ``.fired``).  ``exc=None``
    raises a fresh :class:`KernelFailure` per crossing."""
    inj = Injector(seam, exc, after=after, times=times, match=match)
    with _lock:
        _injectors.setdefault(seam, []).append(inj)
    try:
        yield inj
    finally:
        with _lock:
            _injectors[seam].remove(inj)
            if not _injectors[seam]:
                del _injectors[seam]


def reset() -> None:
    """Disarm everything (test teardown safety net)."""
    with _lock:
        _injectors.clear()


def active() -> Dict[str, int]:
    """Armed injector count per seam (diagnostics)."""
    with _lock:
        return {k: len(v) for k, v in _injectors.items()}
