"""Session health: the observability substrate for the fault runtime.

Every admission decision, overflow retry, pool grow, failover hop and
divergence probe increments a counter here; ``session.health`` exposes
the live object and ``as_dict()`` the JSON-able snapshot a serving
layer's SLO logic would scrape.  Counters are plain ints mutated from
the session's own thread — no locking, matching the single-session
threading model everywhere else in ``repro.api``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional


@dataclasses.dataclass
class SessionHealth:
    # admission
    admitted: int = 0          # batches applied (incl. clamped ones)
    clamped: int = 0           # batches sanitized before admission
    quarantined: int = 0       # batches diverted to the dead-letter buffer
    rejected: int = 0          # batches refused under the reject policy
    empty_skipped: int = 0     # zero-lane batches short-circuited host-side
    conflicts: int = 0         # add+del same-edge lanes (counted, not blocked
                               # under clamp: delete-before-add order applies)
    # pool pressure
    overflow_retries: int = 0  # grow-and-replay attempts
    pool_grows: int = 0        # successful capacity doublings
    # degradation
    failovers: int = 0         # backend hops taken
    reprobes: int = 0          # attempts to return to the preferred backend
    kernel_failures: int = 0   # kernel compile/launch failures observed
    # watchdog
    divergence_probes: int = 0
    # identity / last fault
    backend: Optional[str] = None            # currently bound registry name
    preferred_backend: Optional[str] = None  # what bind() originally asked for
    last_error: Optional[str] = None
    last_error_kind: Optional[str] = None
    dead_letter: Any = None    # the session's DeadLetterBuffer (or None)

    def record_error(self, exc: BaseException) -> None:
        self.last_error = str(exc)
        self.last_error_kind = type(exc).__name__

    @property
    def degraded(self) -> bool:
        return (self.backend is not None
                and self.preferred_backend is not None
                and self.backend != self.preferred_backend)

    def as_dict(self) -> Dict[str, Any]:
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self) if f.name != "dead_letter"}
        d["degraded"] = self.degraded
        if self.dead_letter is not None:
            d["dead_letter"] = {
                "held": len(self.dead_letter),
                "total": self.dead_letter.total,
                "evicted": self.dead_letter.evicted,
            }
        else:
            d["dead_letter"] = None
        return d


@dataclasses.dataclass
class PoolHealth:
    """Pool-level counters, one per :class:`repro.serve.SessionPool`.

    Per-tenant fault counters stay in each session's
    :class:`SessionHealth`; this layer tracks what only the pool can
    see — queueing, batching, eviction, and shed/reject pressure.
    Mutated under the pool's lock (the pool's request queue IS
    multi-threaded, unlike single sessions)."""

    tenants: int = 0             # tenants ever bound (live + evicted)
    resident: int = 0            # sessions currently holding device state
    # request flow
    submitted: int = 0           # requests accepted into the queue
    applied: int = 0             # ΔG batches executed (any path)
    rejected: int = 0            # submits refused (reject policy)
    shed: int = 0                # queued requests dropped (shed policy)
    queue_peak: int = 0          # high-water mark of pending requests
    # batching
    mega_calls: int = 0          # batched multi-graph launches
    mega_sessions: int = 0       # sessions served by those launches
    sequential_fallbacks: int = 0  # armed/singleton/overflow per-session runs
    # eviction
    evictions: int = 0           # sessions spilled via Session.save
    restores: int = 0            # lazy restore_session revivals

    def as_dict(self) -> Dict[str, Any]:
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)}
