"""Divergence watchdog: cheap on-device NaN/Inf probe over property state.

A poisoned float property (one NaN relaxation) silently infects every
subsequent batch; by the time a caller reads results, the provenance is
gone.  The watchdog reduces each *inexact-dtype* property array to a
single any-non-finite device scalar (integer lanes — dist, parent,
Modified masks — are skipped: they cannot hold NaN) and syncs one bool
per probed array.  Sessions call it after each ``run_stream`` and on
demand via ``session.check_divergence()``; a hit raises
:class:`DivergenceError` naming the offending properties.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Tuple

import jax.numpy as jnp

from repro.runtime.errors import DivergenceError


def _is_inexact(arr) -> bool:
    try:
        return bool(jnp.issubdtype(arr.dtype, jnp.inexact))
    except (AttributeError, TypeError):
        return False


def probe(named_arrays: Iterable[Tuple[str, object]]) -> List[str]:
    """Return the names of arrays containing NaN/Inf.  One jitted
    reduction per inexact array, one scalar readback each; integer
    arrays are skipped entirely (zero device work)."""
    bad: List[str] = []
    flags: Dict[str, object] = {}
    for name, arr in named_arrays:
        if _is_inexact(arr):
            # stage all reductions before any sync
            flags[name] = jnp.any(~jnp.isfinite(arr))
    for name, flag in flags.items():
        if bool(flag):
            bad.append(name)
    return bad


def check(named_arrays: Iterable[Tuple[str, object]], *,
          where: str = "stream segment", health=None) -> None:
    """Probe and raise :class:`DivergenceError` on a hit."""
    if health is not None:
        health.divergence_probes += 1
    bad = probe(named_arrays)
    if bad:
        err = DivergenceError(
            f"non-finite values in propert{'y' if len(bad) == 1 else 'ies'} "
            f"{', '.join(bad)} after {where}", props=bad)
        if health is not None:
            health.record_error(err)
        raise err
