"""Performance-tuning knobs (EXPERIMENTS.md §Perf).

Each knob selects between the paper-faithful/baseline lowering and a
beyond-baseline optimized one, so both stay runnable and the roofline
deltas stay reproducible:

  cache_shard:
    'seq' (baseline) — decode KV caches sharded on the sequence dim.
        dynamic-update-slice at a *dynamic* position along the sharded
        dim forces SPMD's involuntary full rematerialization: every
        decode step all-gathers and repartitions the whole cache.
    'dh'  (optimized) — shard the head_dim instead (divides the TP axis
        for every assigned arch, unlike kv-heads).  The per-token DUS is
        then along unsharded S (local), and the attention contraction
        over dh turns into a small per-layer psum of (B,kv,rep,S)
        logits — trading TBs of HBM+DCN churn for MBs of ICI.

  moe_dispatch:
    'scatter' (baseline) — pack tokens into the (E,C,D) expert buffer
        with `.at[slot].set(xt[tok])`.  A scatter of D-wide rows into an
        expert-sharded buffer lowers to an all-reduce over the FULL
        buffer per MoE layer (≈E·C·D bytes — dominates the collective
        roofline term for the MoE archs).
    'gather' — scatter only int32 token *indices* into the slot map
        (E·C·4 bytes), then build the buffer with a row gather
        xt[tok_for_slot].  Halves the wire cost but the backward pass
        still scatters D-wide rows, and the expert compute is replicated
        across the data axis.
    'shard_map' (optimized) — explicit expert parallelism: each (data,
        model) shard routes its LOCAL tokens to its LOCAL experts and
        the per-token outputs are psum-combined over 'model'.  Per-layer
        wire cost drops from the global buffer (≈86 GB for qwen3) to the
        local activations (≈0.5 GB), and the 16× data-axis compute
        redundancy disappears.  This is the paper's own principle — each
        owner processes only its partition, then combines — applied at
        LM scale.  Falls back to 'gather' when no mesh is present.

The active Tuning is a contextvar bound at trace time by Model's step
functions, so the knobs thread through jit without signature churn.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses


@dataclasses.dataclass(frozen=True)
class Tuning:
    cache_shard: str = "dh"         # 'seq' | 'dh'
    moe_dispatch: str = "shard_map"  # 'scatter' | 'gather' | 'shard_map'
    # decode_unroll: scan-over-units keeps the HLO small but makes every
    # step dynamic-slice + dynamic-update-slice the whole stacked cache
    # carry; unrolling (standard for serving) turns those into static
    # slices that alias away.  Train/prefill keep the scan.
    decode_unroll: bool = True
    # window_slice: windowed decode attention reads only the window-sized
    # cache slice (dynamic-slice along unsharded S) instead of computing
    # full-length logits and masking — 128× less cache traffic at 512k.
    window_slice: bool = True
    # attn_seq_parallel: for archs whose head count doesn't divide the TP
    # axis (attn_shard='dh'), head_dim-sharded flash attention contracts
    # the sharded dim → a psum of the full (B,H,S,Kb) logits per KV block
    # (TBs/step at 32k).  Shard the QUERY SEQUENCE over 'model' instead
    # (context parallelism): logits stay local; only K/V replicate
    # (MBs/layer).  Applies to train/prefill self-attention when
    # S % tp == 0; falls back to the dh layout otherwise.
    attn_seq_parallel: bool = True


BASELINE = Tuning(cache_shard="seq", moe_dispatch="scatter",
                  decode_unroll=False, window_slice=False,
                  attn_seq_parallel=False)
OPTIMIZED = Tuning()

_current: contextvars.ContextVar[Tuning] = contextvars.ContextVar(
    "repro_tuning", default=OPTIMIZED)


def get_tuning() -> Tuning:
    return _current.get()


@contextlib.contextmanager
def use_tuning(t: Tuning):
    tok = _current.set(t)
    try:
        yield
    finally:
        _current.reset(tok)
