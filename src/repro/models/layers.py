"""Transformer layer zoo: norms, RoPE, GQA attention (chunked-flash for
train/prefill, cache attention for decode), SwiGLU MLP, capacity-based MoE.

Conventions
-----------
* activations: (B, S, D); attention heads (B, S, H, dh)
* every sublayer is pre-norm residual
* ``shard`` is a callable(x, kind) applying with_sharding_constraint per
  the arch's attention sharding strategy ('head' vs 'dh', DESIGN.md §5);
  it is a no-op outside jit-with-mesh contexts.
* chunked flash attention: lax.scan over KV blocks with running
  (max, denom, acc) — O(S·Kb) memory instead of O(S²), which is what
  makes prefill_32k lowerable; the Pallas kernel in
  repro/kernels/flash_attention.py is the TPU-tiled version of the same
  schedule.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.archs import ArchConfig

F32 = jnp.float32
KV_BLOCK = 1024
NEG = -1e30


def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(F32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * (1.0 + scale)


def rope(x, positions, theta):
    """x: (..., S, H, dh); positions: (..., S)"""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(half, dtype=F32) / half)
    ang = positions[..., :, None].astype(F32) * freqs          # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[..., :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def softcap(logits, cap: Optional[float]):
    if cap is None:
        return logits
    return jnp.tanh(logits / cap) * cap


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def qkv_project(p, x, cfg: ArchConfig, shard):
    B, S, D = x.shape
    H, kv, dh = cfg.n_heads, cfg.n_kv, cfg.dh
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, H, dh)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(B, S, kv, dh)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(B, S, kv, dh)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(H, dh)
        k = k + p["bk"].reshape(kv, dh)
        v = v + p["bv"].reshape(kv, dh)
    return shard(q, "qkv"), shard(k, "qkv"), shard(v, "qkv")


def _flash_body(q, k, v, mask, cap):
    """One KV block: q (B,S,H,dh), k/v (B,Kb,H,dh), mask (S,Kb) or None."""
    logits = jnp.einsum("bshd,bthd->bhst", q, k).astype(F32)
    logits = softcap(logits, cap)
    if mask is not None:
        logits = jnp.where(mask[None, None], logits, NEG)
    m = jnp.max(logits, axis=-1)                              # (B,H,S)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhst,bthd->bshd", p.astype(v.dtype), v)
    return m, l, acc


def flash_attention(q, k, v, cfg: ArchConfig, positions, causal: bool,
                    window: Optional[int], scale: float, shard=None):
    """Chunked-softmax attention; q,k,v: (B,S,H,dh) (kv already repeated).

    ``shard`` constrains the scan carries — without it the while loop
    pins them replicated and SPMD all-gathers the sharded logits every
    KV block (measured: 344 GB/layer at 32k for the 'dh' archs).
    """
    B, S, H, dh = q.shape
    q = q * scale
    Kb = min(KV_BLOCK, S)
    if S % Kb:               # non-power-of-two source lengths (e.g. 1500
        Kb = S               # whisper frames): single block
    nblk = S // Kb
    k = k.reshape(B, nblk, Kb, H, dh).swapaxes(0, 1)
    v = v.reshape(B, nblk, Kb, H, dh).swapaxes(0, 1)
    qpos = positions                                            # (S,)

    def step(carry, xs):
        m0, l0, acc0 = carry
        kb, vb, blk = xs
        kpos = blk * Kb + jnp.arange(Kb)
        mask = None
        if causal:
            mask = qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= qpos[:, None] - kpos[None, :] < window
        m1, l1, a1 = _flash_body(q, kb, vb, mask, cfg.attn_softcap)
        m = jnp.maximum(m0, m1)
        c0 = jnp.exp(m0 - m)
        c1 = jnp.exp(m1 - m)
        l = l0 * c0 + l1 * c1
        acc = acc0 * c0.transpose(0, 2, 1)[..., None].astype(acc0.dtype) \
            + a1 * c1.transpose(0, 2, 1)[..., None].astype(a1.dtype)
        return (m, l, acc), None

    m0 = jnp.full((B, H, S), NEG, F32)
    l0 = jnp.zeros((B, H, S), F32)
    acc0 = jnp.zeros((B, S, H, dh), q.dtype)
    if shard is not None:
        m0 = shard(m0, "flash_ml")
        l0 = shard(l0, "flash_ml")
        acc0 = shard(acc0, "flash_acc")
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (k, v, jnp.arange(nblk)))
    denom = jnp.maximum(l, 1e-20).transpose(0, 2, 1)[..., None]
    return (acc / denom.astype(acc.dtype)).astype(q.dtype)


def repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    B, S, kv, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None], (B, S, kv, n_rep, dh)) \
        .reshape(B, S, kv * n_rep, dh)


def attention_train(p, x, cfg: ArchConfig, positions, shard,
                    causal=True, window=None):
    B, S, D = x.shape
    H, kv, dh = cfg.n_heads, cfg.n_kv, cfg.dh
    q, k, v = qkv_project(p, x, cfg, shard)
    if causal:  # encoders (bidir) skip rope to mimic abs-pos (stub choice)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    k = repeat_kv(k, H // kv)
    v = repeat_kv(v, H // kv)
    o = flash_attention(q, k, v, cfg, positions, causal, window,
                        scale=dh ** -0.5)
    o = shard(o, "qkv")
    return jnp.einsum("bsh,hd->bsd", o.reshape(B, S, H * dh), p["wo"])


def attention_decode(p, x, cfg: ArchConfig, cache_k, cache_v, pos, shard,
                     window=None):
    """x: (B,1,D); cache_k/v: (B,kv,S,dh); pos: scalar write index."""
    B, _, D = x.shape
    H, kv, dh = cfg.n_heads, cfg.n_kv, cfg.dh
    S = cache_k.shape[2]
    q, k, v = qkv_project(p, x, cfg, shard)
    posv = jnp.full((1,), pos)
    q = rope(q, posv, cfg.rope_theta)
    k = rope(k, posv, cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype).transpose(0, 2, 1, 3),
        (0, 0, pos, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype).transpose(0, 2, 1, 3),
        (0, 0, pos, 0))
    q = shard(q, "q_decode")
    out = cache_attend(q, cache_k, cache_v, cfg, pos, window)
    return jnp.einsum("bsh,hd->bsd", out.reshape(B, 1, H * dh), p["wo"]), \
        cache_k, cache_v


def cache_attend(q, cache_k, cache_v, cfg: ArchConfig, pos, window=None,
                 mask_to_pos=True):
    """q: (B,1,H,dh); cache: (B,kv,S,dh) -> (B,1,H,dh)."""
    from repro.models.tuning import get_tuning
    B, _, H, dh = q.shape
    kv = cache_k.shape[1]
    rep = H // kv
    qh = q.reshape(B, kv, rep, dh) * (dh ** -0.5)
    S = cache_k.shape[2]
    base = jnp.zeros((), jnp.int32)
    if window is not None and get_tuning().window_slice and S > window:
        # read only the window-sized slice of the cache (S is unsharded
        # under the 'dh' cache layout, so this is a local slice)
        base = jnp.clip(pos - window + 1, 0, S - window).astype(jnp.int32)
        cache_k = jax.lax.dynamic_slice_in_dim(cache_k, base, window,
                                               axis=2)
        cache_v = jax.lax.dynamic_slice_in_dim(cache_v, base, window,
                                               axis=2)
        S = window
    logits = jnp.einsum("bkrd,bksd->bkrs", qh, cache_k).astype(F32)
    logits = softcap(logits, cfg.attn_softcap)
    jpos = base + jnp.arange(S)
    if mask_to_pos:
        ok = jpos <= pos
        if window is not None:
            ok &= jpos > pos - window
        logits = jnp.where(ok[None, None, None], logits, NEG)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkrs,bksd->bkrd", p.astype(cache_v.dtype), cache_v)
    return out.reshape(B, 1, H, dh)


def cross_attention(p, x, src_k, src_v, cfg: ArchConfig, shard):
    """x: (B,S,D) attending to precomputed source k/v (B,kv,T,dh)."""
    B, S, D = x.shape
    H, kv, dh = cfg.n_heads, cfg.n_kv, cfg.dh
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, H, dh)
    q = shard(q, "qkv")
    rep = H // kv
    qh = q.reshape(B, S, kv, rep, dh) * (dh ** -0.5)
    logits = jnp.einsum("bskrd,bktd->bskrt", qh, src_k).astype(F32)
    pr = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bskrt,bktd->bskrd", pr.astype(src_v.dtype), src_v)
    out = out.reshape(B, S, H * dh)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"])


def xattn_kv(p, src, cfg: ArchConfig, shard):
    """Precompute cross-attention K/V from source embeddings (B,T,D)."""
    B, T, D = src.shape
    kv, dh = cfg.n_kv, cfg.dh
    k = jnp.einsum("btd,dh->bth", src, p["wk"]).reshape(B, T, kv, dh)
    v = jnp.einsum("btd,dh->bth", src, p["wv"]).reshape(B, T, kv, dh)
    return shard(k.transpose(0, 2, 1, 3), "cache"), \
        shard(v.transpose(0, 2, 1, 3), "cache")


# ---------------------------------------------------------------------------
# FFNs
# ---------------------------------------------------------------------------

def mlp(p, x, shard):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = shard(h, "ffn")
    return h @ p["w_down"]


def moe(p, x, cfg: ArchConfig, shard):
    """Capacity-based top-k MoE with expert-parallel einsums.

    Tokens are sorted by expert, packed into a static (E, C, D) buffer
    (overflow drops — standard capacity-factor semantics), pushed through
    expert-sharded einsums, and combined back weighted by router scores.

    Dispatch has two lowerings (repro.models.tuning):
      'scatter' — rows scattered into the expert-sharded buffer (SPMD
        lowers this to an all-reduce of the FULL E·C·D buffer per layer);
      'gather'  — scatter int32 indices only, then row-gather (the wire
        cost drops to the token activations).  Default.
    """
    from repro.models.tuning import get_tuning
    mc = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = mc.n_experts, mc.top_k
    C = max(int(T * K / E * mc.capacity_factor), 8)
    dispatch = get_tuning().moe_dispatch
    if dispatch == "shard_map":
        policy = getattr(shard, "__self__", None)
        if policy is not None and getattr(policy, "mesh", None) is not None:
            return _moe_shard_map(p, x, cfg, policy)
        dispatch = "gather"            # no mesh → single-device fallback
    xt = x.reshape(T, D)
    logits = (xt @ p["router"]).astype(F32)                    # (T, E)
    scores = jax.nn.softmax(logits, axis=-1)
    top_s, top_e = jax.lax.top_k(scores, K)                    # (T, K)
    top_s = top_s / jnp.sum(top_s, axis=-1, keepdims=True)

    eid = top_e.reshape(T * K)
    tok = jnp.repeat(jnp.arange(T), K)
    gate = top_s.reshape(T * K)
    order = jnp.argsort(eid)
    eid_s, tok_s, gate_s = eid[order], tok[order], gate[order]
    # rank within expert group (ELL trick: index − group start)
    start = jnp.searchsorted(eid_s, eid_s, side="left")
    rank = jnp.arange(T * K) - start
    keep = rank < C
    slot = jnp.where(keep, eid_s * C + rank, E * C)

    if dispatch == "gather":
        # indices-only scatter (E·C·4 bytes on the wire) + row gather
        tok_for_slot = jnp.full((E * C + 1,), T, jnp.int32) \
            .at[slot].set(tok_s.astype(jnp.int32), mode="drop")[:E * C]
        valid = tok_for_slot < T
        xe = jnp.where(valid[:, None],
                       xt[jnp.minimum(tok_for_slot, T - 1)],
                       jnp.zeros((), x.dtype)).reshape(E, C, D)
    else:
        xe = jnp.zeros((E * C, D), x.dtype).at[slot].set(
            xt[tok_s], mode="drop").reshape(E, C, D)
    xe = shard(xe, "moe")
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    ye = shard(ye, "moe").reshape(E * C, D)

    contrib = ye[jnp.minimum(slot, E * C - 1)] * \
        (gate_s * keep)[:, None].astype(ye.dtype)
    y = jax.ops.segment_sum(contrib, tok_s, num_segments=T)
    return y.reshape(B, S, D)


def _moe_route_local(xt, router, E, K, cap, E0, E_loc, C_loc):
    """Route local tokens to LOCAL experts [E0, E0+E_loc); returns the
    packed buffer index map + combine metadata.  Pure jnp — runs inside
    the shard_map body (no communication)."""
    T_loc = xt.shape[0]
    logits = (xt @ router).astype(F32)
    scores = jax.nn.softmax(logits, axis=-1)
    top_s, top_e = jax.lax.top_k(scores, K)
    top_s = top_s / jnp.sum(top_s, axis=-1, keepdims=True)
    eid = top_e.reshape(T_loc * K)
    tok = jnp.repeat(jnp.arange(T_loc), K)
    gate = top_s.reshape(T_loc * K)
    mine = (eid >= E0) & (eid < E0 + E_loc)
    eid_l = jnp.where(mine, eid - E0, E_loc)
    order = jnp.argsort(eid_l)
    eid_s, tok_s, gate_s = eid_l[order], tok[order], gate[order]
    start = jnp.searchsorted(eid_s, eid_s, side="left")
    rank = jnp.arange(T_loc * K) - start
    keep = (rank < C_loc) & (eid_s < E_loc)
    slot = jnp.where(keep, eid_s * C_loc + rank, E_loc * C_loc)
    tok_for_slot = jnp.full((E_loc * C_loc + 1,), T_loc, jnp.int32) \
        .at[slot].set(tok_s.astype(jnp.int32), mode="drop")[:E_loc * C_loc]
    return tok_for_slot, slot, tok_s, gate_s, keep


def _moe_shard_map(p, x, cfg: ArchConfig, policy):
    """Expert-parallel MoE: local routing per (data, model) shard, local
    expert FFN on the model shard's experts, psum combine over 'model'.

    Wire cost per layer = the per-token partial outputs (T_loc·D) instead
    of the global (E,C,D) buffer, and the expert flops are computed once
    (the jit lowering replicates them across the data axis).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mc = cfg.moe
    mesh = policy.mesh
    B, S, D = x.shape
    T = B * S
    E, K = mc.n_experts, mc.top_k
    dp = policy.dp_axes
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    tp_size = mesh.shape[policy.tp] if policy.tp in mesh.axis_names else 0
    if not tp_size or E % tp_size or T % dp_size:
        # indivisible / no model axis — fall back to the jit lowering
        from repro.models.tuning import Tuning, use_tuning
        with use_tuning(Tuning(moe_dispatch="gather")):
            return moe(p, x, cfg, policy.shard)
    E_loc = E // tp_size
    T_loc = T // dp_size
    C_loc = max(int(T_loc * K / E * mc.capacity_factor), 8)

    def body(xt, router, wg, wu, wd):
        # xt: (T_loc, D) — this data shard's tokens (replicated over tp)
        m = jax.lax.axis_index(policy.tp)
        E0 = m * E_loc
        tok_for_slot, slot, tok_s, gate_s, keep = _moe_route_local(
            xt, router.astype(xt.dtype), E, K, mc.capacity_factor,
            E0, E_loc, C_loc)
        valid = tok_for_slot < T_loc
        xe = jnp.where(valid[:, None],
                       xt[jnp.minimum(tok_for_slot, T_loc - 1)],
                       jnp.zeros((), xt.dtype)).reshape(E_loc, C_loc, D)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) \
            * jnp.einsum("ecd,edf->ecf", xe, wu)
        ye = jnp.einsum("ecf,efd->ecd", h, wd).reshape(E_loc * C_loc, D)
        contrib = ye[jnp.minimum(slot, E_loc * C_loc - 1)] * \
            (gate_s * keep)[:, None].astype(ye.dtype)
        y = jax.ops.segment_sum(contrib, tok_s, num_segments=T_loc)
        return jax.lax.psum(y, policy.tp)            # combine over experts

    tp = policy.tp
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp or None, None), P(None, None),
                  P(tp, None, None), P(tp, None, None), P(tp, None, None)),
        out_specs=P(dp or None, None), check_rep=False)
    xt = x.reshape(T, D)
    y = fn(xt, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y.reshape(B, S, D)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _init(key, shape, scale=None, dtype=jnp.bfloat16):
    scale = scale if scale is not None else (1.0 / max(shape[0], 1)) ** 0.5
    return (jax.random.normal(key, shape, F32) * scale).astype(dtype)


def init_attn(key, cfg: ArchConfig, dtype, cross=False):
    H, kv, dh, D = cfg.n_heads, cfg.n_kv, cfg.dh, cfg.d_model
    ks = jax.random.split(key, 8)
    p = {
        "ln": jnp.zeros((D,), dtype),
        "wq": _init(ks[0], (D, H * dh), dtype=dtype),
        "wk": _init(ks[1], (D, kv * dh), dtype=dtype),
        "wv": _init(ks[2], (D, kv * dh), dtype=dtype),
        "wo": _init(ks[3], (H * dh, D), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * dh,), dtype)
        p["bk"] = jnp.zeros((kv * dh,), dtype)
        p["bv"] = jnp.zeros((kv * dh,), dtype)
    if cfg.attn_softcap is not None:
        p["post_ln"] = jnp.zeros((D,), dtype)
    return p


def init_mlp(key, cfg: ArchConfig, dtype):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "ln": jnp.zeros((D,), dtype),
        "w_gate": _init(ks[0], (D, F), dtype=dtype),
        "w_up": _init(ks[1], (D, F), dtype=dtype),
        "w_down": _init(ks[2], (F, D), dtype=dtype),
    }


def init_moe(key, cfg: ArchConfig, dtype):
    D, E, F = cfg.d_model, cfg.moe.n_experts, cfg.moe.d_ff
    ks = jax.random.split(key, 4)
    return {
        "ln": jnp.zeros((D,), dtype),
        "router": _init(ks[0], (D, E), dtype=jnp.float32),
        "w_gate": _init(ks[1], (E, D, F), dtype=dtype),
        "w_up": _init(ks[2], (E, D, F), dtype=dtype),
        "w_down": _init(ks[3], (E, F, D), dtype=dtype),
    }
