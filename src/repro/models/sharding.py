"""Sharding policy: DP/TP/EP/FSDP rules for every parameter and activation.

One place owns all PartitionSpecs (DESIGN.md §5):

  * TP ('model'): attention heads (or head_dim when H % tp != 0 — the
    'dh' strategy), FFN hidden, MoE experts, vocab;
  * DP ('data' ×'pod'): batch;
  * FSDP ('data'): the non-TP dim of every ≥2-D weight (ZeRO-3 via
    in_shardings — XLA all-gathers per use, reduce-scatters grads);
  * decode caches: sequence dim over 'model' (uniform across kv-head
    counts, incl. MQA), batch over DP when divisible.

Every spec is divisibility-guarded: a dim that doesn't divide by its
axis size falls back to replicated rather than failing to lower.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.archs import ArchConfig


@dataclasses.dataclass(frozen=True)
class Policy:
    cfg: ArchConfig
    mesh: Optional[Mesh]
    tp: str = "model"

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        if self.mesh is None:
            return ()
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

    @property
    def fsdp(self) -> Optional[str]:
        if self.mesh is None or "data" not in self.mesh.axis_names:
            return None
        return "data"

    def _axsize(self, axes) -> int:
        if self.mesh is None:
            return 1
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    def _seqpar(self, seq_dim: int) -> bool:
        from repro.models.tuning import get_tuning
        return (get_tuning().attn_seq_parallel
                and self.cfg.attn_shard == "dh"
                and seq_dim % self._axsize(self.tp) == 0)

    def guard(self, spec: P, shape) -> P:
        """Drop axis assignments whose dim isn't divisible."""
        entries = list(spec) + [None] * (len(shape) - len(spec))
        out = []
        for dim, ax in zip(shape, entries):
            if ax is None or dim % self._axsize(ax) == 0:
                out.append(ax)
            else:
                out.append(None)
        return P(*out)

    def constraint(self, x, spec: P):
        if self.mesh is None:
            return x
        spec = self.guard(spec, x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    # -- activations ---------------------------------------------------------
    def shard(self, x, kind: str):
        if self.mesh is None:
            return x
        dp = self.dp_axes or None
        tp = self.tp
        c = self.cfg
        if kind == "act":            # (B,S,D)
            return self.constraint(x, P(dp, None, None))
        if kind == "qkv":            # (B,S,H,dh)
            if c.attn_shard == "head":
                return self.constraint(x, P(dp, None, tp, None))
            return self.constraint(x, P(dp, None, None, tp))
        if kind == "ffn":            # (B,S,F)
            return self.constraint(x, P(dp, None, tp))
        if kind == "moe":            # (E,C,D)
            return self.constraint(x, P(tp, None, None))
        if kind == "cache":          # (B,kv,S,dh)
            from repro.models.tuning import get_tuning
            if get_tuning().cache_shard == "dh":
                return self.constraint(x, P(dp, None, None, tp))
            return self.constraint(x, P(dp, None, tp, None))
        if kind == "q_decode":       # (B,1,H,dh): align with the cache so
            from repro.models.tuning import get_tuning    # the contraction
            if get_tuning().cache_shard == "dh":          # needs no permute
                return self.constraint(x, P(dp, None, None, tp))
            return self.shard(x, "qkv")
        if kind == "q_seq":          # (B,S,H,dh): context parallelism —
            # query sequence over tp so logits never cross shards
            if x.shape[1] % self._axsize(tp) == 0:
                return self.constraint(x, P(dp, tp, None, None))
            return self.shard(x, "qkv")
        if kind == "kv_full":        # (B,S,H,dh): replicate K/V over tp
            if x.shape[1] % self._axsize(tp) == 0:
                return self.constraint(x, P(dp, None, None, None))
            return self.shard(x, "qkv")
        if kind == "flash_ml":       # (B,H,S) flash scan carries: must be
            # constrained or the while-loop fixes them replicated and
            # all-gathers the sharded logits every KV block
            if self._seqpar(x.shape[2]):
                return self.constraint(x, P(dp, None, tp))
            if c.attn_shard == "head":
                return self.constraint(x, P(dp, tp, None))
            return self.constraint(x, P(dp, None, None))
        if kind == "flash_acc":      # (B,S,H,dh) flash accumulator
            if self._seqpar(x.shape[1]):
                return self.constraint(x, P(dp, tp, None, None))
            return self.shard(x, "qkv")
        if kind == "vocab":          # (B,S,V)
            return self.constraint(x, P(dp, None, tp))
        return x

    # -- parameters ------------------------------------------------------------
    def param_spec(self, path: str, shape) -> P:
        tp, fs = self.tp, self.fsdp
        stacked = path.startswith("units") or path.startswith("enc_units")
        pre = (None,) if stacked else ()
        name = path.split("/")[-1]
        nd = len(shape) - len(pre)

        def mk(*entries):
            return self.guard(P(*pre, *entries), shape)

        if name == "embed":
            return mk(tp, fs)
        if name == "lm_head":
            return mk(fs, tp)
        if name in ("wq", "wk", "wv", "w_gate", "w_up", "in_proj", "wi",
                    "wf", "wo_gate", "w", "r"):
            if nd == 3:  # MoE expert stacks (E, D, F)
                return mk(tp, fs, None)
            return mk(fs, tp)
        if name in ("wo", "out_proj", "w_down"):
            if nd == 3:  # (E, F, D)
                return mk(tp, None, fs)
            return mk(tp, fs)
        if name == "router":
            return mk(None, None)
        if name in ("x_bc", "x_dt", "A_log"):
            return mk(tp, None)
        if name == "conv_w":
            return mk(None, tp)
        if name in ("bq", "bk", "bv", "conv_b", "dt_bias", "skip_d", "b"):
            return mk(tp)
        # norms and anything else: replicated (modulo stack axis)
        return mk()

    def param_specs(self, params) -> Any:
        def walk(tree, prefix):
            if isinstance(tree, dict):
                return {k: walk(v, f"{prefix}/{k}" if prefix else k)
                        for k, v in tree.items()}
            if isinstance(tree, (tuple, list)):
                t = type(tree)
                return t(walk(v, prefix) for v in tree)
            return self.param_spec(prefix, tree.shape)
        return walk(params, "")

    # -- opt state -------------------------------------------------------------
    def opt_state_specs(self, opt_name: str, params, pspecs) -> Any:
        if opt_name == "adamw":
            return {"m": pspecs, "v": pspecs}
        # adafactor: r drops last dim, c drops second-to-last.
        from repro.optim.opt import adafactor  # for factored() parity
        def st(p, spec):
            entries = list(spec) + [None] * (p.ndim - len(spec))
            if p.ndim >= 2 and p.shape[-1] >= 128 and p.shape[-2] >= 128:
                return {"r": P(*entries[:-1]),
                        "c": P(*(entries[:-2] + entries[-1:]))}
            return {"v": P(*entries)}
        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_s = tdef.flatten_up_to(pspecs)
        return tdef.unflatten([st(p, s) for p, s in zip(flat_p, flat_s)])

    # -- batch / cache ---------------------------------------------------------
    def batch_specs(self):
        dp = self.dp_axes or None
        return P(dp, None)

    def cache_spec(self, path: str, shape) -> P:
        dp = self.dp_axes or None
        tp = self.tp
        name = path.split("/")[-1]
        pre = (None,)  # stacked repeat axis
        if name in ("k", "v"):       # (R,B,kv,S,dh)
            from repro.models.tuning import get_tuning
            if get_tuning().cache_shard == "dh":
                # head_dim-sharded: the per-token dynamic-update-slice is
                # along unsharded S → no SPMD full-remat (tuning.py)
                return self.guard(P(*pre, dp, None, None, tp), shape)
            return self.guard(P(*pre, dp, None, tp, None), shape)
        if name in ("h", "conv"):    # mamba (R,B,E,N)/(R,B,dc-1,E) or slstm
            if len(shape) == 4 and name == "h":
                return self.guard(P(*pre, dp, tp, None), shape)
            if len(shape) == 4:
                return self.guard(P(*pre, dp, None, tp), shape)
            return self.guard(P(*pre, dp, tp), shape)
        if name in ("C",):           # mlstm (R,B,H,dh,dh)
            return self.guard(P(*pre, dp, None, tp, None), shape)
        if name in ("n",):
            return self.guard(P(*pre, dp, None, tp), shape)
        if name == "c":
            return self.guard(P(*pre, dp, tp), shape)
        return self.guard(P(*pre, dp), shape)

    def cache_specs(self, cache) -> Any:
        def walk(tree, prefix):
            if isinstance(tree, dict):
                return {k: walk(v, f"{prefix}/{k}" if prefix else k)
                        for k, v in tree.items()}
            if isinstance(tree, (tuple, list)):
                t = type(tree)
                return t(walk(v, prefix) for v in tree)
            return self.cache_spec(prefix, tree.shape)
        return walk(cache, "")

    def named(self, spec: P) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, spec)
