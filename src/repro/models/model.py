"""Model facade: builds (init, train_step, prefill_step, serve_step) for an
arch config + mesh, with every input/output sharding specified.

This is the single entry point used by the launcher, the dry-run, the
benchmarks and the smoke tests.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.archs import ArchConfig, ShapeCfg
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.sharding import Policy
from repro.models.tuning import Tuning, OPTIMIZED, use_tuning
from repro.optim.opt import make_optimizer

F32 = jnp.float32
CE_CHUNK = 512


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    mesh: Optional[Mesh] = None
    dtype: Any = jnp.bfloat16
    lr: Optional[float] = None
    tuning: Tuning = OPTIMIZED

    @functools.cached_property
    def policy(self) -> Policy:
        return Policy(cfg=self.cfg, mesh=self.mesh)

    @functools.cached_property
    def optimizer(self):
        return make_optimizer(self.cfg.optimizer, self.lr)

    # ------------------------------------------------------------------ init
    def init(self, key):
        return T.init_params(key, self.cfg, self.dtype)

    def init_opt(self, params):
        return self.optimizer.init(params)

    # ------------------------------------------------------------ embeddings
    def _embed(self, params, tokens):
        x = params["embed"][tokens]
        return self.policy.shard(x.astype(self.dtype), "act")

    def _head(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    def _logits(self, params, h):
        logits = (h @ self._head(params)).astype(F32)
        logits = L.softcap(logits, self.cfg.final_softcap)
        return self.policy.shard(logits, "vocab")

    # ------------------------------------------------------------------ loss
    def _ce_loss(self, params, h, labels):
        """Chunked cross-entropy over the sequence (never materializes the
        full (B,S,V) logits — the 202k-vocab archs would need TBs)."""
        B, S, D = h.shape
        n_chunk = max(S // CE_CHUNK, 1)
        csz = S // n_chunk
        hc = h.reshape(B, n_chunk, csz, D).swapaxes(0, 1)
        lc = labels.reshape(B, n_chunk, csz).swapaxes(0, 1)

        def step(tot, xs):
            hh, ll = xs
            logits = self._logits(params, hh)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, ll[..., None],
                                       axis=-1)[..., 0]
            return tot + jnp.sum(lse - gold), None

        tot, _ = jax.lax.scan(step, jnp.zeros((), F32), (hc, lc))
        return tot / (B * S)

    def _aux(self, batch):
        if self.cfg.family in ("vlm", "audio") and "src" in batch:
            return {"src": batch["src"].astype(self.dtype)}
        return {}

    # ------------------------------------------------------------ train step
    def train_step(self, params, opt_state, step, batch):
        with use_tuning(self.tuning):
            return self._train_step(params, opt_state, step, batch)

    def _train_step(self, params, opt_state, step, batch):
        cfg = self.cfg

        def loss_fn(p):
            x = self._embed(p, batch["tokens"])
            aux = self._aux(batch)
            if cfg.enc_layers:
                aux = {"src": T.encoder_pass(cfg, p, aux["src"],
                                             self.policy.shard)}
            h, _ = T.backbone_full(cfg, p, x, self.policy.shard, aux,
                                   collect_cache=False, use_remat=True)
            return self._ce_loss(p, h, batch["labels"])

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, gnorm = self.optimizer.update(
            grads, params, opt_state, step)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    # ---------------------------------------------------------- prefill step
    def prefill_step(self, params, batch):
        with use_tuning(self.tuning):
            return self._prefill_step(params, batch)

    def _prefill_step(self, params, batch):
        cfg = self.cfg
        x = self._embed(params, batch["tokens"])
        aux = self._aux(batch)
        if cfg.enc_layers:
            aux = {"src": T.encoder_pass(cfg, params, aux["src"],
                                         self.policy.shard)}
        h, caches = T.backbone_full(cfg, params, x, self.policy.shard, aux,
                                    collect_cache=True, use_remat=False)
        logits = self._logits(params, h[:, -1:, :])[:, 0]
        return logits, caches

    # ------------------------------------------------------------ serve step
    def serve_step(self, params, cache, token, pos, src=None,
                   long_mode=False):
        """token: (B,1) int32; pos: scalar int32; cache stacked pytree."""
        with use_tuning(self.tuning):
            return self._serve_step(params, cache, token, pos, src,
                                    long_mode)

    def _serve_step(self, params, cache, token, pos, src, long_mode):
        cfg = self.cfg
        x = self._embed(params, token)
        aux = {}
        if src is not None:
            aux = {"src": src.astype(self.dtype)}
        h, cache = T.backbone_decode(cfg, params, x, cache, pos,
                                     self.policy.shard, aux, long_mode)
        logits = self._logits(params, h)[:, 0]
        return logits, cache

    # ------------------------------------------------------------ spec utils
    def shaped(self, tree_specs, tree_shapes):
        """ShapeDtypeStructs with NamedShardings attached."""
        def mk(sd, spec):
            sh = self.policy.named(spec) if spec is not None else None
            return jax.ShapeDtypeStruct(sd.shape, sd.dtype, sharding=sh)
        return jax.tree_util.tree_map(mk, tree_shapes, tree_specs)

    def abstract_params(self):
        shapes = jax.eval_shape(lambda: T.init_params(
            jax.random.PRNGKey(0), self.cfg, self.dtype))
        specs = self.policy.param_specs(shapes)
        return self.shaped(specs, shapes), specs

    def abstract_opt(self, params_shapes):
        shapes = jax.eval_shape(self.optimizer.init, params_shapes)
        pspecs = self.policy.param_specs(params_shapes)
        specs = self.policy.opt_state_specs(self.cfg.optimizer,
                                            params_shapes, pspecs)
        return self.shaped(specs, shapes)

    def abstract_cache(self, B, S):
        with use_tuning(self.tuning):
            shapes = jax.eval_shape(
                lambda: T.init_cache(self.cfg, B, S, self.dtype))
            specs = self.policy.cache_specs(shapes)
            return self.shaped(specs, shapes)


def input_specs(model: Model, shape: ShapeCfg) -> Dict[str, Any]:
    """All ShapeDtypeStruct stand-ins for one dry-run cell (no allocation)."""
    cfg = model.cfg
    pol = model.policy
    B, S = shape.global_batch, shape.seq_len
    dp = pol.dp_axes or None
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32,
                               sharding=pol.named(pol.guard(P(dp, None),
                                                            (B, S))))
    params, _ = model.abstract_params()
    out: Dict[str, Any] = {"params": params}

    def src_struct(batch, length):
        spec = pol.guard(P(dp, None, None), (batch, length, cfg.d_model))
        return jax.ShapeDtypeStruct((batch, length, cfg.d_model),
                                    jnp.bfloat16, sharding=pol.named(spec))

    if shape.kind == "train":
        batch = {"tokens": tok, "labels": tok}
        if cfg.family == "vlm":
            batch["src"] = src_struct(B, cfg.n_img_tokens)
        if cfg.family == "audio":
            batch["src"] = src_struct(B, cfg.enc_seq)
        out["opt_state"] = model.abstract_opt(
            jax.eval_shape(lambda: T.init_params(
                jax.random.PRNGKey(0), cfg, model.dtype)))
        out["step"] = jax.ShapeDtypeStruct((), jnp.int32)
        out["batch"] = batch
    elif shape.kind == "prefill":
        batch = {"tokens": tok}
        if cfg.family == "vlm":
            batch["src"] = src_struct(B, cfg.n_img_tokens)
        if cfg.family == "audio":
            batch["src"] = src_struct(B, cfg.enc_seq)
        out["batch"] = batch
    else:  # decode
        out["cache"] = model.abstract_cache(B, S)
        tok1 = jax.ShapeDtypeStruct(
            (B, 1), jnp.int32,
            sharding=pol.named(pol.guard(P(dp, None), (B, 1))))
        out["token"] = tok1
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
        if cfg.family == "vlm":
            out["src"] = src_struct(B, cfg.n_img_tokens)
        if cfg.family == "audio":
            out["src"] = src_struct(B, cfg.enc_seq)
    return out
