"""State-space / recurrent mixers: Mamba (Jamba), mLSTM + sLSTM (xLSTM).

All three expose a train form (full sequence, chunked to bound memory)
and a decode form (single step carrying explicit recurrent state) — the
state is the sub-quadratic replacement for a KV cache, which is what
makes jamba/xlstm eligible for the long_500k shape.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.archs import ArchConfig

F32 = jnp.float32
CHUNK = 64  # sequence chunk for the associative scans (memory bound)


# ---------------------------------------------------------------------------
# Mamba (selective SSM, diag A) — Jamba's mixer
# ---------------------------------------------------------------------------

def init_mamba(key, cfg: ArchConfig, dtype):
    D = cfg.d_model
    mc = cfg.mamba
    E = mc.expand * D
    N = mc.d_state
    ks = jax.random.split(key, 7)
    s = lambda sh, k: (jax.random.normal(k, sh, F32) / sh[0] ** 0.5).astype(dtype)
    return {
        "ln": jnp.zeros((D,), dtype),
        "in_proj": s((D, 2 * E), ks[0]),
        "conv_w": s((mc.d_conv, E), ks[1]),
        "conv_b": jnp.zeros((E,), dtype),
        "x_bc": s((E, 2 * N), ks[2]),          # data-dependent B, C
        "x_dt": s((E, 1), ks[3]),              # data-dependent Δ (rank-1)
        "dt_bias": jnp.zeros((E,), dtype),
        "A_log": jnp.zeros((E, N), F32),       # A = -exp(A_log) (stable)
        "skip_d": jnp.ones((E,), dtype),
        "out_proj": s((E, D), ks[4]),
    }


def _mamba_scan_chunk(h0, a, bu):
    """h_t = a_t * h_{t-1} + bu_t over a chunk; a/bu: (B, T, E, N)."""
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, b2 + a2 * b1
    a_c, b_c = jax.lax.associative_scan(combine, (a, bu), axis=1)
    h = a_c * h0[:, None] + b_c
    return h, h[:, -1]


def mamba_train(p, x, cfg: ArchConfig, shard):
    """x: (B,S,D) -> (B,S,D); chunked parallel scan over S."""
    B, S, D = x.shape
    mc = cfg.mamba
    E, N = mc.expand * D, mc.d_state
    xz = x @ p["in_proj"]                                   # (B,S,2E)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard(xin, "ffn")
    # depthwise causal conv (kernel d_conv)
    pad = jnp.pad(xin, ((0, 0), (mc.d_conv - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + S] * p["conv_w"][i] for i in range(mc.d_conv))
    u = jax.nn.silu(conv + p["conv_b"])
    # selective parameters
    bc = jnp.einsum("bse,en->bsn", u, p["x_bc"])            # (B,S,2N)
    Bmat, Cmat = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bse,eo->bse", u, p["x_dt"])
                         + p["dt_bias"])                    # (B,S,E)
    A = -jnp.exp(p["A_log"])                                # (E,N)
    nchunk = max(S // CHUNK, 1)
    csz = S // nchunk
    u_c = u.reshape(B, nchunk, csz, E).swapaxes(0, 1)
    dt_c = dt.reshape(B, nchunk, csz, E).swapaxes(0, 1)
    B_c = Bmat.reshape(B, nchunk, csz, N).swapaxes(0, 1)
    C_c = Cmat.reshape(B, nchunk, csz, N).swapaxes(0, 1)

    def step(h, xs):
        uc, dtc, bc_, cc = xs
        a = jnp.exp(dtc[..., None].astype(F32) * A)         # (B,T,E,N)
        bu = (dtc * uc)[..., None].astype(F32) * bc_[..., None, :]
        hs, h1 = _mamba_scan_chunk(h, a, bu)
        y = jnp.einsum("bten,btn->bte", hs, cc.astype(F32))
        return h1, y.astype(x.dtype)

    h0 = jnp.zeros((B, E, N), F32)
    _, ys = jax.lax.scan(step, h0, (u_c, dt_c, B_c, C_c))
    y = ys.swapaxes(0, 1).reshape(B, S, E)
    y = (y + u * p["skip_d"]) * jax.nn.silu(z)
    return y @ p["out_proj"]


def mamba_init_state(cfg: ArchConfig, B, dtype):
    mc = cfg.mamba
    E, N = mc.expand * cfg.d_model, mc.d_state
    return {"h": jnp.zeros((B, E, N), F32),
            "conv": jnp.zeros((B, mc.d_conv - 1, E), dtype)}


def mamba_decode(p, x, state, cfg: ArchConfig):
    """x: (B,1,D); state: {'h': (B,E,N), 'conv': (B,d_conv-1,E)}."""
    B = x.shape[0]
    mc = cfg.mamba
    xz = x[:, 0] @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    hist = jnp.concatenate([state["conv"], xin[:, None]], axis=1)
    conv = jnp.einsum("bke,ke->be", hist, p["conv_w"])
    u = jax.nn.silu(conv + p["conv_b"])
    bc = jnp.einsum("be,en->bn", u, p["x_bc"])
    Bv, Cv = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(jnp.einsum("be,eo->be", u, p["x_dt"]) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[..., None].astype(F32) * A)
    h = a * state["h"] + (dt * u)[..., None].astype(F32) * Bv[:, None, :]
    y = jnp.einsum("ben,bn->be", h, Cv.astype(F32)).astype(x.dtype)
    y = (y + u * p["skip_d"]) * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None]
    return out, {"h": h, "conv": hist[:, 1:]}


# ---------------------------------------------------------------------------
# mLSTM (matrix-memory; linear-attention chunked train form)
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ArchConfig, dtype):
    D = cfg.d_model
    E = 2 * D
    H = cfg.n_heads
    ks = jax.random.split(key, 6)
    s = lambda sh, k: (jax.random.normal(k, sh, F32) / sh[0] ** 0.5).astype(dtype)
    return {
        "ln": jnp.zeros((D,), dtype),
        "wq": s((D, E), ks[0]),
        "wk": s((D, E), ks[1]),
        "wv": s((D, E), ks[2]),
        "wi": s((D, H), ks[3]),                 # input gate (per head)
        "wf": s((D, H), ks[4]),                 # forget gate
        "wo_gate": s((D, E), ks[5]),
        "out_proj": s((E, D), jax.random.fold_in(key, 9)),
    }


def _mlstm_heads(cfg: ArchConfig):
    E = 2 * cfg.d_model
    H = cfg.n_heads
    return H, E // H


def mlstm_train(p, x, cfg: ArchConfig, shard):
    """Chunkwise linear attention with per-head scalar decay gates."""
    B, S, D = x.shape
    H, dh = _mlstm_heads(cfg)
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    k = (x @ p["wk"]).reshape(B, S, H, dh) * dh ** -0.5
    v = (x @ p["wv"]).reshape(B, S, H, dh)
    q, k, v = (shard(t, "qkv") for t in (q, k, v))
    i_g = jax.nn.sigmoid((x @ p["wi"]).astype(F32))           # (B,S,H)
    f_g = jax.nn.sigmoid((x @ p["wf"]).astype(F32))
    nchunk = max(S // CHUNK, 1)
    csz = S // nchunk
    rs = lambda t: t.reshape(B, nchunk, csz, *t.shape[2:]).swapaxes(0, 1)
    qc, kc, vc, ic, fc = map(rs, (q, k, v, i_g, f_g))

    def step(carry, xs):
        Cmem, nmem = carry                                    # (B,H,dh,dh)
        qq, kk, vv, ii, ff = xs
        # intra-chunk: masked quadratic attention with decay weights
        logf = jnp.log(ff + 1e-8)                             # (B,T,H)
        cumf = jnp.cumsum(logf, axis=1)
        # decay from t' to t  (t >= t')
        dmat = cumf[:, :, None] - cumf[:, None, :]            # (B,T,T',H)
        mask = jnp.tril(jnp.ones((csz, csz), bool))
        w = jnp.where(mask[None, :, :, None], jnp.exp(dmat), 0.0)
        w = w * ii[:, None]                                   # gate at source
        att = jnp.einsum("bthd,bshd->btsh", qq, kk).astype(F32)
        intra = jnp.einsum("btsh,btsh,bshd->bthd", att, w, vv.astype(F32))
        # inter-chunk: contribution of the carried matrix memory
        decay_to_t = jnp.exp(cumf)                            # (B,T,H)
        inter = jnp.einsum("bthd,bhde,bth->bthe", qq.astype(F32), Cmem,
                           decay_to_t)
        norm = jnp.einsum("bthd,bhd,bth->bth", qq.astype(F32), nmem,
                          decay_to_t)
        norm = norm + jnp.einsum("btsh,btsh->bth", att, w)
        y = (intra + inter) / jnp.maximum(jnp.abs(norm), 1.0)[..., None]
        # update memory to end of chunk
        tot = cumf[:, -1]                                      # (B,H)
        decay_from_s = jnp.exp(tot[:, None] - cumf)            # (B,T,H)
        upd = jnp.einsum("bshd,bshe,bsh->bhde", kk.astype(F32),
                         vv.astype(F32), decay_from_s * ii)
        nupd = jnp.einsum("bshd,bsh->bhd", kk.astype(F32), decay_from_s * ii)
        Cmem = Cmem * jnp.exp(tot)[..., None, None] + upd
        nmem = nmem * jnp.exp(tot)[..., None] + nupd
        return (Cmem, nmem), y.astype(x.dtype)

    C0 = jnp.zeros((B, H, dh, dh), F32)
    n0 = jnp.zeros((B, H, dh), F32)
    _, ys = jax.lax.scan(step, (C0, n0), (qc, kc, vc, ic, fc))
    y = ys.swapaxes(0, 1).reshape(B, S, H * dh)
    o = jax.nn.sigmoid(x @ p["wo_gate"]) * y
    return o @ p["out_proj"]


def mlstm_init_state(cfg: ArchConfig, B, dtype):
    H, dh = _mlstm_heads(cfg)
    return {"C": jnp.zeros((B, H, dh, dh), F32),
            "n": jnp.zeros((B, H, dh), F32)}


def mlstm_decode(p, x, state, cfg: ArchConfig):
    B = x.shape[0]
    H, dh = _mlstm_heads(cfg)
    xt = x[:, 0]
    q = (xt @ p["wq"]).reshape(B, H, dh)
    k = (xt @ p["wk"]).reshape(B, H, dh) * dh ** -0.5
    v = (xt @ p["wv"]).reshape(B, H, dh)
    i_g = jax.nn.sigmoid((xt @ p["wi"]).astype(F32))           # (B,H)
    f_g = jax.nn.sigmoid((xt @ p["wf"]).astype(F32))
    C = state["C"] * f_g[..., None, None] + \
        jnp.einsum("bhd,bhe,bh->bhde", k.astype(F32), v.astype(F32), i_g)
    n = state["n"] * f_g[..., None] + k.astype(F32) * i_g[..., None]
    y = jnp.einsum("bhd,bhde->bhe", q.astype(F32), C)
    norm = jnp.einsum("bhd,bhd->bh", q.astype(F32), n)
    y = (y / jnp.maximum(jnp.abs(norm), 1.0)[..., None]).astype(x.dtype)
    y = y.reshape(B, H * dh)
    o = jax.nn.sigmoid(xt @ p["wo_gate"]) * y
    return (o @ p["out_proj"])[:, None], {"C": C, "n": n}


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory recurrent; sequential scan)
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ArchConfig, dtype):
    D = cfg.d_model
    ks = jax.random.split(key, 3)
    s = lambda sh, k: (jax.random.normal(k, sh, F32) / sh[0] ** 0.5).astype(dtype)
    return {
        "ln": jnp.zeros((D,), dtype),
        "w": s((D, 4 * D), ks[0]),
        "r": s((D, 4 * D), ks[1]),
        "b": jnp.zeros((4 * D,), dtype),
        "out_proj": s((D, D), ks[2]),
    }


def _slstm_cell(p, xt, h, c):
    gates = xt @ p["w"] + h @ p["r"] + p["b"]
    i, f, z, o = jnp.split(gates.astype(F32), 4, axis=-1)
    c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(z)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h.astype(xt.dtype), c


def slstm_train(p, x, cfg: ArchConfig, shard):
    B, S, D = x.shape

    def step(carry, xt):
        h, c = carry
        h, c = _slstm_cell(p, xt, h, c)
        return (h, c), h

    h0 = jnp.zeros((B, D), x.dtype)
    c0 = jnp.zeros((B, D), F32)
    _, ys = jax.lax.scan(step, (h0, c0), x.swapaxes(0, 1))
    return ys.swapaxes(0, 1) @ p["out_proj"]


def slstm_init_state(cfg: ArchConfig, B, dtype):
    D = cfg.d_model
    return {"h": jnp.zeros((B, D), dtype), "c": jnp.zeros((B, D), F32)}


def slstm_decode(p, x, state, cfg: ArchConfig):
    h, c = _slstm_cell(p, x[:, 0], state["h"], state["c"])
    return (h @ p["out_proj"])[:, None], {"h": h, "c": c}
