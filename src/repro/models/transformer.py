"""Backbone assembly: pattern-unit scan, per-mixer dispatch, KV/state cache.

Every architecture is a repeating *unit* of layers (configs.archs). The
forward pass stacks each unit position's params over the repeat axis R and
``lax.scan``s the unit body — keeping HLO size O(unit), not O(depth),
which is what makes 94-layer MoE dry-runs compile in seconds.

Cache layout (decode): one pytree per unit position, leaves stacked (R,…):
  attn/attn_local : {'k','v'}: (B, kv, S, dh)  — S is the sharded dim
  mamba           : {'h': (B,E,N), 'conv': (B,d_conv-1,E)}
  mlstm           : {'C': (B,H,dh,dh), 'n': (B,H,dh)}
  slstm           : {'h': (B,D), 'c': (B,D)}
  xattn           : {}  (source K/V recomputed from aux embeddings)
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.archs import ArchConfig
from repro.models import layers as L
from repro.models import ssm

Shard = Callable[[jax.Array, str], jax.Array]
noshard: Shard = lambda x, kind: x


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, spec: dict, cfg: ArchConfig, dtype):
    p = {}
    m = spec["mixer"]
    k1, k2 = jax.random.split(key)
    if m in ("attn", "attn_local", "attn_bidir", "xattn"):
        p["mixer"] = L.init_attn(k1, cfg, dtype)
    elif m == "mamba":
        p["mixer"] = ssm.init_mamba(k1, cfg, dtype)
    elif m == "mlstm":
        p["mixer"] = ssm.init_mlstm(k1, cfg, dtype)
    elif m == "slstm":
        p["mixer"] = ssm.init_slstm(k1, cfg, dtype)
    else:
        raise ValueError(m)
    f = spec["ffn"]
    if f == "mlp":
        p["ffn"] = L.init_mlp(k2, cfg, dtype)
    elif f == "moe":
        p["ffn"] = L.init_moe(k2, cfg, dtype)
    return p


def init_unit(key, pattern, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, len(pattern))
    return tuple(init_layer(k, spec, cfg, dtype)
                 for k, spec in zip(ks, pattern))


def init_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    kE, kU, kH, kN, kenc = jax.random.split(key, 5)
    D, V = cfg.d_model, cfg.vocab
    params = {
        "embed": (jax.random.normal(kE, (V, D), jnp.float32) * 0.02
                  ).astype(dtype),
        "final_norm": jnp.zeros((D,), dtype),
        "units": jax.vmap(
            lambda k: init_unit(k, cfg.pattern, cfg, dtype)
        )(jax.random.split(kU, cfg.repeat)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(kH, (D, V), jnp.float32)
                             * 0.02).astype(dtype)
    if cfg.enc_layers:
        enc_pattern = ({"mixer": "attn_bidir", "ffn": "mlp"},)
        params["enc_units"] = jax.vmap(
            lambda k: init_unit(k, enc_pattern, cfg, dtype)
        )(jax.random.split(kenc, cfg.enc_layers))
        params["enc_norm"] = jnp.zeros((D,), dtype)
    return params


# ---------------------------------------------------------------------------
# layer application (full-sequence modes)
# ---------------------------------------------------------------------------

def apply_layer_full(p, spec, x, cfg: ArchConfig, positions, shard: Shard,
                     aux, collect_cache: bool):
    """Returns (x, cache_entry)."""
    m = spec["mixer"]
    pm = p["mixer"]
    cache = {}
    if m in ("attn", "attn_local", "attn_bidir"):
        h = L.rms_norm(x, pm["ln"])
        causal = m != "attn_bidir"
        window = cfg.local_window if m == "attn_local" else None
        B, S, D = x.shape
        H, kv, dh = cfg.n_heads, cfg.n_kv, cfg.dh
        q, k, v = L.qkv_project(pm, h, cfg, shard)
        if causal:
            q = L.rope(q, positions, cfg.rope_theta)
            k = L.rope(k, positions, cfg.rope_theta)
        if collect_cache:
            cache = {"k": shard(k.transpose(0, 2, 1, 3), "cache"),
                     "v": shard(v.transpose(0, 2, 1, 3), "cache")}
        kf = L.repeat_kv(k, H // kv)
        vf = L.repeat_kv(v, H // kv)
        from repro.models.tuning import get_tuning
        if get_tuning().attn_seq_parallel and cfg.attn_shard == "dh":
            # context parallelism for indivisible head counts (tuning.py)
            q = shard(q, "q_seq")
            kf = shard(kf, "kv_full")
            vf = shard(vf, "kv_full")
        o = L.flash_attention(q, kf, vf, cfg, positions, causal, window,
                              scale=dh ** -0.5, shard=shard)
        o = shard(o, "qkv")
        att = jnp.einsum("bsh,hd->bsd", o.reshape(B, S, H * dh), pm["wo"])
        if "post_ln" in pm:
            att = L.rms_norm(att, pm["post_ln"])
        x = x + att
    elif m == "xattn":
        h = L.rms_norm(x, pm["ln"])
        src = aux["src"]
        sk, sv = L.xattn_kv(pm, src, cfg, shard)
        x = x + L.cross_attention(pm, h, sk, sv, cfg, shard)
    elif m == "mamba":
        x = x + ssm.mamba_train(pm, L.rms_norm(x, pm["ln"]), cfg, shard)
        if collect_cache:
            # prefill for SSMs: cheapest correct cache is the final state;
            # recompute it via the decode recurrence is O(S) — instead we
            # run the train scan again capturing the last state.
            cache = _mamba_final_state(pm, L.rms_norm(x, pm["ln"]), cfg)
    elif m == "mlstm":
        x = x + ssm.mlstm_train(pm, L.rms_norm(x, pm["ln"]), cfg, shard)
        if collect_cache:
            cache = ssm.mlstm_init_state(cfg, x.shape[0], x.dtype)
    elif m == "slstm":
        x = x + ssm.slstm_train(pm, L.rms_norm(x, pm["ln"]), cfg, shard)
        if collect_cache:
            cache = ssm.slstm_init_state(cfg, x.shape[0], x.dtype)
    else:
        raise ValueError(m)

    f = spec["ffn"]
    if f == "mlp":
        pf = p["ffn"]
        x = x + L.mlp(pf, L.rms_norm(x, pf["ln"]), shard)
    elif f == "moe":
        pf = p["ffn"]
        x = x + L.moe(pf, L.rms_norm(x, pf["ln"]), cfg, shard)
    x = shard(x, "act")
    return x, cache


def _mamba_final_state(pm, x, cfg):
    # Placeholder final state for prefill caches (exact-state prefill is a
    # TODO optimization; decode smoke tests init from zeros which is the
    # published convention for synthetic-weight shape checks).
    return ssm.mamba_init_state(cfg, x.shape[0], x.dtype)


# ---------------------------------------------------------------------------
# layer application (decode mode)
# ---------------------------------------------------------------------------

def apply_layer_decode(p, spec, x, cfg: ArchConfig, cache, pos, shard: Shard,
                       aux, long_mode: bool):
    m = spec["mixer"]
    pm = p["mixer"]
    new_cache = cache
    if m in ("attn", "attn_local"):
        h = L.rms_norm(x, pm["ln"])
        window = cfg.local_window if (
            m == "attn_local" or (long_mode and cfg.attn_softcap is not None)
        ) else None
        att, ck, cv = L.attention_decode(pm, h, cfg, cache["k"], cache["v"],
                                         pos, shard, window)
        if "post_ln" in pm:
            att = L.rms_norm(att, pm["post_ln"])
        x = x + att
        new_cache = {"k": ck, "v": cv}
    elif m == "xattn":
        h = L.rms_norm(x, pm["ln"])
        sk, sv = L.xattn_kv(pm, aux["src"], cfg, shard)
        x = x + L.cross_attention(pm, h, sk, sv, cfg, shard)
    elif m == "mamba":
        y, st = ssm.mamba_decode(pm, L.rms_norm(x, pm["ln"]), cache, cfg)
        x = x + y
        new_cache = st
    elif m == "mlstm":
        y, st = ssm.mlstm_decode(pm, L.rms_norm(x, pm["ln"]), cache, cfg)
        x = x + y
        new_cache = st
    elif m == "slstm":
        y, st = ssm.slstm_decode(pm, L.rms_norm(x, pm["ln"]), cache, cfg)
        x = x + y
        new_cache = st

    f = spec["ffn"]
    if f == "mlp":
        pf = p["ffn"]
        x = x + L.mlp(pf, L.rms_norm(x, pf["ln"]), shard)
    elif f == "moe":
        pf = p["ffn"]
        x = x + L.moe(pf, L.rms_norm(x, pf["ln"]), cfg, shard)
    return x, new_cache


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------

def init_cache_entry(spec, cfg: ArchConfig, B, S, dtype):
    m = spec["mixer"]
    if m in ("attn", "attn_local"):
        kv, dh = cfg.n_kv, cfg.dh
        return {"k": jnp.zeros((B, kv, S, dh), dtype),
                "v": jnp.zeros((B, kv, S, dh), dtype)}
    if m == "mamba":
        return ssm.mamba_init_state(cfg, B, dtype)
    if m == "mlstm":
        return ssm.mlstm_init_state(cfg, B, dtype)
    if m == "slstm":
        return ssm.slstm_init_state(cfg, B, dtype)
    return {}


def init_cache(cfg: ArchConfig, B, S, dtype=jnp.bfloat16):
    per_pos = tuple(init_cache_entry(spec, cfg, B, S, dtype)
                    for spec in cfg.pattern)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (cfg.repeat,) + x.shape), per_pos)


# ---------------------------------------------------------------------------
# backbone passes
# ---------------------------------------------------------------------------

def _scan_units(body, x, xs, use_remat: bool):
    if use_remat:
        body = jax.checkpoint(body)
    return jax.lax.scan(body, x, xs)


def encoder_pass(cfg: ArchConfig, params, src_embeds, shard: Shard):
    enc_pattern = ({"mixer": "attn_bidir", "ffn": "mlp"},)
    positions = jnp.arange(src_embeds.shape[1])

    def body(x, unit_p):
        for p, spec in zip(unit_p, enc_pattern):
            x, _ = apply_layer_full(p, spec, x, cfg, positions, shard,
                                    {}, False)
        return x, None

    x, _ = _scan_units(body, src_embeds, params["enc_units"], True)
    return L.rms_norm(x, params["enc_norm"])


def backbone_full(cfg: ArchConfig, params, x, shard: Shard, aux,
                  collect_cache: bool, use_remat: bool = True):
    """Full-sequence pass (train / prefill). x: (B,S,D) embedded."""
    positions = jnp.arange(x.shape[1])

    def body(h, unit_p):
        caches = []
        for p, spec in zip(unit_p, cfg.pattern):
            h, c = apply_layer_full(p, spec, h, cfg, positions, shard, aux,
                                    collect_cache)
            caches.append(c)
        return h, tuple(caches)

    x, caches = _scan_units(body, x, params["units"], use_remat)
    return L.rms_norm(x, params["final_norm"]), caches


def backbone_decode(cfg: ArchConfig, params, x, cache, pos, shard: Shard,
                    aux, long_mode: bool):
    """Single-token pass. x: (B,1,D); cache stacked per unit position."""
    from repro.models.tuning import get_tuning

    def body(h, xs):
        unit_p, unit_c = xs
        new_cs = []
        for p, spec, c in zip(unit_p, cfg.pattern, unit_c):
            h, nc = apply_layer_decode(p, spec, h, cfg, c, pos, shard, aux,
                                       long_mode)
            new_cs.append(nc)
        return h, tuple(new_cs)

    if get_tuning().decode_unroll:
        # static unroll: per-unit slices of the stacked params/cache are
        # static-index (fusable/aliasable), unlike the scan carry's
        # dynamic-slice + dynamic-update-slice of the whole stack.
        new_units = []
        for r in range(cfg.repeat):
            unit_p = jax.tree_util.tree_map(lambda a: a[r],
                                            params["units"])
            unit_c = jax.tree_util.tree_map(lambda a: a[r], cache)
            x, new_c = body(x, (unit_p, unit_c))
            new_units.append(new_c)
        new_cache = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, axis=0), *new_units)
    else:
        x, new_cache = jax.lax.scan(body, x, (params["units"], cache))
    return L.rms_norm(x, params["final_norm"]), new_cache
