"""String-keyed engine registry: the paper's "choose a backend" knob.

The public API (``repro.api``) never imports engine classes; it resolves
backends by name here, so a new engine plugs in without touching the
facade:

    from repro.core.registry import register_engine

    register_engine("mybackend", MyEngine)          # a class, or
    register_engine("tuned", lambda: PallasEngine(k=16))   # any factory

Built-in backends are registered lazily (by module path) so importing
the registry stays cheap and free of import cycles — ``DistEngine``'s
shard_map machinery, for instance, only loads when somebody actually
binds ``backend="dist"``.
"""
from __future__ import annotations

import importlib
import threading
from typing import Any, Callable, Dict, List, Tuple

from repro.core.engine import Engine

EngineFactory = Callable[..., Engine]


class UnknownBackendError(KeyError):
    """Raised when a backend name is not registered."""

    def __str__(self):  # KeyError repr-quotes its message; keep it readable
        return self.args[0] if self.args else ""


class DuplicateBackendError(ValueError):
    """Raised when a backend name is registered twice without overwrite."""


# name -> "module:Class" for built-ins, resolved (and cached) on demand
_BUILTIN_PATHS: Dict[str, str] = {
    "jnp": "repro.core.engine:JnpEngine",
    "dist": "repro.core.dist:DistEngine",
    "dist_sharded": "repro.shard.engine:ShardedEngine",
    "pallas": "repro.core.pallas_engine:PallasEngine",
    "pallas_chained": "repro.core.pallas_engine:PallasChainedEngine",
    "frontier": "repro.core.frontier_engine:FrontierEngine",
}

_FACTORIES: Dict[str, EngineFactory] = {}


def _resolve_builtin(name: str) -> EngineFactory:
    mod_name, cls_name = _BUILTIN_PATHS[name].split(":")
    cls = getattr(importlib.import_module(mod_name), cls_name)
    _FACTORIES[name] = cls
    return cls


def register_engine(name: str, factory: EngineFactory, *,
                    overwrite: bool = False) -> None:
    """Register ``factory`` (an Engine subclass or zero/kw-arg callable
    returning an Engine) under ``name`` for ``bind(backend=name)``."""
    if not isinstance(name, str) or not name:
        raise ValueError(f"backend name must be a non-empty string, "
                         f"got {name!r}")
    if not callable(factory):
        raise TypeError(f"engine factory for {name!r} must be callable")
    taken = name in _FACTORIES or name in _BUILTIN_PATHS
    if taken and not overwrite:
        raise DuplicateBackendError(
            f"backend {name!r} is already registered "
            f"(pass overwrite=True to replace it)")
    _FACTORIES[name] = factory


def unregister_engine(name: str) -> None:
    """Remove a registered backend (built-ins revert to their default)."""
    _FACTORIES.pop(name, None)


def engine_factory(name: str) -> EngineFactory:
    """The factory registered under ``name`` (resolving built-ins)."""
    try:
        return _FACTORIES[name]
    except KeyError:
        pass
    if name in _BUILTIN_PATHS:
        return _resolve_builtin(name)
    raise UnknownBackendError(
        f"unknown backend {name!r}; available: "
        f"{', '.join(available_backends())}")


def make_engine(name: str, **options) -> Engine:
    """Instantiate a backend by name, e.g. ``make_engine('pallas', k=16)``."""
    return engine_factory(name)(**options)


def available_backends() -> List[str]:
    """Sorted names of every registered backend."""
    return sorted(set(_BUILTIN_PATHS) | set(_FACTORIES))


# ---------------------------------------------------------------------------
# Shared-executable binding (repro.serve)
# ---------------------------------------------------------------------------
# Compiled executables (jitted scatter programs, stream-segment scans,
# staged DSL lowerings) live on the *engine instance*: two sessions
# bound through ``make_engine`` each pay their own compilations even
# when their programs and shapes are identical.  A session pool instead
# binds all same-shape tenants to ONE engine per (backend, scope,
# options), so the first tenant's compile warms every later tenant.
#
# Engines keep per-graph host state (``_n`` is set by ``prepare``), so
# the shared key MUST scope by anything that state depends on — the
# pool passes the graph's vertex count as ``scope``.  Sharing is safe
# exactly when every session on the instance would set identical host
# state; sessions with different n need different shared instances.

_SHARED_ENGINES: Dict[Tuple, Engine] = {}
_SHARED_LOCK = threading.Lock()


def _device_count() -> int:
    """Process-wide device count (lazy jax import; monkeypatch seam for
    the mesh-key regression test in tests/test_serve.py)."""
    import jax
    return len(jax.devices())


def _mesh_token(name: str, options: Dict[str, Any]):
    """Extra shared-key component for mesh-bound engines (those with a
    ``mesh_scoped`` class attribute, e.g. dist/dist_sharded): the shard
    count the factory would resolve.  Without it a SessionPool could
    hand a 4-shard tenant an engine whose mesh was built for a
    different device set — same name, same scope, incompatible
    compiled executables and shardings."""
    try:
        factory = engine_factory(name)
    except UnknownBackendError:
        return None
    if not getattr(factory, "mesh_scoped", False):
        return None
    shards = options.get("num_shards")
    if not shards:
        devs = options.get("devices")
        shards = len(devs) if devs is not None else _device_count()
    return ("mesh", int(shards))


def shared_engine(name: str, scope: Any = None, **options) -> Engine:
    """One cached engine instance per ``(name, scope, mesh, options)``
    — the pool's shared-executable binding.  ``scope`` must capture
    whatever per-graph host state the engine carries (vertex count at
    minimum); mesh-bound engines additionally key by the shard count
    they would resolve (see :func:`_mesh_token`); callers that cannot
    guarantee a safe scope should use :func:`make_engine` and pay the
    per-session compiles."""
    key = (name, scope, _mesh_token(name, options),
           tuple(sorted(options.items())))
    with _SHARED_LOCK:
        eng = _SHARED_ENGINES.get(key)
        if eng is None:
            eng = _SHARED_ENGINES[key] = make_engine(name, **options)
        return eng


def clear_shared_engines() -> None:
    """Drop every shared engine (tests; frees their compiled caches)."""
    with _SHARED_LOCK:
        _SHARED_ENGINES.clear()


# Degradation order per backend: where a session falls when its bound
# backend's kernels fail to compile or launch.  Every chain bottoms out
# at "jnp" — the pure-XLA reference engine with no custom kernels, the
# backend the conformance matrix holds as oracle.  Keys are *registry
# names*, not Engine.name (pallas and pallas_chained share
# Engine.name == "pallas"; the registry name is what bind() stores).
DEFAULT_CHAIN: Dict[str, tuple] = {
    "pallas": ("pallas_chained", "jnp"),
    "pallas_chained": ("jnp",),
    "frontier": ("jnp",),
    "dist": ("jnp",),
    "dist_sharded": ("dist", "jnp"),
}


def failover_chain(name: str) -> tuple:
    """The fallback backends to try, in order, when ``name`` fails.
    Unknown/custom backends degrade straight to the reference engine."""
    if name == "jnp":
        return ()
    return DEFAULT_CHAIN.get(name, ("jnp",))
