"""DistEngine — the MPI backend analogue, on shard_map + collectives.

Faithful mapping of the paper's §3.6/§5.2 distributed design:

  * **vertex ownership**: vertices are block-partitioned over the mesh's
    ``data`` axis; "a process stores only those edges for which the source
    node is owned by that process" — each shard holds its own DynGraph
    (CSR *and* diff-CSR) containing exactly its out-edges;
  * **distributed diff-CSR**: update batches are broadcast and each shard
    applies only the updates whose source it owns — literally re-using the
    single-device ``update_csr_add/del`` code under shard_map;
  * **RMA windows → all_gather**: remote property reads become one
    ``all_gather`` per sweep, restricted to the read set recovered by
    ``trace_read_set`` (the paper's read-set analysis deciding what to
    expose);
  * **MPI_Accumulate(MIN/SUM) → pmin/psum**: each shard reduces its local
    edges' contributions into a dense n-length buffer; a cross-shard
    pmin/psum/pmax produces the globally combined property — the
    shared-lock atomic-accumulate of §5.2, as one deterministic collective;
  * **TC's remote-neighborhood queries** (the paper's admitted MPI
    bottleneck) become query all_gathers + pmax combines per wedge step —
    same asymptotic communication, kept deliberately so the benchmark
    reproduces the paper's TC trend.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P, NamedSharding

try:                                    # jax >= 0.5 re-exports at top level
    from jax import shard_map as _shard_map
except ImportError:                     # jax 0.4.x keeps it in experimental
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(fn, *, mesh, in_specs, out_specs, check_vma=False, **kw):
    """Version-tolerant shard_map: jax 0.4.x spells the VMA-check kwarg
    ``check_rep``; newer releases renamed it ``check_vma``."""
    try:
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check_vma, **kw)
    except TypeError:
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma, **kw)

from repro.core.ir import EdgeSweep, Reduce, trace_read_set
from repro.core.engine import Engine, Collectives, Props, WedgeCtx, \
    edge_lane_flags, _STREAM_CACHE_LOCK
from repro.graph.csr import CSR, INT, build_csr
from repro.graph import diffcsr
from repro.graph.diffcsr import DynGraph, BOOL
from repro.graph.updates import UpdateBatch


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DistGraph:
    """Per-shard DynGraphs stacked on a leading (sharded) axis."""

    offsets: jax.Array   # (P, n+1)
    src: jax.Array       # (P, Emax)
    dst: jax.Array
    w: jax.Array
    alive: jax.Array
    d_offsets: jax.Array
    d_src: jax.Array
    d_dst: jax.Array
    d_w: jax.Array
    d_alive: jax.Array
    overflow: jax.Array  # (P,)
    n: int = dataclasses.field(metadata=dict(static=True))


def _local(dg: DistGraph) -> DynGraph:
    """Inside shard_map: strip the leading size-1 shard axis."""
    leaf = lambda x: x[0]
    return DynGraph(
        offsets=leaf(dg.offsets), src=leaf(dg.src), dst=leaf(dg.dst),
        w=leaf(dg.w), alive=leaf(dg.alive), d_offsets=leaf(dg.d_offsets),
        d_src=leaf(dg.d_src), d_dst=leaf(dg.d_dst), d_w=leaf(dg.d_w),
        d_alive=leaf(dg.d_alive), overflow=leaf(dg.overflow), n=dg.n)


def _restack(g: DynGraph) -> DistGraph:
    leaf = lambda x: x[None]
    return DistGraph(
        offsets=leaf(g.offsets), src=leaf(g.src), dst=leaf(g.dst),
        w=leaf(g.w), alive=leaf(g.alive), d_offsets=leaf(g.d_offsets),
        d_src=leaf(g.d_src), d_dst=leaf(g.d_dst), d_w=leaf(g.d_w),
        d_alive=leaf(g.d_alive), overflow=leaf(g.overflow), n=g.n)


class DistCollectives(Collectives):
    def __init__(self, axis: str):
        self.axis = axis

    def any(self, x):
        return jax.lax.pmax(jnp.any(x).astype(jnp.int32), self.axis) > 0

    def sum(self, x):
        return jax.lax.psum(jnp.sum(x), self.axis)

    def max(self, x):
        return jax.lax.pmax(jnp.max(x), self.axis)


def _host_fetch(tree):
    """ONE host transfer for a whole pytree of device arrays.  The
    monkeypatchable seam ``tests/test_perf_debts.py`` pins: pack_state's
    edge harvest must cost one sync per save, not one per shard
    (DESIGN.md §3 debt #6)."""
    return jax.device_get(tree)


def _pcombine(red: Reduce, x, axis: str):
    if red.kind in ("min", "argmin"):
        return jax.lax.pmin(x, axis)
    if red.kind == "max":
        return jax.lax.pmax(x, axis)
    if red.kind == "sum":
        return jax.lax.psum(x, axis)
    if red.kind == "or":
        return jax.lax.pmax(x.astype(jnp.int32), axis).astype(BOOL)
    raise ValueError(red.kind)


class _DistStreamView(Engine):
    """In-shard engine view used by stream steps inside the streaming
    shard_map: the same Engine surface, but every method assumes it is
    already running on ONE shard (local DynGraph, (block,)-local vertex
    props) and synchronizes through collectives — the paper's 'same
    algorithm text, MPI synchronization' point carried into the fused
    scan.  Notably has NO ``src_flags_from_dst``: decremental repair
    falls back to its dense seed, exactly like the outer DistEngine."""

    name = "dist-stream"

    def __init__(self, outer: "DistEngine"):
        self._o = outer

    # -- shapes ------------------------------------------------------------
    # _n delegates to the LIVE engine: the cached segment runner retraces
    # when graph shapes change, and the retrace must see the n of the
    # graph currently prepared, not the one at view construction.
    @property
    def _n(self):
        return self._o._n

    @property
    def n_pad(self) -> int:
        return self._o.n_pad

    def full(self, value, dtype) -> jax.Array:
        # vertex properties inside the stream scan are (block,) shards
        return jnp.full((self._o.block,), value, dtype=dtype)

    # -- aggregate ops -----------------------------------------------------
    def vertex_map(self, g, fn, props: Props) -> Props:
        ax = self._o.axis
        blk = self._o.block
        full = {k: jax.lax.all_gather(v, ax, tiled=True)
                for k, v in props.items()}
        out = fn(full)
        i = jax.lax.axis_index(ax)
        return {k: jax.lax.dynamic_slice(v, (i * blk,), (blk,))
                for k, v in out.items()}

    def sweep(self, g, sw: EdgeSweep, props: Props) -> Props:
        read_set = frozenset(sw.read_set(props))
        return self._o._sweep_local(g, sw, props, read_set)

    def count_wedges(self, handle, pair_fn, lane_flags, out_example,
                     bounds=None):
        raise NotImplementedError(
            "wedge enumeration (TC) is not supported inside DistEngine's "
            "fused stream scan; use the per-batch dyn_tc path on dist")

    def fixed_point(self, g, sw: EdgeSweep, props: Props, cond_fn,
                    max_iter: int) -> Props:
        read_set = frozenset(sw.read_set(props))
        col = DistCollectives(self._o.axis)

        def cond(state):
            it, p = state
            return (it < max_iter) & cond_fn(p, it, col)

        def body(state):
            it, p = state
            return it + 1, self._o._sweep_local(g, sw, p, read_set)

        _, out = jax.lax.while_loop(cond, body, (jnp.zeros((), INT), props))
        return out

    def out_degrees(self, g) -> jax.Array:
        esrc, _, _, ealive = g.edge_arrays()
        dense = jax.ops.segment_sum(ealive.astype(INT), esrc,
                                    num_segments=self.n_pad)
        dense = jax.lax.psum(dense, self._o.axis)
        i = jax.lax.axis_index(self._o.axis)
        return jax.lax.dynamic_slice(dense, (i * self._o.block,),
                                     (self._o.block,))

    # -- dynamic updates (ownership-masked, straight onto the local graph) --
    def update_del(self, g, batch: UpdateBatch):
        i = jax.lax.axis_index(self._o.axis)
        own = (batch.del_src // self._o.block) == i
        return diffcsr.update_csr_del(g, batch.del_src, batch.del_dst,
                                      batch.del_mask & own)

    def update_add(self, g, batch: UpdateBatch):
        i = jax.lax.axis_index(self._o.axis)
        own = (batch.add_src // self._o.block) == i
        return diffcsr.update_csr_add(g, batch.add_src, batch.add_dst,
                                      batch.add_w, batch.add_mask & own)

    def batch_edge_flags(self, g, qs, qd, mask) -> jax.Array:
        return edge_lane_flags(g, qs, qd, mask)


class DistEngine(Engine):
    name = "dist"

    # shared_engine keys instances of mesh-bound engines by their shard
    # count: an engine prepared for one mesh must never be handed to a
    # tenant expecting another (see registry.shared_engine).
    mesh_scoped = True

    def __init__(self, num_shards: int | None = None, axis: str = "data",
                 devices=None):
        devices = devices if devices is not None else jax.devices()
        if num_shards is None:
            num_shards = len(devices)
        self.P = num_shards
        self.axis = axis
        self.mesh = Mesh(np.array(devices[: self.P]), (axis,))
        self._n = None
        self._block = None
        self._stream_cache = {}

    # ------------------------------------------------------------------ #
    @property
    def n_pad(self) -> int:
        return self._block * self.P

    @property
    def block(self) -> int:
        return self._block

    def _shmap(self, fn, in_specs, out_specs):
        return shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)

    def _gspec(self):
        """Sharding spec for a stacked DistGraph pytree."""
        return P(self.axis)

    def _pspec(self):
        return P(self.axis)

    # -- construction ------------------------------------------------------
    def prepare(self, csr: CSR, diff_capacity: int) -> DistGraph:
        self._n = csr.n
        self._block = -(-csr.n // self.P)
        n = csr.n
        src = np.asarray(csr.src)
        dst = np.asarray(csr.dst)
        w = np.asarray(csr.w)
        shards = []
        emax = 0
        for p in range(self.P):
            lo, hi = p * self._block, (p + 1) * self._block
            sel = (src >= lo) & (src < hi)
            emax = max(emax, int(sel.sum()))
        emax = max(emax, 1)
        for p in range(self.P):
            lo, hi = p * self._block, (p + 1) * self._block
            sel = (src >= lo) & (src < hi)
            e = np.stack([src[sel], dst[sel]], axis=1)
            sub = build_csr(n, e, w[sel], dedupe=False)
            k = sub.num_edges
            pad = emax - k
            g = DynGraph(
                offsets=sub.offsets,
                src=jnp.concatenate([sub.src, jnp.zeros(pad, INT)]),
                dst=jnp.concatenate([sub.dst, jnp.zeros(pad, INT)]),
                w=jnp.concatenate([sub.w, jnp.ones(pad, INT)]),
                alive=jnp.concatenate([jnp.ones(k, BOOL), jnp.zeros(pad, BOOL)]),
                d_offsets=jnp.zeros((n + 1,), INT),
                d_src=jnp.full((diff_capacity,), n, INT),
                d_dst=jnp.zeros((diff_capacity,), INT),
                d_w=jnp.zeros((diff_capacity,), INT),
                d_alive=jnp.zeros((diff_capacity,), BOOL),
                overflow=jnp.zeros((), INT),
                n=n,
            )
            shards.append(g)
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *shards)
        dg = DistGraph(**{f.name: getattr(stacked, f.name)
                          for f in dataclasses.fields(DynGraph)
                          if f.name != "n"}, n=n)
        sh = NamedSharding(self.mesh, P(self.axis))
        return jax.tree_util.tree_map(lambda x: jax.device_put(x, sh), dg)

    def _gather_edges(self, dg: DistGraph):
        """Host-gather the global alive edge set ``(src, dst, w)`` from
        the stacked shards — shared by ``merge`` and ``pack_state``
        (shard-count-independent, so it is also the re-mesh format).

        The concatenations mirror ``DynGraph.edge_arrays`` on the
        stacked ``(P, ·)`` lanes so the whole harvest is ONE host
        transfer instead of one per shard (debt #6); the row-major
        flatten preserves the per-shard ``[main, diff]`` lane order of
        the old per-shard loop bit-exactly."""
        n = dg.n
        es = jnp.concatenate([dg.src, jnp.minimum(dg.d_src, n - 1)], axis=1)
        ed = jnp.concatenate([dg.dst, dg.d_dst], axis=1)
        ew = jnp.concatenate([dg.w, dg.d_w], axis=1)
        ea = jnp.concatenate([dg.alive, dg.d_alive & (dg.d_src < n)], axis=1)
        es, ed, ew, ea = _host_fetch((es, ed, ew, ea))
        keep = np.asarray(ea).reshape(-1)
        return (np.asarray(es).reshape(-1)[keep],
                np.asarray(ed).reshape(-1)[keep],
                np.asarray(ew).reshape(-1)[keep])

    def merge(self, dg: DistGraph,
              diff_capacity: int | None = None) -> DistGraph:
        """Gather alive edges host-side, rebuild, re-partition."""
        src, dst, w = self._gather_edges(dg)
        edges = np.stack([src, dst], 1)
        csr = build_csr(dg.n, edges, w)
        if diff_capacity is None:
            diff_capacity = max(dg.d_src.shape[1], 1)
        return self.prepare(csr, diff_capacity=diff_capacity)

    # -- durable state -----------------------------------------------------
    # The dist snapshot is the CANONICAL global edge list, not the raw
    # (P, ...) shard leaves: restore re-partitions onto whatever mesh the
    # restoring engine owns, so an elastic session can come back on a
    # different device count.  Consequence (DESIGN.md §5): restore is
    # value-exact for order-independent reductions (integer min/max —
    # SSSP), but float sums may re-associate because the pool layout is
    # rebuilt.
    state_kind = "dist"

    def pack_state(self, dg: DistGraph):
        src, dst, w = self._gather_edges(dg)
        tree = {"src": jnp.asarray(src), "dst": jnp.asarray(dst),
                "w": jnp.asarray(w)}
        meta = {"kind": "dist", "n": dg.n,
                "diff_capacity": int(dg.d_src.shape[1]),
                "num_shards": self.P}
        return tree, meta

    def put_vertex_array(self, arr):
        sh = NamedSharding(self.mesh, P(self.axis))
        return jax.device_put(jnp.asarray(arr), sh)

    def unpack_state(self, tree, meta) -> DistGraph:
        src = np.asarray(tree["src"])
        dst = np.asarray(tree["dst"])
        w = np.asarray(tree["w"])
        edges = np.stack([src, dst], 1)
        csr = build_csr(meta["n"], edges, w)
        # prepare() blocks over THIS mesh's P — the re-mesh happens here
        return self.prepare(csr,
                            diff_capacity=max(int(meta["diff_capacity"]), 1))

    # -- streaming executor hooks ------------------------------------------
    def handle_counters(self, dg: DistGraph) -> jax.Array:
        """(overflow, used, dead): overflow summed over shards, pool
        occupancy as the worst shard (capacity is per shard)."""
        mat = dg.d_src < dg.n
        used = jnp.max(jnp.sum(mat.astype(INT), axis=1))
        dead = jnp.max(jnp.sum((mat & ~dg.d_alive).astype(INT), axis=1))
        return jnp.stack([jnp.sum(dg.overflow), used, dead])

    def grow(self, dg: DistGraph, factor: float = 2.0) -> DistGraph:
        from repro.runtime import faults as _faults
        _faults.fire("pool_merge", engine=self.name,
                     diff_capacity=int(dg.d_src.shape[1]))
        self._evict_stream_cache(self._handle_shape_key(dg))
        cap = dg.d_src.shape[1]
        return self.merge(dg, diff_capacity=max(int(cap * factor), cap + 16))

    def compact_handle(self, dg: DistGraph) -> DistGraph:
        def fn(dgl):
            return _restack(diffcsr.compact(_local(dgl)))
        return self._shmap(fn, in_specs=(self._gspec(),),
                           out_specs=self._gspec())(dg)

    def _diff_capacity(self, dg: DistGraph) -> int:
        return int(dg.d_src.shape[1])

    def _handle_shape_key(self, dg: DistGraph) -> tuple:
        return (int(dg.src.shape[1]), int(dg.d_src.shape[1]))

    def _segment_runner(self, step_fn, dg: DistGraph, batch_size: int):
        key = (step_fn, self._handle_shape_key(dg), batch_size)
        with _STREAM_CACHE_LOCK:
            fn = self._stream_cache.get(key)
            if fn is None:
                view = _DistStreamView(self)
                ax = self.axis

                def seg_run(dgl, c0, batches):
                    g = _local(dgl)

                    def body(state, batch):
                        g, c = step_fn(view, state[0], batch, state[1])
                        return (g, c), None

                    (g, c), _ = jax.lax.scan(body, (g, c0), batches)
                    # reduce the per-shard counters to the driver's triple:
                    # overflow summed, occupancy as the worst shard
                    cnt = diffcsr.pool_counters(g)
                    cnt = jnp.stack([jax.lax.psum(cnt[0], ax),
                                     jax.lax.pmax(cnt[1], ax),
                                     jax.lax.pmax(cnt[2], ax)])
                    return _restack(g), c, cnt[None]

                shmapped = jax.jit(self._shmap(
                    seg_run,
                    in_specs=(self._gspec(), self._pspec(), P()),
                    out_specs=(self._gspec(), self._pspec(), P(self.axis))))

                def fn(dg, carry, stacked):
                    dg, carry, counters = shmapped(dg, carry, stacked)
                    return dg, carry, counters[0]

                self._stream_cache[key] = fn
        return fn

    def run_stream(self, dg: DistGraph, stream, batch_size: int, step_fn,
                   carry, segment_size: int = 8, compact_frac: float = 0.5):
        """Fused stream segments under ONE shard_map: the scan keeps the
        sharded graph and (block,)-local vertex props device-resident,
        synchronizing only through the collectives inside the step (the
        shared driver in ``Engine._run_stream_fused``)."""
        return self._run_stream_fused(dg, stream, batch_size, step_fn,
                                      carry, segment_size, compact_frac)

    def out_degrees(self, dg: DistGraph) -> jax.Array:
        def fn(dgl):
            g = _local(dgl)
            esrc, _, _, ealive = g.edge_arrays()
            dense = jax.ops.segment_sum(ealive.astype(INT), esrc,
                                        num_segments=self.n_pad)
            dense = jax.lax.psum(dense, self.axis)
            i = jax.lax.axis_index(self.axis)
            return jax.lax.dynamic_slice(dense, (i * self.block,),
                                         (self.block,))
        return self._shmap(fn, in_specs=(self._gspec(),),
                           out_specs=self._pspec())(dg)

    # -- core sweep (inside-shard_map body shared with fixed_point) ---------
    def _sweep_local(self, g: DynGraph, sw: EdgeSweep, lp: Props,
                     read_set) -> Props:
        n_pad = self.n_pad
        i = jax.lax.axis_index(self.axis)
        # "RMA window": gather only the properties the edge_fn reads.
        full = {k: (jax.lax.all_gather(v, self.axis, tiled=True)
                    if k in read_set else None) for k, v in lp.items()}
        full = {k: v for k, v in full.items() if v is not None}
        esrc, edst, ew, ealive = g.edge_arrays()
        s = _DView(full, esrc)
        d = _DView(full, edst)
        out = sw.edge_fn(s, d, ew)
        reduced, hit = {}, {}
        for target, red in sw.reduces.items():
            if red.kind == "argmin":
                continue
            val, elig = out[target]
            elig = elig & ealive
            ident = red.identity(val.dtype)
            v = jnp.where(elig, val, ident)
            dense = red.segment(v, edst, n_pad)
            dense = _pcombine(red, dense, self.axis)
            reduced[target] = dense
            h = jax.ops.segment_max(elig.astype(INT), edst,
                                    num_segments=n_pad)
            hit[target] = (jax.lax.pmax(h, self.axis) > 0)
        for target, red in sw.reduces.items():
            if red.kind != "argmin":
                continue
            of = red.of
            val, elig = out[of]
            elig = elig & ealive
            achieved = elig & (val == reduced[of][edst])
            v = jnp.where(achieved, esrc, jnp.asarray(n_pad, INT))
            dense = jax.ops.segment_min(v, edst, num_segments=n_pad)
            reduced[target] = jax.lax.pmin(dense, self.axis)
            hit[target] = hit[of]
        blk = lambda x: jax.lax.dynamic_slice(x, (i * self.block,),
                                              (self.block,))
        reduced = {k: blk(v) for k, v in reduced.items()}
        hit = {k: blk(v) for k, v in hit.items()}
        return sw.post_fn(lp, reduced, hit)

    def sweep(self, dg: DistGraph, sw: EdgeSweep, props: Props) -> Props:
        read_set = frozenset(sw.read_set(props))

        def fn(dgl, p):
            return self._sweep_local(_local(dgl), sw, p, read_set)

        return self._shmap(
            fn, in_specs=(self._gspec(), self._pspec()),
            out_specs=self._pspec())(dg, props)

    def fixed_point(self, dg: DistGraph, sw: EdgeSweep, props: Props,
                    cond_fn: Callable, max_iter: int) -> Props:
        read_set = frozenset(sw.read_set(props))
        col = DistCollectives(self.axis)

        def fn(dgl, p0):
            g = _local(dgl)

            def cond(state):
                it, p = state
                return (it < max_iter) & cond_fn(p, it, col)

            def body(state):
                it, p = state
                return it + 1, self._sweep_local(g, sw, p, read_set)

            _, out = jax.lax.while_loop(cond, body,
                                        (jnp.zeros((), INT), p0))
            return out

        return self._shmap(
            fn, in_specs=(self._gspec(), self._pspec()),
            out_specs=self._pspec())(dg, props)

    def vertex_map(self, dg: DistGraph, fn: Callable, props: Props) -> Props:
        def body(p):
            full = {k: jax.lax.all_gather(v, self.axis, tiled=True)
                    for k, v in p.items()}
            out = fn(full)
            i = jax.lax.axis_index(self.axis)
            return {k: jax.lax.dynamic_slice(v, (i * self.block,),
                                             (self.block,))
                    for k, v in out.items()}
        return self._shmap(body, in_specs=(self._pspec(),),
                           out_specs=self._pspec())(props)

    # -- wedges --------------------------------------------------------------
    def _count_wedges_local(self, g: DynGraph, flags: Dict[str, jax.Array],
                            pair_fn: Callable, out_example,
                            max_main: int, max_diff: int):
        """In-shard wedge-count body (already inside shard_map): local
        wedge enumeration plus all_gather+pmax remote-edge queries — the
        paper's admitted MPI TC bottleneck, kept deliberately.  Shared
        with the sharded engine's stream view, which calls it with
        segment-static bounds."""
        axis = self.axis
        E, D = g.main_capacity, g.diff_capacity
        esrc, edst, ew, ealive = g.edge_arrays()

        def global_is_edge(qs, qd):
            qg = jax.lax.all_gather(jnp.stack([qs, qd]), axis)  # (P,2,L)
            ans = diffcsr.is_edge(g, qg[:, 0], qg[:, 1])
            ans = jax.lax.pmax(ans.astype(INT), axis)
            i = jax.lax.axis_index(axis)
            return ans[i].astype(BOOL)

        def global_edge_flag(name, qs, qd):
            fl = flags[name]
            qg = jax.lax.all_gather(jnp.stack([qs, qd]), axis)
            p1, f1 = diffcsr._locate_main(g, qg[:, 0], qg[:, 1])
            p2, f2 = diffcsr._locate_diff(g, qg[:, 0], qg[:, 1])
            r = jnp.zeros(qg.shape[0:1] + qs.shape, BOOL)
            r = jnp.where(f1 & g.alive[p1],
                          fl[jnp.clip(p1, 0, E + D - 1)], r)
            r = jnp.where(f2 & g.d_alive[p2] & ~f1,
                          fl[jnp.clip(E + p2, 0, E + D - 1)], r)
            r = jax.lax.pmax(r.astype(INT), axis)
            i = jax.lax.axis_index(axis)
            return r[i].astype(BOOL)

        zero = jax.tree_util.tree_map(
            lambda x: jnp.zeros((), jnp.asarray(x).dtype), out_example)

        def accumulate(total, j, region):
            if region == "main":
                pos = g.offsets[esrc] + j
                ok = pos < g.offsets[esrc + 1]
                safe = jnp.clip(pos, 0, max(E - 1, 0))
                z = g.dst[safe]
                z_ok = ok & g.alive[safe]
                nbr_lane = safe
            else:
                pos = g.d_offsets[esrc] + j
                ok = pos < g.d_offsets[esrc + 1]
                safe = jnp.clip(pos, 0, max(D - 1, 0))
                z = g.d_dst[safe]
                z_ok = ok & g.d_alive[safe]
                nbr_lane = E + safe
            ctx = WedgeCtx(g, flags, nbr_lane, global_is_edge,
                           global_edge_flag)
            contrib = pair_fn(esrc, edst, z, z_ok & ealive, ctx)
            return jax.tree_util.tree_map(
                lambda t, c: t + jnp.sum(c), total, contrib)

        total = zero
        if max_main:
            total = jax.lax.fori_loop(
                0, max_main, lambda j, t: accumulate(t, j, "main"), total)
        if max_diff and D:
            total = jax.lax.fori_loop(
                0, max_diff, lambda j, t: accumulate(t, j, "diff"), total)
        return jax.tree_util.tree_map(
            lambda t: jax.lax.psum(t, axis), total)

    def count_wedges(self, dg: DistGraph, pair_fn: Callable,
                     lane_flags: Dict[str, jax.Array], out_example,
                     bounds=None):
        if bounds is not None:
            max_main, max_diff = bounds
        else:
            # host-side loop bounds from the stacked offsets
            offs = np.asarray(dg.offsets)
            doffs = np.asarray(dg.d_offsets)
            max_main = int((offs[:, 1:] - offs[:, :-1]).max()) \
                if offs.size else 0
            max_diff = int((doffs[:, 1:] - doffs[:, :-1]).max()) \
                if doffs.size else 0

        def fn(dgl, flags):
            g = _local(dgl)
            flags = {k: v[0] for k, v in flags.items()}
            return self._count_wedges_local(g, flags, pair_fn, out_example,
                                            max_main, max_diff)

        flag_specs = {k: P(self.axis) for k in lane_flags}
        return self._shmap(
            fn, in_specs=(self._gspec(), flag_specs),
            out_specs=jax.tree_util.tree_map(lambda _: P(), out_example)
        )(dg, lane_flags)

    # -- updates --------------------------------------------------------------
    def update_del(self, dg: DistGraph, batch: UpdateBatch) -> DistGraph:
        blk = self.block

        def fn(dgl, b):
            g = _local(dgl)
            i = jax.lax.axis_index(self.axis)
            own = (b.del_src // blk) == i
            g2 = diffcsr.update_csr_del(g, b.del_src, b.del_dst,
                                        b.del_mask & own)
            return _restack(g2)

        return self._shmap(
            fn, in_specs=(self._gspec(), P()), out_specs=self._gspec()
        )(dg, batch)

    def update_add(self, dg: DistGraph, batch: UpdateBatch) -> DistGraph:
        blk = self.block

        def fn(dgl, b):
            g = _local(dgl)
            i = jax.lax.axis_index(self.axis)
            own = (b.add_src // blk) == i
            g2 = diffcsr.update_csr_add(g, b.add_src, b.add_dst, b.add_w,
                                        b.add_mask & own)
            return _restack(g2)

        return self._shmap(
            fn, in_specs=(self._gspec(), P()), out_specs=self._gspec()
        )(dg, batch)

    def batch_edge_flags(self, dg: DistGraph, qs, qd, mask) -> jax.Array:
        def fn(dgl):
            g = _local(dgl)
            return edge_lane_flags(g, qs, qd, mask)[None]
        return self._shmap(fn, in_specs=(self._gspec(),),
                           out_specs=P(self.axis))(dg)


class _DView:
    __slots__ = ("_p", "_i")

    def __init__(self, props, idx):
        self._p = props
        self._i = idx

    def __getitem__(self, k):
        return self._p[k][self._i]
