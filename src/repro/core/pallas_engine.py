"""PallasEngine — the CUDA backend analogue: hot loops on TPU kernels.

Mirrors the paper's CUDA generator split: control flow stays on the
"host" (XLA program), the per-edge relaxation loop is a generated kernel.
Sweeps that declare a ``gather_form`` lower onto the row-split-ELL Pallas
kernels in ``repro.kernels``; everything else falls back to the JnpEngine
lowering (the paper, likewise, only kernelizes the forall bodies).

Two kernel regimes, selected by the ``fused`` flag:

  * fused (default) — the repair step runs ONE launch per sweep
    (``kernels/pallas_repair.fused_relax_rows``: gather → relax →
    frontier-flag → in-kernel compaction), and ``update_add`` merges the
    batch into the diff pool with the merge-path kernel instead of the
    jnp scatter rounds.  Block sizes come from the (N, E_cap, K)-keyed
    autotuner, cached per handle shape.
  * chained (``fused=False``, registry name ``pallas_chained``) — the
    original per-op kernel chain (rowmin → hit → rowargmin), kept as
    the benchmark baseline for BENCH_pallas.json.

Both regimes are bit-exact against each other and the jnp lowering
(tests/test_kernels.py, tests/test_conformance.py).

The ELL pack is rebuilt once per update batch and *reused across all
fixed-point iterations* — the analogue of the paper's CUDA optimization
of keeping the graph resident on the GPU across kernel launches (§5.3).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.core.ir import EdgeSweep
from repro.core.engine import (JnpEngine, Collectives, Props, dyn_state,
                               dyn_from_state)
from repro.graph.csr import CSR, INT, INF_W
from repro.graph import diffcsr
from repro.graph.diffcsr import DynGraph
from repro.graph.updates import UpdateBatch
from repro.kernels.ell import (Ell, ell_apply_add, ell_apply_del,
                               ell_state, ell_from_state)
from repro.kernels.ell import pack_ell as _pack_ell_raw
pack_ell = jax.jit(_pack_ell_raw, static_argnums=(1, 2))
from repro.kernels import ops as kops
from repro.kernels import pallas_repair as FK
from repro.runtime import faults as _faults


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PallasHandle:
    g: DynGraph
    ell: Ell




@functools.lru_cache(maxsize=None)
def _fused_upd_add(interpret: bool, block: int):
    """Jitted update_csr_add with the merge-path pool kernel plugged in.

    Cached per (interpret, block) so every engine instance (and every
    trace inside a fused stream scan) binds the SAME jitted callable —
    jit's executable cache then keys only on the handle shapes."""
    merge = functools.partial(FK.merge_pool_sorted, block=block,
                              interpret=interpret)
    return jax.jit(functools.partial(diffcsr.update_csr_add,
                                     pool_merge=merge))


class PallasEngine(JnpEngine):
    name = "pallas"

    def __init__(self, k: int = 8, interpret: bool = True,
                 fused: bool = True, autotune: bool = False):
        super().__init__()
        self.k = k
        self.interpret = interpret
        self.fused = fused
        self.autotune = autotune     # measure candidates vs. heuristic
        # stable per-engine jitted repack: ell_apply_add's cond branch
        # then hits jit's cache instead of re-tracing the pack per call
        self._repack = jax.jit(functools.partial(_pack_ell_raw, k=k))

    def _config(self, g: DynGraph) -> FK.RepairConfig:
        return FK.repair_config(
            g.n, g.main_capacity + g.diff_capacity, self.k,
            measure=self.autotune, interpret=self.interpret)

    # -- construction / updates --------------------------------------------
    # The ELL pack stays device-resident across batches: tombstones and
    # revivals patch their slots in place via lane2slot; only structural
    # diff-pool appends (which shift diff lane positions) trigger a
    # repack — and even that decision is a traced lax.cond, so the whole
    # update path runs inside the streaming executor's fused scan.
    def prepare(self, csr: CSR, diff_capacity: int) -> PallasHandle:
        g = super().prepare(csr, diff_capacity)
        return PallasHandle(g=g, ell=pack_ell(g, self.k))

    def merge(self, h: PallasHandle) -> PallasHandle:
        g = diffcsr.merge(h.g)
        return PallasHandle(g=g, ell=pack_ell(g, self.k))

    def out_degrees(self, h: PallasHandle) -> jax.Array:
        return h.g.out_degrees()

    # -- durable state -----------------------------------------------------
    # The Ell pack is saved RAW (not rebuilt on restore): repacking would
    # reassign slots, and float32 segment sums over the lanes depend on
    # slot order — saving the pack is what makes resume bit-exact.
    state_kind = "pallas"

    def pack_state(self, h: PallasHandle):
        return ({"g": dyn_state(h.g), "ell": ell_state(h.ell)},
                {"kind": "pallas", "n": h.g.n, "k": self.k})

    def unpack_state(self, tree, meta) -> PallasHandle:
        if meta["k"] != self.k:
            raise ValueError(
                f"checkpoint was saved with k={meta['k']} lanes per row; "
                f"this engine has k={self.k} — bind the restoring engine "
                f"with the same k (or restore cross-backend)")
        self._n = meta["n"]
        return PallasHandle(g=dyn_from_state(tree["g"], meta["n"]),
                            ell=ell_from_state(tree["ell"], meta["n"]))

    def update_del(self, h: PallasHandle, batch: UpdateBatch) -> PallasHandle:
        g = super().update_del(h.g, batch)
        ell = ell_apply_del(h.ell, h.g, batch.del_src, batch.del_dst,
                            batch.del_mask)
        return PallasHandle(g=g, ell=ell)

    def update_add(self, h: PallasHandle, batch: UpdateBatch) -> PallasHandle:
        # both regimes launch kernels here (fused: the merge-path pool
        # fold; chained: the ELL pack update below), so the chaos seam
        # sits above the branch — ctx carries `fused` for targeting
        _faults.fire("kernel_launch", engine=self.name,
                     fused=self.fused, op="update_add")
        if self.fused:
            # one merge-path launch folds the admitted batch into the
            # sorted diff pool (replaces two binary-search sweeps + four
            # scatter rounds)
            cfg = self._config(h.g)
            g = _fused_upd_add(self.interpret, cfg.merge_block)(
                h.g, batch.add_src, batch.add_dst, batch.add_w,
                batch.add_mask)
        else:
            g = super().update_add(h.g, batch)
        # pull layout: slots hold SOURCES
        ell = ell_apply_add(h.ell, h.g, g, batch.add_src, batch.add_dst,
                            batch.add_w, batch.add_mask,
                            slot_value=batch.add_src,
                            repack=self._repack)
        return PallasHandle(g=g, ell=ell)

    def batch_edge_flags(self, h: PallasHandle, qs, qd, mask):
        return super().batch_edge_flags(h.g, qs, qd, mask)

    def count_wedges(self, h: PallasHandle, pair_fn, lane_flags, out_example,
                     bounds=None):
        return super().count_wedges(h.g, pair_fn, lane_flags, out_example,
                                    bounds=bounds)

    def vertex_map(self, h: PallasHandle, fn, props):
        return fn(props)

    # -- streaming executor hooks ------------------------------------------
    def handle_graph(self, h: PallasHandle) -> DynGraph:
        return h.g

    def grow(self, h: PallasHandle, factor: float = 2.0) -> PallasHandle:
        g = super().grow(h.g, factor)
        return PallasHandle(g=g, ell=pack_ell(g, self.k))

    def compact_handle(self, h: PallasHandle) -> PallasHandle:
        g = JnpEngine._compact(h.g)
        return PallasHandle(g=g, ell=pack_ell(g, self.k))

    # -- kernelized sweep ----------------------------------------------------
    def _kernel_compatible(self, sw: EdgeSweep) -> bool:
        if sw.gather_form is None:
            return False
        kinds = sorted(r.kind for r in sw.reduces.values())
        return kinds in (["min"], ["argmin", "min"], ["sum"])

    def _run_sweep(self, h, sw: EdgeSweep, props: Props) -> Props:
        if isinstance(h, DynGraph):  # fallback path re-entered with raw graph
            return super()._run_sweep(h, sw, props)
        if not self._kernel_compatible(sw):
            return super()._run_sweep(h.g, sw, props)
        _faults.fire("kernel_launch", engine=self.name, fused=self.fused,
                     op="sweep")
        if self.fused:
            return self._run_sweep_fused(h, sw, props)
        return self._run_sweep_chained(h, sw, props)

    def _run_sweep_fused(self, h: PallasHandle, sw: EdgeSweep,
                         props: Props) -> Props:
        """One fused launch per sweep: min/argmin/hit (or sum/hit) come
        out of a single kernel with in-kernel frontier compaction."""
        ell = h.ell
        cfg = self._config(h.g)
        reduced, hit, parents = {}, {}, {}
        for target, red in sw.reduces.items():
            if red.kind == "argmin":
                continue
            vec_fn, use_w = sw.gather_form[target]
            vec = vec_fn(props)
            ident = red.identity(vec.dtype)
            vals_n1 = jnp.concatenate([vec, jnp.full((1,), ident, vec.dtype)])
            if red.kind == "min":
                assert use_w
                vmin, parent, hv = kops.vertex_relax_fused(
                    ell, vals_n1, block=cfg.row_block,
                    interpret=self.interpret)
                reduced[target], hit[target] = vmin, hv
                parents[target] = parent
            else:  # sum
                vsum, hv = kops.vertex_spmv_fused(
                    ell, vals_n1, block=cfg.row_block,
                    interpret=self.interpret)
                reduced[target], hit[target] = vsum, hv
        for target, red in sw.reduces.items():
            if red.kind != "argmin":
                continue
            reduced[target] = parents[red.of]
            hit[target] = hit[red.of]
        return sw.post_fn(props, reduced, hit)

    def _run_sweep_chained(self, h: PallasHandle, sw: EdgeSweep,
                           props: Props) -> Props:
        """Per-op kernel chain (the pre-fusion lowering, benchmark
        baseline): rowmin → vertex combine → hit → rowargmin."""
        g, ell = h.g, h.ell
        n = self.n_pad
        reduced, hit = {}, {}
        # value reduce
        for target, red in sw.reduces.items():
            if red.kind == "argmin":
                continue
            vec_fn, use_w = sw.gather_form[target]
            vec = vec_fn(props)
            ident = red.identity(vec.dtype)
            vals_n1 = jnp.concatenate([vec, jnp.full((1,), ident, vec.dtype)])
            if red.kind == "min":
                assert use_w
                reduced[target] = kops.vertex_min_plus(
                    ell, vals_n1, interpret=self.interpret)
                hit[target] = reduced[target] < ident
            else:  # sum
                r = kops.vertex_spmv(ell, vals_n1, interpret=self.interpret)
                reduced[target] = r
                hit[target] = jax.ops.segment_max(
                    (ell.row2dst < n).astype(INT),
                    jnp.minimum(ell.row2dst, n), num_segments=n + 1
                )[:n].astype(jnp.bool_)
        # arg reduce
        for target, red in sw.reduces.items():
            if red.kind != "argmin":
                continue
            of = red.of
            vec_fn, _ = sw.gather_form[of]
            vec = vec_fn(props)
            vals_n1 = jnp.concatenate(
                [vec, jnp.full((1,), INF_W, vec.dtype)])
            reduced[target] = kops.vertex_argmin_src(
                ell, vals_n1, reduced[of], interpret=self.interpret)
            hit[target] = hit[of]
        return sw.post_fn(props, reduced, hit)


def PallasChainedEngine(**kw) -> PallasEngine:
    """Registry factory for the chained baseline (``pallas_chained``):
    the same engine with per-op kernel chains instead of fused launches
    — conformance keeps it honest, BENCH_pallas.json races it."""
    kw.setdefault("fused", False)
    return PallasEngine(**kw)
