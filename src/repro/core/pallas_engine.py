"""PallasEngine — the CUDA backend analogue: hot loops on TPU kernels.

Mirrors the paper's CUDA generator split: control flow stays on the
"host" (XLA program), the per-edge relaxation loop is a generated kernel.
Sweeps that declare a ``gather_form`` lower onto the row-split-ELL Pallas
kernels in ``repro.kernels``; everything else falls back to the JnpEngine
lowering (the paper, likewise, only kernelizes the forall bodies).

The ELL pack is rebuilt once per update batch and *reused across all
fixed-point iterations* — the analogue of the paper's CUDA optimization
of keeping the graph resident on the GPU across kernel launches (§5.3).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.core.ir import EdgeSweep
from repro.core.engine import JnpEngine, Collectives, Props
from repro.graph.csr import CSR, INT, INF_W
from repro.graph import diffcsr
from repro.graph.diffcsr import DynGraph
from repro.graph.updates import UpdateBatch
from repro.kernels.ell import (Ell, ell_apply_add, ell_apply_del)
from repro.kernels.ell import pack_ell as _pack_ell_raw
pack_ell = jax.jit(_pack_ell_raw, static_argnums=(1, 2))
from repro.kernels import ops as kops


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PallasHandle:
    g: DynGraph
    ell: Ell


class PallasEngine(JnpEngine):
    name = "pallas"

    def __init__(self, k: int = 8, interpret: bool = True):
        super().__init__()
        self.k = k
        self.interpret = interpret

    # -- construction / updates --------------------------------------------
    # The ELL pack stays device-resident across batches: tombstones and
    # revivals patch their slots in place via lane2slot; only structural
    # diff-pool appends (which shift diff lane positions) trigger a
    # repack — and even that decision is a traced lax.cond, so the whole
    # update path runs inside the streaming executor's fused scan.
    def prepare(self, csr: CSR, diff_capacity: int) -> PallasHandle:
        g = super().prepare(csr, diff_capacity)
        return PallasHandle(g=g, ell=pack_ell(g, self.k))

    def merge(self, h: PallasHandle) -> PallasHandle:
        g = diffcsr.merge(h.g)
        return PallasHandle(g=g, ell=pack_ell(g, self.k))

    def out_degrees(self, h: PallasHandle) -> jax.Array:
        return h.g.out_degrees()

    def update_del(self, h: PallasHandle, batch: UpdateBatch) -> PallasHandle:
        g = super().update_del(h.g, batch)
        ell = ell_apply_del(h.ell, h.g, batch.del_src, batch.del_dst,
                            batch.del_mask)
        return PallasHandle(g=g, ell=ell)

    def update_add(self, h: PallasHandle, batch: UpdateBatch) -> PallasHandle:
        g = super().update_add(h.g, batch)
        # pull layout: slots hold SOURCES
        ell = ell_apply_add(h.ell, h.g, g, batch.add_src, batch.add_dst,
                            batch.add_w, batch.add_mask,
                            slot_value=batch.add_src,
                            repack=lambda gg: _pack_ell_raw(gg, self.k))
        return PallasHandle(g=g, ell=ell)

    def batch_edge_flags(self, h: PallasHandle, qs, qd, mask):
        return super().batch_edge_flags(h.g, qs, qd, mask)

    def count_wedges(self, h: PallasHandle, pair_fn, lane_flags, out_example,
                     bounds=None):
        return super().count_wedges(h.g, pair_fn, lane_flags, out_example,
                                    bounds=bounds)

    def vertex_map(self, h: PallasHandle, fn, props):
        return fn(props)

    # -- streaming executor hooks ------------------------------------------
    def handle_graph(self, h: PallasHandle) -> DynGraph:
        return h.g

    def grow(self, h: PallasHandle, factor: float = 2.0) -> PallasHandle:
        g = super().grow(h.g, factor)
        return PallasHandle(g=g, ell=pack_ell(g, self.k))

    def compact_handle(self, h: PallasHandle) -> PallasHandle:
        g = JnpEngine._compact(h.g)
        return PallasHandle(g=g, ell=pack_ell(g, self.k))

    # -- kernelized sweep ----------------------------------------------------
    def _kernel_compatible(self, sw: EdgeSweep) -> bool:
        if sw.gather_form is None:
            return False
        kinds = sorted(r.kind for r in sw.reduces.values())
        return kinds in (["min"], ["argmin", "min"], ["sum"])

    def _run_sweep(self, h, sw: EdgeSweep, props: Props) -> Props:
        if isinstance(h, DynGraph):  # fallback path re-entered with raw graph
            return super()._run_sweep(h, sw, props)
        if not self._kernel_compatible(sw):
            return super()._run_sweep(h.g, sw, props)
        g, ell = h.g, h.ell
        n = self.n_pad
        reduced, hit = {}, {}
        # value reduce
        for target, red in sw.reduces.items():
            if red.kind == "argmin":
                continue
            vec_fn, use_w = sw.gather_form[target]
            vec = vec_fn(props)
            ident = red.identity(vec.dtype)
            vals_n1 = jnp.concatenate([vec, jnp.full((1,), ident, vec.dtype)])
            if red.kind == "min":
                assert use_w
                reduced[target] = kops.vertex_min_plus(
                    ell, vals_n1, interpret=self.interpret)
                hit[target] = reduced[target] < ident
            else:  # sum
                r = kops.vertex_spmv(ell, vals_n1, interpret=self.interpret)
                reduced[target] = r
                hit[target] = jax.ops.segment_max(
                    (ell.row2dst < n).astype(INT),
                    jnp.minimum(ell.row2dst, n), num_segments=n + 1
                )[:n].astype(jnp.bool_)
        # arg reduce
        for target, red in sw.reduces.items():
            if red.kind != "argmin":
                continue
            of = red.of
            vec_fn, _ = sw.gather_form[of]
            vec = vec_fn(props)
            vals_n1 = jnp.concatenate(
                [vec, jnp.full((1,), INF_W, vec.dtype)])
            reduced[target] = kops.vertex_argmin_src(
                ell, vals_n1, reduced[of], interpret=self.interpret)
            hit[target] = hit[of]
        return sw.post_fn(props, reduced, hit)
