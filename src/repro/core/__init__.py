"""repro.core — the paper's compiler + backends.

The DSL pipeline lives in ``repro.core.dsl`` (lexer → parser → analysis
→ codegen), the execution engines in ``repro.core.engine`` /
``dist`` / ``pallas_engine`` / ``frontier_engine``, and the string-keyed
backend registry in ``repro.core.registry``.

Public names re-export lazily (PEP 562) to keep imports cheap and
cycle-free — ``DistEngine``'s shard_map machinery, for instance, only
loads on first touch.
"""

__all__ = [
    "Engine", "JnpEngine", "DistEngine", "PallasEngine", "FrontierEngine",
    "Program", "compile_source", "register_engine", "make_engine",
    "engine_factory", "available_backends", "UnknownBackendError",
    "DuplicateBackendError", "registry",
]

_LAZY = {
    "Engine": ("repro.core.engine", "Engine"),
    "JnpEngine": ("repro.core.engine", "JnpEngine"),
    "DistEngine": ("repro.core.dist", "DistEngine"),
    "PallasEngine": ("repro.core.pallas_engine", "PallasEngine"),
    "FrontierEngine": ("repro.core.frontier_engine", "FrontierEngine"),
    "Program": ("repro.core.dsl.codegen", "Program"),
    "compile_source": ("repro.core.dsl.codegen", "compile_source"),
    "register_engine": ("repro.core.registry", "register_engine"),
    "make_engine": ("repro.core.registry", "make_engine"),
    "engine_factory": ("repro.core.registry", "engine_factory"),
    "available_backends": ("repro.core.registry", "available_backends"),
    "UnknownBackendError": ("repro.core.registry", "UnknownBackendError"),
    "DuplicateBackendError": ("repro.core.registry",
                              "DuplicateBackendError"),
}


def __getattr__(name):
    if name == "registry":
        import repro.core.registry as registry
        return registry
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(
            f"module 'repro.core' has no attribute {name!r}") from None
    import importlib
    return getattr(importlib.import_module(mod_name), attr)


def __dir__():
    return sorted(__all__)
