"""Execution engines: one algorithm spec → three backends.

This is the paper's code-generator layer.  The table in DESIGN.md §2 maps
StarPlat's OpenMP / MPI / CUDA generators to:

  * :class:`JnpEngine`   — single-device XLA (OpenMP analogue),
  * ``DistEngine``       — shard_map + collectives (MPI analogue,
                           see core/dist.py),
  * ``PallasEngine``     — hand-tiled TPU kernels for the hot loops
                           (CUDA analogue, see core/pallas_engine.py).

All three consume the same :class:`repro.core.ir.EdgeSweep` programs; the
algorithms in ``repro.algos`` never mention a backend.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.ir import EdgeSweep, Reduce
from repro.graph.csr import CSR, INT, INF_W
from repro.graph import diffcsr
from repro.graph.diffcsr import DynGraph, BOOL
from repro.graph.updates import UpdateBatch

Props = Dict[str, jax.Array]


class Collectives:
    """Global-reduction helpers handed to fixed-point conditions.

    On the single-device backend these are plain jnp reductions; the
    distributed backend overrides them with psum/pmax over the mesh so the
    *same algorithm text* stays correct — the paper's 'same DSL, different
    synchronization per backend' point, in miniature.
    """

    def any(self, x):
        return jnp.any(x)

    def sum(self, x):
        return jnp.sum(x)

    def max(self, x):
        return jnp.max(x)


def edge_lane_flags(g: DynGraph, qs, qd, mask=None) -> jax.Array:
    """Boolean flags over the (E+D,) edge lanes for a batch of edges —
    the propEdge<bool> ``modified`` marking used by OnAdd/OnDelete."""
    qs = jnp.asarray(qs, INT)
    qd = jnp.asarray(qd, INT)
    if mask is None:
        mask = jnp.ones(qs.shape, BOOL)
    E, D = g.main_capacity, g.diff_capacity
    p1, f1 = diffcsr._locate_main(g, qs, qd)
    p2, f2 = diffcsr._locate_diff(g, qs, qd)
    flags = jnp.zeros((E + D,), BOOL)
    flags = flags.at[jnp.where(f1 & mask, p1, E + D)].set(True, mode="drop")
    flags = flags.at[jnp.where(f2 & mask & ~f1, E + p2, E + D)].set(
        True, mode="drop")
    return flags


class WedgeCtx:
    """Per-iteration context handed to wedge pair functions (TC)."""

    def __init__(self, g: DynGraph, lane_flags: Dict[str, jax.Array],
                 nbr_lane: jax.Array, is_edge_fn, edge_flag_fn):
        self.g = g
        self._lane_flags = lane_flags
        self._nbr_lane = nbr_lane
        self.is_edge = is_edge_fn          # (qs, qd) -> bool lanes
        self.edge_flag = edge_flag_fn      # (name, qs, qd) -> bool lanes

    def nbr_flag(self, name: str) -> jax.Array:
        fl = self._lane_flags[name]
        return fl[jnp.clip(self._nbr_lane, 0, fl.shape[0] - 1)]

    def lane_flag(self, name: str) -> jax.Array:
        return self._lane_flags[name]


class Engine:
    """Backend-neutral interface (the 'generated program' surface)."""

    name = "base"

    # -- construction ------------------------------------------------------
    def prepare(self, csr: CSR, diff_capacity: int) -> Any:
        raise NotImplementedError

    def merge(self, handle) -> Any:
        raise NotImplementedError

    @property
    def n_pad(self) -> int:
        raise NotImplementedError

    @property
    def n_real(self) -> int:
        return self._n

    def out_degrees(self, handle) -> jax.Array:
        raise NotImplementedError

    def full(self, value, dtype) -> jax.Array:
        """Allocate a vertex property (paper: attachNodeProperty)."""
        return jnp.full((self.n_pad,), value, dtype=dtype)

    def read_props(self, props: Props) -> Dict[str, np.ndarray]:
        return {k: np.asarray(v)[: self._n] for k, v in props.items()}

    # -- aggregate ops -----------------------------------------------------
    def sweep(self, handle, sw: EdgeSweep, props: Props) -> Props:
        raise NotImplementedError

    def fixed_point(self, handle, sw: EdgeSweep, props: Props,
                    cond_fn: Callable, max_iter: int) -> Props:
        raise NotImplementedError

    def vertex_map(self, handle, fn: Callable, props: Props) -> Props:
        raise NotImplementedError

    def count_wedges(self, handle, pair_fn: Callable,
                     lane_flags: Dict[str, jax.Array], out_example) -> Any:
        raise NotImplementedError

    # -- dynamic updates ---------------------------------------------------
    def update_del(self, handle, batch: UpdateBatch):
        raise NotImplementedError

    def update_add(self, handle, batch: UpdateBatch):
        raise NotImplementedError

    def batch_edge_flags(self, handle, qs, qd, mask) -> jax.Array:
        raise NotImplementedError

    # -- library routines shared by all backends ---------------------------
    def propagate_flags(self, handle, props: Props, flag: str,
                        max_iter: int = 1_000_000) -> Props:
        """paper: g.propagateNodeFlags — BFS-spread a boolean property to
        everything reachable from the flagged set."""
        sw = EdgeSweep(
            edge_fn=lambda s, d, w: {flag: (s[flag], s[flag])},
            reduces={flag: Reduce("or")},
            post_fn=lambda p, red, hit: {
                **p,
                flag: p[flag] | red[flag],
                "_changed": red[flag] & ~p[flag],
            },
        )
        props = dict(props)
        props["_changed"] = props[flag]
        props = self.fixed_point(
            handle, sw, props,
            cond_fn=lambda p, it, col: col.any(p["_changed"]),
            max_iter=max_iter)
        props.pop("_changed")
        return props


# ===========================================================================
# JnpEngine — single-device XLA (the OpenMP analogue)
# ===========================================================================

class JnpEngine(Engine):
    name = "jnp"

    def __init__(self):
        self._n = None

    # -- construction ------------------------------------------------------
    def prepare(self, csr: CSR, diff_capacity: int) -> DynGraph:
        self._n = csr.n
        return diffcsr.from_csr(csr, diff_capacity)

    def merge(self, g: DynGraph) -> DynGraph:
        return diffcsr.merge(g)

    @property
    def n_pad(self) -> int:
        return self._n

    def out_degrees(self, g: DynGraph) -> jax.Array:
        return g.out_degrees()

    # -- core sweep --------------------------------------------------------
    def _run_sweep(self, g: DynGraph, sw: EdgeSweep, props: Props) -> Props:
        esrc, edst, ew, ealive = g.edge_arrays()
        n = self.n_pad
        sview = {k: v for k, v in props.items()}
        s = _View(sview, esrc)
        d = _View(sview, edst)
        out = sw.edge_fn(s, d, ew)
        reduced, hit = {}, {}
        # value reductions first, arg-reductions second (two-pass argmin).
        for target, red in sw.reduces.items():
            if red.kind == "argmin":
                continue
            val, elig = out[target]
            elig = elig & ealive
            ident = red.identity(val.dtype)
            v = jnp.where(elig, val, ident)
            reduced[target] = red.segment(v, edst, n)
            hit[target] = jax.ops.segment_max(
                elig.astype(INT), edst, num_segments=n) > 0
        for target, red in sw.reduces.items():
            if red.kind != "argmin":
                continue
            of = red.of
            val, elig = out[of]
            elig = elig & ealive
            achieved = elig & (val == reduced[of][edst])
            v = jnp.where(achieved, esrc, jnp.asarray(n, INT))
            reduced[target] = jax.ops.segment_min(v, edst, num_segments=n)
            hit[target] = hit[of]
        return sw.post_fn(props, reduced, hit)

    def sweep(self, g: DynGraph, sw: EdgeSweep, props: Props) -> Props:
        return self._run_sweep(g, sw, props)

    def fixed_point(self, g: DynGraph, sw: EdgeSweep, props: Props,
                    cond_fn: Callable, max_iter: int) -> Props:
        col = Collectives()

        def cond(state):
            it, p = state
            return (it < max_iter) & cond_fn(p, it, col)

        def body(state):
            it, p = state
            return it + 1, self._run_sweep(g, sw, p)

        _, props = jax.lax.while_loop(cond, body, (jnp.zeros((), INT), props))
        return props

    def vertex_map(self, g: DynGraph, fn: Callable, props: Props) -> Props:
        return fn(props)

    # -- wedges (triangle counting) ----------------------------------------
    def count_wedges(self, g: DynGraph, pair_fn: Callable,
                     lane_flags: Dict[str, jax.Array], out_example):
        esrc, edst, ew, ealive = g.edge_arrays()
        E, D = g.main_capacity, g.diff_capacity
        deg_main = np.asarray(g.offsets[1:] - g.offsets[:-1])
        deg_diff = np.asarray(g.d_offsets[1:] - g.d_offsets[:-1])
        max_main = int(deg_main.max()) if deg_main.size else 0
        max_diff = int(deg_diff.max()) if deg_diff.size else 0

        def is_edge_fn(qs, qd):
            return diffcsr.is_edge(g, qs, qd)

        def edge_flag_fn(name, qs, qd):
            fl = lane_flags[name]
            p1, f1 = diffcsr._locate_main(g, qs, qd)
            p2, f2 = diffcsr._locate_diff(g, qs, qd)
            r = jnp.zeros(qs.shape, BOOL)
            r = jnp.where(f1 & g.alive[p1], fl[jnp.clip(p1, 0, E + D - 1)], r)
            r = jnp.where(f2 & g.d_alive[p2] & ~f1,
                          fl[jnp.clip(E + p2, 0, E + D - 1)], r)
            return r

        zero = jax.tree_util.tree_map(
            lambda x: jnp.zeros((), jnp.asarray(x).dtype), out_example)

        def accumulate(total, j, region):
            if region == "main":
                pos = g.offsets[esrc] + j
                ok = (pos < g.offsets[esrc + 1])
                safe = jnp.clip(pos, 0, max(E - 1, 0))
                z = g.dst[safe]
                z_ok = ok & g.alive[safe]
                nbr_lane = safe
            else:
                pos = g.d_offsets[esrc] + j
                ok = (pos < g.d_offsets[esrc + 1])
                safe = jnp.clip(pos, 0, max(D - 1, 0))
                z = g.d_dst[safe]
                z_ok = ok & g.d_alive[safe]
                nbr_lane = E + safe
            ctx = WedgeCtx(g, lane_flags, nbr_lane, is_edge_fn, edge_flag_fn)
            contrib = pair_fn(esrc, edst, z, z_ok & ealive, ctx)
            return jax.tree_util.tree_map(
                lambda t, c: t + jnp.sum(c), total, contrib)

        def scan_region(total, count, region):
            if count == 0:
                return total
            def body(j, tot):
                return accumulate(tot, j, region)
            return jax.lax.fori_loop(0, count, body, total)

        total = scan_region(zero, max_main, "main")
        if D:
            total = scan_region(total, max_diff, "diff")
        return total

    # -- updates (jitted: the scatter programs re-trace cheaply and the
    # compiled executables cache on the static (E, D, B) shapes) ----------
    _upd_del = staticmethod(jax.jit(diffcsr.update_csr_del))
    _upd_add = staticmethod(jax.jit(diffcsr.update_csr_add))

    def update_del(self, g: DynGraph, batch: UpdateBatch) -> DynGraph:
        return JnpEngine._upd_del(g, batch.del_src, batch.del_dst,
                                  batch.del_mask)

    def update_add(self, g: DynGraph, batch: UpdateBatch) -> DynGraph:
        return JnpEngine._upd_add(g, batch.add_src, batch.add_dst,
                                  batch.add_w, batch.add_mask)

    def batch_edge_flags(self, g: DynGraph, qs, qd, mask) -> jax.Array:
        return edge_lane_flags(g, qs, qd, mask)

    def src_flags_from_dst(self, g: DynGraph, dst_mask) -> jax.Array:
        """Mark sources having an alive out-edge into the flagged dst set
        (the push-repair boundary; engines without it fall back to a
        dense seed)."""
        esrc, edst, ew, ealive = g.edge_arrays()
        n = self.n_pad
        hit = ealive & (edst < n) & dst_mask[jnp.clip(edst, 0, n - 1)]
        return jnp.zeros((n,), BOOL).at[
            jnp.where(hit, esrc, n)].set(True, mode="drop")


class _View:
    """Gathered endpoint view (no read-logging on the hot path)."""

    __slots__ = ("_p", "_i")

    def __init__(self, props, idx):
        self._p = props
        self._i = idx

    def __getitem__(self, k):
        return self._p[k][self._i]
