"""Execution engines: one algorithm spec → three backends.

This is the paper's code-generator layer.  The table in DESIGN.md §2 maps
StarPlat's OpenMP / MPI / CUDA generators to:

  * :class:`JnpEngine`   — single-device XLA (OpenMP analogue),
  * ``DistEngine``       — shard_map + collectives (MPI analogue,
                           see core/dist.py),
  * ``PallasEngine``     — hand-tiled TPU kernels for the hot loops
                           (CUDA analogue, see core/pallas_engine.py).

All three consume the same :class:`repro.core.ir.EdgeSweep` programs; the
algorithms in ``repro.algos`` never mention a backend.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.ir import EdgeSweep, Reduce
from repro.graph.csr import CSR, INT, INF_W, build_csr
from repro.graph import diffcsr
from repro.graph.diffcsr import DynGraph, BOOL
from repro.graph.updates import UpdateBatch
from repro.runtime import faults as _faults

Props = Dict[str, jax.Array]

# Guards every engine's per-instance ``_stream_cache`` (compiled stream
# executables): a session pool applies batches from worker threads, and
# an unguarded dict get/compile/set races into duplicate compilations —
# or, interleaved with ``grow``'s eviction sweep, a RuntimeError from
# mutating the dict mid-iteration.  One process-wide lock (not
# per-instance) keeps lazy lock creation itself race-free; the critical
# sections are dict ops only, so contention is negligible.
_STREAM_CACHE_LOCK = threading.Lock()


class Collectives:
    """Global-reduction helpers handed to fixed-point conditions.

    On the single-device backend these are plain jnp reductions; the
    distributed backend overrides them with psum/pmax over the mesh so the
    *same algorithm text* stays correct — the paper's 'same DSL, different
    synchronization per backend' point, in miniature.
    """

    def any(self, x):
        return jnp.any(x)

    def sum(self, x):
        return jnp.sum(x)

    def max(self, x):
        return jnp.max(x)


def edge_lane_flags(g: DynGraph, qs, qd, mask=None) -> jax.Array:
    """Boolean flags over the (E+D,) edge lanes for a batch of edges —
    the propEdge<bool> ``modified`` marking used by OnAdd/OnDelete."""
    qs = jnp.asarray(qs, INT)
    qd = jnp.asarray(qd, INT)
    if mask is None:
        mask = jnp.ones(qs.shape, BOOL)
    E, D = g.main_capacity, g.diff_capacity
    p1, f1 = diffcsr._locate_main(g, qs, qd)
    p2, f2 = diffcsr._locate_diff(g, qs, qd)
    flags = jnp.zeros((E + D,), BOOL)
    flags = flags.at[jnp.where(f1 & mask, p1, E + D)].set(True, mode="drop")
    flags = flags.at[jnp.where(f2 & mask & ~f1, E + p2, E + D)].set(
        True, mode="drop")
    return flags


class _StreamView:
    """Engine facade handed to stream steps inside ``run_stream``.

    Semantics are identical to the wrapped engine; the only difference is
    that ``count_wedges`` runs with host-precomputed static degree bounds
    (``bounds``) so wedge enumeration never syncs to host mid-scan.
    Engines whose interactive paths are host-driven (FrontierEngine's
    direction optimization) subclass this to swap in their jit-safe
    lowering."""

    def __init__(self, engine: "Engine", bounds=None):
        self._engine = engine
        self._bounds = bounds

    def __getattr__(self, name):
        return getattr(self._engine, name)

    def count_wedges(self, handle, pair_fn, lane_flags, out_example,
                     bounds=None):
        return self._engine.count_wedges(
            handle, pair_fn, lane_flags, out_example,
            bounds=bounds if bounds is not None else self._bounds)


class WedgeCtx:
    """Per-iteration context handed to wedge pair functions (TC)."""

    def __init__(self, g: DynGraph, lane_flags: Dict[str, jax.Array],
                 nbr_lane: jax.Array, is_edge_fn, edge_flag_fn):
        self.g = g
        self._lane_flags = lane_flags
        self._nbr_lane = nbr_lane
        self.is_edge = is_edge_fn          # (qs, qd) -> bool lanes
        self.edge_flag = edge_flag_fn      # (name, qs, qd) -> bool lanes

    def nbr_flag(self, name: str) -> jax.Array:
        fl = self._lane_flags[name]
        return fl[jnp.clip(self._nbr_lane, 0, fl.shape[0] - 1)]

    def lane_flag(self, name: str) -> jax.Array:
        return self._lane_flags[name]


class Engine:
    """Backend-neutral interface (the 'generated program' surface)."""

    name = "base"

    # -- construction ------------------------------------------------------
    def prepare(self, csr: CSR, diff_capacity: int) -> Any:
        raise NotImplementedError

    def merge(self, handle) -> Any:
        raise NotImplementedError

    @property
    def n_pad(self) -> int:
        raise NotImplementedError

    @property
    def n_real(self) -> int:
        return self._n

    def out_degrees(self, handle) -> jax.Array:
        raise NotImplementedError

    def full(self, value, dtype) -> jax.Array:
        """Allocate a vertex property (paper: attachNodeProperty)."""
        return jnp.full((self.n_pad,), value, dtype=dtype)

    def read_props(self, props: Props) -> Dict[str, np.ndarray]:
        return {k: np.asarray(v)[: self._n] for k, v in props.items()}

    # -- aggregate ops -----------------------------------------------------
    def sweep(self, handle, sw: EdgeSweep, props: Props) -> Props:
        raise NotImplementedError

    def fixed_point(self, handle, sw: EdgeSweep, props: Props,
                    cond_fn: Callable, max_iter: int) -> Props:
        raise NotImplementedError

    def vertex_map(self, handle, fn: Callable, props: Props) -> Props:
        raise NotImplementedError

    def count_wedges(self, handle, pair_fn: Callable,
                     lane_flags: Dict[str, jax.Array], out_example,
                     bounds=None) -> Any:
        raise NotImplementedError

    # -- dynamic updates ---------------------------------------------------
    def update_del(self, handle, batch: UpdateBatch):
        raise NotImplementedError

    def update_add(self, handle, batch: UpdateBatch):
        raise NotImplementedError

    def batch_edge_flags(self, handle, qs, qd, mask) -> jax.Array:
        raise NotImplementedError

    # -- streaming executor (DESIGN.md §3) ---------------------------------
    # A *stream step* is the engine-neutral per-batch body
    #     step_fn(engine, handle, batch, carry) -> (handle, carry)
    # (update → affected-seed → incremental repair).  ``run_stream`` drives
    # a whole padded batch stream through it; engines with a fused path
    # override it with one jitted lax.scan per stream segment, checking
    # the diff-pool counters once per segment instead of once per batch.

    def handle_graph(self, handle) -> DynGraph:
        """The DynGraph inside an engine handle (identity for raw graphs)."""
        return handle

    def handle_counters(self, handle) -> jax.Array:
        """(overflow, used, dead) pool counters, on device."""
        return diffcsr.pool_counters(self.handle_graph(handle))

    def grow(self, handle, factor: float = 2.0):
        """Host-side merge with grown diff capacity — the one remaining
        numpy exit, reserved for true pool overflow."""
        raise NotImplementedError

    def compact_handle(self, handle):
        """Device-side reclamation of tombstoned diff slots."""
        raise NotImplementedError

    def stream_view(self, bounds=None) -> "Engine":
        """The engine facade handed to stream steps (see _StreamView)."""
        return _StreamView(self, bounds)

    # -- durable state (DESIGN.md §5: session durability contract) ---------
    # Every engine exposes its resident handle as a (nested-dict array
    # tree, JSON-able meta) pair.  ``state_kind`` names the tree layout;
    # a same-kind ``unpack_state`` is *bit-exact* (raw leaves restored,
    # pool layout preserved), while a cross-kind restore goes through the
    # module-level ``state_to_csr`` + ``prepare`` (value-preserving, pool
    # layout reset).

    state_kind = "none"

    def pack_state(self, handle) -> Tuple[Dict[str, Any], dict]:
        """Flattenable snapshot of the resident graph handle."""
        raise NotImplementedError

    def unpack_state(self, tree: Dict[str, Any], meta: dict):
        """Rebuild a handle from ``pack_state`` output on THIS engine;
        must also restore the engine's host-side shape state (_n)."""
        raise NotImplementedError

    def put_vertex_array(self, arr) -> jax.Array:
        """Place a restored (n_pad,) vertex property the way this
        engine's lowerings expect it (dist: sharded over the mesh)."""
        return jnp.asarray(arr)

    def static_wedge_bounds(self, handle):
        """Host-static (max_main_deg, max_diff_deg) loop bounds usable
        inside a jitted stream segment.  The main region's offsets only
        change at merge/grow (segment boundaries), so its true max degree
        is static within a segment; the diff region is bounded by its
        capacity."""
        g = self.handle_graph(handle)
        deg = np.asarray(g.offsets[1:] - g.offsets[:-1])
        max_main = int(deg.max()) if deg.size else 0
        return max_main, g.diff_capacity

    def _diff_capacity(self, handle) -> int:
        return self.handle_graph(handle).diff_capacity

    def _handle_shape_key(self, handle) -> tuple:
        """The handle's static capacities (E_cap, D_cap) — the part of a
        compiled stream executable's identity that ``grow`` invalidates."""
        g = self.handle_graph(handle)
        return (g.main_capacity, g.diff_capacity)

    def _evict_stream_cache(self, shape_key: tuple) -> None:
        """Drop compiled stream executables specialized on ``shape_key``.
        Called by ``grow``: the old-capacity executables can never run
        again, so keeping them leaks one per capacity step.  Cache keys
        embed the shape key as a top-level tuple element."""
        cache = getattr(self, "_stream_cache", None)
        if cache:
            with _STREAM_CACHE_LOCK:
                for k in [k for k in cache if shape_key in k]:
                    cache.pop(k, None)

    def _segment_runner(self, step_fn, handle, batch_size: int):
        """Compiled ``(handle, carry, stacked_batches) -> (handle, carry,
        (overflow, used, dead))`` for one fused stream segment."""
        raise NotImplementedError

    def _run_stream_fused(self, handle, stream, batch_size: int, step_fn,
                          carry, segment_size: int, compact_frac: float):
        """Shared fused-stream driver: cut the stream into segments of
        padded batches, run each through ``_segment_runner`` (one
        compiled scan — no host round-trips between batches), and once
        per segment read back the pool counters: overflow rolls the
        segment back, grows capacity host-side (the one numpy exit) and
        replays; heavy tombstoning triggers the on-device compact."""
        nb = stream.num_batches(batch_size)
        if nb == 0:
            return handle, carry
        seg = max(1, min(segment_size or nb, nb))
        of0 = int(np.asarray(self.handle_counters(handle)[0]))
        i = 0
        while i < nb:
            k = min(seg, nb - i)
            # stack the segment ONCE; grow-and-replay retries reuse it
            # (the batch content is capacity-independent)
            stacked = stream.stacked(batch_size, i, k)
            while True:
                snap = (handle, carry)
                _faults.fire("segment_scan", engine=self.name,
                             start=i, count=k)
                run = self._segment_runner(step_fn, handle, batch_size)
                handle, carry, counters = run(handle, carry, stacked)
                of, _used, dead = (int(x) for x in np.asarray(counters))
                if of > of0:
                    # adds were dropped inside the segment: roll back,
                    # grow the pool, replay on the larger shapes.
                    handle, carry = self.grow(snap[0]), snap[1]
                    of0 = 0
                    continue
                break
            of0 = of
            if dead > compact_frac * max(self._diff_capacity(handle), 1):
                handle = self.compact_handle(handle)
            i += k
        return handle, carry

    def run_stream(self, handle, stream, batch_size: int, step_fn,
                   carry, segment_size: int = 8, compact_frac: float = 0.5):
        """Baseline per-batch dispatch: one device round-trip per batch
        (``segment_size`` has no effect — every batch is its own
        segment).  Fused engines override this."""
        view = self.stream_view()
        of0 = int(np.asarray(self.handle_counters(handle)[0]))
        for i in range(stream.num_batches(batch_size)):
            batch = stream.batch(i, batch_size)
            snap = (handle, carry)
            _faults.fire("segment_scan", engine=self.name, start=i, count=1)
            handle, carry = step_fn(view, handle, batch, carry)
            # ONE counter sync per batch (and per replay): read the
            # (overflow, used, dead) triple once, branch on the host copy.
            of, _used, dead = (int(x) for x in
                               np.asarray(self.handle_counters(handle)))
            while of > of0:
                # adds were dropped: roll back, grow capacity, replay.
                handle, carry = self.grow(snap[0]), snap[1]
                of0 = 0
                snap = (handle, carry)
                handle, carry = step_fn(view, handle, batch, carry)
                of, _used, dead = (int(x) for x in
                                   np.asarray(self.handle_counters(handle)))
            of0 = of
            if dead > compact_frac * max(self._diff_capacity(handle), 1):
                handle = self.compact_handle(handle)
        return handle, carry

    # -- library routines shared by all backends ---------------------------
    def propagate_flags(self, handle, props: Props, flag: str,
                        max_iter: int = 1_000_000) -> Props:
        """paper: g.propagateNodeFlags — BFS-spread a boolean property to
        everything reachable from the flagged set."""
        sw = EdgeSweep(
            edge_fn=lambda s, d, w: {flag: (s[flag], s[flag])},
            reduces={flag: Reduce("or")},
            post_fn=lambda p, red, hit: {
                **p,
                flag: p[flag] | red[flag],
                "_changed": red[flag] & ~p[flag],
            },
        )
        props = dict(props)
        props["_changed"] = props[flag]
        props = self.fixed_point(
            handle, sw, props,
            cond_fn=lambda p, it, col: col.any(p["_changed"]),
            max_iter=max_iter)
        props.pop("_changed")
        return props


# ---------------------------------------------------------------------------
# Durable-state helpers shared by every backend
# ---------------------------------------------------------------------------

_DYN_FIELDS = tuple(f.name for f in dataclasses.fields(DynGraph)
                    if f.name != "n")


def dyn_state(g: DynGraph) -> Dict[str, jax.Array]:
    """A DynGraph's array leaves as a flat dict (the 'dyn' tree layout)."""
    return {f: getattr(g, f) for f in _DYN_FIELDS}


def dyn_from_state(tree: Dict[str, Any], n: int) -> DynGraph:
    return DynGraph(**{f: jnp.asarray(tree[f]) for f in _DYN_FIELDS}, n=n)


def state_to_csr(tree: Dict[str, Any], meta: dict) -> Tuple[CSR, int]:
    """Collapse ANY engine's packed state to ``(CSR, diff_capacity)`` —
    the cross-backend restore path.  Value-preserving (the alive edge
    set survives exactly) but pool-layout-resetting: the target engine
    re-``prepare``s, so float summation order may differ from the saved
    run (DESIGN.md §5)."""
    kind, n = meta["kind"], meta["n"]
    if kind == "dist":
        src = np.asarray(tree["src"])
        dst = np.asarray(tree["dst"])
        w = np.asarray(tree["w"])
        cap = int(meta["diff_capacity"])
    elif kind in ("dyn", "pallas", "frontier"):
        g = dyn_from_state(tree if kind == "dyn" else tree["g"], n)
        es, ed, ew, ea = (np.asarray(x) for x in g.edge_arrays())
        keep = ea
        src, dst, w = es[keep], ed[keep], ew[keep]
        cap = g.diff_capacity
    else:
        raise ValueError(f"unknown packed-state kind {kind!r}")
    edges = np.stack([src, dst], axis=1) if len(src) else \
        np.zeros((0, 2), np.int64)
    return build_csr(n, edges, w), max(cap, 1)


# ===========================================================================
# JnpEngine — single-device XLA (the OpenMP analogue)
# ===========================================================================

class JnpEngine(Engine):
    name = "jnp"

    def __init__(self):
        self._n = None
        # (array, value) pairs keyed by offsets-array identity: updates
        # replace d_offsets (cache invalidates itself), deletions and
        # repeated wedge calls on one handle reuse the cached bound —
        # no per-call host sync in count_wedges.
        self._deg_cache: Dict[str, tuple] = {}
        self._stream_cache: Dict[Any, Callable] = {}

    # -- construction ------------------------------------------------------
    def prepare(self, csr: CSR, diff_capacity: int) -> DynGraph:
        self._n = csr.n
        return diffcsr.from_csr(csr, diff_capacity)

    def merge(self, g: DynGraph) -> DynGraph:
        return diffcsr.merge(g)

    @property
    def n_pad(self) -> int:
        return self._n

    def out_degrees(self, g: DynGraph) -> jax.Array:
        return g.out_degrees()

    # -- durable state -----------------------------------------------------
    state_kind = "dyn"

    def pack_state(self, g: DynGraph):
        return dyn_state(g), {"kind": "dyn", "n": g.n}

    def unpack_state(self, tree, meta) -> DynGraph:
        self._n = meta["n"]
        return dyn_from_state(tree, meta["n"])

    # -- core sweep --------------------------------------------------------
    def _run_sweep(self, g: DynGraph, sw: EdgeSweep, props: Props) -> Props:
        esrc, edst, ew, ealive = g.edge_arrays()
        n = self.n_pad
        sview = {k: v for k, v in props.items()}
        s = _View(sview, esrc)
        d = _View(sview, edst)
        out = sw.edge_fn(s, d, ew)
        reduced, hit = {}, {}
        # value reductions first, arg-reductions second (two-pass argmin).
        for target, red in sw.reduces.items():
            if red.kind == "argmin":
                continue
            val, elig = out[target]
            elig = elig & ealive
            ident = red.identity(val.dtype)
            v = jnp.where(elig, val, ident)
            reduced[target] = red.segment(v, edst, n)
            hit[target] = jax.ops.segment_max(
                elig.astype(INT), edst, num_segments=n) > 0
        for target, red in sw.reduces.items():
            if red.kind != "argmin":
                continue
            of = red.of
            val, elig = out[of]
            elig = elig & ealive
            achieved = elig & (val == reduced[of][edst])
            v = jnp.where(achieved, esrc, jnp.asarray(n, INT))
            reduced[target] = jax.ops.segment_min(v, edst, num_segments=n)
            hit[target] = hit[of]
        return sw.post_fn(props, reduced, hit)

    def sweep(self, g: DynGraph, sw: EdgeSweep, props: Props) -> Props:
        return self._run_sweep(g, sw, props)

    def fixed_point(self, g: DynGraph, sw: EdgeSweep, props: Props,
                    cond_fn: Callable, max_iter: int) -> Props:
        col = Collectives()

        def cond(state):
            it, p = state
            return (it < max_iter) & cond_fn(p, it, col)

        def body(state):
            it, p = state
            return it + 1, self._run_sweep(g, sw, p)

        _, props = jax.lax.while_loop(cond, body, (jnp.zeros((), INT), props))
        return props

    def vertex_map(self, g: DynGraph, fn: Callable, props: Props) -> Props:
        return fn(props)

    def _max_deg(self, region: str, offsets: jax.Array) -> int:
        cached = self._deg_cache.get(region)
        if cached is None or cached[0] is not offsets:
            deg = np.asarray(offsets[1:] - offsets[:-1])
            cached = (offsets, int(deg.max()) if deg.size else 0)
            self._deg_cache[region] = cached
        return cached[1]

    # -- wedges (triangle counting) ----------------------------------------
    def count_wedges(self, g: DynGraph, pair_fn: Callable,
                     lane_flags: Dict[str, jax.Array], out_example,
                     bounds=None):
        esrc, edst, ew, ealive = g.edge_arrays()
        E, D = g.main_capacity, g.diff_capacity
        if bounds is not None:
            max_main, max_diff = bounds
        else:
            max_main = self._max_deg("main", g.offsets)
            max_diff = self._max_deg("diff", g.d_offsets)

        def is_edge_fn(qs, qd):
            return diffcsr.is_edge(g, qs, qd)

        def edge_flag_fn(name, qs, qd):
            fl = lane_flags[name]
            p1, f1 = diffcsr._locate_main(g, qs, qd)
            p2, f2 = diffcsr._locate_diff(g, qs, qd)
            r = jnp.zeros(qs.shape, BOOL)
            r = jnp.where(f1 & g.alive[p1], fl[jnp.clip(p1, 0, E + D - 1)], r)
            r = jnp.where(f2 & g.d_alive[p2] & ~f1,
                          fl[jnp.clip(E + p2, 0, E + D - 1)], r)
            return r

        zero = jax.tree_util.tree_map(
            lambda x: jnp.zeros((), jnp.asarray(x).dtype), out_example)

        def accumulate(total, j, region):
            if region == "main":
                pos = g.offsets[esrc] + j
                ok = (pos < g.offsets[esrc + 1])
                safe = jnp.clip(pos, 0, max(E - 1, 0))
                z = g.dst[safe]
                z_ok = ok & g.alive[safe]
                nbr_lane = safe
            else:
                pos = g.d_offsets[esrc] + j
                ok = (pos < g.d_offsets[esrc + 1])
                safe = jnp.clip(pos, 0, max(D - 1, 0))
                z = g.d_dst[safe]
                z_ok = ok & g.d_alive[safe]
                nbr_lane = E + safe
            ctx = WedgeCtx(g, lane_flags, nbr_lane, is_edge_fn, edge_flag_fn)
            contrib = pair_fn(esrc, edst, z, z_ok & ealive, ctx)
            return jax.tree_util.tree_map(
                lambda t, c: t + jnp.sum(c), total, contrib)

        def scan_region(total, count, region):
            if count == 0:
                return total
            def body(j, tot):
                return accumulate(tot, j, region)
            return jax.lax.fori_loop(0, count, body, total)

        total = scan_region(zero, max_main, "main")
        if D:
            total = scan_region(total, max_diff, "diff")
        return total

    # -- updates (jitted: the scatter programs re-trace cheaply and the
    # compiled executables cache on the static (E, D, B) shapes) ----------
    _upd_del = staticmethod(jax.jit(diffcsr.update_csr_del))
    _upd_add = staticmethod(jax.jit(diffcsr.update_csr_add))

    def update_del(self, g: DynGraph, batch: UpdateBatch) -> DynGraph:
        return JnpEngine._upd_del(g, batch.del_src, batch.del_dst,
                                  batch.del_mask)

    def update_add(self, g: DynGraph, batch: UpdateBatch) -> DynGraph:
        return JnpEngine._upd_add(g, batch.add_src, batch.add_dst,
                                  batch.add_w, batch.add_mask)

    def batch_edge_flags(self, g: DynGraph, qs, qd, mask) -> jax.Array:
        return edge_lane_flags(g, qs, qd, mask)

    def src_flags_from_dst(self, g: DynGraph, dst_mask) -> jax.Array:
        """Mark sources having an alive out-edge into the flagged dst set
        (the push-repair boundary; engines without it fall back to a
        dense seed)."""
        esrc, edst, ew, ealive = g.edge_arrays()
        n = self.n_pad
        hit = ealive & (edst < n) & dst_mask[jnp.clip(edst, 0, n - 1)]
        return jnp.zeros((n,), BOOL).at[
            jnp.where(hit, esrc, n)].set(True, mode="drop")

    # -- streaming executor (fused scan) -----------------------------------
    _compact = staticmethod(jax.jit(diffcsr.compact))

    def static_wedge_bounds(self, handle):
        g = self.handle_graph(handle)
        return self._max_deg("main", g.offsets), g.diff_capacity

    def grow(self, g: DynGraph, factor: float = 2.0) -> DynGraph:
        _faults.fire("pool_merge", engine=self.name,
                     diff_capacity=g.diff_capacity)
        # the old-capacity stream executables can never run again
        self._evict_stream_cache((g.main_capacity, g.diff_capacity))
        cap = max(int(g.diff_capacity * factor), g.diff_capacity + 16)
        return diffcsr.merge(g, diff_capacity=cap)

    def compact_handle(self, g: DynGraph) -> DynGraph:
        return JnpEngine._compact(g)

    def _stream_scan(self, step_fn, bounds, shape_key, batch_size):
        """One jitted program scanning a whole stream segment through
        update → affected-seed → incremental repair.  Cached per
        (step_fn, bounds, handle shapes, batch size) so ``grow`` can
        evict the executables its capacity change strands (jit's own
        aval cache would otherwise keep one per capacity step alive
        forever — PR 5 debt #1)."""
        key = (step_fn, bounds, shape_key, batch_size)
        with _STREAM_CACHE_LOCK:
            fn = self._stream_cache.get(key)
            if fn is None:
                view = self.stream_view(bounds)

                def seg_run(handle, carry, batches):
                    def body(state, batch):
                        h, c = step_fn(view, state[0], batch, state[1])
                        return (h, c), None

                    (h, c), _ = jax.lax.scan(body, (handle, carry), batches)
                    return h, c, self.handle_counters(h)

                fn = jax.jit(seg_run)  # wraps only; tracing is deferred
                self._stream_cache[key] = fn
        return fn

    def _segment_runner(self, step_fn, handle, batch_size: int):
        return self._stream_scan(step_fn, self.static_wedge_bounds(handle),
                                 self._handle_shape_key(handle), batch_size)

    def run_stream(self, handle, stream, batch_size: int, step_fn,
                   carry, segment_size: int = 8, compact_frac: float = 0.5):
        """Device-resident streaming executor: the ΔG batch loop becomes
        one lax.scan per stream segment — no host round-trips between
        batches (the shared driver in ``Engine._run_stream_fused``)."""
        return self._run_stream_fused(handle, stream, batch_size, step_fn,
                                      carry, segment_size, compact_frac)


class _View:
    """Gathered endpoint view (no read-logging on the hot path)."""

    __slots__ = ("_p", "_i")

    def __init__(self, props, idx):
        self._p = props
        self._i = idx

    def __getitem__(self, k):
        return self._p[k][self._i]
