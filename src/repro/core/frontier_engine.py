"""FrontierEngine — work-efficient sparse-frontier sweeps.

The paper's CPU/GPU backends win on dynamic updates because their
worklists touch only the affected vertices per iteration.  Dense
TPU-style sweeps pay O(E) per fixed-point iteration regardless of
frontier size, which erases the dynamic-vs-static advantage on
small-diameter graphs (EXPERIMENTS.md §Reproduction).  This engine
restores work-efficiency with Ligra-style direction optimization:

  * the graph keeps a push-oriented row-split ELL
    (kernels/ell.pack_push_ell): active vertices map to their out-edge
    rows, each holding ≤ K destinations;
  * each fixed-point iteration reads |frontier| on the host (one small
    sync — the same host-driven loop the paper's OpenMP backend runs):
      - frontier > sparse_frac·R  →  dense sweep (inherited lowering);
      - else                       →  sparse step: gather the active
        rows (capacity = next pow2, so recompiles are O(log R)),
        compute candidates, and scatter-min/-max into the property —
        O(|frontier|·K + n) work instead of O(E);
  * sweeps opt in by declaring ``frontier`` (the boolean source-side
    property) on their EdgeSweep; everything else falls back to the
    dense lowering, so the full algorithm suite runs unchanged.

Semantics note: the scatter-min is the same re-associated combiner the
dense path uses — results are identical (tests/test_backends.py runs
this engine through the whole SSSP/PR/TC matrix).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.engine import (JnpEngine, Collectives, Props,
    _StreamView, dyn_state, dyn_from_state)
from repro.core.ir import EdgeSweep
from repro.graph.csr import CSR, INT, INF_W
from repro.graph import diffcsr
from repro.graph.diffcsr import DynGraph
from repro.graph.updates import UpdateBatch
from repro.runtime import faults as _faults
from repro.kernels.ell import (Ell, ell_apply_add, ell_apply_del,
                               ell_state, ell_from_state)
from repro.kernels.ell import pack_push_ell as _pack_push_ell_raw
pack_push_ell = jax.jit(_pack_push_ell_raw, static_argnums=(1, 2))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FrontierHandle:
    g: DynGraph
    push: Ell


def _next_pow2(x: int) -> int:
    p = 16
    while p < x:
        p <<= 1
    return p


class _DenseStreamView(_StreamView):
    """Stream-scan facade for the FrontierEngine: identical semantics,
    but fixed points run the fused dense while_loop (jit-safe) instead
    of the host-driven direction-optimized loop."""

    def fixed_point(self, h, sw: EdgeSweep, props: Props, cond_fn,
                    max_iter: int) -> Props:
        return JnpEngine.fixed_point(self._engine, h, sw, props, cond_fn,
                                     max_iter)


class FrontierEngine(JnpEngine):
    name = "frontier"

    def __init__(self, k: int = 8, sparse_frac: float = 0.05):
        super().__init__()
        self.k = k
        self.sparse_frac = sparse_frac
        self._jit_cache: Dict = {}
        # stable per-engine jitted repack (see PallasEngine): the
        # ell_apply_add cond branch binds a cached jaxpr per call
        # instead of re-tracing the whole push pack
        self._repack = jax.jit(functools.partial(_pack_push_ell_raw, k=k))

    # -- construction / updates (repack after structural change) -----------
    def prepare(self, csr: CSR, diff_capacity: int) -> FrontierHandle:
        g = super().prepare(csr, diff_capacity)
        return FrontierHandle(g=g, push=pack_push_ell(g, self.k))

    def merge(self, h: FrontierHandle) -> FrontierHandle:
        g = diffcsr.merge(h.g)
        return FrontierHandle(g=g, push=pack_push_ell(g, self.k))

    def out_degrees(self, h: FrontierHandle) -> jax.Array:
        return h.g.out_degrees()

    # -- durable state -----------------------------------------------------
    # Like PallasEngine, the push pack travels RAW so resume keeps the
    # exact slot layout (and hence summation order) of the saved run.
    state_kind = "frontier"

    def pack_state(self, h: FrontierHandle):
        return ({"g": dyn_state(h.g), "push": ell_state(h.push)},
                {"kind": "frontier", "n": h.g.n, "k": self.k})

    def unpack_state(self, tree, meta) -> FrontierHandle:
        if meta["k"] != self.k:
            raise ValueError(
                f"checkpoint was saved with k={meta['k']} lanes per row; "
                f"this engine has k={self.k} — bind the restoring engine "
                f"with the same k (or restore cross-backend)")
        self._n = meta["n"]
        return FrontierHandle(g=dyn_from_state(tree["g"], meta["n"]),
                              push=ell_from_state(tree["push"], meta["n"]))

    def update_del(self, h: FrontierHandle, batch: UpdateBatch):
        g = super().update_del(h.g, batch)
        push = ell_apply_del(h.push, h.g, batch.del_src, batch.del_dst,
                             batch.del_mask)
        return FrontierHandle(g=g, push=push)

    def update_add(self, h: FrontierHandle, batch: UpdateBatch):
        g = super().update_add(h.g, batch)
        # push layout: slots hold DESTINATIONS
        push = ell_apply_add(h.push, h.g, g, batch.add_src, batch.add_dst,
                             batch.add_w, batch.add_mask,
                             slot_value=batch.add_dst,
                             repack=self._repack)
        return FrontierHandle(g=g, push=push)

    def batch_edge_flags(self, h: FrontierHandle, qs, qd, mask):
        return super().batch_edge_flags(h.g, qs, qd, mask)

    def count_wedges(self, h: FrontierHandle, pair_fn, lane_flags,
                     out_example, bounds=None):
        return super().count_wedges(h.g, pair_fn, lane_flags, out_example,
                                    bounds=bounds)

    def vertex_map(self, h: FrontierHandle, fn, props):
        return fn(props)

    # -- streaming executor hooks ------------------------------------------
    def handle_graph(self, h: FrontierHandle) -> DynGraph:
        return h.g

    def grow(self, h: FrontierHandle, factor: float = 2.0) -> FrontierHandle:
        g = JnpEngine.grow(self, h.g, factor)
        return FrontierHandle(g=g, push=pack_push_ell(g, self.k))

    def compact_handle(self, h: FrontierHandle) -> FrontierHandle:
        g = JnpEngine._compact(h.g)
        return FrontierHandle(g=g, push=pack_push_ell(g, self.k))

    def stream_view(self, bounds=None):
        # the direction-optimized fixed point reads |frontier| on the
        # host per iteration — inside the fused scan we must stay on
        # device, so stream steps get the dense while_loop lowering.
        return _DenseStreamView(self, bounds)

    def sweep(self, h, sw: EdgeSweep, props: Props) -> Props:
        g = h.g if isinstance(h, FrontierHandle) else h
        return super()._run_sweep(g, sw, props)

    def _run_sweep(self, h, sw: EdgeSweep, props: Props) -> Props:
        g = h.g if isinstance(h, FrontierHandle) else h
        return super()._run_sweep(g, sw, props)

    # -- sparse push step ----------------------------------------------------
    def _sparse_step(self, handle, sw: EdgeSweep, props: Props,
                     frontier_mask, cap: int) -> Props:
        """One frontier-compacted iteration of a min-combining sweep."""
        push = handle.push
        n = self.n_pad
        K = push.K
        row_src = push.row2dst                      # (R,) row's SOURCE
        # rows owned by active vertices
        src_clip = jnp.minimum(row_src, n - 1)
        row_active = (row_src < n) & frontier_mask[src_clip]
        rows = jnp.nonzero(row_active, size=cap, fill_value=push.R)[0]
        safe = jnp.minimum(rows, push.R - 1)
        srcs = jnp.where(rows < push.R, row_src[safe], n)   # (cap,)
        dsts = push.ell_src[safe]                           # (cap, K)
        ws = push.ell_w[safe]

        (target, red), = [(t, r) for t, r in sw.reduces.items()
                          if r.kind in ("min", "max")]
        vec_fn, use_w = sw.gather_form[target]
        vec = vec_fn(props)                                 # (n,) source vals
        vec1 = jnp.concatenate([vec, jnp.full((1,), red.identity(vec.dtype),
                                              vec.dtype)])
        cand = vec1[jnp.minimum(srcs, n)][:, None]
        if use_w:
            cand = cand + ws
        valid = (dsts < n) & (srcs < n)[:, None]
        ident = red.identity(cand.dtype)
        cand = jnp.where(valid, cand, ident)
        tgt = jnp.where(valid, dsts, n)

        old = props[target]
        buf = jnp.full((n + 1,), ident, old.dtype)
        if red.kind == "min":
            buf = buf.at[tgt.reshape(-1)].min(cand.reshape(-1))
        else:
            buf = buf.at[tgt.reshape(-1)].max(cand.reshape(-1))
        reduced = {target: buf[:n]}
        hit = {target: buf[:n] != ident}

        # argmin ride: smallest source id achieving the reduced value
        for t2, r2 in sw.reduces.items():
            if r2.kind != "argmin":
                continue
            ach = valid & (cand == reduced[r2.of][jnp.minimum(dsts, n - 1)])
            sid = jnp.where(ach, jnp.broadcast_to(srcs[:, None], ach.shape),
                            n)
            abuf = jnp.full((n + 1,), n, INT) \
                .at[tgt.reshape(-1)].min(sid.reshape(-1).astype(INT))
            reduced[t2] = abuf[:n]
            hit[t2] = hit[r2.of]
        return sw.post_fn(props, reduced, hit)

    def _sparse_capable(self, sw: EdgeSweep) -> bool:
        if sw.frontier is None or sw.gather_form is None:
            return False
        kinds = sorted(r.kind for r in sw.reduces.values())
        return kinds in (["min"], ["argmin", "min"], ["max"])

    # -- direction-optimized fixed point --------------------------------------
    def fixed_point(self, h, sw: EdgeSweep, props: Props,
                    cond_fn: Callable, max_iter: int) -> Props:
        if not self._sparse_capable(sw):
            return super().fixed_point(h, sw, props, cond_fn, max_iter)
        col = Collectives()
        n = self.n_pad
        R = h.push.R
        # cache key on the sweep's CODE objects: algorithms rebuild their
        # EdgeSweep per call, but the factory's closures share code
        swkey = (sw.edge_fn.__code__, sw.post_fn.__code__,
                 tuple(sorted((t, r.kind, r.of)
                              for t, r in sw.reduces.items())),
                 sw.frontier, n)

        def sparse_jitted(cap):
            key = (swkey, cap)
            fn = self._jit_cache.get(key)
            if fn is None:
                fn = jax.jit(lambda hh, p, m: self._sparse_step(
                    hh, sw, p, m, cap))
                self._jit_cache[key] = fn
            return fn

        DENSE_CHUNK = 8
        it = 0
        while it < max_iter:
            if not bool(cond_fn(props, jnp.asarray(it, INT), col)):
                break
            fmask = props[sw.frontier]
            # active out-edge rows (one scalar sync per direction check —
            # the same host-driven loop the paper's OpenMP backend runs)
            f_rows = int(jnp.sum(
                fmask[jnp.minimum(h.push.row2dst, n - 1)]
                & (h.push.row2dst < n)))
            if f_rows > self.sparse_frac * R:
                # big frontier: run a fused dense while_loop chunk, then
                # re-check direction (Ligra's dense mode)
                props = super().fixed_point(
                    h, sw, props, cond_fn,
                    max_iter=min(DENSE_CHUNK, max_iter - it))
                it += DENSE_CHUNK
            else:
                cap = _next_pow2(max(f_rows, 1))
                _faults.fire("kernel_launch", engine=self.name,
                             op="sparse_step", cap=cap)
                props = sparse_jitted(cap)(h, props, fmask)
                it += 1
        return props
