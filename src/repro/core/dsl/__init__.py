"""StarPlat-Dynamic DSL frontend (the paper's §3–§4 pipeline).

``compile_source`` runs the full pipeline:

    DSL text ──lexer──▶ tokens ──parser──▶ AST ──semantic──▶ symbol table
        ──analysis──▶ read/write sets + combiner inference
        ──codegen──▶ staged programs against the Engine interface
                      ('jnp' | 'dist' | 'pallas' chosen at run time)

The paper parses its DSL into an AST, performs race/read-write-set
analyses, and emits backend-specific C++ (OpenMP/MPI/CUDA).  Here the
same front-half is reproduced verbatim (a real lexer/parser over the
appendix syntax), and the back-half stages the analysed AST into JAX
programs executed by any of the three TPU-native engines — our analogue
of the three generated backends.
"""
from repro.core.dsl.lexer import tokenize, Token, LexError
from repro.core.dsl.parser import parse, ParseError
from repro.core.dsl import ast_nodes as ast
from repro.core.dsl.analysis import analyze, SemanticError, FuncInfo
from repro.core.dsl.codegen import compile_source, Program

__all__ = [
    "tokenize", "Token", "LexError", "parse", "ParseError", "ast",
    "analyze", "SemanticError", "FuncInfo", "compile_source", "Program",
]
