"""Semantic analysis: symbol tables, read/write sets, race → combiner.

This mirrors the paper's §2/§4 program analyses:

  * symbol table per function (params, locals, attached properties) with
    type checking of prop element types;
  * read/write-set computation per ``forall`` — which vertex/edge
    properties each aggregate op touches.  The paper uses this to place
    cudaMemcpys and RMA windows; our backends use it to decide which
    property arrays the distributed engine all-gathers and which the
    Pallas engine keeps resident;
  * race detection inside parallel loops: a write to ``nbr.p`` (or to the
    outer vertex from a pull loop) from many edge lanes is a race.  The
    paper inserts atomics / `omp critical`; we *infer the combiner*
    (min / max / sum / or / argmin) from the write idiom and re-associate
    the update into a deterministic segment reduction — strictly stronger
    synchronization (DESIGN.md §2).

Idioms recognized as combiners (RaceInfo.kind):
  <x.p, x.f, x.q> = <Min(x.p, e), True, v>   →  min + or + argmin
  if (x.p > e) { x.p = e; x.q = v; }         →  min + argmin
  x.f = True / x.f = expr(bool)              →  or
  x.p += e / local += e                      →  sum
Anything else that races is a compile error — same contract as the
paper's "analysis fails → reject program".
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from repro.core.dsl import ast_nodes as A

PRIM_ELEM = {"int": "int", "long": "int", "float": "float",
             "double": "float", "bool": "bool"}


class SemanticError(Exception):
    pass


@dataclasses.dataclass
class Symbol:
    name: str
    type: A.Type
    is_param: bool = False


@dataclasses.dataclass
class RaceInfo:
    """One racy write inside a forall, with its inferred combiner."""
    target: str                 # property name
    kind: str                   # min | max | sum | or | argmin
    line: int
    of: Optional[str] = None    # argmin: the property whose min it rides


@dataclasses.dataclass
class SweepInfo:
    """Read/write sets + races for one (possibly nested) forall."""
    line: int
    orientation: str            # 'push' (neighbors) | 'pull' (nodes_to) |
                                # 'vertex' | 'wedge' | 'batch'
    reads: Set[str] = dataclasses.field(default_factory=set)
    writes: Set[str] = dataclasses.field(default_factory=set)
    races: List[RaceInfo] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class FuncInfo:
    name: str
    kind: str
    symbols: Dict[str, Symbol]
    node_props: Dict[str, str]      # prop name -> elem type
    edge_props: Dict[str, str]
    sweeps: List[SweepInfo]
    returns: Optional[str] = None   # return expression's rough type


def _iter_kind(it: A.Expr) -> Tuple[str, Optional[str]]:
    """Classify a forall iterator expr: ('nodes'|'neighbors'|'nodes_to'|
    'batch', base-object-name)."""
    if isinstance(it, A.Call) and isinstance(it.func, A.Attr):
        m = it.func.name
        if m == "nodes":
            return "nodes", None
        if m == "neighbors":
            return "neighbors", _name_of(it.args[0]) if it.args else None
        if m == "nodes_to":
            return "nodes_to", _name_of(it.args[0]) if it.args else None
        if m == "currentBatch":
            return "batch", None
    if isinstance(it, A.Name):
        return "batch", it.ident       # updates<g>-typed local (addBatch)
    raise SemanticError(f"line {it.line}: unsupported forall iterator")


def _name_of(e: A.Expr) -> Optional[str]:
    return e.ident if isinstance(e, A.Name) else None


def _collect_props(func: A.FuncDef) -> Tuple[Dict[str, str], Dict[str, str]]:
    nprops, eprops = {}, {}
    for node in A.walk(func):
        if isinstance(node, (A.Decl, A.Param)) and node.type.is_prop:
            name = node.name
            elem = PRIM_ELEM.get(node.type.arg)
            if elem is None:
                raise SemanticError(
                    f"line {node.line}: bad prop element {node.type.arg}")
            if node.type.name == "propNode":
                nprops[name] = elem
            else:
                eprops[name] = elem
    return nprops, eprops


def _analyze_sweep(fa: A.ForAll, node_props: Dict[str, str],
                   outer_var: Optional[str] = None) -> SweepInfo:
    kind, base = _iter_kind(fa.iter)
    if kind == "nodes":
        orientation = "vertex"
    elif kind == "neighbors":
        orientation = "push"
    elif kind == "nodes_to":
        orientation = "pull"
    else:
        orientation = "batch"

    # nested neighbor loop upgrades a vertex sweep to an edge sweep; two
    # nested neighbor loops (or batch+neighbors) make a wedge sweep.
    inner = [s for s in fa.body.stmts if isinstance(s, A.ForAll)]
    if orientation in ("vertex", "batch") and inner:
        ik, _ = _iter_kind(inner[0].iter)
        if ik in ("neighbors", "nodes_to"):
            sub = [s for s in inner[0].body.stmts if isinstance(s, A.ForAll)]
            if sub or (orientation == "batch"):
                orientation = "wedge"
            else:
                orientation = "push" if ik == "neighbors" else "pull"

    info = SweepInfo(line=fa.line, orientation=orientation)
    loop_vars = {fa.var} | {s.var for s in inner}

    for node in A.walk(fa):
        if isinstance(node, A.Attr) and node.name in node_props:
            info.reads.add(node.name)
    for node in A.walk(fa):
        if isinstance(node, A.Assign) and isinstance(node.target, A.Attr):
            tname = node.target.name
            if tname in node_props:
                info.writes.add(tname)
        if isinstance(node, A.MultiAssign):
            for tgt, val in zip(node.targets, node.values):
                if isinstance(tgt, A.Attr) and tgt.name in node_props:
                    info.writes.add(tgt.name)
                    if isinstance(val, A.MinMax):
                        info.races.append(RaceInfo(
                            target=tgt.name,
                            kind="min" if val.op == "Min" else "max",
                            line=node.line))
                    elif isinstance(val, A.Bool):
                        info.races.append(RaceInfo(
                            target=tgt.name, kind="or", line=node.line))
                    elif isinstance(val, A.Name) and val.ident in loop_vars:
                        info.races.append(RaceInfo(
                            target=tgt.name, kind="argmin", line=node.line))
    return info


# Attributes the runtime provides without a declaration: edge weight and
# the endpoints of an update-batch entry.
_BUILTIN_ATTRS = {"weight", "source", "destination"}


def _validate_names(func: A.FuncDef, symbols: Dict[str, Symbol],
                    nprops: Dict[str, str], eprops: Dict[str, str],
                    func_names: Set[str]) -> None:
    """Reject undeclared properties and undeclared-variable reads.

    The paper's contract: analysis failure rejects the program.  Two
    checks: (1) every ``x.p`` attribute access names a declared
    propNode/propEdge (or a builtin like ``e.weight``/``u.source``);
    (2) every bare identifier read is a declared symbol, a loop
    variable, a property (the ``filter(modified == True)`` shorthand),
    or a function name.
    """
    loop_vars = {n.var for n in A.walk(func)
                 if isinstance(n, (A.ForAll, A.OnUpdate))}
    flag_vars = {n.flag for n in A.walk(func) if isinstance(n, A.FixedPoint)}
    known = (set(symbols) | loop_vars | flag_vars | set(nprops)
             | set(eprops) | func_names | {"abs"})
    call_funcs = {id(n.func) for n in A.walk(func) if isinstance(n, A.Call)}
    for node in A.walk(func):
        if isinstance(node, A.Attr) and id(node) not in call_funcs:
            if node.name not in nprops and node.name not in eprops \
                    and node.name not in _BUILTIN_ATTRS:
                raise SemanticError(
                    f"line {node.line}: undeclared property "
                    f"'{node.name}' (declare a propNode/propEdge)")
        if isinstance(node, A.Name) and node.ident not in known:
            raise SemanticError(
                f"line {node.line}: read of undeclared name "
                f"'{node.ident}'")


def _validate_init_order(func: A.FuncDef) -> None:
    """Reject reads of a primitive local before its first assignment.

    Path-sensitive where it matters: an assignment inside a conditional
    branch only initializes the variable if every branch assigns it; a
    while/forall body may run zero times, so its assignments never
    initialize anything for the code after the loop; a do-while body
    always runs once, so its assignments do (and the body is scanned
    *before* the loop condition is checked).
    """

    def check_expr(e: Optional[A.Expr], uninit: Set[str]):
        if e is None:
            return
        for n in A.walk(e):
            if isinstance(n, A.Name) and n.ident in uninit:
                raise SemanticError(
                    f"line {n.line}: '{n.ident}' is read before it is "
                    f"written")

    def scan(stmts, uninit: Set[str]):
        for st in stmts:
            if isinstance(st, A.Decl):
                check_expr(st.init, uninit)
                if st.init is None and not st.type.is_prop and \
                        st.type.name in ("int", "long", "float", "double",
                                         "bool"):
                    uninit.add(st.name)
                else:
                    uninit.discard(st.name)
            elif isinstance(st, A.Assign):
                check_expr(st.value, uninit)
                if isinstance(st.target, A.Name):
                    if st.op == "=":
                        uninit.discard(st.target.ident)
                    elif st.target.ident in uninit:
                        raise SemanticError(
                            f"line {st.line}: '{st.target.ident}' is "
                            f"updated before it is written")
                else:
                    check_expr(st.target, uninit)
            elif isinstance(st, A.MultiAssign):
                for v in st.values:
                    check_expr(v, uninit)
            elif isinstance(st, A.If):
                check_expr(st.cond, uninit)
                u_then = set(uninit)
                scan(st.then.stmts, u_then)
                if st.orelse is not None:
                    u_else = set(uninit)
                    scan(st.orelse.stmts, u_else)
                    # initialized only if assigned on *both* paths
                    uninit.clear()
                    uninit.update(u_then | u_else)
                # no else: the skip path keeps everything uninitialized
            elif isinstance(st, A.DoWhile):
                scan(st.body.stmts, uninit)      # body runs before cond
                check_expr(st.cond, uninit)
            elif isinstance(st, A.While):
                check_expr(st.cond, uninit)
                scan(st.body.stmts, set(uninit))  # may run zero times
            elif isinstance(st, A.ForAll):
                check_expr(st.filter, uninit)
                scan(st.body.stmts, set(uninit))
            elif isinstance(st, (A.FixedPoint, A.BatchStmt, A.OnUpdate)):
                scan(st.body.stmts, set(uninit))
            elif isinstance(st, A.CallStmt):
                check_expr(st.call, uninit)
            elif isinstance(st, A.Return):
                check_expr(st.value, uninit)

    scan(func.body.stmts, set())


def analyze(prog: A.ProgramAST) -> Dict[str, FuncInfo]:
    """Build per-function symbol tables + sweep analyses; validate."""
    infos: Dict[str, FuncInfo] = {}
    func_names = {f.name for f in prog.funcs}
    for func in prog.funcs:
        symbols: Dict[str, Symbol] = {}
        for p in func.params:
            symbols[p.name] = Symbol(p.name, p.type, is_param=True)
        for node in A.walk(func):
            if isinstance(node, A.Decl):
                symbols.setdefault(node.name, Symbol(node.name, node.type))
        nprops, eprops = _collect_props(func)
        _validate_names(func, symbols, nprops, eprops, func_names)
        _validate_init_order(func)
        sweeps = []
        for node in A.walk(func):
            if isinstance(node, A.ForAll):
                sweeps.append(_analyze_sweep(node, nprops))
        ret = None
        for node in A.walk(func):
            if isinstance(node, A.Return):
                ret = "scalar"
        if func.name in infos:
            raise SemanticError(f"duplicate function {func.name}")
        infos[func.name] = FuncInfo(
            name=func.name, kind=func.kind, symbols=symbols,
            node_props=nprops, edge_props=eprops, sweeps=sweeps, returns=ret)
    return infos
