"""Emit the lowering report — our analogue of the paper's generated C++.

StarPlat's compiler writes OpenMP/MPI/CUDA source files; our staged
backend has no source artifact, so ``emit_report`` renders what the code
generator *decided* per construct for each backend: the aggregate-op
lowering, inferred combiners (the race analysis result), read/write
sets (the transfer/RMA-window analysis result), and the backend-specific
synchronization each engine will use.
"""
from __future__ import annotations

from typing import List

from repro.core.dsl import ast_nodes as A
from repro.core.dsl.analysis import analyze, FuncInfo

_BACKEND_SYNC = {
    "jnp": "segment_min/sum/max (single-device XLA; OpenMP analogue)",
    "dist": "per-shard segment reduce + cross-shard combine via psum/pmin "
            "(shard_map; MPI-RMA analogue)",
    "pallas": "ELL row-blocked kernel tiles in VMEM (TPU kernel; CUDA "
              "analogue)",
}


def emit_report(prog, backend: str = "jnp") -> str:
    """Human-readable lowering report for every function in ``prog``."""
    infos = prog.infos
    out: List[str] = []
    out.append(f"== StarPlat-Dynamic lowering report (backend={backend}) ==")
    out.append(f"synchronization: {_BACKEND_SYNC.get(backend, '?')}")
    for fname, info in infos.items():
        out.append("")
        out.append(f"{info.kind} {fname}:")
        if info.node_props:
            out.append(f"  node props: "
                       f"{', '.join(f'{k}:{v}' for k, v in sorted(info.node_props.items()))}")
        if info.edge_props:
            out.append(f"  edge props: "
                       f"{', '.join(f'{k}:{v}' for k, v in sorted(info.edge_props.items()))}")
        func = prog.ast.func(fname)
        _emit_block(func.body, out, infos[fname], indent=2)
    return "\n".join(out)


def _emit_block(block: A.Block, out: List[str], info: FuncInfo, indent: int):
    pad = " " * indent
    for st in block.stmts:
        if isinstance(st, A.ForAll):
            _emit_forall(st, out, info, indent)
        elif isinstance(st, A.FixedPoint):
            out.append(f"{pad}fixedPoint(!{_fmt(st.cond)}) → "
                       f"engine.fixed_point(cond=any({_fmt(st.cond)[1:]}))")
            _emit_block(st.body, out, info, indent + 2)
        elif isinstance(st, (A.While, A.DoWhile)):
            k = "while" if isinstance(st, A.While) else "do-while"
            out.append(f"{pad}{k}({_fmt(st.cond)}) → engine.fixed_point"
                       f"(cond staged from scalar accumulators/counters)")
            _emit_block(st.body, out, info, indent + 2)
        elif isinstance(st, A.BatchStmt):
            out.append(f"{pad}Batch({st.updates}:{st.batch_size}) → host "
                       f"loop over UpdateStream.batches()")
            _emit_block(st.body, out, info, indent + 2)
        elif isinstance(st, A.OnUpdate):
            op = "OnAdd" if st.kind == "add" else "OnDelete"
            out.append(f"{pad}{op}({st.var}) → masked scatter over batch "
                       f"lanes / batch_edge_flags")
        elif isinstance(st, A.CallStmt):
            name = _callee(st.call)
            low = {"updateCSRAdd": "engine.update_add (diff-CSR insert)",
                   "updateCSRDel": "engine.update_del (tombstone)",
                   "propagateNodeFlags": "engine.propagate_flags "
                                         "(or-combine BFS fixed point)",
                   "attachNodeProperty": "engine.full per property",
                   "attachEdgeProperty": "lane-array alloc"}.get(name)
            if low:
                out.append(f"{pad}{name} → {low}")
            else:
                out.append(f"{pad}call {name}(...)")


def _emit_forall(fa: A.ForAll, out: List[str], info: FuncInfo, indent: int):
    pad = " " * indent
    sw = next((s for s in info.sweeps if s.line == fa.line), None)
    inner = [s for s in fa.body.stmts if isinstance(s, A.ForAll)]
    shape = sw.orientation if sw else "?"
    line = f"{pad}forall({fa.var} in {_fmt(fa.iter)}"
    if fa.filter is not None:
        line += f" filter {_fmt(fa.filter)}"
    line += f") → {shape} sweep"
    out.append(line)
    if sw:
        if sw.reads:
            out.append(f"{pad}  reads  {{{', '.join(sorted(sw.reads))}}}  "
                       f"(gather/window set)")
        if sw.writes:
            out.append(f"{pad}  writes {{{', '.join(sorted(sw.writes))}}}")
        for r in sw.races:
            of = f" of={r.of}" if r.of else ""
            out.append(f"{pad}  race on '{r.target}' → Reduce"
                       f"({r.kind}{of})  [atomics re-associated]")
    for s in inner:
        _emit_forall(s, out, info, indent + 2)


def _callee(c: A.Call) -> str:
    if isinstance(c.func, A.Attr):
        return c.func.name
    if isinstance(c.func, A.Name):
        return c.func.ident
    return "?"


def _fmt(e: A.Expr) -> str:
    if isinstance(e, A.Name):
        return e.ident
    if isinstance(e, A.Num):
        return str(e.value)
    if isinstance(e, A.Bool):
        return str(e.value)
    if isinstance(e, A.Inf):
        return "INF"
    if isinstance(e, A.Unary):
        return f"{e.op}{_fmt(e.operand)}"
    if isinstance(e, A.Binary):
        return f"{_fmt(e.left)} {e.op} {_fmt(e.right)}"
    if isinstance(e, A.Attr):
        return f"{_fmt(e.obj)}.{e.name}"
    if isinstance(e, A.Call):
        args = ", ".join(_fmt(a) for a in e.args)
        return f"{_fmt(e.func)}({args})"
    if isinstance(e, A.MinMax):
        return f"{e.op}({', '.join(_fmt(a) for a in e.args)})"
    if isinstance(e, A.Kwarg):
        return f"{e.name}={_fmt(e.value)}"
    return type(e).__name__
