"""Recursive-descent parser: tokens → AST (paper front-end, §4.1).

The grammar covers the full appendix programs (Figs. 19–21):

  program     := funcdef*
  funcdef     := kind IDENT? '(' params ')' block
  kind        := 'Static' | 'Dynamic' | 'Incremental' | 'Decremental'
  type        := prim | ('propNode'|'propEdge') '<' prim '>'
               | 'updates' '<' IDENT '>'
  stmt        := decl | assign | multiassign | if | while | dowhile
               | forall | fixedPoint | Batch | OnAdd | OnDelete
               | call ';' | return
  forall      := ('forall'|'for') '(' IDENT 'in' postfix
                 ['.' 'filter' '(' expr ')'] ')' block
  fixedPoint  := 'fixedPoint' 'until' '(' IDENT ':' expr ')' block
  multiassign := '<' lval,+ '>' '=' '<' expr,+ '>' ';'

Expressions use C precedence (|| < && < ==/!= < rel < +- < */% < unary
< postfix).  ``Min``/``Max`` parse as dedicated nodes since they carry
the paper's atomic multi-assignment semantics.
"""
from __future__ import annotations

from typing import List, Optional

from repro.core.dsl import ast_nodes as A
from repro.core.dsl.lexer import Token, tokenize

_PRIM_TYPES = {"int", "long", "float", "double", "bool", "node", "edge",
               "Graph"}
_FUNC_KINDS = {"Static", "Dynamic", "Incremental", "Decremental"}


class ParseError(SyntaxError):
    pass


class Parser:
    def __init__(self, toks: List[Token]):
        self.toks = toks
        self.i = 0

    # -- token helpers -------------------------------------------------------
    @property
    def cur(self) -> Token:
        return self.toks[self.i]

    def peek(self, k: int = 1) -> Token:
        return self.toks[min(self.i + k, len(self.toks) - 1)]

    def advance(self) -> Token:
        t = self.cur
        self.i += 1
        return t

    def at(self, kind: str, text: Optional[str] = None) -> bool:
        return self.cur.kind == kind and (text is None or self.cur.text == text)

    def accept(self, kind: str, text: Optional[str] = None) -> Optional[Token]:
        if self.at(kind, text):
            return self.advance()
        return None

    def expect(self, kind: str, text: Optional[str] = None) -> Token:
        if not self.at(kind, text):
            raise ParseError(
                f"line {self.cur.line}: expected "
                f"{text or kind}, got {self.cur.text!r}")
        return self.advance()

    # -- program / functions ---------------------------------------------------
    def parse_program(self) -> A.ProgramAST:
        funcs = []
        while not self.at("eof"):
            funcs.append(self.parse_funcdef())
        return A.ProgramAST(funcs=funcs, line=1)

    def parse_funcdef(self) -> A.FuncDef:
        t = self.cur
        if not (t.kind == "kw" and t.text in _FUNC_KINDS):
            raise ParseError(f"line {t.line}: expected function kind, "
                             f"got {t.text!r}")
        kind = self.advance().text
        name = kind
        if self.at("ident"):
            name = self.advance().text
        self.expect("op", "(")
        params = []
        if not self.at("op", ")"):
            params.append(self.parse_param())
            while self.accept("op", ","):
                params.append(self.parse_param())
        self.expect("op", ")")
        body = self.parse_block()
        return A.FuncDef(kind=kind, name=name, params=params, body=body,
                         line=t.line)

    def parse_param(self) -> A.Param:
        ty = self.parse_type()
        name = self.expect("ident").text
        return A.Param(type=ty, name=name, line=self.cur.line)

    def parse_type(self) -> A.Type:
        t = self.cur
        if t.kind == "kw" and t.text in ("propNode", "propEdge"):
            self.advance()
            self.expect("op", "<")
            inner = self.expect("kw").text
            if inner not in _PRIM_TYPES:
                raise ParseError(f"line {t.line}: bad prop type {inner}")
            self.expect("op", ">")
            return A.Type(name=t.text, arg=inner, line=t.line)
        if t.kind == "kw" and t.text == "updates":
            self.advance()
            self.expect("op", "<")
            g = self.expect("ident").text
            self.expect("op", ">")
            return A.Type(name="updates", arg=g, line=t.line)
        if t.kind == "kw" and t.text in _PRIM_TYPES:
            self.advance()
            return A.Type(name=t.text, line=t.line)
        raise ParseError(f"line {t.line}: expected type, got {t.text!r}")

    # -- statements -----------------------------------------------------------
    def parse_block(self) -> A.Block:
        t = self.expect("op", "{")
        stmts = []
        while not self.at("op", "}"):
            stmts.append(self.parse_stmt())
        self.expect("op", "}")
        return A.Block(stmts=stmts, line=t.line)

    def parse_block_or_stmt(self) -> A.Block:
        if self.at("op", "{"):
            return self.parse_block()
        s = self.parse_stmt()
        return A.Block(stmts=[s], line=s.line)

    def parse_stmt(self) -> A.Stmt:
        t = self.cur
        if t.kind == "kw":
            if t.text in _PRIM_TYPES or t.text in ("propNode", "propEdge",
                                                   "updates"):
                return self.parse_decl()
            if t.text == "if":
                return self.parse_if()
            if t.text == "while":
                return self.parse_while()
            if t.text == "do":
                return self.parse_dowhile()
            if t.text in ("forall", "for"):
                return self.parse_forall()
            if t.text == "fixedPoint":
                return self.parse_fixedpoint()
            if t.text == "Batch":
                return self.parse_batch()
            if t.text in ("OnAdd", "OnDelete"):
                return self.parse_onupdate()
            if t.text == "return":
                self.advance()
                v = self.parse_expr()
                self.expect("op", ";")
                return A.Return(value=v, line=t.line)
        if t.kind == "op" and t.text == "<":
            return self.parse_multiassign()
        # expression statement: assignment or call
        e = self.parse_expr()
        if self.at("op") and self.cur.text in ("=", "+=", "-="):
            op = self.advance().text
            v = self.parse_expr()
            self.expect("op", ";")
            if not isinstance(e, (A.Name, A.Attr)):
                raise ParseError(f"line {t.line}: bad assignment target")
            return A.Assign(target=e, op=op, value=v, line=t.line)
        self.expect("op", ";")
        if isinstance(e, A.Call):
            return A.CallStmt(call=e, line=t.line)
        raise ParseError(f"line {t.line}: expression has no effect")

    def parse_decl(self) -> A.Decl:
        t = self.cur
        ty = self.parse_type()
        name = self.expect("ident").text
        init = None
        if self.accept("op", "="):
            init = self.parse_expr()
        self.expect("op", ";")
        return A.Decl(type=ty, name=name, init=init, line=t.line)

    def parse_if(self) -> A.If:
        t = self.expect("kw", "if")
        self.expect("op", "(")
        cond = self.parse_expr()
        self.expect("op", ")")
        then = self.parse_block_or_stmt()
        orelse = None
        if self.accept("kw", "else"):
            orelse = self.parse_block_or_stmt()
        return A.If(cond=cond, then=then, orelse=orelse, line=t.line)

    def parse_while(self) -> A.While:
        t = self.expect("kw", "while")
        self.expect("op", "(")
        cond = self.parse_expr()
        self.expect("op", ")")
        body = self.parse_block()
        return A.While(cond=cond, body=body, line=t.line)

    def parse_dowhile(self) -> A.DoWhile:
        t = self.expect("kw", "do")
        body = self.parse_block()
        self.expect("kw", "while")
        self.expect("op", "(")
        cond = self.parse_expr()
        self.expect("op", ")")
        self.expect("op", ";")
        return A.DoWhile(body=body, cond=cond, line=t.line)

    def parse_forall(self) -> A.ForAll:
        t = self.advance()               # 'forall' | 'for'
        parallel = t.text == "forall"
        self.expect("op", "(")
        var = self.expect("ident").text
        self.expect("kw", "in")
        it = self.parse_postfix()
        # iterator-level filter: g.nodes().filter(cond) parses into the
        # postfix chain; pull it off so codegen sees iter + filter apart.
        filt = None
        if isinstance(it, A.Call) and isinstance(it.func, A.Attr) \
                and it.func.name == "filter":
            filt = it.args[0] if it.args else None
            it = it.func.obj
        self.expect("op", ")")
        # optional ':' before block (paper Fig. 21 writes `):{`)
        self.accept("op", ":")
        body = self.parse_block()
        return A.ForAll(var=var, iter=it, filter=filt, body=body,
                        parallel=parallel, line=t.line)

    def parse_fixedpoint(self) -> A.FixedPoint:
        t = self.expect("kw", "fixedPoint")
        self.expect("kw", "until")
        self.expect("op", "(")
        flag = self.expect("ident").text
        self.expect("op", ":")
        cond = self.parse_expr()
        self.expect("op", ")")
        body = self.parse_block()
        return A.FixedPoint(flag=flag, cond=cond, body=body, line=t.line)

    def parse_batch(self) -> A.BatchStmt:
        t = self.expect("kw", "Batch")
        self.expect("op", "(")
        ups = self.expect("ident").text
        self.expect("op", ":")
        bs = self.expect("ident").text
        self.expect("op", ")")
        body = self.parse_block()
        return A.BatchStmt(updates=ups, batch_size=bs, body=body, line=t.line)

    def parse_onupdate(self) -> A.OnUpdate:
        t = self.advance()
        kind = "add" if t.text == "OnAdd" else "delete"
        self.expect("op", "(")
        var = self.expect("ident").text
        self.expect("kw", "in")
        src = self.parse_postfix()
        self.expect("op", ")")
        self.accept("op", ":")
        body = self.parse_block()
        return A.OnUpdate(kind=kind, var=var, source=src, body=body,
                          line=t.line)

    def parse_multiassign(self) -> A.MultiAssign:
        t = self.expect("op", "<")
        targets = [self.parse_postfix()]
        while self.accept("op", ","):
            targets.append(self.parse_postfix())
        self.expect("op", ">")
        self.expect("op", "=")
        self.expect("op", "<")
        # values parse at additive precedence so the closing '>' is not
        # mistaken for a relation (Min(...) args are full exprs in parens)
        values = [self.parse_add()]
        while self.accept("op", ","):
            values.append(self.parse_add())
        self.expect("op", ">")
        self.expect("op", ";")
        if len(targets) != len(values):
            raise ParseError(f"line {t.line}: multi-assignment arity "
                             f"mismatch")
        return A.MultiAssign(targets=targets, values=values, line=t.line)

    # -- expressions -----------------------------------------------------------
    def parse_expr(self) -> A.Expr:
        return self.parse_or()

    def _binop(self, sub, ops):
        e = sub()
        while self.cur.kind == "op" and self.cur.text in ops:
            op = self.advance().text
            rhs = sub()
            e = A.Binary(op=op, left=e, right=rhs, line=e.line)
        return e

    def parse_or(self):
        return self._binop(self.parse_and, ("||",))

    def parse_and(self):
        return self._binop(self.parse_eq, ("&&",))

    def parse_eq(self):
        return self._binop(self.parse_rel, ("==", "!="))

    def parse_rel(self):
        # NB: '<'/'>' only appear as relations inside parenthesized
        # expression context; multi-assign '<' is handled at stmt level.
        return self._binop(self.parse_add, ("<", ">", "<=", ">="))

    def parse_add(self):
        return self._binop(self.parse_mul, ("+", "-"))

    def parse_mul(self):
        return self._binop(self.parse_unary, ("*", "/", "%"))

    def parse_unary(self):
        t = self.cur
        if t.kind == "op" and t.text in ("!", "-"):
            self.advance()
            return A.Unary(op=t.text, operand=self.parse_unary(), line=t.line)
        return self.parse_postfix()

    def parse_args(self) -> list:
        """'(' already consumed; parses positional and ``name = expr``
        keyword arguments (paper: g.attachNodeProperty(dist=INF, ...))."""
        args = []
        if not self.at("op", ")"):
            args.append(self.parse_arg())
            while self.accept("op", ","):
                args.append(self.parse_arg())
        self.expect("op", ")")
        return args

    def parse_arg(self) -> A.Expr:
        if self.at("ident") and self.peek().kind == "op" \
                and self.peek().text == "=":
            name = self.advance().text
            self.advance()             # '='
            return A.Kwarg(name=name, value=self.parse_expr(),
                           line=self.cur.line)
        return self.parse_expr()

    def parse_postfix(self) -> A.Expr:
        e = self.parse_primary()
        while True:
            if self.accept("op", "."):
                name = self.advance()
                if name.kind not in ("ident", "kw"):
                    raise ParseError(f"line {name.line}: bad attribute")
                if self.at("op", "("):
                    self.advance()
                    args = self.parse_args()
                    e = A.Call(func=A.Attr(obj=e, name=name.text,
                                           line=name.line),
                               args=args, line=name.line)
                else:
                    e = A.Attr(obj=e, name=name.text, line=name.line)
            elif self.at("op", "(") and isinstance(e, A.Name):
                self.advance()
                args = self.parse_args()
                e = A.Call(func=e, args=args, line=e.line)
            else:
                return e

    def parse_primary(self) -> A.Expr:
        t = self.cur
        if t.kind == "num":
            self.advance()
            isf = "." in t.text
            return A.Num(value=float(t.text) if isf else int(t.text),
                         is_float=isf, line=t.line)
        if t.kind == "kw" and t.text in ("True", "False"):
            self.advance()
            return A.Bool(value=t.text == "True", line=t.line)
        if t.kind == "kw" and t.text == "INF":
            self.advance()
            return A.Inf(line=t.line)
        if t.kind == "kw" and t.text in ("Min", "Max"):
            self.advance()
            self.expect("op", "(")
            args = [self.parse_expr()]
            while self.accept("op", ","):
                args.append(self.parse_expr())
            self.expect("op", ")")
            return A.MinMax(op=t.text, args=args, line=t.line)
        if t.kind == "ident":
            self.advance()
            return A.Name(ident=t.text, line=t.line)
        if t.kind == "kw" and t.text in _FUNC_KINDS:
            # calls to the special Incremental/Decremental functions
            self.advance()
            return A.Name(ident=t.text, line=t.line)
        if t.kind == "op" and t.text == "(":
            self.advance()
            e = self.parse_expr()
            self.expect("op", ")")
            return e
        raise ParseError(f"line {t.line}: unexpected token {t.text!r}")


def parse(src: str) -> A.ProgramAST:
    return Parser(tokenize(src)).parse_program()
