"""Aggregate-op lowering: forall / fixedPoint / Batch bodies → engine ops.

The pattern grammar recognized here is exactly the shape of the paper's
appendix programs (and their natural variations):

  vertex sweep   forall (v in g.nodes().filter(F)) { elementwise body }
  edge sweep     forall (v ...) { [locals] forall (nbr in g.neighbors(v)
                 | g.nodes_to(v)) { racy writes } [elementwise tail] }
  wedge sweep    forall (v) { forall (u in N(v)) { forall (w in N(v))
                 {...} } }   |   forall (upd in batch) { forall (v3 in
                 N(v1)) {...} }
  loops          fixedPoint / do-while / while around one core sweep plus
                 elementwise post statements

Racy writes inside edge sweeps are matched to combiner idioms
(analysis.py) and staged as :class:`repro.core.ir.Reduce` entries; the
rest of the body is interpreted by a *masked vectorizing interpreter*
(``vexec``) that turns straight-line code with ifs into jnp ``where``
chains — the moral equivalent of the paper emitting guarded CUDA/OpenMP
bodies.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.dsl import ast_nodes as A
from repro.core.ir import EdgeSweep, Reduce
from repro.graph.csr import INT, INF_W
from repro.graph.diffcsr import BOOL

F32 = jnp.float32
_BIG = 1 << 30


class LowerError(Exception):
    pass


# ---------------------------------------------------------------------------
# vec values
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SideMarker:
    """Edge-sweep view handle: 's' (edge source) or 'd' (destination)."""
    side: str


@dataclasses.dataclass
class IdLane:
    """Node ids as a lane array (vertex / wedge / scatter contexts).

    ``identity=True`` marks the iota lane of a vertex sweep — reads skip
    the gather and writes become where-merges instead of scatters.
    """
    idx: Any
    identity: bool = False


@dataclasses.dataclass
class EdgeSym:
    """edge e = g.get_edge(a, b) inside a sweep: symbolic endpoints."""
    a: Any
    b: Any
    weight: Any = None


def _as_raw(v):
    if isinstance(v, IdLane):
        return v.idx
    return v


def _where(mask, new, old):
    new = jnp.asarray(new)
    old = jnp.asarray(old)
    if old.dtype != new.dtype:
        new = new.astype(old.dtype)
    return jnp.where(mask, new, old)


def _binop_vec(op, a, b):
    a, b = _as_raw(a), _as_raw(b)
    import repro.core.dsl.codegen as CG
    return CG._binop(op, a, b)


# ---------------------------------------------------------------------------
# masked vectorizing interpreter
# ---------------------------------------------------------------------------

class VecCtx:
    """Hooks for attribute reads/writes + accumulators in vec contexts."""

    def __init__(self, ex, frame):
        self.ex = ex
        self.frame = frame
        self.accums: Dict[str, Any] = {}
        self.changed = None           # while-flag tracking
        self.flag_name: Optional[str] = None

    # overridden per context ------------------------------------------------
    def read_attr(self, obj, name, env):
        raise LowerError(f"attribute {name} not readable here")

    def write_attr(self, obj, name, value, mask, env):
        raise LowerError(f"attribute {name} not writable here")

    def call(self, e: A.Call, env, mask):
        raise LowerError(f"call not supported here (line {e.line})")

    def multi_assign(self, st: A.MultiAssign, env, mask):
        raise LowerError(f"line {st.line}: multi-assignment not "
                         f"supported in this context")

    # shared ------------------------------------------------------------------
    def host(self, name):
        return self.frame.lookup(name)


def veval(e: A.Expr, env: Dict[str, Any], ctx: VecCtx, mask=None):
    if isinstance(e, A.Num):
        return e.value
    if isinstance(e, A.Bool):
        return e.value
    if isinstance(e, A.Inf):
        return INF_W
    if isinstance(e, A.Name):
        if e.ident in env:
            return env[e.ident]
        v = ctx.host(e.ident)
        import repro.core.dsl.codegen as CG
        if isinstance(v, CG.PropRef):
            return v                      # whole-array reference
        if isinstance(v, CG.NodeIdx):
            return v.idx
        return v
    if isinstance(e, A.Unary):
        v = _as_raw(veval(e.operand, env, ctx, mask))
        if e.op == "!":
            return ~v if hasattr(v, "dtype") else (not v)
        return -v
    if isinstance(e, A.Binary):
        a = veval(e.left, env, ctx, mask)
        b = veval(e.right, env, ctx, mask)
        return _binop_vec(e.op, a, b)
    if isinstance(e, A.MinMax):
        vals = [_as_raw(veval(a, env, ctx, mask)) for a in e.args]
        out = vals[0]
        for v in vals[1:]:
            out = jnp.minimum(out, v) if e.op == "Min" else \
                jnp.maximum(out, v)
        return out
    if isinstance(e, A.Attr):
        obj = veval(e.obj, env, ctx, mask)
        return ctx.read_attr(obj, e.name, env)
    if isinstance(e, A.Call):
        return ctx.call(e, env, mask)
    raise LowerError(f"line {e.line}: cannot stage {type(e).__name__}")


def vexec(stmts: List[A.Stmt], env: Dict[str, Any], ctx: VecCtx, mask):
    """Masked sequential execution of straight-line code with ifs."""
    for st in stmts:
        if isinstance(st, A.Decl):
            init = veval(st.init, env, ctx, mask) if st.init is not None \
                else (0 if st.type.name != "bool" else False)
            if st.type.name == "node" and not isinstance(init, IdLane):
                init = IdLane(_as_raw(init))
            env[st.name] = init
        elif isinstance(st, A.Assign) and isinstance(st.target, A.Name):
            name = st.target.ident
            val = veval(st.value, env, ctx, mask)
            if st.op in ("+=", "-="):
                if name in ctx.accums:
                    contrib = _as_raw(val)
                    contrib = jnp.where(mask, contrib, 0) if st.op == "+=" \
                        else jnp.where(mask, -contrib, 0)
                    ctx.accums[name] = ctx.accums[name] + contrib
                    continue
                cur = env.get(name, ctx.host(name))
                val = _binop_vec("+" if st.op == "+=" else "-", cur, val)
            if name == ctx.flag_name:
                # `finished = False` inside the loop body: convergence ride
                if isinstance(st.value, A.Bool) and not st.value.value:
                    ctx.changed = mask if ctx.changed is None \
                        else (ctx.changed | mask)
                continue
            if name in ctx.accums:
                # `sum = sum + expr` accumulation spelling
                if isinstance(st.value, A.Binary) and \
                        _mentions(st.value, name):
                    contrib = _strip_self(st.value, name, env, ctx, mask)
                    ctx.accums[name] = ctx.accums[name] + \
                        jnp.where(mask, _as_raw(contrib), 0)
                    continue
            cur = env.get(name)
            if cur is None:
                env[name] = val
            else:
                if isinstance(cur, IdLane) or isinstance(val, IdLane):
                    env[name] = IdLane(_where(mask, _as_raw(val),
                                              _as_raw(cur)))
                else:
                    env[name] = _where(mask, _as_raw(val), _as_raw(cur))
        elif isinstance(st, A.Assign) and isinstance(st.target, A.Attr):
            obj = veval(st.target.obj, env, ctx, mask)
            val = veval(st.value, env, ctx, mask)
            ctx.write_attr(obj, st.target.name, val, mask, env)
        elif isinstance(st, A.MultiAssign):
            ctx.multi_assign(st, env, mask)
        elif isinstance(st, A.If):
            c = _as_raw(veval(st.cond, env, ctx, mask))
            m_then = mask & c
            vexec(st.then.stmts, env, ctx, m_then)
            if st.orelse is not None:
                vexec(st.orelse.stmts, env, ctx, mask & ~c)
        elif isinstance(st, A.CallStmt):
            ctx.call(st.call, env, mask)
        else:
            raise LowerError(f"line {st.line}: unsupported statement in "
                             f"parallel body: {type(st).__name__}")


def _mentions(e: A.Expr, name: str) -> bool:
    return any(isinstance(n, A.Name) and n.ident == name for n in A.walk(e))


def _strip_self(e: A.Binary, name: str, env, ctx, mask):
    """sum = sum + expr  →  expr (the self operand removed)."""
    if isinstance(e.left, A.Name) and e.left.ident == name and e.op == "+":
        return veval(e.right, env, ctx, mask)
    if isinstance(e.right, A.Name) and e.right.ident == name and e.op == "+":
        return veval(e.left, env, ctx, mask)
    raise LowerError(f"line {e.line}: unsupported accumulation form")


# ---------------------------------------------------------------------------
# forall classification
# ---------------------------------------------------------------------------

def _iter_info(ex, it: A.Expr, frame):
    """('nodes'|'neighbors'|'nodes_to'|'batch', base-arg)"""
    import repro.core.dsl.codegen as CG
    if isinstance(it, A.Call) and isinstance(it.func, A.Attr):
        m = it.func.name
        if m == "nodes":
            return "nodes", None
        if m in ("neighbors", "nodes_to"):
            return m, it.args[0]
        if m == "currentBatch":
            base = ex.eval_host(it, frame)
            return "batch", base
    if isinstance(it, A.Name):
        v = frame.lookup(it.ident)
        if isinstance(v, CG.UpdatesRef):
            return "batch", v
    raise LowerError(f"line {it.line}: unsupported forall iterator")


def classify_forall(ex, fa: A.ForAll, frame) -> str:
    kind, _ = _iter_info(ex, fa.iter, frame)
    inner = [s for s in fa.body.stmts if isinstance(s, A.ForAll)]
    if kind == "nodes":
        if not inner:
            return "vertex"
        ik, _ = _iter_info(ex, inner[0].iter, frame)
        sub = [s for s in inner[0].body.stmts if isinstance(s, A.ForAll)]
        if sub:
            return "wedge_static"
        return "edge"
    if kind == "batch":
        if inner:
            return "wedge_batch"
        return "scatter"
    raise LowerError(f"line {fa.line}: forall over {kind} at "
                     f"statement level")


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _real_mask(engine):
    return jnp.arange(engine.n_pad, dtype=INT) < engine.n_real


def _needs_outdeg(node: A.Node) -> bool:
    return any(isinstance(n, A.Call) and isinstance(n.func, A.Attr)
               and n.func.name == "count_outNbrs" for n in A.walk(node))


def _gather_props(ex, frame, extra: Optional[Dict[str, Any]] = None):
    props = dict(frame.props_arrays())
    props["_real"] = _real_mask(ex.engine)
    if extra:
        props.update(extra)
    return props


def _write_back(frame, props: Dict[str, Any]):
    frame.write_back({k: v for k, v in props.items()
                      if not k.startswith("_")})


# ===========================================================================
# vertex sweeps
# ===========================================================================

class VertexCtx(VecCtx):
    """Elementwise sweep over vertices; obj values are IdLane indices."""

    def __init__(self, ex, frame, props: Dict[str, Any], n_pad: int):
        super().__init__(ex, frame)
        self.props = props
        self.n_pad = n_pad

    def read_attr(self, obj, name, env):
        import repro.core.dsl.codegen as CG
        if isinstance(obj, IdLane):
            arr = self.props[name]
            if obj.identity:
                return arr
            return arr[jnp.clip(obj.idx, 0, self.n_pad - 1)]
        if isinstance(obj, CG.PropRef):
            return self.props[obj.name]
        raise LowerError(f"cannot read .{name}")

    def write_attr(self, obj, name, value, mask, env):
        if not isinstance(obj, IdLane):
            raise LowerError(f"cannot write .{name}")
        arr = self.props[name]
        value = _as_raw(value)
        # identity index → where-merge; general index → masked scatter
        if obj.identity:
            self.props[name] = _where(mask, value, arr)
        else:
            tgt = jnp.where(mask, obj.idx, self.n_pad)
            val = jnp.broadcast_to(jnp.asarray(value, arr.dtype),
                                   obj.idx.shape)
            self.props[name] = arr.at[tgt].set(val, mode="drop")

    def call(self, e: A.Call, env, mask):
        if isinstance(e.func, A.Name) and e.func.ident == "abs":
            return jnp.abs(_as_raw(veval(e.args[0], env, self, mask)))
        if isinstance(e.func, A.Attr) and e.func.name == "count_outNbrs":
            x = veval(e.args[0], env, self, mask)
            return self.props["_outdeg"][jnp.clip(_as_raw(x), 0,
                                                  self.n_pad - 1)]
        raise LowerError(f"line {e.line}: unsupported call in vertex sweep")


class _Iota:
    pass


def make_vertex_fn(ex, fa: A.ForAll, frame,
                   flag_name: Optional[str] = None) -> Callable:
    """Stage ``forall (v in g.nodes().filter(F)) { body }`` into an
    elementwise fn(props) -> props (with '_changed' when flag-tracked)."""
    engine = ex.engine
    n_pad = engine.n_pad

    def fn(props: Dict[str, Any]) -> Dict[str, Any]:
        ctx = VertexCtx(ex, frame, dict(props), n_pad)
        ctx.flag_name = flag_name
        lane = IdLane(jnp.arange(n_pad, dtype=INT), identity=True)
        env = {fa.var: lane}
        mask = props["_real"]
        if fa.filter is not None:
            fmask = _as_raw(veval(fa.filter, _FilterEnv(env, ctx, fa.var),
                                  ctx))
            mask = mask & fmask
        vexec(fa.body.stmts, env, ctx, mask)
        out = ctx.props
        if flag_name is not None:
            ch = ctx.changed if ctx.changed is not None \
                else jnp.zeros((n_pad,), BOOL)
            out["_changed"] = ch
        return out

    return fn


class _FilterEnv(dict):
    """filter(modified == True): bare prop names refer to the loop var's
    own attributes (paper shorthand)."""

    def __init__(self, base, ctx, var):
        super().__init__(base)
        self._ctx = ctx
        self._var = var

    def __missing__(self, key):
        if key in self._ctx.props:
            return self._ctx.props[key]
        raise KeyError(key)

    def __contains__(self, key):
        return super().__contains__(key) or key in self._ctx.props


# ===========================================================================
# edge sweeps
# ===========================================================================

@dataclasses.dataclass
class MinGroup:
    prop: str
    cand: A.Expr                    # candidate value expression
    guards: List[A.Expr]            # extra eligibility conjuncts
    kind: str = "min"               # 'min' | 'max'
    argmin: Optional[str] = None    # prop assigned the winning source id
    or_rides: List[str] = dataclasses.field(default_factory=list)
    changed: bool = False           # `finished = False` rides the update


@dataclasses.dataclass
class OrGroup:
    prop: str
    guards: List[A.Expr]


@dataclasses.dataclass
class AccumGroup:
    local: str                      # local scalar accumulated in the loop
    value: A.Expr


@dataclasses.dataclass
class EdgePlan:
    orientation: str                # 'push' | 'pull'
    outer: str
    inner: str
    filter: Optional[A.Expr]
    mins: List[MinGroup]
    ors: List[OrGroup]
    accums: List[AccumGroup]
    edge_vars: Dict[str, Tuple[A.Expr, A.Expr]]
    pre_stmts: List[A.Stmt]         # outer-body decls before the inner loop
    post_stmts: List[A.Stmt]        # outer-body stmts after the inner loop
    line: int = 0


def plan_edge_sweep(ex, fa: A.ForAll, frame,
                    flag_name: Optional[str]) -> EdgePlan:
    inner = next(s for s in fa.body.stmts if isinstance(s, A.ForAll))
    i = fa.body.stmts.index(inner)
    pre = fa.body.stmts[:i]
    post = fa.body.stmts[i + 1:]
    ik, _ = _iter_info(ex, inner.iter, frame)
    orientation = "push" if ik == "neighbors" else "pull"
    plan = EdgePlan(orientation=orientation, outer=fa.var, inner=inner.var,
                    filter=fa.filter, mins=[], ors=[], accums=[],
                    edge_vars={}, pre_stmts=pre, post_stmts=post,
                    line=fa.line)
    src_var = fa.var if orientation == "push" else inner.var
    dst_var = inner.var if orientation == "push" else fa.var

    # accumulators: scalar locals declared in pre (float sum = 0.0)
    accum_names = {s.name for s in pre if isinstance(s, A.Decl)
                   and not s.type.is_prop and s.type.name != "node"}

    def scan(stmts, guards):
        for st in stmts:
            if isinstance(st, A.Decl) and st.type.name == "edge":
                if isinstance(st.init, A.Call) and \
                        isinstance(st.init.func, A.Attr) and \
                        st.init.func.name == "get_edge":
                    plan.edge_vars[st.name] = (st.init.args[0],
                                               st.init.args[1])
                continue
            if isinstance(st, A.MultiAssign):
                _plan_multi(plan, st, guards, src_var, dst_var)
                continue
            if isinstance(st, A.Assign) and isinstance(st.target, A.Name):
                name = st.target.ident
                if name in accum_names:
                    val = st.value
                    if st.op == "+=":
                        plan.accums.append(AccumGroup(name, val))
                    elif isinstance(val, A.Binary):
                        if isinstance(val.left, A.Name) \
                                and val.left.ident == name:
                            plan.accums.append(AccumGroup(name, val.right))
                        else:
                            plan.accums.append(AccumGroup(name, val.left))
                    continue
                raise LowerError(f"line {st.line}: scalar write {name} in "
                                 f"edge body is not an accumulation")
            if isinstance(st, A.Assign) and isinstance(st.target, A.Attr):
                # standalone bool set: d.flag = True  → or-combine
                if isinstance(st.value, A.Bool) and st.value.value:
                    tgt = st.target
                    if _varname(tgt.obj) == dst_var:
                        plan.ors.append(OrGroup(tgt.name, list(guards)))
                        continue
                raise LowerError(f"line {st.line}: unsupported racy write")
            if isinstance(st, A.If):
                g2 = guards + [st.cond]
                hit = _plan_guarded_min(plan, st, guards, src_var, dst_var,
                                        flag_name)
                if hit:
                    continue
                scan(st.then.stmts, g2)
                if st.orelse is not None:
                    neg = A.Unary(op="!", operand=st.cond, line=st.line)
                    scan(st.orelse.stmts, guards + [neg])
                continue
            raise LowerError(f"line {st.line}: unsupported statement in "
                             f"edge body: {type(st).__name__}")

    scan(inner.body.stmts, [])
    if inner.filter is not None:
        for g in plan.mins + plan.ors:
            g.guards.append(inner.filter)
    return plan


def _varname(e: A.Expr) -> Optional[str]:
    return e.ident if isinstance(e, A.Name) else None


def _plan_multi(plan: EdgePlan, st: A.MultiAssign, guards,
                src_var: str, dst_var: str):
    """<d.p, d.f, d.q> = <Min(d.p, cand), True, v>"""
    grp: Optional[MinGroup] = None
    rides: List[Tuple[A.Expr, A.Expr]] = []
    for tgt, val in zip(st.targets, st.values):
        if isinstance(val, A.MinMax):
            if not isinstance(tgt, A.Attr):
                raise LowerError(f"line {st.line}: Min target not a "
                                 f"property")
            cand = None
            for a in val.args:
                if isinstance(a, A.Attr) and a.name == tgt.name and \
                        _varname(a.obj) == _varname(tgt.obj):
                    continue
                cand = a
            if cand is None:
                raise LowerError(f"line {st.line}: cannot find Min "
                                 f"candidate")
            grp = MinGroup(prop=tgt.name, cand=cand, guards=list(guards),
                           kind="min" if val.op == "Min" else "max")
        else:
            rides.append((tgt, val))
    if grp is None:
        raise LowerError(f"line {st.line}: multi-assignment without "
                         f"Min/Max")
    for tgt, val in rides:
        if not isinstance(tgt, A.Attr):
            raise LowerError(f"line {st.line}: bad ride target")
        if isinstance(val, A.Bool) and val.value:
            grp.or_rides.append(tgt.name)
        elif isinstance(val, A.Name) and val.ident == src_var:
            grp.argmin = tgt.name
        else:
            raise LowerError(f"line {st.line}: unsupported ride value")
    plan.mins.append(grp)


def _plan_guarded_min(plan: EdgePlan, st: A.If, guards, src_var, dst_var,
                      flag_name) -> bool:
    """if (d.p > cand) { d.p = cand; d.q = src; finished = False; }"""
    conj = _conjuncts(st.cond)
    min_prop, cand, kind = None, None, None
    extra = []
    for c in conj:
        if isinstance(c, A.Binary) and c.op in (">", "<") and \
                isinstance(c.left, A.Attr) and \
                _varname(c.left.obj) == dst_var:
            min_prop = c.left.name
            cand = c.right
            kind = "min" if c.op == ">" else "max"
        else:
            extra.append(c)
    if min_prop is None or st.orelse is not None:
        return False
    # the body must assign exactly that prop (same candidate), optional
    # argmin ride, optional flag ride
    grp = MinGroup(prop=min_prop, cand=cand, guards=list(guards) + extra,
                   kind=kind)
    matched = False
    for s in st.then.stmts:
        if isinstance(s, A.Assign) and isinstance(s.target, A.Attr) and \
                s.target.name == min_prop and \
                _varname(s.target.obj) == dst_var:
            matched = True
        elif isinstance(s, A.Assign) and isinstance(s.target, A.Attr) and \
                isinstance(s.value, A.Name) and s.value.ident == src_var:
            grp.argmin = s.target.name
        elif isinstance(s, A.Assign) and isinstance(s.target, A.Attr) and \
                isinstance(s.value, A.Bool) and s.value.value:
            grp.or_rides.append(s.target.name)
        elif isinstance(s, A.Assign) and isinstance(s.target, A.Name) and \
                s.target.ident == flag_name and \
                isinstance(s.value, A.Bool) and not s.value.value:
            grp.changed = True
        else:
            return False
    if not matched:
        return False
    plan.mins.append(grp)
    return True


def _conjuncts(e: A.Expr) -> List[A.Expr]:
    if isinstance(e, A.Binary) and e.op == "&&":
        return _conjuncts(e.left) + _conjuncts(e.right)
    return [e]


class EdgeFnCtx(VecCtx):
    """Evaluation context inside edge_fn: s/d views + lane weight."""

    def __init__(self, ex, frame, plan: EdgePlan, s, d, w):
        super().__init__(ex, frame)
        self.plan = plan
        self.s, self.d, self.w = s, d, w

    def _view(self, side):
        return self.s if side == "s" else self.d

    def read_attr(self, obj, name, env):
        if isinstance(obj, SideMarker):
            return self._view(obj.side)[name]
        if isinstance(obj, EdgeSym):
            if name == "weight":
                return self.w
            raise LowerError(f"edge property .{name} unavailable inside an "
                             f"edge sweep (use weight)")
        import repro.core.dsl.codegen as CG
        if isinstance(obj, CG.PropRef):
            raise LowerError(f"whole-property read .{name}")
        raise LowerError(f"cannot read .{name} in edge fn")

    def call(self, e: A.Call, env, mask):
        if isinstance(e.func, A.Name) and e.func.ident == "abs":
            return jnp.abs(_as_raw(veval(e.args[0], env, self, mask)))
        if isinstance(e.func, A.Attr):
            if e.func.name == "count_outNbrs":
                x = veval(e.args[0], env, self, mask)
                if isinstance(x, SideMarker):
                    return self._view(x.side)["_outdeg"]
            if e.func.name == "get_edge":
                return EdgeSym(a=e.args[0], b=e.args[1])
        raise LowerError(f"line {e.line}: unsupported call in edge sweep")


def build_edge_sweep(ex, plan: EdgePlan, frame,
                     track_changed: bool) -> Tuple[EdgeSweep, bool]:
    """EdgePlan → EdgeSweep (+ whether '_changed' is produced)."""
    engine = ex.engine
    outer_side = "s" if plan.orientation == "push" else "d"
    inner_side = "d" if plan.orientation == "push" else "s"

    def bind(ctx):
        env = {plan.outer: SideMarker(outer_side),
               plan.inner: SideMarker(inner_side)}
        for name, (a, b) in plan.edge_vars.items():
            env[name] = EdgeSym(a=a, b=b)
        return env

    def outer_mask_edge(ctx, env):
        view = ctx._view(outer_side)
        m = view["_real"]
        if plan.filter is not None:
            fenv = _SideFilterEnv(env, view)
            m = m & _as_raw(veval(plan.filter, fenv, ctx))
        return m

    def edge_fn(s, d, w):
        ctx = EdgeFnCtx(ex, frame, plan, s, d, w)
        env = bind(ctx)
        base = outer_mask_edge(ctx, env)
        out = {}
        for g in plan.mins:
            m = base
            for gd in g.guards:
                m = m & _as_raw(veval(gd, env, ctx))
            out[g.prop] = (_as_raw(veval(g.cand, env, ctx)), m)
        for g in plan.ors:
            m = base
            for gd in g.guards:
                m = m & _as_raw(veval(gd, env, ctx))
            out[g.prop] = (m, m)
        for g in plan.accums:
            val = _as_raw(veval(g.value, env, ctx))
            out["_red_" + g.local] = (val, base)
        return out

    reduces: Dict[str, Reduce] = {}
    for g in plan.mins:
        reduces[g.prop] = Reduce(g.kind)
        if g.argmin is not None:
            reduces[g.argmin] = Reduce("argmin", of=g.prop)
    for g in plan.ors:
        reduces[g.prop] = Reduce("or")
    for g in plan.accums:
        reduces["_red_" + g.local] = Reduce("sum")

    has_changed = track_changed and (any(g.changed for g in plan.mins)
                                     or bool(plan.ors))

    def post_fn(p, red, hit):
        props = dict(p)
        n_pad = props["_real"].shape[0]
        changed = jnp.zeros((n_pad,), BOOL)
        for g in plan.mins:
            cur = props[g.prop]
            if g.kind == "min":
                better = hit[g.prop] & (red[g.prop] < cur)
            else:
                better = hit[g.prop] & (red[g.prop] > cur)
            props[g.prop] = jnp.where(better, red[g.prop], cur)
            if g.argmin is not None:
                props[g.argmin] = _where(better, red[g.argmin],
                                         props[g.argmin])
            for f in g.or_rides:
                props[f] = props[f] | better
            if g.changed:
                changed = changed | better
        for g in plan.ors:
            newly = red[g.prop] & ~props[g.prop]
            props[g.prop] = props[g.prop] | red[g.prop]
            changed = changed | newly
        # post-inner elementwise tail (PR: val / diff / pageRank_nxt)
        if plan.post_stmts or plan.accums:
            ctx = VertexCtx(ex, frame, props, n_pad)
            env = {plan.outer: IdLane(jnp.arange(n_pad, dtype=INT),
                                      identity=True)}
            mask = props["_real"]
            if plan.filter is not None:
                mask = mask & _as_raw(veval(plan.filter,
                                            _FilterEnv(env, ctx, plan.outer),
                                            ctx))
            for g in plan.accums:
                env[g.local] = red["_red_" + g.local]
            # function-scope scalar accumulators (diff) become _acc_ arrays
            ctx.accums = {k[5:]: jnp.zeros((n_pad,), F32)
                          for k in props if k.startswith("_acc_")}
            vexec(plan.post_stmts, env, ctx, mask)
            props = ctx.props
            for name, arr in ctx.accums.items():
                props["_acc_" + name] = arr
        if has_changed:
            props["_changed"] = changed
        return props

    return EdgeSweep(edge_fn=edge_fn, reduces=reduces, post_fn=post_fn), \
        has_changed


class _SideFilterEnv(dict):
    def __init__(self, base, view):
        super().__init__(base)
        self._view = view

    def __missing__(self, key):
        return self._view[key]

    def __contains__(self, key):
        if super().__contains__(key):
            return True
        try:
            self._view[key]
            return True
        except Exception:
            return False


# ===========================================================================
# loops (fixedPoint / do-while / while)
# ===========================================================================

def _find_accum_names(stmts: List[A.Stmt], frame) -> List[str]:
    """Function-scope scalars reset + accumulated inside the loop (diff)."""
    out = []
    for st in stmts:
        if isinstance(st, A.Assign) and isinstance(st.target, A.Name) \
                and st.op == "=" and isinstance(st.value, A.Num):
            out.append(st.target.ident)
    return out


def _find_counters(stmts: List[A.Stmt]) -> List[str]:
    """x = x + 1 loop counters → mapped to the iteration index."""
    out = []
    for st in stmts:
        if isinstance(st, A.Assign) and isinstance(st.target, A.Name):
            v = st.value
            if isinstance(v, A.Binary) and v.op == "+" and \
                    isinstance(v.left, A.Name) and \
                    v.left.ident == st.target.ident and \
                    isinstance(v.right, A.Num) and v.right.value == 1:
                out.append(st.target.ident)
    return out


def run_loop(ex, stmts: List[A.Stmt], frame, kind: str,
             flag: Optional[str] = None, cond: Optional[A.Expr] = None):
    """Lower fixedPoint / do-while / while around one core sweep."""
    engine = ex.engine
    foralls = [s for s in stmts if isinstance(s, A.ForAll)]
    if not foralls:
        raise LowerError("loop without aggregate body is not lowerable")

    flag_name = flag
    if kind == "while" and isinstance(cond, A.Unary) and cond.op == "!" \
            and isinstance(cond.operand, A.Name):
        flag_name = cond.operand.ident

    kinds = [ex.staged(("kind", id(fa)),
                       lambda fa=fa: classify_forall(ex, fa, frame))
             for fa in foralls]
    core_idx = kinds.index("edge") if "edge" in kinds else 0
    core = foralls[core_idx]
    core_kind = kinds[core_idx]

    accum_names = _find_accum_names(stmts, frame)
    counters = _find_counters(stmts)

    # trailing post items: statements after the core forall
    core_pos = stmts.index(core)
    post_items = [s for s in stmts[core_pos + 1:]
                  if not (isinstance(s, A.Assign)
                          and isinstance(s.target, A.Name)
                          and (s.target.ident in counters
                               or s.target.ident == flag_name))]

    needs_outdeg = _needs_outdeg(core)
    extra: Dict[str, Any] = {}
    if needs_outdeg:
        extra["_outdeg"] = engine.out_degrees(frame.graph().box.value) \
            .astype(F32)
    for name in accum_names:
        extra["_acc_" + name] = jnp.zeros((engine.n_pad,), F32)

    if core_kind == "vertex":
        _run_vertex_loop(ex, core, frame, flag_name, extra)
        return

    plan = ex.staged(("plan", id(core), flag_name),
                     lambda: plan_edge_sweep(ex, core, frame, flag_name))
    sweep, has_changed = build_edge_sweep(ex, plan, frame,
                                          track_changed=kind == "while")

    post_closures = _stage_post_items(ex, post_items, frame)
    if post_closures:
        base_post = sweep.post_fn

        def post_fn(p, red, hit):
            props = base_post(p, red, hit)
            for c in post_closures:
                props = c(props)
            return props
        sweep = EdgeSweep(edge_fn=sweep.edge_fn, reduces=sweep.reduces,
                          post_fn=post_fn, gather_form=sweep.gather_form)

    if has_changed:
        extra["_changed"] = jnp.zeros((engine.n_pad,), BOOL)

    cond_fn = _make_cond(ex, frame, kind, flag_name, cond, accum_names,
                         counters, has_changed)

    props = _gather_props(ex, frame, extra)
    gref = frame.graph()
    props = engine.fixed_point(gref.box.value, sweep, props, cond_fn,
                               max_iter=_BIG)
    _write_back(frame, props)


def _make_cond(ex, frame, kind, flag_name, cond, accum_names, counters,
               has_changed):
    if kind == "fixedPoint":
        # fixedPoint until (f : !p) — converged when p is False everywhere
        if isinstance(cond, A.Unary) and cond.op == "!" and \
                isinstance(cond.operand, A.Name):
            prop = cond.operand.ident
            return lambda p, it, col: col.any(p[prop])
        raise LowerError("fixedPoint condition must be !<boolean prop>")
    if kind == "while":
        # while (!finished) with change-tracked sweep
        if has_changed:
            return lambda p, it, col: (it == 0) | col.any(p["_changed"])
        return lambda p, it, col: (it == 0)
    # do-while: scalar condition over accumulators / counters
    def cond_fn(p, it, col):
        def ev(e: A.Expr):
            if isinstance(e, A.Num):
                return e.value
            if isinstance(e, A.Name):
                if e.ident in accum_names:
                    return col.sum(p["_acc_" + e.ident])
                if e.ident in counters:
                    return it             # it bodies completed == counter
                return frame.lookup(e.ident)
            if isinstance(e, A.Binary):
                import repro.core.dsl.codegen as CG
                return CG._binop(e.op, ev(e.left), ev(e.right))
            if isinstance(e, A.Unary):
                v = ev(e.operand)
                return ~v if e.op == "!" else -v
            raise LowerError("unsupported do-while condition term")
        return (it == 0) | ev(cond)
    return cond_fn


def _run_vertex_loop(ex, fa: A.ForAll, frame, flag_name, extra):
    """while(!f){ f=True; forall(vertex...) } → vertex_map + while_loop."""
    engine = ex.engine
    fn = make_vertex_fn(ex, fa, frame, flag_name=flag_name)

    def outer(props):
        state = dict(props)
        state["_changed"] = jnp.ones((engine.n_pad,), BOOL)

        def cond(st):
            return jnp.any(st["_changed"])

        def body(st):
            st = dict(st)
            st["_changed"] = jnp.zeros((engine.n_pad,), BOOL)
            return fn(st)

        out = jax.lax.while_loop(cond, body, state)
        out.pop("_changed")
        return out

    props = _gather_props(ex, frame, extra)
    gref = frame.graph()
    props = engine.vertex_map(gref.box.value, outer, props)
    _write_back(frame, props)


def _stage_post_items(ex, items: List[A.Stmt], frame) -> List[Callable]:
    """Trailing loop statements → closures(props)->props run in post_fn."""
    out = []
    engine = ex.engine
    import repro.core.dsl.codegen as CG
    for st in items:
        if isinstance(st, A.Assign) and isinstance(st.target, A.Name):
            # whole-prop copy: modified = modified_nxt
            tgt = st.target.ident
            ref = frame.lookup(tgt)
            if isinstance(ref, CG.PropRef) and isinstance(st.value, A.Name):
                src = st.value.ident

                def copy(props, tgt=tgt, src=src):
                    props = dict(props)
                    props[tgt] = props[src]
                    return props
                out.append(copy)
                continue
            raise LowerError(f"line {st.line}: unsupported loop tail "
                             f"assignment")
        if isinstance(st, A.CallStmt):
            c = st.call
            if isinstance(c.func, A.Attr) and c.func.name in (
                    "attachNodeProperty", "attachEdgeProperty"):
                sets = []
                for kw in c.args:
                    ref = frame.lookup(kw.name)
                    val = ex.eval_host(kw.value, frame) \
                        if not isinstance(kw.value, (A.Bool, A.Num, A.Inf)) \
                        else None
                    cval = kw.value
                    if isinstance(cval, A.Bool):
                        val = cval.value
                    elif isinstance(cval, A.Num):
                        val = cval.value
                    elif isinstance(cval, A.Inf):
                        val = INF_W
                    sets.append((kw.name, val, ref.dtype))

                def attach(props, sets=sets):
                    props = dict(props)
                    # size off the carry's own vertex length, not the
                    # engine's n_pad: under dist's shard_map the props
                    # in flight are (block,)-local shards
                    n = props["_real"].shape[0]
                    for name, val, dt in sets:
                        props[name] = jnp.full((n,), val, dt)
                    return props
                out.append(attach)
                continue
            raise LowerError(f"line {st.line}: unsupported loop tail call")
        if isinstance(st, A.ForAll):
            if classify_forall(ex, st, frame) != "vertex":
                raise LowerError(f"line {st.line}: only vertex foralls may "
                                 f"follow the core sweep")
            fn = make_vertex_fn(ex, st, frame)
            out.append(lambda props, fn=fn: fn(props))
            continue
        raise LowerError(f"line {st.line}: unsupported loop statement "
                         f"{type(st).__name__}")
    return out


# ===========================================================================
# host-level forall
# ===========================================================================

def run_forall(ex, fa: A.ForAll, frame):
    engine = ex.engine
    kind = ex.staged(("kind", id(fa)),
                     lambda: classify_forall(ex, fa, frame))
    if kind == "vertex":
        extra = {}
        if _needs_outdeg(fa):
            extra["_outdeg"] = engine.out_degrees(
                frame.graph().box.value).astype(F32)
        fn = make_vertex_fn(ex, fa, frame)
        props = _gather_props(ex, frame, extra)
        props = engine.vertex_map(frame.graph().box.value, fn, props)
        _write_back(frame, props)
        return
    if kind == "edge":
        extra = {}
        if _needs_outdeg(fa):
            extra["_outdeg"] = engine.out_degrees(
                frame.graph().box.value).astype(F32)
        plan = ex.staged(("plan", id(fa), None),
                         lambda: plan_edge_sweep(ex, fa, frame,
                                                 flag_name=None))
        sweep, _ = build_edge_sweep(ex, plan, frame, track_changed=False)
        props = _gather_props(ex, frame, extra)
        props = engine.sweep(frame.graph().box.value, sweep, props)
        _write_back(frame, props)
        return
    if kind in ("wedge_static", "wedge_batch"):
        run_wedge(ex, fa, frame, kind)
        return
    raise LowerError(f"line {fa.line}: cannot lower forall kind {kind}")


# ===========================================================================
# wedges (triangle counting)
# ===========================================================================

class WedgeVecCtx(VecCtx):
    """pair_fn body context: ids x/y/z + edge-flag resolution."""

    def __init__(self, ex, frame, wctx, bindings: Dict[str, str],
                 eprops: Dict[str, Any], accum_names):
        super().__init__(ex, frame)
        self.wctx = wctx                 # engine WedgeCtx
        self.bindings = bindings         # DSL var -> 'x' | 'y' | 'z'
        self.eprops = eprops
        self.accums = {n: 0 for n in accum_names}

    def resolve(self, e: A.Expr, env):
        v = env.get(_varname(e)) if _varname(e) else None
        if isinstance(v, IdLane):
            return v.idx
        return _as_raw(veval(e, env, self))

    def read_attr(self, obj, name, env):
        if isinstance(obj, EdgeSym):
            a_role = self.bindings.get(_varname(obj.a), None)
            b_role = self.bindings.get(_varname(obj.b), None)
            if name == "weight":
                raise LowerError("edge weight unavailable in wedge sweep")
            if (a_role, b_role) == ("x", "z"):
                return self.wctx.nbr_flag(name)
            if (a_role, b_role) == ("y", "z"):
                return self.wctx.edge_flag(name, self._id("y", env),
                                           self._id("z", env))
            if (a_role, b_role) == ("x", "y"):
                return self.wctx.lane_flag(name)
            raise LowerError(f"cannot resolve edge flag .{name} for "
                             f"({a_role},{b_role})")
        raise LowerError(f"cannot read .{name} in wedge body")

    def _id(self, role, env):
        for var, r in self.bindings.items():
            if r == role:
                return env[var].idx
        raise LowerError(f"no {role} binding")

    def call(self, e: A.Call, env, mask):
        if isinstance(e.func, A.Attr) and e.func.name == "is_an_edge":
            a = self.resolve(e.args[0], env)
            b = self.resolve(e.args[1], env)
            return self.wctx.is_edge(a, b)
        if isinstance(e.func, A.Attr) and e.func.name == "get_edge":
            return EdgeSym(a=e.args[0], b=e.args[1])
        if isinstance(e.func, A.Name) and e.func.ident == "abs":
            return jnp.abs(_as_raw(veval(e.args[0], env, self, mask)))
        raise LowerError(f"line {e.line}: unsupported call in wedge body")


def _accum_targets(fa: A.ForAll, frame) -> List[str]:
    """Function-scope scalars '+=' -accumulated inside the wedge body."""
    import repro.core.dsl.codegen as CG
    names = []
    for n in A.walk(fa):
        if isinstance(n, A.Assign) and n.op in ("+=",) and \
                isinstance(n.target, A.Name):
            try:
                v = frame.lookup(n.target.ident)
            except CG.CodegenError:
                continue
            if not isinstance(v, (CG.PropRef, CG.GraphRef)):
                names.append(n.target.ident)
    seen = []
    for n in names:
        if n not in seen:
            seen.append(n)
    return seen


def run_wedge(ex, fa: A.ForAll, frame, kind: str):
    engine = ex.engine
    import repro.core.dsl.codegen as CG
    g = frame.graph().box.value
    accum_names = ex.staged(("wedge_accums", id(fa)),
                            lambda: _accum_targets(fa, frame))
    if not accum_names:
        raise LowerError(f"line {fa.line}: wedge loop without counters")

    lane_flags: Dict[str, Any] = {}
    # every propEdge visible in the frame rides along as lane flags
    f = frame
    while f is not None:
        for k, v in f.env.items():
            if isinstance(v, CG.PropRef) and v.is_edge and \
                    v.box.value is not None and k not in lane_flags:
                lane_flags[k] = v.box.value
        f = f.parent

    if kind == "wedge_static":
        def _shape_static():
            inner1 = next(s for s in fa.body.stmts
                          if isinstance(s, A.ForAll))
            inner2 = next(s for s in inner1.body.stmts
                          if isinstance(s, A.ForAll))
            bindings = {fa.var: "x", inner1.var: "y", inner2.var: "z"}
            filters = [e for e in (inner1.filter, inner2.filter)
                       if e is not None]
            return bindings, filters, inner2.body.stmts
        bindings, filters, body = ex.staged(("wedge", id(fa)),
                                            _shape_static)
    else:
        # batch iteration: v1 = u.source; v2 = u.destination; forall v3 ...
        ups = _iter_info(ex, fa.iter, frame)[1]
        batch = frame.current_batch
        if batch is None:
            raise LowerError(f"line {fa.line}: batch wedge outside Batch")
        sel = ups.selector if isinstance(ups, CG.UpdatesRef) else "both"
        if sel == "del":
            it_flags = engine.batch_edge_flags(
                g, batch.del_src, batch.del_dst, batch.del_mask)
        elif sel == "add":
            it_flags = engine.batch_edge_flags(
                g, batch.add_src, batch.add_dst, batch.add_mask)
        else:
            fa_ = engine.batch_edge_flags(
                g, batch.add_src, batch.add_dst, batch.add_mask)
            fd_ = engine.batch_edge_flags(
                g, batch.del_src, batch.del_dst, batch.del_mask)
            it_flags = fa_ | fd_
        lane_flags["_iter"] = it_flags

        def _shape_batch():
            inner1 = next(s for s in fa.body.stmts
                          if isinstance(s, A.ForAll))
            bindings = {fa.var: None, inner1.var: "z"}
            # resolve v1/v2 decls
            for st in fa.body.stmts:
                if isinstance(st, A.Decl) and st.type.name == "node" and \
                        isinstance(st.init, A.Attr):
                    if st.init.name == "source":
                        bindings[st.name] = "x"
                    elif st.init.name == "destination":
                        bindings[st.name] = "y"
            filters = [inner1.filter] if inner1.filter is not None else []
            return bindings, filters, inner1.body.stmts
        bindings, filters, body = ex.staged(("wedge", id(fa)),
                                            _shape_batch)

    def pair_fn(x, y, z, z_ok, wctx):
        ctx = WedgeVecCtx(ex, frame, wctx, bindings, lane_flags,
                          accum_names)
        env: Dict[str, Any] = {}
        for var, role in bindings.items():
            if role == "x":
                env[var] = IdLane(x)
            elif role == "y":
                env[var] = IdLane(y)
            elif role == "z":
                env[var] = IdLane(z)
        mask = z_ok
        if kind == "wedge_batch":
            mask = mask & wctx.lane_flag("_iter")
        for fe in filters:
            mask = mask & _as_raw(veval(fe, env, ctx))
        zero = jnp.zeros(jnp.shape(x), jnp.int32) if hasattr(x, "shape") \
            else jnp.zeros((), jnp.int32)
        for n in accum_names:
            ctx.accums[n] = jnp.zeros_like(zero)
        vexec(body, env, ctx, mask)
        return tuple(ctx.accums[n] for n in accum_names)

    out_example = tuple(jnp.zeros((), jnp.int32) for _ in accum_names)
    totals = engine.count_wedges(g, pair_fn, lane_flags=lane_flags,
                                 out_example=out_example)
    if not isinstance(totals, tuple):
        totals = (totals,)
    from repro.core.dsl.codegen import _set_env
    for name, total in zip(accum_names, totals):
        cur = frame.lookup(name)
        _set_env(frame, name, cur + total)


# ===========================================================================
# OnAdd / OnDelete scatters
# ===========================================================================

class ScatterCtx(VecCtx):
    """OnUpdate body: lanes are batch entries; writes scatter to props
    (or mark edge-flag lanes via batch_edge_flags)."""

    def __init__(self, ex, frame, props, n_pad, upd_kind, batch):
        super().__init__(ex, frame)
        self.props = props
        self.n_pad = n_pad
        self.upd_kind = upd_kind
        self.batch = batch
        self.edge_flag_writes: List[Tuple[str, Any, Any, Any]] = []

    def read_attr(self, obj, name, env):
        import repro.core.dsl.codegen as CG
        if isinstance(obj, IdLane):
            arr = self.props.get(name)
            if arr is None:
                ref = self.frame.lookup(name)
                arr = ref.box.value
            return arr[jnp.clip(obj.idx, 0, self.n_pad - 1)]
        if isinstance(obj, _UpdateLane):
            if name == "source":
                return IdLane(self.batch.add_src if self.upd_kind == "add"
                              else self.batch.del_src)
            if name == "destination":
                return IdLane(self.batch.add_dst if self.upd_kind == "add"
                              else self.batch.del_dst)
            raise LowerError(f"update has no attribute .{name}")
        if isinstance(obj, EdgeSym):
            if name == "weight":
                if self.upd_kind == "add":
                    return self.batch.add_w
                raise LowerError("deleted edges carry no weight")
            # edge-prop read on the update edge
            ref = self.frame.lookup(name)
            import repro.core.dsl.codegen as CG2
            if isinstance(ref, CG2.PropRef) and ref.is_edge:
                raise LowerError("edge-prop reads in OnUpdate are not "
                                 "supported")
        raise LowerError(f"cannot read .{name} in OnUpdate body")

    def write_attr(self, obj, name, value, mask, env):
        import repro.core.dsl.codegen as CG
        if isinstance(obj, IdLane):
            arr = self.props[name]
            tgt = jnp.where(mask, obj.idx, self.n_pad)
            val = jnp.broadcast_to(
                jnp.asarray(_as_raw(value), arr.dtype), obj.idx.shape)
            self.props[name] = arr.at[tgt].set(val, mode="drop")
            return
        if isinstance(obj, EdgeSym):
            # e.modified = True on the update edge → lane flags
            if not (isinstance(value, (bool, np.bool_)) and value) and \
                    not (hasattr(value, "dtype") and bool(jnp.all(value))):
                raise LowerError("edge-prop writes must set True")
            a = env.get(_varname(obj.a))
            b = env.get(_varname(obj.b))
            self.edge_flag_writes.append((name, a.idx, b.idx, mask))
            return
        raise LowerError(f"cannot write .{name} in OnUpdate body")

    def call(self, e: A.Call, env, mask):
        if isinstance(e.func, A.Attr) and e.func.name == "get_edge":
            return EdgeSym(a=e.args[0], b=e.args[1])
        if isinstance(e.func, A.Name) and e.func.ident == "abs":
            return jnp.abs(_as_raw(veval(e.args[0], env, self, mask)))
        raise LowerError(f"line {e.line}: unsupported call in OnUpdate")


class _UpdateLane:
    pass


def run_onupdate(ex, st: A.OnUpdate, frame):
    engine = ex.engine
    batch = frame.current_batch
    if batch is None:
        raise LowerError(f"line {st.line}: OnAdd/OnDelete outside Batch")
    props = _gather_props(ex, frame)
    ctx = ScatterCtx(ex, frame, props, engine.n_pad, st.kind, batch)
    env = {st.var: _UpdateLane()}
    mask = batch.add_mask if st.kind == "add" else batch.del_mask
    vexec(st.body.stmts, env, ctx, mask)
    _write_back(frame, ctx.props)
    # apply edge-flag lane writes
    import repro.core.dsl.codegen as CG
    g = frame.graph().box.value
    for name, qs, qd, m in ctx.edge_flag_writes:
        ref = frame.lookup(name)
        flags = engine.batch_edge_flags(g, qs, qd, m)
        if ref.box.value is None:
            ref.box.value = flags
        else:
            ref.box.value = ref.box.value | flags
