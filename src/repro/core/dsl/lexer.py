"""Tokenizer for the StarPlat-Dynamic DSL (paper appendix syntax)."""
from __future__ import annotations

import dataclasses
import re
from typing import Iterator, List

KEYWORDS = {
    # function kinds
    "Static", "Dynamic", "Incremental", "Decremental",
    # types
    "Graph", "node", "edge", "int", "long", "float", "double", "bool",
    "propNode", "propEdge", "updates",
    # control
    "if", "else", "while", "do", "for", "forall", "return",
    "fixedPoint", "until", "in", "filter",
    # dynamic constructs
    "Batch", "OnAdd", "OnDelete",
    # builtins / literals
    "Min", "Max", "True", "False", "INF",
}

_TOKEN_RE = re.compile(r"""
      (?P<comment>//[^\n]*|/\*.*?\*/)
    | (?P<num>\d+\.\d+|\.\d+|\d+)
    | (?P<ident>[A-Za-z_][A-Za-z_0-9]*)
    | (?P<op><=|>=|==|!=|&&|\|\||\+=|-=|\*=|/=|<|>|=|\+|-|\*|/|%|!|\.|,|;|:|
         \(|\)|\{|\}|\[|\])
    | (?P<ws>[ \t\r\n]+)
    | (?P<bad>.)
""", re.VERBOSE | re.DOTALL)


class LexError(SyntaxError):
    pass


@dataclasses.dataclass(frozen=True)
class Token:
    kind: str        # 'kw' | 'ident' | 'num' | 'op' | 'eof'
    text: str
    line: int
    col: int

    def __repr__(self):
        return f"{self.kind}:{self.text!r}@{self.line}"


def tokenize(src: str) -> List[Token]:
    toks: List[Token] = []
    line, col = 1, 1
    pos = 0
    while pos < len(src):
        m = _TOKEN_RE.match(src, pos)
        if m is None:  # pragma: no cover - regex has catch-all
            raise LexError(f"cannot tokenize at {line}:{col}")
        text = m.group(0)
        kind = m.lastgroup
        if kind == "bad":
            raise LexError(f"unexpected character {text!r} at {line}:{col}")
        if kind not in ("ws", "comment"):
            if kind == "ident" and text in KEYWORDS:
                toks.append(Token("kw", text, line, col))
            elif kind == "ident":
                toks.append(Token("ident", text, line, col))
            elif kind == "num":
                toks.append(Token("num", text, line, col))
            else:
                toks.append(Token("op", text, line, col))
        nl = text.count("\n")
        if nl:
            line += nl
            col = len(text) - text.rfind("\n")
        else:
            col += len(text)
        pos = m.end()
    toks.append(Token("eof", "", line, col))
    return toks
