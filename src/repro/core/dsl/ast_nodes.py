"""AST node definitions — the paper's intermediate representation (§3.4).

Every node carries the source line for diagnostics; the tree mirrors the
constructs in the paper's Fig. 5 (Dynamic SSSP AST): function roots,
declarations, assignments, if/while/do-while, forall (with optional
filter), fixedPoint, Batch, OnAdd/OnDelete, and the ``<a,b,c> = <...>``
atomic multi-assignment that carries the Min/Max constructs.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass
class Node:
    line: int = dataclasses.field(default=0, kw_only=True)


# --- types -----------------------------------------------------------------

@dataclasses.dataclass
class Type(Node):
    name: str                      # 'int' | 'bool' | ... | 'propNode' | ...
    arg: Optional[str] = None      # element type or graph name

    def __str__(self):
        return f"{self.name}<{self.arg}>" if self.arg else self.name

    @property
    def is_prop(self) -> bool:
        return self.name in ("propNode", "propEdge")


# --- expressions -------------------------------------------------------------

@dataclasses.dataclass
class Expr(Node):
    pass


@dataclasses.dataclass
class Num(Expr):
    value: float
    is_float: bool = False


@dataclasses.dataclass
class Bool(Expr):
    value: bool


@dataclasses.dataclass
class Inf(Expr):
    pass


@dataclasses.dataclass
class Name(Expr):
    ident: str


@dataclasses.dataclass
class Attr(Expr):
    obj: Expr
    name: str                      # v.dist, e.weight, u.source


@dataclasses.dataclass
class Call(Expr):
    func: Expr                     # Name or Attr (method call)
    args: List[Expr]


@dataclasses.dataclass
class Unary(Expr):
    op: str                        # '!' | '-'
    operand: Expr


@dataclasses.dataclass
class Binary(Expr):
    op: str
    left: Expr
    right: Expr


@dataclasses.dataclass
class MinMax(Expr):
    """Min(a, b) / Max(a, b) — the paper's atomic compare-assign carrier."""
    op: str                        # 'Min' | 'Max'
    args: List[Expr]


@dataclasses.dataclass
class Kwarg(Expr):
    """name = value inside a call: g.attachNodeProperty(dist=INF, ...)."""
    name: str
    value: Expr


# --- statements ---------------------------------------------------------------

@dataclasses.dataclass
class Stmt(Node):
    pass


@dataclasses.dataclass
class Block(Stmt):
    stmts: List[Stmt]


@dataclasses.dataclass
class Decl(Stmt):
    type: Type
    name: str
    init: Optional[Expr]


@dataclasses.dataclass
class Assign(Stmt):
    target: Expr                   # Name or Attr
    op: str                        # '=' | '+=' | '-='
    value: Expr


@dataclasses.dataclass
class MultiAssign(Stmt):
    """<t1, t2, ...> = <e1, e2, ...>;  (atomic; e1 may be Min/Max)."""
    targets: List[Expr]
    values: List[Expr]


@dataclasses.dataclass
class If(Stmt):
    cond: Expr
    then: Block
    orelse: Optional[Block]


@dataclasses.dataclass
class While(Stmt):
    cond: Expr
    body: Block


@dataclasses.dataclass
class DoWhile(Stmt):
    body: Block
    cond: Expr


@dataclasses.dataclass
class ForAll(Stmt):
    """forall/for (var in iter[.filter(cond)]) { body }

    parallel=True for ``forall``; ``for`` is a sequential neighbor
    iteration in the paper (we keep the distinction for the analysis,
    both vectorize identically on TPU).
    """
    var: str
    iter: Expr                     # g.nodes() / g.neighbors(v) / batch expr
    filter: Optional[Expr]
    body: Block
    parallel: bool


@dataclasses.dataclass
class FixedPoint(Stmt):
    """fixedPoint until (flagvar : convergence-expr) { body }"""
    flag: str
    cond: Expr
    body: Block


@dataclasses.dataclass
class BatchStmt(Stmt):
    """Batch(updates : batchSize) { body }"""
    updates: str
    batch_size: str
    body: Block


@dataclasses.dataclass
class OnUpdate(Stmt):
    """OnAdd/OnDelete (e in updates.currentBatch()) { body }"""
    kind: str                      # 'add' | 'delete'
    var: str
    source: Expr
    body: Block


@dataclasses.dataclass
class CallStmt(Stmt):
    call: Call


@dataclasses.dataclass
class Return(Stmt):
    value: Expr


# --- functions ------------------------------------------------------------------

@dataclasses.dataclass
class Param(Node):
    type: Type
    name: str


@dataclasses.dataclass
class FuncDef(Node):
    kind: str                      # 'Static' | 'Dynamic' | 'Incremental' | ...
    name: str                      # Incremental/Decremental may be anonymous
    params: List[Param]
    body: Block


@dataclasses.dataclass
class ProgramAST(Node):
    funcs: List[FuncDef]

    def func(self, name: str) -> FuncDef:
        for f in self.funcs:
            if f.name == name:
                return f
        raise KeyError(name)


def walk(node):
    """Yield every AST node under ``node`` (pre-order)."""
    yield node
    for f in dataclasses.fields(node):
        v = getattr(node, f.name)
        if isinstance(v, Node):
            yield from walk(v)
        elif isinstance(v, list):
            for item in v:
                if isinstance(item, Node):
                    yield from walk(item)
