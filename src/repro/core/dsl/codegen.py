"""Code generation: analysed AST → staged JAX programs on the Engine API.

This is the paper's backend (§4): where StarPlat emits OpenMP / MPI /
CUDA C++, we *stage* the same aggregate constructs into the engine
interface (`repro.core.engine.Engine`), so one compiled Program runs on
any of the three TPU-native backends ('jnp' | 'dist' | 'pallas').

Lowering map (paper construct → engine op):

  forall (v in g.nodes())           elementwise    → engine.vertex_map
  forall(v) { forall(nbr in
      g.neighbors/nodes_to(v)) }    edge sweep     → EdgeSweep + reduces
  nested wedge loops / batch+nbr    wedge sweep    → engine.count_wedges
  fixedPoint until (f : !p)         iteration      → engine.fixed_point
  do {...} while (scalar-cond)      iteration      → engine.fixed_point
  while (!f) { f=True; forall... }  iteration      → fixed_point / while
  Batch(U : bs)                     host loop over UpdateStream batches
  OnAdd/OnDelete                    masked scatters / batch_edge_flags
  g.updateCSRAdd/Del                engine.update_add/del (diff-CSR)
  g.propagateNodeFlags(p)           engine.propagate_flags
  <x.a,x.b,x.c> = <Min(..),True,v>  Reduce(min) + or-ride + argmin
  if (x.p > e) { x.p = e; x.q = v }  Reduce(min) + argmin   (race→combiner)

Races are *re-associated* into deterministic segment reductions rather
than guarded by atomics — the TPU-native synchronization (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import pathlib
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.dsl import ast_nodes as A
from repro.core.dsl.analysis import analyze, SemanticError
from repro.core.dsl.parser import parse
from repro.core.ir import EdgeSweep, Reduce
from repro.core.engine import Engine
from repro.graph.csr import CSR, INT, INF_W
from repro.graph.diffcsr import BOOL
from repro.graph.updates import UpdateStream, UpdateBatch

F32 = jnp.float32
_DTYPES = {"int": INT, "long": INT, "float": F32, "double": F32,
           "bool": BOOL}
_BIG = 1 << 30


class CodegenError(Exception):
    pass


# ---------------------------------------------------------------------------
# Runtime value wrappers
# ---------------------------------------------------------------------------

class Box:
    """Mutable cell so props passed to callees reflect writes back."""

    __slots__ = ("value",)

    def __init__(self, value=None):
        self.value = value


@dataclasses.dataclass
class PropRef:
    """A vertex- or edge-property binding (name local to this frame)."""
    name: str
    elem: str                  # 'int' | 'float' | 'bool'
    box: Box
    is_edge: bool = False

    @property
    def dtype(self):
        return _DTYPES[self.elem]


@dataclasses.dataclass
class GraphRef:
    box: Box                   # engine graph handle


@dataclasses.dataclass
class UpdatesRef:
    stream: Optional[UpdateStream]
    selector: str = "both"     # 'both' | 'del' | 'add'


@dataclasses.dataclass
class NodeIdx:
    """A node-typed value: a scalar index or a lane array of indices."""
    idx: Any


@dataclasses.dataclass
class EdgeRef:
    """edge e = g.get_edge(a, b): endpoints remembered symbolically."""
    a: Any
    b: Any
    weight: Any = None         # bound lane weights where known


@dataclasses.dataclass
class RunResult:
    g: Any
    props: Dict[str, np.ndarray]
    value: Any = None


# ---------------------------------------------------------------------------
# Frames
# ---------------------------------------------------------------------------

class Frame:
    """Per-function environment; shares engine/graph with its caller."""

    def __init__(self, engine: Engine, parent: Optional["Frame"] = None):
        self.engine = engine
        self.env: Dict[str, Any] = {}
        self.parent = parent
        self.current_batch: Optional[UpdateBatch] = None
        self.ret = None
        if parent is not None:
            self.current_batch = parent.current_batch

    def lookup(self, name: str):
        f: Optional[Frame] = self
        while f is not None:
            if name in f.env:
                return f.env[name]
            f = f.parent
        raise CodegenError(f"undefined name {name!r}")

    def graph(self) -> GraphRef:
        for v in self.env.values():
            if isinstance(v, GraphRef):
                return v
        if self.parent:
            return self.parent.graph()
        raise CodegenError("no graph in scope")

    # -- prop helpers -------------------------------------------------------
    def node_props(self) -> Dict[str, PropRef]:
        out = {}
        f: Optional[Frame] = self
        while f is not None:
            for k, v in f.env.items():
                if isinstance(v, PropRef) and not v.is_edge and k not in out:
                    out[k] = v
            f = f.parent
        return out

    def props_arrays(self) -> Dict[str, jax.Array]:
        return {k: v.box.value for k, v in self.node_props().items()
                if v.box.value is not None}

    def write_back(self, props: Dict[str, jax.Array]):
        refs = self.node_props()
        for k, arr in props.items():
            if k in refs:
                refs[k].box.value = arr


def _const_value(expr: A.Expr, elem: str):
    if isinstance(expr, A.Inf):
        return INF_W if elem in ("int",) else (jnp.inf if elem == "float"
                                               else INF_W)
    if isinstance(expr, A.Bool):
        return expr.value
    if isinstance(expr, A.Num):
        return expr.value
    if isinstance(expr, A.Unary) and expr.op == "-" \
            and isinstance(expr.operand, A.Num):
        return -expr.operand.value
    return None


def _is_int(x) -> bool:
    if isinstance(x, bool):
        return False
    if isinstance(x, int):
        return True
    if hasattr(x, "dtype"):
        return jnp.issubdtype(x.dtype, jnp.integer)
    return False


def _binop(op: str, a, b):
    if op == "+":
        return a + b
    if op == "-":
        return a - b
    if op == "*":
        return a * b
    if op == "/":
        if _is_int(a) and _is_int(b):
            return a // b
        return a / b
    if op == "%":
        return a % b
    if op == "<":
        return a < b
    if op == ">":
        return a > b
    if op == "<=":
        return a <= b
    if op == ">=":
        return a >= b
    if op == "==":
        return a == b
    if op == "!=":
        return a != b
    if op == "&&":
        return a & b if hasattr(a, "dtype") or hasattr(b, "dtype") \
            else (a and b)
    if op == "||":
        return a | b if hasattr(a, "dtype") or hasattr(b, "dtype") \
            else (a or b)
    raise CodegenError(f"bad operator {op}")


# ===========================================================================
# Program
# ===========================================================================

class Program:
    """A compiled DSL program; run any function on any engine.

    ``stage(func, engine)`` is the bind-time half: it builds (and caches,
    per engine instance) a :class:`StagedFunc` holding the executor and
    its lowering caches, so repeat calls skip host-side AST
    pattern-matching and reuse the engine's jitted executables.
    ``Program.run`` remains as a deprecated one-shot shim over it — new
    code should go through :mod:`repro.api` sessions.
    """

    def __init__(self, source: str):
        self.source = source
        self.ast = parse(source)
        self.infos = analyze(self.ast)

    # -- public API ----------------------------------------------------------
    def stage(self, func_name: str, engine: Engine) -> "StagedFunc":
        """Bind ``func_name`` to ``engine``: returns a fresh executable
        wrapper.  Callers that want the bind-time caches to pay off
        (``repro.api.Session``) hold on to it — a StagedFunc references
        its engine, so its lifetime is the owner's, not the Program's."""
        return StagedFunc(self, func_name, engine)

    def run(self, func_name: str, engine: Engine, csr: CSR,
            args: Optional[Dict[str, Any]] = None,
            diff_capacity: int = 64) -> RunResult:
        """Deprecated one-shot execution (prepare + run + host readback).

        Kept as a thin shim over :meth:`stage` for existing callers; use
        ``repro.api.compile(...).bind(...)`` instead — a Session keeps
        the graph device-resident across calls, while this shim
        re-``prepare``s the graph and syncs every property to host numpy
        on each invocation.
        """
        warnings.warn(
            "Program.run is deprecated; use repro.api.compile(...)"
            ".bind(csr, backend=...) sessions instead",
            DeprecationWarning, stacklevel=2)
        g = engine.prepare(csr, diff_capacity=diff_capacity)
        g, props, ret = self.stage(func_name, engine).call(g, args)
        host = {k: np.asarray(v)[: engine.n_real] for k, v in props.items()}
        return RunResult(g=g, props=host, value=ret)


class StagedFunc:
    """One DSL function bound to one engine instance.

    The split mirrors what the paper's generated C++ gets for free from
    compilation: everything derivable from the AST alone (parameter
    binding plan, forall classification, edge-sweep plans) is computed
    once here and cached on the executor; per-call work is only the
    actual staged execution against a graph handle.
    """

    def __init__(self, program: Program, func_name: str, engine: Engine):
        self.program = program
        self.func_name = func_name
        self.func = program.ast.func(func_name)
        self.engine = engine
        self.executor = Executor(program, engine)
        # params an *armed* run may omit: the update stream itself plus
        # the batch-size names the (bypassed) Batch statements read
        self._armable = {st.batch_size for st in A.walk(self.func.body)
                         if isinstance(st, A.BatchStmt)}

    # -- parameter binding ---------------------------------------------------
    def bind_frame(self, g, args: Optional[Dict[str, Any]],
                   armed: bool = False) -> Tuple["Frame", Box]:
        args = dict(args or {})
        frame = Frame(self.engine)
        gbox = Box(g)
        for p in self.func.params:
            t = p.type
            if t.name == "Graph":
                frame.env[p.name] = GraphRef(gbox)
            elif t.is_prop:
                frame.env[p.name] = PropRef(
                    p.name, _elem(t), Box(None), is_edge=t.name == "propEdge")
            elif t.name == "updates":
                stream = args.pop(p.name, None)
                if isinstance(stream, UpdatesRef):
                    frame.env[p.name] = stream
                else:
                    frame.env[p.name] = UpdatesRef(stream)
                if stream is None and not armed:
                    raise CodegenError(
                        f"{self.func_name}: missing updates arg {p.name!r}")
            elif p.name in args:
                frame.env[p.name] = args.pop(p.name)
            elif armed and p.name in self._armable:
                frame.env[p.name] = None
            else:
                raise CodegenError(
                    f"{self.func_name}: missing arg {p.name!r}")
        if args:
            raise CodegenError(f"unused args: {sorted(args)}")
        return frame, gbox

    # -- call-time execution -------------------------------------------------
    def call(self, g, args: Optional[Dict[str, Any]] = None):
        """One-shot execution against an existing handle ``g``; returns
        ``(new_handle, device_props, return_value)`` — no host syncs."""
        frame, gbox = self.bind_frame(g, args)
        self.executor.exec_block(self.func.body, frame)
        return gbox.value, frame.props_arrays(), frame.ret

    def begin(self, g, args: Optional[Dict[str, Any]] = None) -> "ArmedRun":
        """Incremental execution: run the prologue (everything before the
        ``Batch`` statement), then hand back an :class:`ArmedRun` whose
        ``apply(batch)`` executes the Batch body one ΔG batch at a time
        against the live frame — the long-lived streaming-consumer mode.
        """
        frame, gbox = self.bind_frame(g, args, armed=True)
        stmts = self.func.body.stmts
        batch_idx = next((i for i, s in enumerate(stmts)
                          if isinstance(s, A.BatchStmt)), None)
        if batch_idx is None:
            raise CodegenError(
                f"{self.func_name} has no Batch statement; use call()")
        for st in stmts[:batch_idx]:
            if frame.ret is not None:
                break
            self.executor.exec_stmt(st, frame)
        return ArmedRun(self, frame, gbox, stmts[batch_idx],
                        stmts[batch_idx + 1:])


class ArmedRun:
    """A Dyn* function paused at its ``Batch`` loop, state held live.

    ``apply`` replays exactly what ``Executor.exec_batch`` does for one
    batch, so N applies are bit-identical to a one-shot run over the
    same N batches.  ``snapshot``/``restore`` save and roll back every
    mutable cell (graph box, property boxes, host scalars) — the
    grow-on-overflow backstop in ``repro.api.Session`` uses them to
    replay a batch after growing the diff pool.
    """

    def __init__(self, staged: StagedFunc, frame: "Frame", gbox: Box,
                 batch_stmt: A.BatchStmt, epilogue: List[A.Stmt]):
        self.staged = staged
        self.frame = frame
        self.gbox = gbox
        self.batch_stmt = batch_stmt
        self.epilogue = epilogue

    @property
    def returned(self) -> bool:
        """True once a batch body hit a ``return`` — the Batch loop is
        over, exactly as ``exec_batch`` would have stopped it."""
        return self.frame.ret is not None

    def apply(self, batch: UpdateBatch) -> None:
        if self.returned:
            raise CodegenError(f"{self.staged.func_name} already returned; "
                               f"no further batches can be applied")
        inner = Frame(self.staged.engine, parent=self.frame)
        inner.current_batch = batch
        self.staged.executor.exec_block(self.batch_stmt.body, inner)
        if inner.ret is not None:
            self.frame.ret = inner.ret

    def value(self):
        """The function's return value as of the current state.  The
        post-Batch epilogue is evaluated under a snapshot/restore, so
        reading it never disturbs the live state — even for epilogues
        with assignments or property writes."""
        if self.frame.ret is not None:
            return self.frame.ret
        if not self.epilogue:
            return None
        snap = self.snapshot()
        try:
            child = Frame(self.staged.engine, parent=self.frame)
            for st in self.epilogue:
                if child.ret is not None:
                    break
                self.staged.executor.exec_stmt(st, child)
            return child.ret
        finally:
            self.restore(snap)

    def device_props(self) -> Dict[str, Any]:
        return self.frame.props_arrays()

    # -- rollback support ----------------------------------------------------
    def snapshot(self):
        boxes = {}
        f: Optional[Frame] = self.frame
        envs = []
        while f is not None:
            envs.append((f, dict(f.env)))
            for v in f.env.values():
                if isinstance(v, PropRef):
                    boxes[v.box] = v.box.value
            f = f.parent
        return envs, boxes, self.gbox.value, self.frame.ret

    def restore(self, snap) -> None:
        envs, boxes, g, ret = snap
        for f, env in envs:
            f.env.clear()
            f.env.update(env)
        for box, val in boxes.items():
            box.value = val
        self.gbox.value = g
        self.frame.ret = ret

    # -- durable form (DESIGN.md §5: armed-frame serialization) --------------
    def serialize(self) -> Tuple[Dict[str, Any], dict]:
        """The armed loop position as ``(arrays, meta)``: a flat dict of
        array leaves plus JSON-able metadata describing every frame
        binding by kind.  ``deserialize`` rebuilds the paused loop from
        this WITHOUT re-running the prologue — the durable counterpart
        of ``snapshot()`` (which holds live Python objects)."""
        arrays: Dict[str, Any] = {}
        env_meta: Dict[str, dict] = {}
        for name, v in self.frame.env.items():
            if isinstance(v, GraphRef):
                env_meta[name] = {"kind": "graph"}
            elif isinstance(v, UpdatesRef):
                if v.stream is not None:
                    raise CodegenError(
                        f"armed frame binds a live update stream "
                        f"{name!r}; only stream-less (armed) frames "
                        f"serialize")
                env_meta[name] = {"kind": "updates", "selector": v.selector}
            elif isinstance(v, PropRef):
                env_meta[name] = {"kind": "prop", "elem": v.elem,
                                  "is_edge": v.is_edge,
                                  "bound": v.box.value is not None}
                if v.box.value is not None:
                    arrays[f"prop_{name}"] = v.box.value
            elif isinstance(v, NodeIdx):
                if hasattr(v.idx, "dtype"):
                    arrays[f"node_{name}"] = v.idx
                    env_meta[name] = {"kind": "node_array"}
                else:
                    env_meta[name] = {"kind": "node", "value": int(v.idx)}
            elif v is None:
                env_meta[name] = {"kind": "none"}
            elif isinstance(v, (bool, int, float, str)):
                env_meta[name] = {"kind": "py", "value": v}
            elif hasattr(v, "dtype"):
                arrays[f"val_{name}"] = v
                env_meta[name] = {"kind": "array"}
            else:
                raise CodegenError(
                    f"cannot serialize armed binding {name!r} of type "
                    f"{type(v).__name__}")
        ret = self.frame.ret
        if ret is None:
            ret_meta = {"kind": "none"}
        elif hasattr(ret, "dtype"):
            arrays["__ret__"] = ret
            ret_meta = {"kind": "array"}
        else:
            ret_meta = {"kind": "py", "value": ret}
        meta = {"func": self.staged.func_name,
                "batch_idx": self.staged.func.body.stmts.index(
                    self.batch_stmt),
                "env": env_meta, "ret": ret_meta}
        return arrays, meta

    @classmethod
    def deserialize(cls, staged: StagedFunc, g, arrays: Dict[str, Any],
                    meta: dict) -> "ArmedRun":
        """Rebuild a paused Batch loop from ``serialize()`` output.  The
        prologue is NOT re-executed — the frame env is repopulated
        directly, and the graph box wraps the caller's restored handle
        ``g`` (shared with the owning session)."""
        if meta["func"] != staged.func_name:
            raise CodegenError(
                f"checkpoint armed {meta['func']!r}, staged function is "
                f"{staged.func_name!r}")
        frame = Frame(staged.engine)
        gbox = Box(g)
        for name, m in meta["env"].items():
            kind = m["kind"]
            if kind == "graph":
                frame.env[name] = GraphRef(gbox)
            elif kind == "updates":
                frame.env[name] = UpdatesRef(None, m.get("selector", "both"))
            elif kind == "prop":
                ref = PropRef(name, m["elem"], Box(None),
                              is_edge=m["is_edge"])
                if m["bound"]:
                    ref.box.value = jnp.asarray(arrays[f"prop_{name}"],
                                                ref.dtype)
                frame.env[name] = ref
            elif kind == "node":
                frame.env[name] = NodeIdx(m["value"])
            elif kind == "node_array":
                frame.env[name] = NodeIdx(jnp.asarray(arrays[f"node_{name}"]))
            elif kind == "none":
                frame.env[name] = None
            elif kind == "py":
                frame.env[name] = m["value"]
            elif kind == "array":
                frame.env[name] = jnp.asarray(arrays[f"val_{name}"])
            else:
                raise CodegenError(f"unknown serialized binding kind "
                                   f"{kind!r} for {name!r}")
        rm = meta["ret"]
        frame.ret = (None if rm["kind"] == "none" else
                     jnp.asarray(arrays["__ret__"]) if rm["kind"] == "array"
                     else rm["value"])
        stmts = staged.func.body.stmts
        bi = meta["batch_idx"]
        if not (0 <= bi < len(stmts) and isinstance(stmts[bi], A.BatchStmt)):
            raise CodegenError(
                f"checkpoint batch_idx {bi} does not name a Batch "
                f"statement in {staged.func_name!r} — program source "
                f"changed since the save")
        return cls(staged, frame, gbox, stmts[bi], stmts[bi + 1:])


def _elem(t: A.Type) -> str:
    return {"int": "int", "long": "int", "float": "float",
            "double": "float", "bool": "bool"}[t.arg]


def compile_source(source_or_path: str) -> Program:
    """Compile DSL text (or a path to a .sp file) into a Program."""
    p = pathlib.Path(str(source_or_path))
    if str(source_or_path).endswith(".sp") and p.exists():
        source_or_path = p.read_text()
    return Program(str(source_or_path))


# ===========================================================================
# Executor: host-level statement interpretation
# ===========================================================================

class Executor:
    def __init__(self, prog: Program, engine: Engine):
        self.prog = prog
        self.engine = engine
        # bind-time lowering cache: AST-only analyses (forall
        # classification, edge-sweep plans, wedge shapes) keyed on node
        # identity — repeat calls through one StagedFunc skip the
        # pattern-matching interpretation entirely.
        self.stage_cache: Dict[Any, Any] = {}

    def staged(self, key, build: Callable[[], Any]):
        """Memoize an AST-only lowering artifact under ``key``."""
        try:
            return self.stage_cache[key]
        except KeyError:
            val = self.stage_cache[key] = build()
            return val

    # -- blocks / statements --------------------------------------------------
    def exec_block(self, block: A.Block, frame: Frame):
        for st in block.stmts:
            if frame.ret is not None:
                return
            self.exec_stmt(st, frame)

    def exec_stmt(self, st: A.Stmt, frame: Frame):
        if isinstance(st, A.Decl):
            self.exec_decl(st, frame)
        elif isinstance(st, A.Assign):
            self.exec_assign(st, frame)
        elif isinstance(st, A.CallStmt):
            self.eval_host(st.call, frame)
        elif isinstance(st, A.Return):
            frame.ret = self.eval_host(st.value, frame)
        elif isinstance(st, A.If):
            cond = self.eval_host(st.cond, frame)
            if bool(cond):
                self.exec_block(st.then, frame)
            elif st.orelse is not None:
                self.exec_block(st.orelse, frame)
        elif isinstance(st, A.ForAll):
            run_forall(self, st, frame)
        elif isinstance(st, A.FixedPoint):
            run_loop(self, st.body.stmts, frame, kind="fixedPoint",
                     flag=st.flag, cond=st.cond)
        elif isinstance(st, A.DoWhile):
            run_loop(self, st.body.stmts, frame, kind="do", cond=st.cond)
        elif isinstance(st, A.While):
            run_loop(self, st.body.stmts, frame, kind="while", cond=st.cond)
        elif isinstance(st, A.BatchStmt):
            self.exec_batch(st, frame)
        elif isinstance(st, A.OnUpdate):
            run_onupdate(self, st, frame)
        else:
            raise CodegenError(f"line {st.line}: unsupported statement "
                               f"{type(st).__name__}")

    def exec_decl(self, st: A.Decl, frame: Frame):
        t = st.type
        if t.is_prop:
            frame.env[st.name] = PropRef(st.name, _elem(t), Box(None),
                                         is_edge=t.name == "propEdge")
        elif t.name == "updates":
            v = self.eval_host(st.init, frame) if st.init else None
            frame.env[st.name] = v
        elif t.name == "node":
            v = self.eval_host(st.init, frame) if st.init else 0
            frame.env[st.name] = NodeIdx(v) if not isinstance(v, NodeIdx) \
                else v
        elif t.name == "edge":
            frame.env[st.name] = self.eval_host(st.init, frame)
        else:
            v = self.eval_host(st.init, frame) if st.init is not None else 0
            if t.name in ("float", "double"):
                v = float(v) if not hasattr(v, "dtype") else v.astype(F32)
            frame.env[st.name] = v

    def exec_assign(self, st: A.Assign, frame: Frame):
        if isinstance(st.target, A.Name):
            name = st.target.ident
            cur = None
            try:
                cur = frame.lookup(name)
            except CodegenError:
                pass
            val = self.eval_host(st.value, frame)
            if isinstance(cur, PropRef):
                # whole-property copy: pageRank = pageRank_nxt
                if isinstance(val, PropRef):
                    val = val.box.value
                cur.box.value = val
                return
            if st.op == "+=":
                val = _binop("+", cur, val)
            elif st.op == "-=":
                val = _binop("-", cur, val)
            _set_env(frame, name, val)
            return
        if isinstance(st.target, A.Attr):
            # host-level scatter: src.modified = True (src: scalar node)
            obj = self.eval_host(st.target.obj, frame)
            pname = st.target.name
            ref = frame.lookup(pname)
            if not isinstance(ref, PropRef):
                raise CodegenError(f"line {st.line}: {pname} not a property")
            idx = obj.idx if isinstance(obj, NodeIdx) else obj
            val = self.eval_host(st.value, frame)
            if isinstance(val, NodeIdx):
                val = val.idx
            arr = ref.box.value
            ref.box.value = arr.at[idx].set(jnp.asarray(val, arr.dtype))
            return
        raise CodegenError(f"line {st.line}: bad assignment")

    def exec_batch(self, st: A.BatchStmt, frame: Frame):
        ups = frame.lookup(st.updates)
        if not isinstance(ups, UpdatesRef):
            raise CodegenError(f"line {st.line}: {st.updates} is not "
                               f"an updates<g> value")
        bs = frame.lookup(st.batch_size)
        for batch in ups.stream.batches(int(bs)):
            inner = Frame(self.engine, parent=frame)
            inner.current_batch = batch
            self.exec_block(st.body, inner)
            if inner.ret is not None:
                frame.ret = inner.ret
                return

    # -- host expression evaluation -----------------------------------------
    def eval_host(self, e: A.Expr, frame: Frame):
        if isinstance(e, A.Num):
            return e.value
        if isinstance(e, A.Bool):
            return e.value
        if isinstance(e, A.Inf):
            return INF_W
        if isinstance(e, A.Name):
            return frame.lookup(e.ident)
        if isinstance(e, A.Unary):
            v = self.eval_host(e.operand, frame)
            return (not v) if e.op == "!" else (-v)
        if isinstance(e, A.Binary):
            a = self.eval_host(e.left, frame)
            b = self.eval_host(e.right, frame)
            if isinstance(a, NodeIdx):
                a = a.idx
            if isinstance(b, NodeIdx):
                b = b.idx
            return _binop(e.op, a, b)
        if isinstance(e, A.MinMax):
            vals = [self.eval_host(a, frame) for a in e.args]
            return min(vals) if e.op == "Min" else max(vals)
        if isinstance(e, A.Attr):
            obj = self.eval_host(e.obj, frame)
            if isinstance(obj, NodeIdx):
                ref = frame.lookup(e.name)
                if isinstance(ref, PropRef):
                    return ref.box.value[obj.idx]
            raise CodegenError(f"line {e.line}: bad attribute {e.name}")
        if isinstance(e, A.Call):
            return self.eval_call(e, frame)
        raise CodegenError(f"line {e.line}: cannot evaluate "
                           f"{type(e).__name__}")

    def eval_call(self, e: A.Call, frame: Frame):
        eng = self.engine
        # method calls g.X(...) / updates.currentBatch(...)
        if isinstance(e.func, A.Attr):
            base = self.eval_host(e.func.obj, frame)
            m = e.func.name
            if isinstance(base, GraphRef):
                return self.graph_method(base, m, e, frame)
            if isinstance(base, UpdatesRef) and m == "currentBatch":
                sel = "both"
                if e.args:
                    sel = "del" if self.eval_host(e.args[0], frame) == 0 \
                        else "add"
                return UpdatesRef(base.stream, selector=sel)
            raise CodegenError(f"line {e.line}: unknown method {m}")
        # free functions
        assert isinstance(e.func, A.Name)
        fname = e.func.ident
        if fname == "abs":
            return jnp.abs(self.eval_host(e.args[0], frame))
        if fname in self.prog.infos:
            return self.call_function(fname, e.args, frame)
        raise CodegenError(f"line {e.line}: unknown function {fname}")

    def graph_method(self, gref: GraphRef, m: str, e: A.Call, frame: Frame):
        eng = self.engine
        if m == "num_nodes":
            return eng.n_real
        if m == "count_outNbrs":
            x = self.eval_host(e.args[0], frame)
            idx = x.idx if isinstance(x, NodeIdx) else x
            return eng.out_degrees(gref.box.value)[idx]
        if m in ("attachNodeProperty", "attachEdgeProperty"):
            for kw in e.args:
                if not isinstance(kw, A.Kwarg):
                    raise CodegenError(f"line {e.line}: attach* takes "
                                       f"name=value arguments")
                ref = frame.lookup(kw.name)
                val = self.eval_host(kw.value, frame)
                if isinstance(val, NodeIdx):
                    val = val.idx
                if ref.is_edge:
                    if val not in (False, 0):
                        raise CodegenError(f"line {e.line}: edge props "
                                           f"initialize to False")
                    # empty query → an all-False lane array in whatever
                    # lane layout this engine uses (sharded for dist)
                    ref.box.value = eng.batch_edge_flags(
                        gref.box.value, jnp.zeros((1,), INT),
                        jnp.zeros((1,), INT), jnp.zeros((1,), BOOL))
                else:
                    ref.box.value = eng.full(val, ref.dtype)
            return None
        if m == "updateCSRDel":
            gref.box.value = eng.update_del(gref.box.value,
                                            self._cur_batch(frame, e))
            return None
        if m == "updateCSRAdd":
            gref.box.value = eng.update_add(gref.box.value,
                                            self._cur_batch(frame, e))
            return None
        if m == "propagateNodeFlags":
            flag = e.args[0]
            assert isinstance(flag, A.Name)
            ref = frame.lookup(flag.ident)
            props = frame.props_arrays()
            props = eng.propagate_flags(gref.box.value, props, flag.ident)
            frame.write_back(props)
            return None
        if m == "get_edge":
            a = self.eval_host(e.args[0], frame)
            b = self.eval_host(e.args[1], frame)
            return EdgeRef(a=a, b=b)
        raise CodegenError(f"line {e.line}: unsupported graph method {m}")

    def _cur_batch(self, frame: Frame, e) -> UpdateBatch:
        b = frame.current_batch
        if b is None:
            raise CodegenError(f"line {e.line}: updateCSR* outside Batch")
        return b

    # -- user function calls ---------------------------------------------------
    def call_function(self, fname: str, arg_exprs: List[A.Expr],
                      frame: Frame):
        func = self.prog.ast.func(fname)
        if len(arg_exprs) != len(func.params):
            raise CodegenError(f"call {fname}: arity mismatch")
        callee = Frame(self.engine)
        callee.current_batch = frame.current_batch
        for p, ae in zip(func.params, arg_exprs):
            val = self.eval_host(ae, frame)
            if p.type.is_prop:
                if not isinstance(val, PropRef):
                    raise CodegenError(
                        f"call {fname}: param {p.name} expects a property")
                # rebind under the callee's name, sharing the Box
                callee.env[p.name] = PropRef(p.name, val.elem, val.box,
                                             val.is_edge)
            elif p.type.name == "Graph":
                callee.env[p.name] = val
            elif p.type.name == "updates":
                callee.env[p.name] = val
            elif p.type.name == "node":
                callee.env[p.name] = val if isinstance(val, NodeIdx) \
                    else NodeIdx(val)
            else:
                callee.env[p.name] = val
        self.exec_block(func.body, callee)
        return callee.ret


def _set_env(frame: Frame, name: str, val):
    f: Optional[Frame] = frame
    while f is not None:
        if name in f.env:
            f.env[name] = val
            return
        f = f.parent
    frame.env[name] = val


# the sweep/loop/wedge/onupdate lowerings live in a sibling module to keep
# file sizes reviewable; import at the bottom to avoid cycles.
from repro.core.dsl.lowering import (          # noqa: E402
    run_forall, run_loop, run_onupdate)
