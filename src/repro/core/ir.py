"""The StarPlat-Dynamic intermediate representation, staged for JAX.

The paper parses DSL text into an AST, runs read/write-set and race
analyses, then hands the annotated tree to one of three code generators.
Our embedded-DSL equivalent:

  * algorithms are written against a handful of *aggregate ops*
    (:class:`EdgeSweep`, wedge enumeration, vertex maps, fixed points) —
    the moral equivalents of ``forall``/``fixedPoint``/``Min``;
  * every write inside a ``forall`` is declared as a :class:`Reduce`
    (min/sum/max/or).  This replaces the paper's race analysis: instead
    of *detecting* races and inserting atomics, the IR makes the
    combiner explicit, and each backend lowers it to its native
    synchronization (segment reduction / cross-shard pmin / kernel);
  * :class:`ReadSetTracer` recovers the paper's read-set analysis: it
    records which vertex properties an ``edge_fn`` actually touches, so
    the distributed backend gathers (— opens "RMA windows" for —) only
    those.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Tuple

import jax
import jax.numpy as jnp

from repro.graph.csr import INF_W

# ---------------------------------------------------------------------------
# Reductions (the paper's Min / += / |= constructs)
# ---------------------------------------------------------------------------

_IDENTITIES = {
    "min": lambda dt: jnp.asarray(INF_W, dt) if jnp.issubdtype(dt, jnp.integer)
    else jnp.asarray(jnp.inf, dt),
    "max": lambda dt: jnp.asarray(-INF_W, dt) if jnp.issubdtype(dt, jnp.integer)
    else jnp.asarray(-jnp.inf, dt),
    "sum": lambda dt: jnp.zeros((), dt),
    "or": lambda dt: jnp.zeros((), jnp.bool_),
}

_SEGMENT = {
    "min": jax.ops.segment_min,
    "max": jax.ops.segment_max,
    "sum": jax.ops.segment_sum,
    # NB: segment_max fills empty segments with dtype-min, so 'or' must
    # compare > 0 rather than astype(bool).
    "or": lambda v, s, num_segments: jax.ops.segment_max(
        v.astype(jnp.int32), s, num_segments=num_segments) > 0,
}


@dataclasses.dataclass(frozen=True)
class Reduce:
    """Declared combiner for one property written inside a forall-edges.

    kind='argmin' picks, per destination vertex, the smallest source id
    among edges achieving the min of the ``of`` target — how the paper's
    ``nbr.parent = v`` rides along its ``Min`` multi-assignment, made
    deterministic.
    """

    kind: str  # 'min' | 'sum' | 'max' | 'or' | 'argmin'
    of: str | None = None

    def identity(self, dtype):
        return _IDENTITIES[self.kind](dtype)

    def segment(self, values, segids, num_segments):
        return _SEGMENT[self.kind](values, segids, num_segments=num_segments)

    def combine(self, a, b):
        if self.kind == "min":
            return jnp.minimum(a, b)
        if self.kind == "max":
            return jnp.maximum(a, b)
        if self.kind == "sum":
            return a + b
        if self.kind == "or":
            return a | b
        raise ValueError(self.kind)


# ---------------------------------------------------------------------------
# Property views + read-set analysis
# ---------------------------------------------------------------------------

class PropView(Mapping):
    """Read-only view of vertex properties gathered at one edge endpoint.

    Records every key it serves — the embedded-DSL version of the paper's
    read-set analysis on the AST (used there to place cudaMemcpys and RMA
    windows; used here to pick which properties the distributed backend
    all-gathers).
    """

    def __init__(self, props: Dict[str, jax.Array], idx: jax.Array,
                 read_log: set | None = None):
        self._props = props
        self._idx = idx
        self._log = read_log

    def __getitem__(self, k: str) -> jax.Array:
        if self._log is not None:
            self._log.add(k)
        return self._props[k][self._idx]

    def __iter__(self):
        return iter(self._props)

    def __len__(self):
        return len(self._props)


def trace_read_set(edge_fn: Callable, props: Dict[str, jax.Array]) -> set:
    """Abstractly run edge_fn on 1-lane shapes to recover its read set."""
    log: set = set()
    one = {k: v[:1] for k, v in props.items()}
    idx = jnp.zeros((1,), jnp.int32)
    s = PropView(one, idx, log)
    d = PropView(one, idx, log)
    w = jnp.zeros((1,), jnp.int32)
    try:
        jax.eval_shape(lambda: edge_fn(s, d, w))
    except Exception:
        # Tracing only for the read log; a failure here falls back to
        # gathering everything (always sound).
        return set(props)
    return log


# ---------------------------------------------------------------------------
# Aggregate ops
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EdgeSweep:
    """One ``forall (e in g.edges)`` with declared reductions.

    edge_fn(src_view, dst_view, w) -> {target: (value, eligible_mask)}
      target is a vertex-property name; value/mask are per-edge-lane.
      Reduction is always *at the destination vertex* (push along the
      edge); pull formulations pass the transposed graph.
    reduces: {target: Reduce}
    post_fn(props, reduced, hit) -> new props
      Pure element-wise over (n,)-arrays: 'reduced' holds the combined
      values (identity where no eligible edge), 'hit' the per-vertex
      any-eligible-edge mask.  This is where the paper's
      ``<nbr.dist, nbr.modified_nxt, nbr.parent> = <Min(...), True, v>``
      multi-assignment lands.
    """

    edge_fn: Callable
    reduces: Dict[str, Reduce]
    post_fn: Callable
    # Optional declaration that the sweep is of gather-combine form
    #   cand(e=(u,v)) = vec[u] (+ w(e))
    # with eligibility folded into vec via the reduction identity.  The
    # Pallas backend lowers such sweeps onto the ELL kernels; others fall
    # back to segment reductions.  {target: (vec_fn(props)->(n,), use_w)}.
    gather_form: Dict[str, Tuple[Callable, bool]] | None = None
    # Optional name of the boolean SOURCE-side property that gates which
    # vertices push this iteration — lets the FrontierEngine run the
    # sweep work-efficiently over O(|frontier|) rows (Ligra-style).
    frontier: str | None = None

    def read_set(self, props: Dict[str, jax.Array]) -> set:
        return trace_read_set(self.edge_fn, props)
