"""The stable public API: compile once, bind to any backend, keep graph
state device-resident across calls.

This is the contract the paper's DSL promises ("one program, N generated
backends") surfaced as a first-class Python API — GraphIt's
algorithm/schedule separation, StarPlat's resident Batch-loop driver:

    import repro.api as api

    prog = api.compile("src/repro/dsl_programs/sssp.sp")
    sess = prog.bind(csr, backend="pallas", capacity="auto")

    # one-shot, same semantics as the deprecated Program.run:
    res = sess.run("DynSSSP", updateBatch=stream, batchSize=16, src=0)
    res.props["dist"]          # device array — no host sync
    res.to_host()["dist"]      # explicit numpy readback

    # long-lived streaming consumer: omit the stream to arm the Batch
    # loop, then feed ΔG batches as they arrive; graph + properties stay
    # on device between calls and `engine.prepare` runs exactly once.
    sess = prog.bind(csr, backend="jnp", capacity="auto")
    sess.run("DynSSSP", src=0)
    for batch in live_feed:
        sess.apply(batch)
        serve(sess.props["dist"])

Backends are resolved by name through ``repro.core.registry``;
``register_engine`` plugs new engines in without touching this facade.
Backend options ride ``bind(**opts)`` — e.g. the sharded backend's
mesh knobs, ``bind(csr, backend="dist_sharded", num_shards=8,
partitioner="degree")``.
Hand-staged algorithms (``repro.algos``) ride the same session via
``bind_graph`` — an algorithm-agnostic session owning the resident
handle — and its ``call``/``run_stream`` helpers.
"""
from __future__ import annotations

import functools
import pathlib
import threading
from typing import Any, Callable, Dict, Iterator, Mapping, Optional, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.core.dsl.codegen import (ArmedRun, CodegenError, Program,
                                    compile_source)
from repro.core.engine import Engine, state_to_csr
from repro.core.registry import (available_backends, failover_chain,
                                 make_engine, register_engine)
from repro.graph.csr import CSR
from repro.graph.updates import UpdateBatch, UpdateStream
from repro.runtime import faults as _faults
from repro.runtime import watchdog as _watchdog
from repro.runtime.admission import DEFAULT_MAX_BATCH, AdmissionGuard
from repro.runtime.errors import (AdmissionError, DivergenceError,
                                  KernelFailure, PoolOverflowError)
from repro.runtime.failover import FailoverPolicy
from repro.runtime.health import SessionHealth

__all__ = [
    "compile", "CompiledProgram", "Session", "GraphSession", "bind_graph",
    "SessionResult", "PropertyView", "register_engine",
    "available_backends", "restore_session",
    "AdmissionError", "PoolOverflowError", "KernelFailure",
    "DivergenceError", "SessionHealth",
]

_DEFAULT_CAPACITY = 64

# lru_cache's dict ops are GIL-atomic, but a miss is not: two threads
# binding the same program race compile_source and one result is thrown
# away — and CompiledProgram identity is the pool's grouping key, so the
# loser's sessions would land in a different group.  Serialize misses.
_COMPILE_LOCK = threading.Lock()


@functools.lru_cache(maxsize=256)
def _compile_once(source_or_path: str, stamp) -> "CompiledProgram":
    return CompiledProgram(compile_source(source_or_path))


def _compile_cached(source_or_path: str, stamp) -> "CompiledProgram":
    with _COMPILE_LOCK:
        return _compile_once(source_or_path, stamp)


def compile(source_or_path: str) -> "CompiledProgram":
    """Compile DSL text (or a path to a ``.sp`` file) once; the result
    is cached per source (``.sp`` cache entries key on the file's
    mtime, so on-disk edits recompile)."""
    s = str(source_or_path)
    stamp = None
    if s.endswith(".sp"):
        p = pathlib.Path(s)
        if p.exists():
            stamp = p.stat().st_mtime_ns
    return _compile_cached(s, stamp)


def _dedupe_chain(names) -> tuple:
    """Order-preserving dedupe of a failover candidate list.  A chain
    like ``(jnp, pallas, jnp)`` (user-supplied, or a custom chain that
    re-lists the requested backend) used to construct — and on total
    failure, report — the same backend twice."""
    seen = set()
    out = []
    for name in names:
        if name not in seen:
            seen.add(name)
            out.append(name)
    return tuple(out)


def _make_engine_failover(backend: str, failover, **backend_opts):
    """Instantiate ``backend``; with failover enabled, a factory that
    raises (missing accelerator, import error) falls down the chain at
    bind time.  Returns ``(engine, bound_registry_name)``."""
    if not failover:
        return make_engine(backend, **backend_opts), backend
    chain = failover_chain(backend) if failover is True else tuple(failover)
    last = None
    for name in _dedupe_chain((backend, *chain)):
        try:
            # backend_opts are engine-specific (e.g. pallas k=): only
            # the requested backend gets them
            opts = backend_opts if name == backend else {}
            return make_engine(name, **opts), name
        except Exception as e:       # noqa: BLE001 — bind-time failover
            last = e
    raise KernelFailure(
        f"no backend in {_dedupe_chain((backend, *chain))} could be "
        f"constructed", backend=backend, cause=last)


def _post_bind_failover(sess: "GraphSession", requested: str, bound: str,
                        failover) -> None:
    """Record a bind-time degradation (the requested backend's factory
    failed and a fallback was bound instead)."""
    if bound == requested or not failover:
        return
    chain = failover_chain(requested) if failover is True \
        else _dedupe_chain(failover)
    sess._failover = FailoverPolicy(requested, chain)
    sess._failover.degraded_from()
    sess._health.preferred_backend = requested
    sess._health.backend = bound
    sess._health.failovers += 1


def bind_graph(csr: CSR, backend: str = "jnp",
               capacity: Union[str, int] = "auto",
               admission: Optional[str] = "clamp",
               max_batch: int = DEFAULT_MAX_BATCH,
               dead_letter: int = 64,
               failover=None,
               **backend_opts) -> "GraphSession":
    """An algorithm-agnostic session (no DSL program): a device-resident
    graph handle for hand-staged ``repro.algos`` code.

    ``admission`` / ``max_batch`` / ``dead_letter`` configure the ΔG
    admission guard (policy ``reject | clamp | quarantine | off``;
    DESIGN.md §6); ``failover=True`` (or an explicit chain of registry
    names) arms graceful backend degradation."""
    engine, bound = _make_engine_failover(backend, failover, **backend_opts)
    sess = GraphSession(engine, csr, capacity, backend_name=bound,
                        admission=admission, max_batch=max_batch,
                        dead_letter=dead_letter, failover=failover)
    _post_bind_failover(sess, backend, bound, failover)
    return sess


def _auto_capacity(stream: Optional[UpdateStream] = None,
                   batch: Optional[UpdateBatch] = None) -> int:
    """Diff-pool size derived from the bound stream/batch: every add may
    land in the pool (deletes only tombstone), doubled for headroom.
    With neither in sight — arming a Batch loop prepares the graph for
    the prologue before any update exists — the pool starts at the
    default.  The grow-on-overflow path backstops all underestimates.

    Every path floors at ``_DEFAULT_CAPACITY``: the stream path used to
    floor at 16, so tiny streams (e.g. a 4-add probe stream) prepared a
    pool 4x smaller than an armed session's, and the first real batch
    paid a grow-merge-replay an identically-bound armed session never
    saw."""
    if stream is not None:
        return max(_DEFAULT_CAPACITY, 2 * stream.num_adds)
    if batch is not None:
        return max(_DEFAULT_CAPACITY, 8 * batch.size)
    return _DEFAULT_CAPACITY


def _tree_spec(tree):
    """Per-leaf ``[shape, dtype]`` mirror of a nested-dict array tree —
    JSON-able, enough to rebuild an example tree for ``ckpt.restore``
    without needing the (unrecoverable) pickled treedef."""
    if isinstance(tree, dict):
        return {k: _tree_spec(v) for k, v in tree.items()}
    return [list(np.shape(tree)),
            str(getattr(tree, "dtype", np.asarray(tree).dtype))]


def _example_from_spec(spec):
    if isinstance(spec, dict):
        return {k: _example_from_spec(v) for k, v in spec.items()}
    shape, dtype = spec
    return jnp.zeros(tuple(shape), np.dtype(dtype))


def _fit_pad(arr, n_real: int, n_pad: int):
    """Refit a saved vertex array to the restoring engine's padding
    (dist n_pad = block·P changes with the device count).  The pad
    region is dead for forall lowerings — lowering masks them with
    ``idx < n_real`` — so it is filled from the saved pad value when one
    exists, else dtype-zero."""
    arr = jnp.asarray(arr)
    if arr.ndim == 0 or arr.shape[0] == n_pad:
        return arr
    body = arr[:n_real]
    if n_pad == n_real:
        return body
    fill = arr[n_real] if arr.shape[0] > n_real else jnp.zeros((), arr.dtype)
    return jnp.concatenate(
        [body, jnp.full((n_pad - n_real,), fill, arr.dtype)])


class PropertyView(Mapping):
    """Lazy view over a session's vertex properties.

    Indexing returns the **device** array (padded; no host sync);
    ``to_host()`` / ``host(name)`` perform the explicit numpy readback,
    sliced to the real vertex count — the one place the API syncs."""

    def __init__(self, arrays: Dict[str, Any], n_real: int):
        self._arrays = arrays
        self._n = n_real

    def __getitem__(self, name: str):
        return self._arrays[name]

    def __iter__(self) -> Iterator[str]:
        return iter(self._arrays)

    def __len__(self) -> int:
        return len(self._arrays)

    def host(self, name: str) -> np.ndarray:
        return np.asarray(self._arrays[name])[: self._n]

    def to_host(self) -> Dict[str, np.ndarray]:
        return {k: self.host(k) for k in self._arrays}

    def __repr__(self):
        return (f"PropertyView({sorted(self._arrays)}, "
                f"n={self._n}, device-resident)")


class SessionResult:
    """What ``Session.run`` returns: device-resident props + the DSL
    return value.  ``to_host()`` is the explicit sync point."""

    def __init__(self, session: "GraphSession", props: PropertyView,
                 value: Any = None):
        self.session = session
        self.props = props
        self.value = value

    @property
    def graph(self):
        return self.session.handle

    def to_host(self) -> Dict[str, np.ndarray]:
        return self.props.to_host()

    def __repr__(self):
        return (f"SessionResult(props={sorted(self.props)}, "
                f"value={self.value!r})")


class GraphSession:
    """Owns one engine instance and its device-resident graph handle.

    ``prepare`` runs exactly once per session — lazily, so
    ``capacity='auto'`` can wait for the first stream/batch to size the
    diff pool.  Structural updates, hand-staged drivers, and the fused
    stream executor all route through here and keep the handle warm.
    """

    # grow-and-replay attempts before _retry_on_overflow gives up with
    # PoolOverflowError (capacity doubles each attempt, so 8 attempts =
    # 256x the starting pool — past that the batch is hostile, not big)
    _max_grow_attempts = 8

    def __init__(self, engine: Engine, csr: CSR,
                 capacity: Union[str, int] = "auto", *,
                 backend_name: Optional[str] = None,
                 admission: Optional[str] = "clamp",
                 max_batch: int = DEFAULT_MAX_BATCH,
                 dead_letter: int = 64,
                 failover=None):
        if not (capacity == "auto" or isinstance(capacity, int)):
            raise ValueError(f"capacity must be 'auto' or an int, "
                             f"got {capacity!r}")
        self._engine = engine
        self._csr = csr
        self._capacity = capacity
        self._handle = None
        self._props: Dict[str, Any] = {}
        # last host-observed overflow counter (see _retry_on_overflow)
        self._of_base = 0
        # ΔG batches applied through apply()/run_stream() — the resume
        # position checkpointed by save()
        self._cursor = 0
        # -- fault runtime (DESIGN.md §6) ----------------------------------
        # engines share Engine.name across registry entries (pallas and
        # pallas_chained are both "pallas"), so the session keeps the
        # registry name it was bound under — the failover chain keys on it
        self._backend_name = backend_name or engine.name
        self._health = SessionHealth(backend=self._backend_name,
                                     preferred_backend=self._backend_name)
        self._guard = AdmissionGuard(admission, max_batch=max_batch,
                                     dead_letter=dead_letter,
                                     health=self._health)
        self._health.dead_letter = self._guard.buffer
        if failover:
            chain = failover_chain(self._backend_name) if failover is True \
                else _dedupe_chain(failover)
            self._failover: Optional[FailoverPolicy] = FailoverPolicy(
                self._backend_name, chain)
        else:
            self._failover = None

    # -- resident state ------------------------------------------------------
    @property
    def engine(self) -> Engine:
        return self._engine

    @property
    def backend(self) -> str:
        return self._engine.name

    @property
    def backend_name(self) -> str:
        """The registry name this session is currently bound under
        (distinct from ``backend``/``Engine.name``: pallas_chained
        binds a PallasEngine whose ``name`` is also "pallas")."""
        return self._backend_name

    @property
    def health(self) -> SessionHealth:
        """Live fault-runtime counters (admission, overflow retries,
        failovers, watchdog probes) — ``health.as_dict()`` is the
        JSON-able snapshot a serving layer scrapes."""
        self._health.backend = self._backend_name
        return self._health

    @property
    def dead_letter(self):
        """Quarantined-batch records (bounded; oldest evicted first)."""
        return self._guard.buffer.records()

    @property
    def handle(self):
        """The device-resident graph handle (prepared on first access)."""
        self._ensure_prepared()
        return self._handle

    @property
    def prepared(self) -> bool:
        return self._handle is not None

    def _ensure_prepared(self, stream: Optional[UpdateStream] = None,
                         batch: Optional[UpdateBatch] = None) -> None:
        if self._handle is not None:
            return
        cap = self._capacity if isinstance(self._capacity, int) \
            else _auto_capacity(stream, batch)
        self._handle = self._engine.prepare(self._csr, diff_capacity=cap)

    @property
    def props(self) -> PropertyView:
        """Current vertex properties, device-resident; ``.to_host()``
        syncs explicitly.  Empty until the session has run something."""
        if self._handle is None:
            return PropertyView({}, 0)
        return PropertyView(dict(self._props), self._engine.n_real)

    def _sync_counters(self) -> tuple:
        """ONE host readback of the (overflow, used, dead) pool triple."""
        _faults.fire("counter_sync", engine=self._backend_name)
        return tuple(int(x) for x in
                     np.asarray(self._engine.handle_counters(self._handle)))

    def _n_vertices(self) -> int:
        """Real vertex count, available before AND after prepare (a
        restored session has a handle but no CSR)."""
        return self._engine.n_real if self._handle is not None \
            else self._csr.n

    def _retry_on_overflow(self, attempt: Callable[[], None],
                           regrow: Callable[[], None],
                           batch=None,
                           rollback: Optional[Callable[[], None]] = None
                           ) -> None:
        """The one grow-on-overflow backstop: run ``attempt()`` (which
        mutates session state); while it raised the overflow counter,
        ``regrow()`` (roll back + grow the pool) and replay — **bounded**
        to ``_max_grow_attempts`` grows, after which ``rollback()``
        restores the pre-batch state and :class:`PoolOverflowError`
        carries the offending batch + pool stats out (growing until OOM
        is how a hostile batch used to take the whole process down).
        ``rollback()`` also runs if an attempt raises (an injected
        kernel fault mid-batch must not leave half-applied state).

        Exactly one counter sync per attempt: the triple is read once
        *post*-attempt and compared against the running ``_of_base``
        (the pre+post pair this replaces reintroduced the per-batch host
        sync PR 6's debt #4 removed from ``run_stream``)."""
        def run_attempt():
            try:
                attempt()
            except BaseException:
                if rollback is not None:
                    rollback()
                raise

        run_attempt()
        of = self._sync_counters()[0]
        grows = 0
        while of > self._of_base:
            self._health.overflow_retries += 1
            if grows >= self._max_grow_attempts:
                if rollback is not None:
                    rollback()
                counters = self._sync_counters()
                cap = self._engine._diff_capacity(self._handle)
                err = PoolOverflowError(
                    f"batch still overflows the diff pool after "
                    f"{grows} grow-and-replay attempts "
                    f"(capacity now {cap}); state rolled back to the "
                    f"pre-batch graph", batch=batch, attempts=grows,
                    diff_capacity=cap, counters=counters)
                self._health.record_error(err)
                raise err
            regrow()
            grows += 1
            self._health.pool_grows += 1
            self._of_base = 0  # grow merges the pool, clearing counters
            run_attempt()
            of = self._sync_counters()[0]
        self._of_base = of

    # -- graceful backend degradation (DESIGN.md §6) -------------------------
    def _guarded(self, op: Callable[[], Any]):
        """Run ``op`` with backend failover: a kernel/compile failure
        hops down the failover chain (migrating device state through
        ``state_to_csr``) and replays ``op`` on the survivor.  The typed
        data-plane faults (admission, pool overflow, divergence) pass
        through — they are the stream's fault, not the backend's.  ``op``
        must read ``self._engine`` / ``self._handle`` fresh so a replay
        sees the migrated state."""
        if self._failover is None:
            try:
                return op()
            except (AdmissionError, PoolOverflowError, DivergenceError):
                raise
            except Exception as exc:   # noqa: BLE001 — health bookkeeping
                self._health.kernel_failures += 1
                self._health.record_error(exc)
                raise
        self._maybe_reprobe()
        try:
            return op()
        except (AdmissionError, PoolOverflowError, DivergenceError):
            raise
        except Exception as exc:       # noqa: BLE001 — failover boundary
            return self._degrade_and_retry(op, exc)

    def _degrade_and_retry(self, op: Callable[[], Any], exc: Exception):
        self._health.kernel_failures += 1
        self._health.record_error(exc)
        last = exc
        for name in self._failover.candidates(self._backend_name):
            try:
                self._migrate(name)
            except Exception as mexc:  # noqa: BLE001 — try next in chain
                last = mexc
                continue
            self._failover.degraded_from()
            self._health.failovers += 1
            try:
                return op()
            except (AdmissionError, PoolOverflowError, DivergenceError):
                raise
            except Exception as nexc:  # noqa: BLE001 — keep degrading
                self._health.kernel_failures += 1
                self._health.record_error(nexc)
                last = nexc
        err = KernelFailure(
            f"backend {self._failover.preferred!r} and its failover "
            f"chain {tuple(self._failover.chain)} all failed",
            backend=self._backend_name, cause=last)
        self._health.record_error(err)
        raise err

    def _maybe_reprobe(self) -> None:
        """Sticky degradation with periodic re-probe: once the backoff
        window since the last failure elapses, try converting back to
        the preferred backend; a failed probe doubles the window."""
        if (self._backend_name == self._failover.preferred
                or not self._failover.should_probe()):
            return
        self._health.reprobes += 1
        try:
            self._migrate(self._failover.preferred)
        except Exception as exc:       # noqa: BLE001 — probe failed
            self._failover.probe_failed()
            self._health.record_error(exc)
        else:
            self._failover.recovered()

    def _migrate(self, name: str) -> None:
        """Re-bind this session's device state onto backend ``name``
        through the cross-backend conversion path (PR 7):
        ``pack_state`` (pure data access — works even when the source
        backend's kernels are broken) → host → ``state_to_csr`` →
        ``prepare`` on the new engine, properties re-placed per the new
        engine's padding.  Value-preserving; pool layout resets."""
        self._ensure_prepared()
        old = self._engine
        n = old.n_real
        tree, hmeta = old.pack_state(self._handle)
        tree = jax.tree_util.tree_map(np.asarray, tree)
        props = {k: np.asarray(v)[:n] for k, v in self._props.items()}
        csr, cap = state_to_csr(tree, hmeta)
        engine = make_engine(name)
        handle = engine.prepare(csr, diff_capacity=cap)
        self._engine = engine
        self._handle = handle
        self._backend_name = name
        self._health.backend = name
        self._props = {k: engine.put_vertex_array(
            _fit_pad(v, n, engine.n_pad)) for k, v in props.items()}
        self._of_base = self._sync_counters()[0]

    # -- divergence watchdog -------------------------------------------------
    def _watch(self, arrays: Dict[str, Any], where: str) -> None:
        if self._guard.policy != "off":
            _watchdog.check(arrays.items(), where=where,
                            health=self._health)

    def check_divergence(self) -> None:
        """On-demand NaN/Inf probe over the resident property arrays;
        raises :class:`DivergenceError` naming the poisoned ones."""
        _watchdog.check(self._props.items(), where="check_divergence",
                        health=self._health)

    # -- structural updates --------------------------------------------------
    def apply(self, batch: UpdateBatch) -> "GraphSession":
        """Apply one ΔG batch structurally (deletes then adds), after
        admission (reject/clamp/quarantine — see ``bind_graph``), growing
        the diff pool and replaying on overflow."""
        admitted = self._admit_for_apply(batch)
        if admitted is not None:
            self._apply_admitted(admitted)
        return self

    def _admit_for_apply(self, batch: UpdateBatch) -> Optional[UpdateBatch]:
        """The admission half of :meth:`apply`: guard the batch and do
        the quarantine/empty-skip cursor bookkeeping.  Returns the
        admitted batch, or None when the batch was consumed without
        device work.  Split out so the serving pool admits on its own
        thread and executes through the batched path while staying on
        the exact code (and health accounting) a solo ``apply`` uses."""
        self._ensure_prepared(batch=batch)
        admitted = self._guard.admit(batch, self._n_vertices(),
                                     cursor=self._cursor)
        if admitted is None:           # quarantined: consumed, not applied
            self._cursor += 1
            return None
        if self._guard.policy != "off" and not (
                np.asarray(admitted.add_mask).any()
                or np.asarray(admitted.del_mask).any()):
            # zero active lanes: a masked-out scatter is a device no-op,
            # so skip the launch entirely (structural path only — the
            # armed path runs every batch body for one-shot bit-equality)
            self._health.empty_skipped += 1
            self._cursor += 1
            return None
        return admitted

    def _apply_admitted(self, admitted: UpdateBatch) -> None:
        """The execution half of :meth:`apply`: deletes-then-adds under
        the failover guard with the bounded grow-and-replay backstop."""

        def work():
            base = self._handle

            def attempt():
                h = self._engine.update_del(base, admitted)
                self._handle = self._engine.update_add(h, admitted)

            def regrow():
                nonlocal base
                base = self._handle = self._engine.grow(base)

            def rollback():
                self._handle = base

            self._retry_on_overflow(attempt, regrow, batch=admitted,
                                    rollback=rollback)

        self._guarded(work)
        self._cursor += 1

    # -- hand-staged drivers -------------------------------------------------
    def call(self, fn: Callable, *args, **kwargs):
        """Run a hand-staged driver ``fn(engine, handle, *args)`` (the
        ``repro.algos`` convention).  A ``(new_handle, result)`` return
        — recognized by the first element having the session's handle
        type — is adopted into the session; anything else passes
        through untouched."""
        self._ensure_prepared()
        ret = {}

        def work():
            base = self._handle

            def attempt():
                self._handle = base
                out = fn(self._engine, base, *args, **kwargs)
                if isinstance(out, tuple) and len(out) == 2 and \
                        type(out[0]) is type(base):
                    self._handle, result = out
                    if isinstance(result, dict):
                        self._props = dict(result)
                    ret["value"] = result
                else:
                    ret["value"] = out

            def regrow():
                # the driver overflowed the pool: grow it and re-run the
                # driver from the grown pre-call graph
                nonlocal base
                base = self._engine.grow(base)

            def rollback():
                self._handle = base

            self._retry_on_overflow(attempt, regrow, rollback=rollback)

        self._guarded(work)
        return ret["value"]

    def run_stream(self, stream: UpdateStream, batch_size: int,
                   step_fn: Callable, carry, **kw):
        """Drive a stream through the engine's fused executor
        (``Engine.run_stream``); the updated handle stays resident and
        the final carry is returned.

        Admission runs as ONE vectorized host pass over the raw stream
        arrays before any device work — a clean stream (the common case)
        then takes the fused path untouched.  Poison batches are spliced
        out per policy and the surviving contiguous ranges still run
        fused (``UpdateStream.window`` keeps batch boundaries
        lane-identical)."""
        self._ensure_prepared(stream=stream)
        nb = stream.num_batches(batch_size)
        poison = self._guard.inspect_stream(stream, batch_size,
                                            self._n_vertices())
        if poison:
            carry = self._run_stream_guarded(stream, batch_size, step_fn,
                                             carry, poison, **kw)
        else:
            if self._guard.policy != "off":
                self._health.admitted += nb

            def op(c=carry):
                return self._engine.run_stream(self._handle, stream,
                                               batch_size, step_fn, c,
                                               **kw)

            self._handle, carry = self._guarded(op)
            # the fused executor may have grown/merged internally —
            # resync the overflow base with one triple read
            self._of_base = self._sync_counters()[0]
            self._cursor += nb
        if isinstance(carry, dict):
            self._props = dict(carry)
            self._watch(carry, where="run_stream")
        return carry

    def _apply_step(self, batch: UpdateBatch, step_fn: Callable, carry,
                    **kw):
        """One per-batch stream step (the poison-splice path): the
        baseline executor's body for a single admitted batch, with the
        bounded grow-and-replay backstop."""
        out = {}

        def attempt():
            view = self._engine.stream_view()
            h, c = step_fn(view, base[0], batch, carry)
            self._handle = h
            out["carry"] = c

        base = [self._handle]

        def regrow():
            base[0] = self._handle = self._engine.grow(base[0])

        def rollback():
            self._handle = base[0]

        self._retry_on_overflow(attempt, regrow, batch=batch,
                                rollback=rollback)
        return out["carry"]

    def _run_stream_guarded(self, stream: UpdateStream, batch_size: int,
                            step_fn: Callable, carry, poison, **kw):
        """Poison batches present: under ``reject`` fail fast before any
        device work; otherwise walk the stream, running clean contiguous
        ranges through the fused executor and resolving each poison
        batch individually (clamp → sanitize + single step; quarantine →
        dead-letter + skip, cursor still advancing — the batch was
        *consumed*, keeping durable-resume alignment)."""
        n = self._n_vertices()
        nb = stream.num_batches(batch_size)
        if self._guard.policy == "reject":
            first = min(poison)
            self._guard.resolve(stream.batch(first, batch_size),
                                poison[first], self._cursor, first, n)
            raise AssertionError("reject policy must raise")   # pragma: no cover
        i = 0
        while i < nb:
            if i in poison:
                admitted = self._guard.resolve(
                    stream.batch(i, batch_size), poison[i],
                    self._cursor, i, n)
                if admitted is not None:
                    carry = self._guarded(
                        lambda b=admitted, c=carry:
                        self._apply_step(b, step_fn, c, **kw))
                self._cursor += 1
                i += 1
            else:
                j = i
                while j < nb and j not in poison:
                    j += 1
                sub = stream.window(batch_size, i, j - i)
                self._health.admitted += j - i

                def op(s=sub, c=carry):
                    return self._engine.run_stream(self._handle, s,
                                                   batch_size, step_fn,
                                                   c, **kw)

                self._handle, carry = self._guarded(op)
                self._cursor += j - i
                i = j
        self._of_base = self._sync_counters()[0]
        return carry

    def to_host(self) -> Dict[str, np.ndarray]:
        return self.props.to_host()

    # -- durability (DESIGN.md §5) -------------------------------------------
    @property
    def stream_cursor(self) -> int:
        """ΔG batches applied through ``apply``/``run_stream`` so far —
        the resume position recorded by ``save``."""
        return self._cursor

    def state_tree(self):
        """Everything a durable restore needs, as one flattenable
        ``(nested-dict array tree, JSON-able meta)`` pair: the packed
        graph handle, the device-resident property arrays, and the
        stream cursor."""
        self._ensure_prepared()
        handle_tree, handle_meta = self._engine.pack_state(self._handle)
        tree = {"handle": handle_tree, "props": dict(self._props)}
        meta = {"version": 1, "kind": "graph",
                "backend": self._engine.name,
                "n": self._engine.n_real, "n_pad": self._engine.n_pad,
                "handle": handle_meta, "cursor": self._cursor}
        return tree, meta

    def save(self, ckpt_dir, step: Optional[int] = None, keep: int = 3):
        """Durably checkpoint the session (atomic-rename commit protocol,
        see ``repro.ckpt.checkpoint``).  ``step`` defaults to the stream
        cursor, so successive saves of a streaming session are ordered;
        returns the committed step directory."""
        tree, meta = self.state_tree()
        meta["tree_spec"] = _tree_spec(tree)
        step = self._cursor if step is None else int(step)
        return ckpt.save(ckpt_dir, step, tree, extra=meta, keep=keep)

    @staticmethod
    def restore(ckpt_dir, backend: Optional[str] = None,
                step: Optional[int] = None, **backend_opts):
        """Rebuild a session from ``save()`` output — see
        :func:`restore_session`."""
        return restore_session(ckpt_dir, backend=backend, step=step,
                               **backend_opts)


class Session(GraphSession):
    """A CompiledProgram bound to one backend + one graph.

    Two modes per DSL function:

    * **one-shot** — ``run("DynSSSP", updateBatch=stream, ...)`` executes
      the whole function (prologue, Batch loop over the given stream,
      epilogue) against the resident handle; bit-identical to the
      deprecated ``Program.run`` but with no re-prepare and no implicit
      host readback.
    * **armed** — omit the ``updates<g>`` argument and ``run`` executes
      only the prologue (e.g. the static algorithm), leaving the Batch
      loop armed: each ``apply(batch)`` then executes one loop body
      against the live state, and ``run_stream(stream, batch_size)``
      folds a whole stream through it.  N applies are bit-identical to
      one one-shot run over the same N batches.
    """

    def __init__(self, compiled: "CompiledProgram", engine: Engine,
                 csr: CSR, capacity: Union[str, int] = "auto", **runtime_kw):
        super().__init__(engine, csr, capacity, **runtime_kw)
        self.compiled = compiled
        self._armed: Optional[ArmedRun] = None
        # binding caches the staged per-(func, engine) executables, so
        # repeat calls skip host-side AST pattern interpretation
        self._staged_funcs: Dict[str, Any] = {}

    # -- DSL execution -------------------------------------------------------
    def _staged(self, func: str):
        """The staged executable for ``func`` on the CURRENT engine
        (failover migration clears the cache, so always resolve late)."""
        st = self._staged_funcs.get(func)
        if st is None:
            st = self._staged_funcs[func] = \
                self.compiled.program.stage(func, self._engine)
        return st

    def _batch_size_hint(self, staged, args) -> Optional[int]:
        """The batch size a one-shot run will use, when statically
        determinable host-side: Batch statements name their size
        (usually a scalar param like ``batchSize``), so the caller's
        args resolve it before execution.  None = undeterminable (the
        admission guard then admits the one-shot path unchecked)."""
        sizes = set()
        for name in staged._armable:
            v = args.get(name) if isinstance(name, str) else name
            if isinstance(v, bool):
                continue
            if isinstance(v, (int, np.integer)):
                sizes.add(int(v))
        return sizes.pop() if len(sizes) == 1 else None

    def run(self, func: str, **args) -> SessionResult:
        """Execute DSL function ``func`` against the resident graph.

        Scalars and the update stream are passed by parameter name, as
        keyword arguments.  If the function takes an ``updates<g>``
        parameter and it is omitted (or None), the session arms the
        Batch loop instead of running it (see class docstring)."""
        program = self.compiled.program
        fnode = program.ast.func(func)   # raises early on unknown names
        upd_params = [p.name for p in fnode.params
                      if p.type.name == "updates"]
        streams = [args[p] for p in upd_params
                   if args.get(p) is not None]
        staged = self._staged(func)
        self._ensure_prepared(stream=streams[0] if streams else None)

        if upd_params and not streams:
            def arm():
                armed = self._staged(func).begin(self._handle, args)
                self._armed = armed
                self._handle = armed.gbox.value
                self._props = armed.device_props()

            self._guarded(arm)
            return SessionResult(self, self.props, value=None)

        if streams and self._guard.policy != "off":
            res = self._run_oneshot_guarded(func, staged, args, upd_params)
            if res is not None:
                return res

        out = {}

        def op():
            st = self._staged(func)
            base = self._handle

            def attempt():
                g, props, ret = st.call(base, args)
                self._handle = g
                out["props"], out["ret"] = props, ret

            def regrow():
                # adds were dropped: grow the pool and replay the whole
                # run from the pre-run graph (same backstop as
                # apply/run_stream)
                nonlocal base
                base = self._engine.grow(base)

            def rollback():
                self._handle = base

            self._retry_on_overflow(attempt, regrow, rollback=rollback)

        self._guarded(op)
        # disarm only now: a run that raised (bad args, lowering error)
        # must leave a previously armed loop intact
        self._armed = None
        self._props = out["props"]
        self._watch(self._props, where=f"run({func})")
        return SessionResult(self, self.props, value=out["ret"])

    def _run_oneshot_guarded(self, func: str, staged, args,
                             upd_params) -> Optional[SessionResult]:
        """Admission for one-shot runs: inspect the stream host-side
        before execution.  A clean stream returns None — the caller
        takes the normal one-shot path bit-exactly.  With poison
        batches, ``reject`` raises; clamp/quarantine fall back to
        arming the Batch loop and feeding guarded per-batch applies
        (documented bit-identical to one-shot over the same batches)."""
        if len(upd_params) != 1:
            return None
        pname = upd_params[0]
        stream = args[pname]
        bs = self._batch_size_hint(staged, args)
        if bs is None or not isinstance(stream, UpdateStream):
            return None
        poison = self._guard.inspect_stream(stream, bs, self._n_vertices())
        if not poison:
            self._health.admitted += stream.num_batches(bs)
            return None
        arm_args = {k: v for k, v in args.items() if k != pname}

        def arm():
            armed = self._staged(func).begin(self._handle, arm_args)
            self._armed = armed
            self._handle = armed.gbox.value
            self._props = armed.device_props()

        self._guarded(arm)
        self._armed_stream_loop(stream, bs)
        value = self._armed.value()
        self._armed = None
        self._watch(self._props, where=f"run({func})")
        return SessionResult(self, self.props, value=value)

    @property
    def armed(self) -> bool:
        return self._armed is not None

    def call(self, fn: Callable, *args, **kwargs):
        out = super().call(fn, *args, **kwargs)
        # a hand-staged driver advancing the handle would leave an armed
        # frame's graph box stale (a later apply() would silently revert
        # its updates) — successful hand-staged execution supersedes the
        # armed loop; a driver that raised leaves it intact
        self._armed = None
        return out

    @property
    def value(self):
        """The DSL return value as of the current state (armed sessions
        evaluate the post-Batch epilogue without disturbing state)."""
        if self._armed is not None:
            return self._armed.value()
        raise CodegenError("no armed function; use the SessionResult "
                           "returned by run()")

    # -- incremental updates -------------------------------------------------
    def apply(self, batch: UpdateBatch) -> "Session":
        """Feed one ΔG batch to the armed Batch loop (falling back to a
        structural update when nothing is armed).  On diff-pool overflow
        the state is rolled back, the pool grown, and the batch
        replayed — so ``capacity='auto'`` underestimates are repaired,
        not wrong."""
        if self._armed is None:
            super().apply(batch)
            return self
        if self._armed.returned:
            return self    # a batch body returned: the Batch loop is
                           # over, exactly as in a one-shot run
        admitted = self._guard.admit(batch, self._n_vertices(),
                                     cursor=self._cursor)
        if admitted is None:          # quarantined: batch consumed
            self._cursor += 1
            return self
        self._apply_armed(admitted)
        return self

    def _apply_armed(self, batch: UpdateBatch) -> None:
        """One already-admitted batch through the armed loop body, with
        snapshot rollback (overflow regrow-and-replay, and clean state
        for failover's migrate-and-replay on kernel failure)."""

        def op():
            armed = self._armed   # re-read: migration re-arms
            snap = [armed.snapshot()]

            def attempt():
                armed.apply(batch)
                self._handle = armed.gbox.value

            def regrow():
                armed.restore(snap[0])
                armed.gbox.value = self._engine.grow(armed.gbox.value)
                self._handle = armed.gbox.value
                snap[0] = armed.snapshot()

            def rollback():
                armed.restore(snap[0])
                self._handle = armed.gbox.value

            self._retry_on_overflow(attempt, regrow, batch=batch,
                                    rollback=rollback)

        self._guarded(op)
        self._props = self._armed.device_props()
        self._cursor += 1

    def _armed_stream_loop(self, stream: UpdateStream, bs: int) -> None:
        """Fold a stream through the armed loop with STREAM-level
        admission.  Batch-level inspection cannot see every violation —
        ``UpdateStream.batch()`` int-casts NaN weights and clamps them
        to >= 1 while padding — so poison batches are located on the raw
        host rows first and resolved per policy as the loop reaches
        them."""
        n = self._n_vertices()
        poison = self._guard.inspect_stream(stream, bs, n)
        if poison and self._guard.policy == "reject":
            first = min(poison)
            self._guard.resolve(stream.batch(first, bs), poison[first],
                                self._cursor, first, n)
            raise AssertionError("reject policy must raise")  # pragma: no cover
        for i in range(stream.num_batches(bs)):
            if self._armed.returned:
                break            # a batch body returned: stop, like the
            batch = stream.batch(i, bs)   # one-shot Batch loop does
            if i in poison:
                batch = self._guard.resolve(batch, poison[i],
                                            self._cursor, i, n)
                if batch is None:         # quarantined: batch consumed
                    self._cursor += 1
                    continue
            elif self._guard.policy != "off":
                self._health.admitted += 1
            self._apply_armed(batch)

    # -- failover ------------------------------------------------------------
    def _migrate(self, name: str) -> None:
        """Backend migration with the armed Batch loop carried across:
        the paused frame is serialized on the failing backend (pure data
        access — works even when its kernels don't), the graph state is
        converted through the canonical alive-edge list, and the frame
        is re-staged and deserialized on the survivor."""
        armed_state = None
        if self._armed is not None:
            arrays, armed_meta = self._armed.serialize()
            for pname, m in armed_meta["env"].items():
                if m["kind"] == "prop" and m.get("bound") and m["is_edge"]:
                    raise KernelFailure(
                        f"cannot fail over to {name!r}: armed edge "
                        f"property {pname!r} is bound to the "
                        f"{self._backend_name!r} pool layout",
                        backend=name)
            armed_state = ({k: np.asarray(v) for k, v in arrays.items()},
                           armed_meta)
        n = self._engine.n_real
        super()._migrate(name)
        # staged executables embed the old engine's jitted closures
        self._staged_funcs.clear()
        if armed_state is not None:
            self._rearm(armed_state[1], armed_state[0], n)

    def _rearm(self, armed_meta: dict, arrays: dict, n: int) -> None:
        """Re-stage an armed Batch loop on the CURRENT engine and
        rebuild its paused frame from serialized arrays (shared by
        failover migration and ``restore_session``)."""
        for pname, m in armed_meta["env"].items():
            if m["kind"] == "prop" and m.get("bound") and not m["is_edge"]:
                arrays[f"prop_{pname}"] = self._engine.put_vertex_array(
                    _fit_pad(arrays[f"prop_{pname}"], n,
                             self._engine.n_pad))
        staged = self._staged(armed_meta["func"])
        self._armed = ArmedRun.deserialize(staged, self._handle, arrays,
                                           armed_meta)
        self._handle = self._armed.gbox.value
        self._props = self._armed.device_props()

    # -- durability ----------------------------------------------------------
    def state_tree(self):
        """Adds the program identity and (when armed) the serialized
        Batch-loop position to the GraphSession snapshot."""
        tree, meta = super().state_tree()
        meta["kind"] = "session"
        meta["source"] = self.compiled.program.source
        if self._armed is not None:
            arrays, armed_meta = self._armed.serialize()
            tree["armed"] = arrays
            meta["armed"] = armed_meta
        return tree, meta

    def run_stream(self, stream: UpdateStream, batch_size: Optional[int] =
                   None, step_fn: Optional[Callable] = None, carry=None,
                   **kw):
        """Armed sessions: fold a whole update stream through the armed
        Batch loop, one ``apply`` per batch; returns a
        :class:`SessionResult`.  With an explicit ``step_fn`` this
        instead delegates to the engine's fused executor (the
        GraphSession/hand-staged path) and returns the final carry,
        not a SessionResult."""
        if step_fn is not None:
            out = super().run_stream(stream, batch_size, step_fn, carry,
                                     **kw)
            # successful hand-staged streaming supersedes any armed DSL
            # loop: the armed frame's graph box would otherwise go stale
            # and a later apply() would silently revert these updates
            self._armed = None
            return out
        if self._armed is None:
            raise CodegenError("run_stream without step_fn needs an armed "
                               "function; call run(func, ...) without its "
                               "updates argument first")
        if carry is not None or kw:
            raise TypeError(
                f"run_stream on an armed session takes only (stream, "
                f"batch_size); carry/{sorted(kw)} belong to the step_fn "
                f"(hand-staged) path")
        bs = batch_size
        if bs is None:
            # the batchSize the function was armed with, if any
            try:
                bs = self._armed.frame.lookup(
                    self._armed.batch_stmt.batch_size)
            except CodegenError:
                bs = None
        if bs is None:
            raise CodegenError("no batch size: pass run_stream(..., "
                               "batch_size=N) or batchSize= at arm time")
        self._ensure_prepared(stream=stream)
        self._armed_stream_loop(stream, int(bs))
        self._watch(self._props, where="run_stream(armed)")
        return SessionResult(self, self.props, value=self._armed.value())


class CompiledProgram:
    """A compiled DSL program, backend-agnostic; ``bind`` picks the
    backend by registry name and yields a :class:`Session`."""

    def __init__(self, program: Program):
        self.program = program

    @property
    def functions(self):
        """Names of the functions this program defines."""
        return [f.name for f in self.program.ast.funcs]

    def bind(self, csr: CSR, backend: str = "jnp",
             capacity: Union[str, int] = "auto",
             admission: str = "clamp",
             max_batch: int = DEFAULT_MAX_BATCH,
             dead_letter: int = 64,
             failover=None,
             **backend_opts) -> Session:
        """Bind to a graph on a named backend.  ``capacity`` sizes the
        diff-CSR pool: an int is explicit; ``"auto"`` derives it from
        the stream of the first one-shot run (armed sessions prepare
        for the prologue before any update exists, so they start at the
        default size), with grow-on-overflow as the backstop either
        way.

        Runtime knobs mirror :func:`bind_graph`: ``admission`` is the
        ΔG validation policy (``reject | clamp | quarantine | off``),
        ``failover=True`` enables the registry's degradation chain for
        ``backend`` (or pass an explicit tuple of fallback names) —
        including at bind time: if the preferred backend fails to
        construct, the session comes up degraded on the first survivor
        and re-probes the preferred backend on a backoff timer."""
        if failover:
            engine, bound = _make_engine_failover(backend, failover,
                                                  **backend_opts)
        else:
            engine, bound = make_engine(backend, **backend_opts), backend
        sess = Session(self, engine, csr, capacity,
                       backend_name=bound, admission=admission,
                       max_batch=max_batch, dead_letter=dead_letter,
                       failover=failover)
        _post_bind_failover(sess, backend, bound, failover)
        return sess

    def __repr__(self):
        return f"CompiledProgram(functions={self.functions})"


def restore_session(ckpt_dir, backend: Optional[str] = None,
                    step: Optional[int] = None,
                    engine: Optional[Engine] = None,
                    **backend_opts) -> GraphSession:
    """Reconstruct a session from a checkpoint directory written by
    ``Session.save`` / ``GraphSession.save``.

    ``step=None`` picks the latest committed step.  ``backend=None``
    restores onto the backend that saved:

    * same backend kind — **bit-exact**: the raw handle leaves (diff
      pool, tombstones, ELL pack) are restored, so resumed streaming is
      bit-identical to the uninterrupted run;
    * the dist backends (``dist`` / ``dist_sharded``) re-partition
      their canonical edge list onto the *current* mesh — an elastic
      restore may come back on a different device count
      (``restore_session(dir, num_shards=M)``) or, for the sharded
      backend, a different row partitioner (value-exact for
      order-independent reductions);
    * naming a **different** backend converts through the canonical
      alive-edge list and re-``prepare``s (value-preserving, pool
      layout reset).

    An armed Batch loop resumes exactly where it paused; the prologue is
    not re-run.  The result is a :class:`Session` when the checkpoint
    was written by one (program source travels in the manifest),
    otherwise a :class:`GraphSession`.

    ``engine=`` restores onto an ALREADY-CONSTRUCTED engine instance
    instead of building a fresh one (mutually exclusive with
    ``backend``/``backend_opts``).  The serving pool revives evicted
    sessions this way so they rejoin the pool's shared-executable
    engine — a fresh engine would recompile everything and break the
    pool's batching groups.  Bit-exactness then requires the instance's
    ``state_kind`` to match the saver's, same as a name-based restore.
    """
    if step is None:
        step = ckpt.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(
                f"no committed checkpoint under {ckpt_dir}")
    meta = ckpt.read_manifest(ckpt_dir, step)["extra"]
    if engine is not None:
        if backend is not None or backend_opts:
            raise ValueError("restore_session: pass either engine= or "
                             "backend=/**backend_opts, not both")
    else:
        engine = make_engine(backend or meta["backend"], **backend_opts)
    example = _example_from_spec(meta["tree_spec"])
    tree, _ = ckpt.restore(ckpt_dir, step, example)
    # strip the restore's single-device commitment: the engine re-places
    # every leaf (dist shards vertex arrays over its own mesh)
    tree = jax.tree_util.tree_map(np.asarray, tree)

    hmeta = meta["handle"]
    exact = engine.state_kind == hmeta["kind"]
    if exact:
        handle = engine.unpack_state(tree["handle"], hmeta)
    else:
        csr, cap = state_to_csr(tree["handle"], hmeta)
        handle = engine.prepare(csr, diff_capacity=cap)
    # edge-LANE state only survives when the pool layout does: a dist
    # restore re-partitions even same-kind, invalidating lane indices
    lanes_ok = exact and hmeta["kind"] != "dist"

    if meta["kind"] == "session":
        sess: GraphSession = Session(compile(meta["source"]), engine,
                                     csr=None)
    else:
        sess = GraphSession(engine, csr=None)
    sess._handle = handle
    n = int(meta["n"])
    sess._props = {k: engine.put_vertex_array(_fit_pad(v, n, engine.n_pad))
                   for k, v in tree.get("props", {}).items()}
    sess._cursor = int(meta["cursor"])

    armed_meta = meta.get("armed")
    if armed_meta is not None:
        arrays = dict(tree.get("armed") or {})
        for name, m in armed_meta["env"].items():
            if m["kind"] == "prop" and m.get("bound") and m["is_edge"] \
                    and not lanes_ok:
                raise ValueError(
                    f"armed edge property {name!r} is bound to the "
                    f"saved pool layout; it cannot survive a "
                    f"cross-backend restore or a dist re-mesh — "
                    f"restore onto the saving backend, or disarm "
                    f"before saving")
        sess._rearm(armed_meta, arrays, n)
    # one triple read pins the overflow base for the restored pool
    sess._of_base = sess._sync_counters()[0]
    return sess
