"""The stable public API: compile once, bind to any backend, keep graph
state device-resident across calls.

This is the contract the paper's DSL promises ("one program, N generated
backends") surfaced as a first-class Python API — GraphIt's
algorithm/schedule separation, StarPlat's resident Batch-loop driver:

    import repro.api as api

    prog = api.compile("src/repro/dsl_programs/sssp.sp")
    sess = prog.bind(csr, backend="pallas", capacity="auto")

    # one-shot, same semantics as the deprecated Program.run:
    res = sess.run("DynSSSP", updateBatch=stream, batchSize=16, src=0)
    res.props["dist"]          # device array — no host sync
    res.to_host()["dist"]      # explicit numpy readback

    # long-lived streaming consumer: omit the stream to arm the Batch
    # loop, then feed ΔG batches as they arrive; graph + properties stay
    # on device between calls and `engine.prepare` runs exactly once.
    sess = prog.bind(csr, backend="jnp", capacity="auto")
    sess.run("DynSSSP", src=0)
    for batch in live_feed:
        sess.apply(batch)
        serve(sess.props["dist"])

Backends are resolved by name through ``repro.core.registry``;
``register_engine`` plugs new engines in without touching this facade.
Hand-staged algorithms (``repro.algos``) ride the same session via
``bind_graph`` — an algorithm-agnostic session owning the resident
handle — and its ``call``/``run_stream`` helpers.
"""
from __future__ import annotations

import functools
import pathlib
from typing import Any, Callable, Dict, Iterator, Mapping, Optional, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.core.dsl.codegen import (ArmedRun, CodegenError, Program,
                                    compile_source)
from repro.core.engine import Engine, state_to_csr
from repro.core.registry import (available_backends, make_engine,
                                 register_engine)
from repro.graph.csr import CSR
from repro.graph.updates import UpdateBatch, UpdateStream

__all__ = [
    "compile", "CompiledProgram", "Session", "GraphSession", "bind_graph",
    "SessionResult", "PropertyView", "register_engine",
    "available_backends", "restore_session",
]

_DEFAULT_CAPACITY = 64


@functools.lru_cache(maxsize=256)
def _compile_cached(source_or_path: str, stamp) -> "CompiledProgram":
    return CompiledProgram(compile_source(source_or_path))


def compile(source_or_path: str) -> "CompiledProgram":
    """Compile DSL text (or a path to a ``.sp`` file) once; the result
    is cached per source (``.sp`` cache entries key on the file's
    mtime, so on-disk edits recompile)."""
    s = str(source_or_path)
    stamp = None
    if s.endswith(".sp"):
        p = pathlib.Path(s)
        if p.exists():
            stamp = p.stat().st_mtime_ns
    return _compile_cached(s, stamp)


def bind_graph(csr: CSR, backend: str = "jnp",
               capacity: Union[str, int] = "auto",
               **backend_opts) -> "GraphSession":
    """An algorithm-agnostic session (no DSL program): a device-resident
    graph handle for hand-staged ``repro.algos`` code."""
    return GraphSession(make_engine(backend, **backend_opts), csr, capacity)


def _auto_capacity(stream: Optional[UpdateStream] = None,
                   batch: Optional[UpdateBatch] = None) -> int:
    """Diff-pool size derived from the bound stream/batch: every add may
    land in the pool (deletes only tombstone), doubled for headroom.
    With neither in sight — arming a Batch loop prepares the graph for
    the prologue before any update exists — the pool starts at the
    default.  The grow-on-overflow path backstops all underestimates."""
    if stream is not None:
        return max(16, 2 * stream.num_adds)
    if batch is not None:
        return max(_DEFAULT_CAPACITY, 8 * batch.size)
    return _DEFAULT_CAPACITY


def _tree_spec(tree):
    """Per-leaf ``[shape, dtype]`` mirror of a nested-dict array tree —
    JSON-able, enough to rebuild an example tree for ``ckpt.restore``
    without needing the (unrecoverable) pickled treedef."""
    if isinstance(tree, dict):
        return {k: _tree_spec(v) for k, v in tree.items()}
    return [list(np.shape(tree)),
            str(getattr(tree, "dtype", np.asarray(tree).dtype))]


def _example_from_spec(spec):
    if isinstance(spec, dict):
        return {k: _example_from_spec(v) for k, v in spec.items()}
    shape, dtype = spec
    return jnp.zeros(tuple(shape), np.dtype(dtype))


def _fit_pad(arr, n_real: int, n_pad: int):
    """Refit a saved vertex array to the restoring engine's padding
    (dist n_pad = block·P changes with the device count).  The pad
    region is dead for forall lowerings — lowering masks them with
    ``idx < n_real`` — so it is filled from the saved pad value when one
    exists, else dtype-zero."""
    arr = jnp.asarray(arr)
    if arr.ndim == 0 or arr.shape[0] == n_pad:
        return arr
    body = arr[:n_real]
    if n_pad == n_real:
        return body
    fill = arr[n_real] if arr.shape[0] > n_real else jnp.zeros((), arr.dtype)
    return jnp.concatenate(
        [body, jnp.full((n_pad - n_real,), fill, arr.dtype)])


class PropertyView(Mapping):
    """Lazy view over a session's vertex properties.

    Indexing returns the **device** array (padded; no host sync);
    ``to_host()`` / ``host(name)`` perform the explicit numpy readback,
    sliced to the real vertex count — the one place the API syncs."""

    def __init__(self, arrays: Dict[str, Any], n_real: int):
        self._arrays = arrays
        self._n = n_real

    def __getitem__(self, name: str):
        return self._arrays[name]

    def __iter__(self) -> Iterator[str]:
        return iter(self._arrays)

    def __len__(self) -> int:
        return len(self._arrays)

    def host(self, name: str) -> np.ndarray:
        return np.asarray(self._arrays[name])[: self._n]

    def to_host(self) -> Dict[str, np.ndarray]:
        return {k: self.host(k) for k in self._arrays}

    def __repr__(self):
        return (f"PropertyView({sorted(self._arrays)}, "
                f"n={self._n}, device-resident)")


class SessionResult:
    """What ``Session.run`` returns: device-resident props + the DSL
    return value.  ``to_host()`` is the explicit sync point."""

    def __init__(self, session: "GraphSession", props: PropertyView,
                 value: Any = None):
        self.session = session
        self.props = props
        self.value = value

    @property
    def graph(self):
        return self.session.handle

    def to_host(self) -> Dict[str, np.ndarray]:
        return self.props.to_host()

    def __repr__(self):
        return (f"SessionResult(props={sorted(self.props)}, "
                f"value={self.value!r})")


class GraphSession:
    """Owns one engine instance and its device-resident graph handle.

    ``prepare`` runs exactly once per session — lazily, so
    ``capacity='auto'`` can wait for the first stream/batch to size the
    diff pool.  Structural updates, hand-staged drivers, and the fused
    stream executor all route through here and keep the handle warm.
    """

    def __init__(self, engine: Engine, csr: CSR,
                 capacity: Union[str, int] = "auto"):
        if not (capacity == "auto" or isinstance(capacity, int)):
            raise ValueError(f"capacity must be 'auto' or an int, "
                             f"got {capacity!r}")
        self._engine = engine
        self._csr = csr
        self._capacity = capacity
        self._handle = None
        self._props: Dict[str, Any] = {}
        # last host-observed overflow counter (see _retry_on_overflow)
        self._of_base = 0
        # ΔG batches applied through apply()/run_stream() — the resume
        # position checkpointed by save()
        self._cursor = 0

    # -- resident state ------------------------------------------------------
    @property
    def engine(self) -> Engine:
        return self._engine

    @property
    def backend(self) -> str:
        return self._engine.name

    @property
    def handle(self):
        """The device-resident graph handle (prepared on first access)."""
        self._ensure_prepared()
        return self._handle

    @property
    def prepared(self) -> bool:
        return self._handle is not None

    def _ensure_prepared(self, stream: Optional[UpdateStream] = None,
                         batch: Optional[UpdateBatch] = None) -> None:
        if self._handle is not None:
            return
        cap = self._capacity if isinstance(self._capacity, int) \
            else _auto_capacity(stream, batch)
        self._handle = self._engine.prepare(self._csr, diff_capacity=cap)

    @property
    def props(self) -> PropertyView:
        """Current vertex properties, device-resident; ``.to_host()``
        syncs explicitly.  Empty until the session has run something."""
        if self._handle is None:
            return PropertyView({}, 0)
        return PropertyView(dict(self._props), self._engine.n_real)

    def _sync_counters(self) -> tuple:
        """ONE host readback of the (overflow, used, dead) pool triple."""
        return tuple(int(x) for x in
                     np.asarray(self._engine.handle_counters(self._handle)))

    def _retry_on_overflow(self, attempt: Callable[[], None],
                           regrow: Callable[[], None]) -> None:
        """The one grow-on-overflow backstop: run ``attempt()`` (which
        mutates session state); while it raised the overflow counter,
        ``regrow()`` (roll back + grow the pool) and replay.

        Exactly one counter sync per attempt: the triple is read once
        *post*-attempt and compared against the running ``_of_base``
        (the pre+post pair this replaces reintroduced the per-batch host
        sync PR 6's debt #4 removed from ``run_stream``)."""
        attempt()
        of = self._sync_counters()[0]
        while of > self._of_base:
            regrow()
            self._of_base = 0  # grow merges the pool, clearing counters
            attempt()
            of = self._sync_counters()[0]
        self._of_base = of

    # -- structural updates --------------------------------------------------
    def apply(self, batch: UpdateBatch) -> "GraphSession":
        """Apply one ΔG batch structurally (deletes then adds), growing
        the diff pool and replaying on overflow."""
        self._ensure_prepared(batch=batch)
        base = self._handle

        def attempt():
            h = self._engine.update_del(base, batch)
            self._handle = self._engine.update_add(h, batch)

        def regrow():
            nonlocal base
            base = self._handle = self._engine.grow(base)

        self._retry_on_overflow(attempt, regrow)
        self._cursor += 1
        return self

    # -- hand-staged drivers -------------------------------------------------
    def call(self, fn: Callable, *args, **kwargs):
        """Run a hand-staged driver ``fn(engine, handle, *args)`` (the
        ``repro.algos`` convention).  A ``(new_handle, result)`` return
        — recognized by the first element having the session's handle
        type — is adopted into the session; anything else passes
        through untouched."""
        self._ensure_prepared()
        base = self._handle
        ret = {}

        def attempt():
            self._handle = base
            out = fn(self._engine, base, *args, **kwargs)
            if isinstance(out, tuple) and len(out) == 2 and \
                    type(out[0]) is type(base):
                self._handle, result = out
                if isinstance(result, dict):
                    self._props = dict(result)
                ret["value"] = result
            else:
                ret["value"] = out

        def regrow():
            # the driver overflowed the pool: grow it and re-run the
            # driver from the grown pre-call graph
            nonlocal base
            base = self._engine.grow(base)

        self._retry_on_overflow(attempt, regrow)
        return ret["value"]

    def run_stream(self, stream: UpdateStream, batch_size: int,
                   step_fn: Callable, carry, **kw):
        """Drive a stream through the engine's fused executor
        (``Engine.run_stream``); the updated handle stays resident and
        the final carry is returned."""
        self._ensure_prepared(stream=stream)
        self._handle, carry = self._engine.run_stream(
            self._handle, stream, batch_size, step_fn, carry, **kw)
        # the fused executor may have grown/merged internally — resync
        # the overflow base with one triple read
        self._of_base = self._sync_counters()[0]
        self._cursor += stream.num_batches(batch_size)
        if isinstance(carry, dict):
            self._props = dict(carry)
        return carry

    def to_host(self) -> Dict[str, np.ndarray]:
        return self.props.to_host()

    # -- durability (DESIGN.md §5) -------------------------------------------
    @property
    def stream_cursor(self) -> int:
        """ΔG batches applied through ``apply``/``run_stream`` so far —
        the resume position recorded by ``save``."""
        return self._cursor

    def state_tree(self):
        """Everything a durable restore needs, as one flattenable
        ``(nested-dict array tree, JSON-able meta)`` pair: the packed
        graph handle, the device-resident property arrays, and the
        stream cursor."""
        self._ensure_prepared()
        handle_tree, handle_meta = self._engine.pack_state(self._handle)
        tree = {"handle": handle_tree, "props": dict(self._props)}
        meta = {"version": 1, "kind": "graph",
                "backend": self._engine.name,
                "n": self._engine.n_real, "n_pad": self._engine.n_pad,
                "handle": handle_meta, "cursor": self._cursor}
        return tree, meta

    def save(self, ckpt_dir, step: Optional[int] = None, keep: int = 3):
        """Durably checkpoint the session (atomic-rename commit protocol,
        see ``repro.ckpt.checkpoint``).  ``step`` defaults to the stream
        cursor, so successive saves of a streaming session are ordered;
        returns the committed step directory."""
        tree, meta = self.state_tree()
        meta["tree_spec"] = _tree_spec(tree)
        step = self._cursor if step is None else int(step)
        return ckpt.save(ckpt_dir, step, tree, extra=meta, keep=keep)

    @staticmethod
    def restore(ckpt_dir, backend: Optional[str] = None,
                step: Optional[int] = None, **backend_opts):
        """Rebuild a session from ``save()`` output — see
        :func:`restore_session`."""
        return restore_session(ckpt_dir, backend=backend, step=step,
                               **backend_opts)


class Session(GraphSession):
    """A CompiledProgram bound to one backend + one graph.

    Two modes per DSL function:

    * **one-shot** — ``run("DynSSSP", updateBatch=stream, ...)`` executes
      the whole function (prologue, Batch loop over the given stream,
      epilogue) against the resident handle; bit-identical to the
      deprecated ``Program.run`` but with no re-prepare and no implicit
      host readback.
    * **armed** — omit the ``updates<g>`` argument and ``run`` executes
      only the prologue (e.g. the static algorithm), leaving the Batch
      loop armed: each ``apply(batch)`` then executes one loop body
      against the live state, and ``run_stream(stream, batch_size)``
      folds a whole stream through it.  N applies are bit-identical to
      one one-shot run over the same N batches.
    """

    def __init__(self, compiled: "CompiledProgram", engine: Engine,
                 csr: CSR, capacity: Union[str, int] = "auto"):
        super().__init__(engine, csr, capacity)
        self.compiled = compiled
        self._armed: Optional[ArmedRun] = None
        # binding caches the staged per-(func, engine) executables, so
        # repeat calls skip host-side AST pattern interpretation
        self._staged_funcs: Dict[str, Any] = {}

    # -- DSL execution -------------------------------------------------------
    def run(self, func: str, **args) -> SessionResult:
        """Execute DSL function ``func`` against the resident graph.

        Scalars and the update stream are passed by parameter name, as
        keyword arguments.  If the function takes an ``updates<g>``
        parameter and it is omitted (or None), the session arms the
        Batch loop instead of running it (see class docstring)."""
        program = self.compiled.program
        fnode = program.ast.func(func)   # raises early on unknown names
        upd_params = [p.name for p in fnode.params
                      if p.type.name == "updates"]
        streams = [args[p] for p in upd_params
                   if args.get(p) is not None]
        staged = self._staged_funcs.get(func)
        if staged is None:
            staged = self._staged_funcs[func] = program.stage(func,
                                                              self._engine)
        self._ensure_prepared(stream=streams[0] if streams else None)

        if upd_params and not streams:
            self._armed = staged.begin(self._handle, args)
            self._handle = self._armed.gbox.value
            self._props = self._armed.device_props()
            return SessionResult(self, self.props, value=None)

        base = self._handle
        out = {}

        def attempt():
            g, props, ret = staged.call(base, args)
            self._handle = g
            out["props"], out["ret"] = props, ret

        def regrow():
            # adds were dropped: grow the pool and replay the whole run
            # from the pre-run graph (same backstop as apply/run_stream)
            nonlocal base
            base = self._engine.grow(base)

        self._retry_on_overflow(attempt, regrow)
        # disarm only now: a run that raised (bad args, lowering error)
        # must leave a previously armed loop intact
        self._armed = None
        self._props = out["props"]
        return SessionResult(self, self.props, value=out["ret"])

    @property
    def armed(self) -> bool:
        return self._armed is not None

    def call(self, fn: Callable, *args, **kwargs):
        out = super().call(fn, *args, **kwargs)
        # a hand-staged driver advancing the handle would leave an armed
        # frame's graph box stale (a later apply() would silently revert
        # its updates) — successful hand-staged execution supersedes the
        # armed loop; a driver that raised leaves it intact
        self._armed = None
        return out

    @property
    def value(self):
        """The DSL return value as of the current state (armed sessions
        evaluate the post-Batch epilogue without disturbing state)."""
        if self._armed is not None:
            return self._armed.value()
        raise CodegenError("no armed function; use the SessionResult "
                           "returned by run()")

    # -- incremental updates -------------------------------------------------
    def apply(self, batch: UpdateBatch) -> "Session":
        """Feed one ΔG batch to the armed Batch loop (falling back to a
        structural update when nothing is armed).  On diff-pool overflow
        the state is rolled back, the pool grown, and the batch
        replayed — so ``capacity='auto'`` underestimates are repaired,
        not wrong."""
        if self._armed is None:
            super().apply(batch)
            return self
        if self._armed.returned:
            return self    # a batch body returned: the Batch loop is
        armed = self._armed    # over, exactly as in a one-shot run
        snap = armed.snapshot()

        def attempt():
            armed.apply(batch)
            self._handle = armed.gbox.value

        def regrow():
            nonlocal snap
            armed.restore(snap)
            armed.gbox.value = self._engine.grow(armed.gbox.value)
            self._handle = armed.gbox.value
            snap = armed.snapshot()

        self._retry_on_overflow(attempt, regrow)
        self._props = armed.device_props()
        self._cursor += 1
        return self

    # -- durability ----------------------------------------------------------
    def state_tree(self):
        """Adds the program identity and (when armed) the serialized
        Batch-loop position to the GraphSession snapshot."""
        tree, meta = super().state_tree()
        meta["kind"] = "session"
        meta["source"] = self.compiled.program.source
        if self._armed is not None:
            arrays, armed_meta = self._armed.serialize()
            tree["armed"] = arrays
            meta["armed"] = armed_meta
        return tree, meta

    def run_stream(self, stream: UpdateStream, batch_size: Optional[int] =
                   None, step_fn: Optional[Callable] = None, carry=None,
                   **kw):
        """Armed sessions: fold a whole update stream through the armed
        Batch loop, one ``apply`` per batch; returns a
        :class:`SessionResult`.  With an explicit ``step_fn`` this
        instead delegates to the engine's fused executor (the
        GraphSession/hand-staged path) and returns the final carry,
        not a SessionResult."""
        if step_fn is not None:
            out = super().run_stream(stream, batch_size, step_fn, carry,
                                     **kw)
            # successful hand-staged streaming supersedes any armed DSL
            # loop: the armed frame's graph box would otherwise go stale
            # and a later apply() would silently revert these updates
            self._armed = None
            return out
        if self._armed is None:
            raise CodegenError("run_stream without step_fn needs an armed "
                               "function; call run(func, ...) without its "
                               "updates argument first")
        if carry is not None or kw:
            raise TypeError(
                f"run_stream on an armed session takes only (stream, "
                f"batch_size); carry/{sorted(kw)} belong to the step_fn "
                f"(hand-staged) path")
        bs = batch_size
        if bs is None:
            # the batchSize the function was armed with, if any
            try:
                bs = self._armed.frame.lookup(
                    self._armed.batch_stmt.batch_size)
            except CodegenError:
                bs = None
        if bs is None:
            raise CodegenError("no batch size: pass run_stream(..., "
                               "batch_size=N) or batchSize= at arm time")
        self._ensure_prepared(stream=stream)
        for batch in stream.batches(int(bs)):
            if self._armed.returned:
                break            # a batch body returned: stop, like the
            self.apply(batch)    # one-shot Batch loop does
        return SessionResult(self, self.props, value=self._armed.value())


class CompiledProgram:
    """A compiled DSL program, backend-agnostic; ``bind`` picks the
    backend by registry name and yields a :class:`Session`."""

    def __init__(self, program: Program):
        self.program = program

    @property
    def functions(self):
        """Names of the functions this program defines."""
        return [f.name for f in self.program.ast.funcs]

    def bind(self, csr: CSR, backend: str = "jnp",
             capacity: Union[str, int] = "auto",
             **backend_opts) -> Session:
        """Bind to a graph on a named backend.  ``capacity`` sizes the
        diff-CSR pool: an int is explicit; ``"auto"`` derives it from
        the stream of the first one-shot run (armed sessions prepare
        for the prologue before any update exists, so they start at the
        default size), with grow-on-overflow as the backstop either
        way."""
        return Session(self, make_engine(backend, **backend_opts), csr,
                       capacity)

    def __repr__(self):
        return f"CompiledProgram(functions={self.functions})"


def restore_session(ckpt_dir, backend: Optional[str] = None,
                    step: Optional[int] = None,
                    **backend_opts) -> GraphSession:
    """Reconstruct a session from a checkpoint directory written by
    ``Session.save`` / ``GraphSession.save``.

    ``step=None`` picks the latest committed step.  ``backend=None``
    restores onto the backend that saved:

    * same backend kind — **bit-exact**: the raw handle leaves (diff
      pool, tombstones, ELL pack) are restored, so resumed streaming is
      bit-identical to the uninterrupted run;
    * the dist backend re-partitions its canonical edge list onto the
      *current* mesh — an elastic restore may come back on a different
      device count (value-exact for order-independent reductions);
    * naming a **different** backend converts through the canonical
      alive-edge list and re-``prepare``s (value-preserving, pool
      layout reset).

    An armed Batch loop resumes exactly where it paused; the prologue is
    not re-run.  The result is a :class:`Session` when the checkpoint
    was written by one (program source travels in the manifest),
    otherwise a :class:`GraphSession`.
    """
    if step is None:
        step = ckpt.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(
                f"no committed checkpoint under {ckpt_dir}")
    meta = ckpt.read_manifest(ckpt_dir, step)["extra"]
    engine = make_engine(backend or meta["backend"], **backend_opts)
    example = _example_from_spec(meta["tree_spec"])
    tree, _ = ckpt.restore(ckpt_dir, step, example)
    # strip the restore's single-device commitment: the engine re-places
    # every leaf (dist shards vertex arrays over its own mesh)
    tree = jax.tree_util.tree_map(np.asarray, tree)

    hmeta = meta["handle"]
    exact = engine.state_kind == hmeta["kind"]
    if exact:
        handle = engine.unpack_state(tree["handle"], hmeta)
    else:
        csr, cap = state_to_csr(tree["handle"], hmeta)
        handle = engine.prepare(csr, diff_capacity=cap)
    # edge-LANE state only survives when the pool layout does: a dist
    # restore re-partitions even same-kind, invalidating lane indices
    lanes_ok = exact and hmeta["kind"] != "dist"

    if meta["kind"] == "session":
        sess: GraphSession = Session(compile(meta["source"]), engine,
                                     csr=None)
    else:
        sess = GraphSession(engine, csr=None)
    sess._handle = handle
    n = int(meta["n"])
    sess._props = {k: engine.put_vertex_array(_fit_pad(v, n, engine.n_pad))
                   for k, v in tree.get("props", {}).items()}
    sess._cursor = int(meta["cursor"])

    armed_meta = meta.get("armed")
    if armed_meta is not None:
        arrays = dict(tree.get("armed") or {})
        for name, m in armed_meta["env"].items():
            if m["kind"] == "prop" and m.get("bound"):
                if m["is_edge"] and not lanes_ok:
                    raise ValueError(
                        f"armed edge property {name!r} is bound to the "
                        f"saved pool layout; it cannot survive a "
                        f"cross-backend restore or a dist re-mesh — "
                        f"restore onto the saving backend, or disarm "
                        f"before saving")
                if not m["is_edge"]:
                    arrays[f"prop_{name}"] = engine.put_vertex_array(
                        _fit_pad(arrays[f"prop_{name}"], n, engine.n_pad))
        staged = sess._staged_funcs.get(armed_meta["func"])
        if staged is None:
            staged = sess._staged_funcs[armed_meta["func"]] = \
                sess.compiled.program.stage(armed_meta["func"], engine)
        sess._armed = ArmedRun.deserialize(staged, handle, arrays,
                                           armed_meta)
        sess._handle = sess._armed.gbox.value
        sess._props = sess._armed.device_props()
    # one triple read pins the overflow base for the restored pool
    sess._of_base = sess._sync_counters()[0]
    return sess
