"""Fused Pallas repair kernels for the ΔG hot path (DESIGN.md §3).

Two kernels replace the per-op chains in the Pallas backend:

``fused_relax_rows`` — one launch does the whole SSSP repair step that
previously took three (rowmin → hit → rowargmin):

  grid = (R // block,)
  in:  ell_src / ell_w (block, K) VMEM tiles, vals (n+1,) full residency
       with the reduction identity at slot n
  out: row_min  (R,)   min_k vals[src] + w        per row
       row_arg  (R,)   min_k {src | cand == row_min}  (deterministic)
       rows     (R,)   in-tile compacted ids of frontier rows
                       (row_min < identity), sentinel R past each
                       tile's count — the frontier is ready for a
                       scatter without re-scanning R rows
       counts   (R // block,) per-tile frontier sizes

``fused_spmv_rows`` is the same fusion for sum-combining sweeps
(PageRank): row sums + compacted materialized-row frontier.

``merge_pool_sorted`` — the ΔG sorted-merge for ``update_csr_add``:
merges the sorted diff pool (vacant rows src == n sunk to the end)
with the sorted admitted batch in ONE launch via a merge-path binary
search per output slot (the two-list diagonal split), replacing the
two ``_pair_searchsorted`` sweeps + four scatter rounds of the jnp
path.  Ties take the pool side first; since a fresh edge equal to a
materialized pool key would have been a revival, real ties only occur
between vacant/padding sentinels, whose payloads are identical — the
merged pool is bit-exact against the scatter path.

Block sizes come from a tiny autotuner keyed on (N, E_cap, K) and
cached per handle shape: the heuristic picks the largest row block
that divides the ELL row count (tile granularity vs. grid overhead),
and ``measure=True`` (benchmarks) times the candidates instead.
"""
from __future__ import annotations

import dataclasses
import functools
import threading

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.graph.csr import INT
from repro.kernels.ell import ell_capacity

ROW_TILE = 128


def _iota(length: int) -> jax.Array:
    # 1-D iota is not supported on TPU; build it 2-D and drop the axis.
    return jax.lax.broadcasted_iota(jnp.int32, (length, 1), 0).reshape(length)


# ---------------------------------------------------------------------------
# fused relax: gather → relax → frontier-flag → compact, one launch
# ---------------------------------------------------------------------------

def _fused_relax_kernel(src_ref, w_ref, vals_ref, min_ref, arg_ref,
                        rows_ref, cnt_ref, *, n, bt, R):
    s = src_ref[...]                       # (bt, K) int32
    w = w_ref[...]
    cand = vals_ref[s] + w                 # gather + relax
    rmin = jnp.min(cand, axis=1)
    min_ref[...] = rmin
    # deterministic per-row parent: smallest src achieving the row min
    arg_ref[...] = jnp.min(jnp.where(cand == rmin[:, None], s, n), axis=1)
    # frontier flag: the row improved on the identity at sentinel slot n
    hit = rmin < vals_ref[n]
    # in-tile compaction: frontier row ids packed to the tile's prefix
    rowid = pl.program_id(0) * bt + _iota(bt)
    pos = jnp.cumsum(hit.astype(jnp.int32)) - 1
    rows_ref[...] = jnp.full((bt,), R, jnp.int32).at[
        jnp.where(hit, pos, bt)].set(rowid, mode="drop")
    cnt_ref[0] = jnp.sum(hit.astype(jnp.int32))


def _fused_spmv_kernel(src_ref, r2d_ref, vals_ref, sum_ref,
                       rows_ref, cnt_ref, *, n, bt, R):
    s = src_ref[...]
    sum_ref[...] = jnp.sum(vals_ref[s], axis=1)
    hit = r2d_ref[...] < n                 # materialized row for some vertex
    rowid = pl.program_id(0) * bt + _iota(bt)
    pos = jnp.cumsum(hit.astype(jnp.int32)) - 1
    rows_ref[...] = jnp.full((bt,), R, jnp.int32).at[
        jnp.where(hit, pos, bt)].set(rowid, mode="drop")
    cnt_ref[0] = jnp.sum(hit.astype(jnp.int32))


def _fused_specs(R, K, n1, bt):
    row_spec = pl.BlockSpec((bt, K), lambda i: (i, 0))
    col_spec = pl.BlockSpec((bt,), lambda i: (i,))
    vec_spec = pl.BlockSpec((n1,), lambda i: (0,))
    cnt_spec = pl.BlockSpec((1,), lambda i: (i,))
    return row_spec, col_spec, vec_spec, cnt_spec


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fused_relax_rows(ell_src, ell_w, vals, *, block=ROW_TILE, interpret=True):
    """(row_min, row_arg, compacted frontier rows, per-tile counts)."""
    R, K = ell_src.shape
    bt = block if R % block == 0 else ROW_TILE
    n = vals.shape[0] - 1
    row_spec, col_spec, vec_spec, cnt_spec = _fused_specs(
        R, K, vals.shape[0], bt)
    return pl.pallas_call(
        functools.partial(_fused_relax_kernel, n=n, bt=bt, R=R),
        grid=(R // bt,),
        in_specs=[row_spec, row_spec, vec_spec],
        out_specs=[col_spec, col_spec, col_spec, cnt_spec],
        out_shape=[jax.ShapeDtypeStruct((R,), vals.dtype),
                   jax.ShapeDtypeStruct((R,), ell_src.dtype),
                   jax.ShapeDtypeStruct((R,), jnp.int32),
                   jax.ShapeDtypeStruct((R // bt,), jnp.int32)],
        interpret=interpret,
    )(ell_src, ell_w, vals)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fused_spmv_rows(ell_src, row2dst, vals, *, block=ROW_TILE,
                    interpret=True):
    """(row_sum, compacted materialized rows, per-tile counts)."""
    R, K = ell_src.shape
    bt = block if R % block == 0 else ROW_TILE
    n = vals.shape[0] - 1
    row_spec, col_spec, vec_spec, cnt_spec = _fused_specs(
        R, K, vals.shape[0], bt)
    return pl.pallas_call(
        functools.partial(_fused_spmv_kernel, n=n, bt=bt, R=R),
        grid=(R // bt,),
        in_specs=[row_spec, col_spec, vec_spec],
        out_specs=[col_spec, col_spec, cnt_spec],
        out_shape=[jax.ShapeDtypeStruct((R,), vals.dtype),
                   jax.ShapeDtypeStruct((R,), jnp.int32),
                   jax.ShapeDtypeStruct((R // bt,), jnp.int32)],
        interpret=interpret,
    )(ell_src, row2dst, vals)


# ---------------------------------------------------------------------------
# ΔG sorted-merge: diff pool + admitted batch, one merge-path launch
# ---------------------------------------------------------------------------

def _merge_iters(length: int) -> int:
    it = 1
    while (1 << it) < length + 1:
        it += 1
    return it + 1


def _merge_kernel(ps_ref, pd_ref, pw_ref, pa_ref,
                  fs_ref, fd_ref, fw_ref, fa_ref,
                  os_ref, od_ref, ow_ref, oa_ref,
                  *, n, D, B, bt, iters):
    j = pl.program_id(0) * bt + _iota(bt)
    # merge-path diagonal split: a = #fresh rows among the first j merged.
    # Invariant P(a) = fresh[a] < pool[j-1-a] (strict: pool wins ties) is
    # monotone in a; binary-search the first a where it fails.
    lo = jnp.maximum(j - D, 0)
    hi = jnp.minimum(j, B)

    def body(_, carry):
        lo, hi = carry
        active = lo < hi
        mid = (lo + hi) // 2
        ms = fs_ref[jnp.clip(mid, 0, B - 1)]
        md = fd_ref[jnp.clip(mid, 0, B - 1)]
        pi = jnp.clip(j - 1 - mid, 0, D - 1)
        qs = ps_ref[pi]
        qd = pd_ref[pi]
        less = (ms < qs) | ((ms == qs) & (md < qd))
        lo = jnp.where(active & less, mid + 1, lo)
        hi = jnp.where(active & ~less, mid, hi)
        return lo, hi

    a, _ = jax.lax.fori_loop(0, iters, body, (lo, hi))
    ip = j - a
    a_ok = a < B
    p_ok = ip < D
    a_safe = jnp.clip(a, 0, B - 1)
    p_safe = jnp.clip(ip, 0, D - 1)
    fs = fs_ref[a_safe]
    fd = fd_ref[a_safe]
    qs = ps_ref[p_safe]
    qd = pd_ref[p_safe]
    fresh_less = (fs < qs) | ((fs == qs) & (fd < qd))
    take_f = a_ok & (~p_ok | fresh_less)
    os_ref[...] = jnp.where(take_f, fs, jnp.where(p_ok, qs, n))
    od_ref[...] = jnp.where(take_f, fd, jnp.where(p_ok, qd, 0))
    ow_ref[...] = jnp.where(take_f, fw_ref[a_safe],
                            jnp.where(p_ok, pw_ref[p_safe], 0))
    oa_ref[...] = jnp.where(take_f, fa_ref[a_safe],
                            jnp.where(p_ok, pa_ref[p_safe], 0))


@functools.partial(jax.jit, static_argnames=("n", "block", "interpret"))
def merge_pool_sorted(d_src, d_dst, d_w, d_alive, f_src, f_dst, f_w,
                      f_alive, *, n, block=ROW_TILE, interpret=True):
    """Merge the sorted diff pool with the sorted admitted batch.

    Both lists are sorted by (src, dst) with sentinel rows (src == n,
    dst == 0, w == 0, dead) at the end; returns the merged pool arrays
    (d_src, d_dst, d_w, d_alive) with the same (D,) shape.
    """
    D = int(d_src.shape[0])
    B = int(f_src.shape[0])
    bt = min(block, ROW_TILE) if D < block else block
    Dp = -(-D // bt) * bt
    iters = _merge_iters(B)
    pa = d_alive.astype(INT)
    fa = f_alive.astype(INT)
    full = lambda m: pl.BlockSpec((m,), lambda i: (0,))
    out_spec = pl.BlockSpec((bt,), lambda i: (i,))
    o_src, o_dst, o_w, o_al = pl.pallas_call(
        functools.partial(_merge_kernel, n=n, D=D, B=B, bt=bt, iters=iters),
        grid=(Dp // bt,),
        in_specs=[full(D)] * 4 + [full(B)] * 4,
        out_specs=[out_spec] * 4,
        out_shape=[jax.ShapeDtypeStruct((Dp,), INT)] * 4,
        interpret=interpret,
    )(d_src, d_dst, d_w, pa, f_src, f_dst, f_w, fa)
    return (o_src[:D], o_dst[:D], o_w[:D], o_al[:D].astype(jnp.bool_))


# ---------------------------------------------------------------------------
# autotuner: block sizes keyed on (N, E_cap, K), cached per handle shape
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RepairConfig:
    row_block: int      # fused relax/spmv row tile (divides R)
    merge_block: int    # merge-path output tile


_TUNE_CACHE: dict = {}
# Pool workers race bind-time tuning for one shape; under measure=True a
# duplicate tuning is not just wasted compiles but a nondeterministic
# winner (timing noise picks the config).  The lock makes the first
# tuner authoritative.
_TUNE_LOCK = threading.Lock()
_ROW_CANDIDATES = (512, 256, 128)
_MERGE_CANDIDATES = (256, 128)


def clear_tune_cache() -> None:
    with _TUNE_LOCK:
        _TUNE_CACHE.clear()


def repair_config(n: int, e_cap: int, k: int, *, measure: bool = False,
                  interpret: bool = True) -> RepairConfig:
    """Block config for a handle shape; one tuning per (N, E_cap, K)."""
    key = (int(n), int(e_cap), int(k))
    with _TUNE_LOCK:
        cfg = _TUNE_CACHE.get(key)
        if cfg is None:
            cfg = (_measure_config(*key, interpret=interpret) if measure
                   else _heuristic_config(*key))
            _TUNE_CACHE[key] = cfg
    return cfg


def _heuristic_config(n: int, e_cap: int, k: int) -> RepairConfig:
    R = ell_capacity(n, e_cap, k)
    # largest candidate that divides R and leaves ≥ 2 grid steps (so the
    # pipeline has something to overlap); ROW_TILE always divides R.
    row = ROW_TILE
    for cand in _ROW_CANDIDATES:
        if R % cand == 0 and R // cand >= 2:
            row = cand
            break
    merge = _MERGE_CANDIDATES[0] if e_cap >= 4096 else _MERGE_CANDIDATES[-1]
    return RepairConfig(row_block=row, merge_block=merge)


def _measure_config(n: int, e_cap: int, k: int, *,
                    interpret: bool) -> RepairConfig:
    """Time the candidates on synthetic data of the keyed shape."""
    import numpy as np
    import timeit
    rng = np.random.default_rng(0)
    R = ell_capacity(n, e_cap, k)
    src = jnp.asarray(rng.integers(0, n + 1, (R, k)).astype(np.int32))
    w = jnp.asarray(rng.integers(1, 50, (R, k)).astype(np.int32))
    vals = jnp.asarray(
        np.concatenate([rng.integers(0, 1000, n), [2 ** 30]]).astype(np.int32))

    def time_row(bt):
        run = lambda: jax.block_until_ready(fused_relax_rows(
            src, w, vals, block=bt, interpret=interpret))
        run()                                        # compile
        return min(timeit.repeat(run, number=1, repeat=3))

    rows = [bt for bt in _ROW_CANDIDATES if R % bt == 0 and R // bt >= 1] \
        or [ROW_TILE]
    best_row = min(rows, key=time_row)

    D = max(e_cap // 4, 16)
    B = 64
    ds = jnp.asarray(np.full(D, n, np.int32))
    dz = jnp.zeros((D,), INT)
    da = jnp.zeros((D,), jnp.bool_)
    fs = jnp.asarray(np.sort(rng.integers(0, n, B)).astype(np.int32))
    fz = jnp.zeros((B,), INT)
    fa = jnp.ones((B,), jnp.bool_)

    def time_merge(bt):
        run = lambda: jax.block_until_ready(merge_pool_sorted(
            ds, dz, dz, da, fs, fz, fz, fa, n=n, block=bt,
            interpret=interpret))
        run()
        return min(timeit.repeat(run, number=1, repeat=3))

    best_merge = min(_MERGE_CANDIDATES, key=time_merge)
    return RepairConfig(row_block=best_row, merge_block=best_merge)
