"""Pallas TPU flash-attention forward (the LM stack's hot kernel).

Canonical TPU tiling: grid = (B·H, S_q/BQ, S_k/BK), online-softmax
accumulation in VMEM scratch across the KV grid axis (innermost), output
written on the last KV step.  BQ/BK default to 128/512 — q tile rows sit
on the MXU's 128 sublanes; dh (128/256) fills lanes.

The jnp-chunked attention in ``repro.models.layers.flash_attention`` is
the oracle-equivalent schedule the models use on non-TPU backends; this
kernel is the TPU lowering, validated against ``ref.flash_ref`` in
interpret mode (tests/test_kernels.py sweeps shapes & dtypes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                  causal: bool, bq: int, bk: int, nk: int, scale: float,
                  softcap):
    j = pl.program_id(2)
    i = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0] * scale                       # (BQ, dh)
    k = k_ref[0]                               # (BK, dh)
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=F32)     # (BQ, BK)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    if causal:
        qi = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kj = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(qi >= kj, s, NEG)
    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * alpha + jnp.sum(p, axis=1)
    acc_sc[...] = acc_sc[...] * alpha[:, None] + \
        jnp.dot(p.astype(v.dtype), v, preferred_element_type=F32)
    m_sc[...] = m_new

    @pl.when(j == nk - 1)
    def _done():
        denom = jnp.maximum(l_sc[...], 1e-20)[:, None]
        o_ref[0] = (acc_sc[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "softcap", "interpret"))
def flash_attention(q, k, v, *, causal=True, bq=128, bk=512, softcap=None,
                    interpret=True):
    """q,k,v: (BH, S, dh) with kv heads already repeated; returns (BH,S,dh).

    Causal masking is block-exact (whole future blocks still execute but
    are fully masked; the skip-block optimization is recorded as a perf
    lever in EXPERIMENTS.md §Perf).
    """
    BH, S, dh = q.shape
    bq = min(bq, S)
    bk = min(bk, S)
    nq, nk = S // bq, S // bk
    grid = (BH, nq, nk)
    kern = functools.partial(
        _flash_kernel, causal=causal, bq=bq, bk=bk, nk=nk,
        scale=dh ** -0.5, softcap=softcap)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), F32),
            pltpu.VMEM((bq,), F32),
            pltpu.VMEM((bq, dh), F32),
        ],
        interpret=interpret,
    )(q, k, v)
