"""ELL packing: the TPU-native layout for the paper's edge-relaxation loops.

The paper's CUDA backend launches one thread per vertex/edge over CSR,
relying on scatter atomics.  TPUs want dense tiles, so we repack the
(possibly diff-CSR-fragmented) alive edge set into a *row-split ELLPACK*:

  * edges are grouped by destination vertex;
  * each destination's in-edges are split into segments of K slots
    ("row splitting" bounds the work per row for skewed degrees);
  * segment r holds slot arrays ``ell_src[r, :K]`` / ``ell_w[r, :K]``
    with a sentinel src == n for empty slots, and ``row2dst[r]`` maps
    the segment back to its vertex (sentinel n for unused rows).

Row count is statically bounded: every vertex needs at most
ceil(deg/K) ≤ deg/K + 1 segments, so R_cap = n + ceil(E_cap/K),
rounded up to the kernel row-tile.  The pack itself is jit-compatible
(one sort + scatters), and is rebuilt once per update batch — the
fixed-point sweeps reuse it, which is exactly where the kernel wins.

Sentinel trick: property vectors handed to the kernels are padded to
n+1 with the reduction identity at slot n, so empty ELL slots gather
the identity and need no masking inside the kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.graph.csr import INT
from repro.graph.diffcsr import DynGraph, update_lanes


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Ell:
    ell_src: jax.Array    # (R, K) int32, sentinel n
    ell_w: jax.Array      # (R, K) int32
    row2dst: jax.Array    # (R,) int32, sentinel n
    # (E+D,) flat slot index (< R*K) of every materialized edge lane,
    # sentinel R*K for unmaterialized diff rows — lets revive/tombstone
    # batches patch the pack in place instead of rebuilding it.
    lane2slot: jax.Array
    n: int = dataclasses.field(metadata=dict(static=True))

    @property
    def R(self) -> int:
        return int(self.ell_src.shape[0])

    @property
    def K(self) -> int:
        return int(self.ell_src.shape[1])


_ELL_FIELDS = tuple(f.name for f in dataclasses.fields(Ell)
                    if f.name != "n")


def ell_state(e: Ell) -> dict:
    """An Ell pack's array leaves as a flat dict (for engine pack_state:
    the pack is checkpointed raw so restore keeps the exact slot layout,
    and with it the float summation order, of the saved run)."""
    return {f: getattr(e, f) for f in _ELL_FIELDS}


def ell_from_state(tree: dict, n: int) -> Ell:
    return Ell(**{f: jnp.asarray(tree[f]) for f in _ELL_FIELDS}, n=n)


def ell_capacity(n: int, e_cap: int, k: int, row_tile: int = 128) -> int:
    r = n + -(-e_cap // k)
    return -(-r // row_tile) * row_tile


def _materialized(g: DynGraph) -> jax.Array:
    """Lanes that own a pack slot: every main lane (dead ones keep their
    slot so a later revival can patch in place) + occupied diff rows."""
    return jnp.concatenate(
        [jnp.ones((g.main_capacity,), jnp.bool_), g.d_src < g.n])


def pack_ell(g: DynGraph, k: int = 8, row_tile: int = 128) -> Ell:
    """Repack the edge set (main + diff regions) into row-split ELL
    grouped by DESTINATION (pull layout — the SpMV/relax kernels).

    Every materialized lane gets a slot, dead lanes holding the sentinel
    src == n: revive/tombstone batches then patch the pack in place via
    ``lane2slot`` (patch_ell_*); only structural diff-pool appends —
    which shift diff lane positions — force a rebuild."""
    esrc, edst, ew, ealive = g.edge_arrays()
    return _pack(g.n, esrc, edst, ew, _materialized(g), ealive, k, row_tile)


def pack_push_ell(g: DynGraph, k: int = 8, row_tile: int = 128) -> Ell:
    """Row-split ELL grouped by SOURCE (push layout).

    Used by the work-efficient frontier sweeps: active vertices map to
    their out-edge rows, so one iteration touches O(|frontier|·k) slots
    instead of all E lanes.  Field names keep the Ell convention with
    roles swapped: ``row2dst`` holds the row's SOURCE vertex and
    ``ell_src`` holds the edge DESTINATIONS.
    """
    esrc, edst, ew, ealive = g.edge_arrays()
    return _pack(g.n, edst, esrc, ew, _materialized(g), ealive, k, row_tile)


# Python-side trace telemetry: bumped once per TRACE of the pack (not
# per execution), so tests can pin that cached update paths stop
# re-tracing the repack branch (PR 5 debt #2).
TRACE_COUNTS = {"pack": 0}


def _pack(n, eother, egroup, ew, emat, ealive, k, row_tile) -> Ell:
    """Group materialized lanes by ``egroup``; slots hold ``eother``
    endpoints for alive lanes and the sentinel n for tombstoned ones."""
    TRACE_COUNTS["pack"] += 1
    E = egroup.shape[0]
    R = ell_capacity(n, E, k, row_tile)

    # Sort materialized lanes by the grouping endpoint; unmaterialized
    # lanes sink to a sentinel group.
    sdst = jnp.where(emat, egroup, n)
    order = jnp.argsort(sdst, stable=True)
    sdst = sdst[order]
    salive = ealive[order]
    ssrc = jnp.where(salive, eother[order], n)
    sw = jnp.where(salive, ew[order], 0)
    # Rank within the destination group.
    start = jnp.searchsorted(sdst, sdst, side="left")
    rank = jnp.arange(E, dtype=INT) - start.astype(INT)
    # Row base per vertex: exclusive cumsum of ceil(indeg/K).
    indeg = jax.ops.segment_sum(jnp.ones((E,), INT), sdst, num_segments=n + 1)
    segs = -(-indeg // k)
    base = jnp.concatenate([jnp.zeros((1,), INT),
                            jnp.cumsum(segs[:n], dtype=INT)])
    row = base[jnp.minimum(sdst, n)] + rank // k
    col = rank % k
    valid = sdst < n
    flat = jnp.where(valid, row * k + col, R * k)

    ell_src = jnp.full((R * k,), n, INT).at[flat].set(ssrc, mode="drop")
    ell_w = jnp.zeros((R * k,), INT).at[flat].set(sw, mode="drop")
    row2dst = jnp.full((R,), n, INT).at[jnp.where(valid, row, R)].set(
        jnp.minimum(sdst, n), mode="drop")
    lane2slot = jnp.full((E,), R * k, INT).at[order].set(flat)
    return Ell(ell_src=ell_src.reshape(R, k), ell_w=ell_w.reshape(R, k),
               row2dst=row2dst, lane2slot=lane2slot, n=n)


# ---------------------------------------------------------------------------
# In-place maintenance: revive / tombstone without repacking (DESIGN.md §3)
# ---------------------------------------------------------------------------

def _lane_slots(ell: Ell, lane: jax.Array, active: jax.Array) -> jax.Array:
    cap = ell.lane2slot.shape[0]
    slot = ell.lane2slot[jnp.clip(lane, 0, max(cap - 1, 0))]
    return jnp.where(active & (lane < cap), slot, ell.R * ell.K)


def patch_ell_tombstone(ell: Ell, lane: jax.Array,
                        mask: jax.Array) -> Ell:
    """Clear the slots of tombstoned edge lanes (set sentinel src = n);
    the slot stays reserved for a later revival."""
    slot = _lane_slots(ell, lane, mask)
    src = ell.ell_src.reshape(-1).at[slot].set(ell.n, mode="drop")
    return dataclasses.replace(ell, ell_src=src.reshape(ell.R, ell.K))


def patch_ell_revive(ell: Ell, lane: jax.Array, value: jax.Array,
                     w: jax.Array, mask: jax.Array) -> Ell:
    """Re-arm the slots of revived lanes with their non-grouping endpoint
    (source for the pull layout, destination for push) and weight."""
    slot = _lane_slots(ell, lane, mask)
    src = ell.ell_src.reshape(-1).at[slot].set(value, mode="drop")
    ww = ell.ell_w.reshape(-1).at[slot].set(w, mode="drop")
    return dataclasses.replace(ell, ell_src=src.reshape(ell.R, ell.K),
                               ell_w=ww.reshape(ell.R, ell.K))


def ell_apply_del(ell: Ell, g_prev: DynGraph, src, dst, mask) -> Ell:
    """A deletion batch against the pack: tombstones only flip slots in
    place, so no repack is ever needed."""
    lane, active = update_lanes(g_prev, src, dst, mask)
    return patch_ell_tombstone(ell, lane, active)


# Stable jitted revive branch: eager ``lax.cond`` re-traces both
# branches per call, but tracing a jitted callable only binds its cached
# jaxpr — the heavy bodies compile once per shape (PR 5 debt #2).
_patch_ell_revive = jax.jit(patch_ell_revive)


def ell_apply_add(ell: Ell, g_prev: DynGraph, g_new: DynGraph,
                  src, dst, w, mask, slot_value, repack) -> Ell:
    """An addition batch against the pack.  Revivals resolve against the
    PRE-update graph: lane positions only move when fresh edges were
    appended to the diff pool, and then ``repack`` rebuilds the pack —
    a traced lax.cond, so the whole path runs inside the fused scan.
    ``slot_value`` is the non-grouping endpoint stored in the slots
    (source for the pull layout, destination for push).  ``repack``
    must be a STABLE jitted callable (one per engine, not a per-call
    lambda): eager cond tracing then hits jit's jaxpr cache instead of
    re-tracing the whole pack every batch."""
    lane, active = update_lanes(g_prev, src, dst, mask)
    structural = jnp.any(g_new.d_offsets != g_prev.d_offsets)
    return jax.lax.cond(
        structural,
        lambda _: repack(g_new),
        lambda _: _patch_ell_revive(ell, lane, slot_value, w, active),
        operand=None)
