"""ELL packing: the TPU-native layout for the paper's edge-relaxation loops.

The paper's CUDA backend launches one thread per vertex/edge over CSR,
relying on scatter atomics.  TPUs want dense tiles, so we repack the
(possibly diff-CSR-fragmented) alive edge set into a *row-split ELLPACK*:

  * edges are grouped by destination vertex;
  * each destination's in-edges are split into segments of K slots
    ("row splitting" bounds the work per row for skewed degrees);
  * segment r holds slot arrays ``ell_src[r, :K]`` / ``ell_w[r, :K]``
    with a sentinel src == n for empty slots, and ``row2dst[r]`` maps
    the segment back to its vertex (sentinel n for unused rows).

Row count is statically bounded: every vertex needs at most
ceil(deg/K) ≤ deg/K + 1 segments, so R_cap = n + ceil(E_cap/K),
rounded up to the kernel row-tile.  The pack itself is jit-compatible
(one sort + scatters), and is rebuilt once per update batch — the
fixed-point sweeps reuse it, which is exactly where the kernel wins.

Sentinel trick: property vectors handed to the kernels are padded to
n+1 with the reduction identity at slot n, so empty ELL slots gather
the identity and need no masking inside the kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.graph.csr import INT
from repro.graph.diffcsr import DynGraph


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Ell:
    ell_src: jax.Array    # (R, K) int32, sentinel n
    ell_w: jax.Array      # (R, K) int32
    row2dst: jax.Array    # (R,) int32, sentinel n
    n: int = dataclasses.field(metadata=dict(static=True))

    @property
    def R(self) -> int:
        return int(self.ell_src.shape[0])

    @property
    def K(self) -> int:
        return int(self.ell_src.shape[1])


def ell_capacity(n: int, e_cap: int, k: int, row_tile: int = 128) -> int:
    r = n + -(-e_cap // k)
    return -(-r // row_tile) * row_tile


def pack_ell(g: DynGraph, k: int = 8, row_tile: int = 128) -> Ell:
    """Repack the alive edge set (main + diff regions) into row-split ELL
    grouped by DESTINATION (pull layout — the SpMV/relax kernels)."""
    esrc, edst, ew, ealive = g.edge_arrays()
    return _pack(g.n, esrc, edst, ew, ealive, k, row_tile)


def pack_push_ell(g: DynGraph, k: int = 8, row_tile: int = 128) -> Ell:
    """Row-split ELL grouped by SOURCE (push layout).

    Used by the work-efficient frontier sweeps: active vertices map to
    their out-edge rows, so one iteration touches O(|frontier|·k) slots
    instead of all E lanes.  Field names keep the Ell convention with
    roles swapped: ``row2dst`` holds the row's SOURCE vertex and
    ``ell_src`` holds the edge DESTINATIONS.
    """
    esrc, edst, ew, ealive = g.edge_arrays()
    return _pack(g.n, edst, esrc, ew, ealive, k, row_tile)


def _pack(n, eother, egroup, ew, ealive, k, row_tile) -> Ell:
    """Group edges by ``egroup``; slots hold ``eother`` endpoints."""
    E = egroup.shape[0]
    R = ell_capacity(n, E, k, row_tile)

    # Sort alive edges by the grouping endpoint; dead edges sink to a
    # sentinel group.
    sdst = jnp.where(ealive, egroup, n)
    order = jnp.argsort(sdst, stable=True)
    sdst = sdst[order]
    ssrc = eother[order]
    sw = ew[order]
    # Rank within the destination group.
    start = jnp.searchsorted(sdst, sdst, side="left")
    rank = jnp.arange(E, dtype=INT) - start.astype(INT)
    # Row base per vertex: exclusive cumsum of ceil(indeg/K).
    indeg = jax.ops.segment_sum(jnp.ones((E,), INT), sdst, num_segments=n + 1)
    segs = -(-indeg // k)
    base = jnp.concatenate([jnp.zeros((1,), INT),
                            jnp.cumsum(segs[:n], dtype=INT)])
    row = base[jnp.minimum(sdst, n)] + rank // k
    col = rank % k
    valid = sdst < n
    flat = jnp.where(valid, row * k + col, R * k)

    ell_src = jnp.full((R * k,), n, INT).at[flat].set(ssrc, mode="drop")
    ell_w = jnp.zeros((R * k,), INT).at[flat].set(sw, mode="drop")
    row2dst = jnp.full((R,), n, INT).at[jnp.where(valid, row, R)].set(
        jnp.minimum(sdst, n), mode="drop")
    return Ell(ell_src=ell_src.reshape(R, k), ell_w=ell_w.reshape(R, k),
               row2dst=row2dst, n=n)
