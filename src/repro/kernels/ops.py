"""jit'd wrappers turning the ELL kernels into vertex-level graph ops."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.graph.csr import INF_W, INT
from repro.kernels.ell import Ell
from repro.kernels import csr_relax as K


def _combine_rows(row_vals, row2dst, n, kind, identity):
    seg = {"min": jax.ops.segment_min, "sum": jax.ops.segment_sum,
           "max": jax.ops.segment_max}[kind]
    dense = seg(row_vals, row2dst, num_segments=n + 1)
    return dense[:n]


def vertex_min_plus(ell: Ell, vals_n1: jax.Array, *, interpret=True):
    """out[v] = min over in-edges (u,v) of vals[u] + w(u,v); INF if none."""
    rows = K.relax_rowmin(ell.ell_src, ell.ell_w, vals_n1,
                          interpret=interpret)
    return _combine_rows(rows, ell.row2dst, ell.n, "min",
                         jnp.asarray(INF_W, vals_n1.dtype))


def vertex_spmv(ell: Ell, vals_n1: jax.Array, *, interpret=True):
    """out[v] = sum over in-edges (u,v) of vals[u]  (PageRank pull)."""
    rows = K.spmv_rowsum(ell.ell_src, vals_n1, interpret=interpret)
    return _combine_rows(rows, ell.row2dst, ell.n, "sum",
                         jnp.zeros((), vals_n1.dtype))


def vertex_argmin_src(ell: Ell, vals_n1: jax.Array, vertex_min: jax.Array,
                      *, interpret=True):
    """Smallest source achieving vertex_min[v] (deterministic parent)."""
    n = ell.n
    tgt_full = jnp.concatenate([vertex_min,
                                jnp.full((1,), INF_W, vertex_min.dtype)])
    row_tgt = tgt_full[jnp.minimum(ell.row2dst, n)]
    rows = K.relax_rowargmin(ell.ell_src, ell.ell_w, vals_n1, row_tgt,
                             n=n, interpret=interpret)
    return _combine_rows(rows, ell.row2dst, ell.n, "min",
                         jnp.asarray(n, INT))
