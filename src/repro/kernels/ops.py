"""jit'd wrappers turning the ELL kernels into vertex-level graph ops."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.graph.csr import INF_W, INT
from repro.kernels.ell import Ell
from repro.kernels import csr_relax as K
from repro.kernels import pallas_repair as FK


def _combine_rows(row_vals, row2dst, n, kind, identity):
    seg = {"min": jax.ops.segment_min, "sum": jax.ops.segment_sum,
           "max": jax.ops.segment_max}[kind]
    dense = seg(row_vals, row2dst, num_segments=n + 1)
    return dense[:n]


def vertex_min_plus(ell: Ell, vals_n1: jax.Array, *, interpret=True):
    """out[v] = min over in-edges (u,v) of vals[u] + w(u,v); INF if none."""
    rows = K.relax_rowmin(ell.ell_src, ell.ell_w, vals_n1,
                          interpret=interpret)
    return _combine_rows(rows, ell.row2dst, ell.n, "min",
                         jnp.asarray(INF_W, vals_n1.dtype))


def vertex_spmv(ell: Ell, vals_n1: jax.Array, *, interpret=True):
    """out[v] = sum over in-edges (u,v) of vals[u]  (PageRank pull)."""
    rows = K.spmv_rowsum(ell.ell_src, vals_n1, interpret=interpret)
    return _combine_rows(rows, ell.row2dst, ell.n, "sum",
                         jnp.zeros((), vals_n1.dtype))


def vertex_argmin_src(ell: Ell, vals_n1: jax.Array, vertex_min: jax.Array,
                      *, interpret=True):
    """Smallest source achieving vertex_min[v] (deterministic parent)."""
    n = ell.n
    tgt_full = jnp.concatenate([vertex_min,
                                jnp.full((1,), INF_W, vertex_min.dtype)])
    row_tgt = tgt_full[jnp.minimum(ell.row2dst, n)]
    rows = K.relax_rowargmin(ell.ell_src, ell.ell_w, vals_n1, row_tgt,
                             n=n, interpret=interpret)
    return _combine_rows(rows, ell.row2dst, ell.n, "min",
                         jnp.asarray(n, INT))


# ---------------------------------------------------------------------------
# fused repair path (kernels/pallas_repair.py): one launch per sweep
# ---------------------------------------------------------------------------

def _frontier_hit(ell: Ell, front_rows: jax.Array) -> jax.Array:
    """Scatter the in-kernel compacted frontier rows to a vertex mask —
    O(frontier) writes instead of a dense segment reduction over R."""
    n = ell.n
    safe = jnp.minimum(front_rows, ell.R - 1)
    dsts = jnp.where(front_rows < ell.R,
                     jnp.minimum(ell.row2dst[safe], n), n)
    return jnp.zeros((n + 1,), jnp.bool_).at[dsts].set(
        True, mode="drop")[:n]


def vertex_relax_fused(ell: Ell, vals_n1: jax.Array, *, block=None,
                       interpret=True):
    """(vertex_min, parent, hit) from ONE fused relax launch.

    Bit-exact against the chained vertex_min_plus → hit →
    vertex_argmin_src composition: the vertex min is the min of row
    mins, the lexicographic argmin decomposes row-wise (rows not
    achieving the vertex min contribute the sentinel n), and a vertex
    is hit iff one of its rows improved on the identity."""
    n = ell.n
    row_min, row_arg, front_rows, _ = FK.fused_relax_rows(
        ell.ell_src, ell.ell_w, vals_n1,
        block=block or FK.ROW_TILE, interpret=interpret)
    seg = jnp.minimum(ell.row2dst, n)
    vmin = jax.ops.segment_min(row_min, seg, num_segments=n + 1)[:n]
    tgt_full = jnp.concatenate([vmin,
                                jnp.full((1,), INF_W, vmin.dtype)])
    contrib = jnp.where(row_min == tgt_full[seg], row_arg,
                        jnp.asarray(n, row_arg.dtype))
    parent = jax.ops.segment_min(contrib, seg, num_segments=n + 1)[:n]
    return vmin, parent, _frontier_hit(ell, front_rows)


def vertex_spmv_fused(ell: Ell, vals_n1: jax.Array, *, block=None,
                      interpret=True):
    """(vertex_sum, hit) from one fused SpMV launch; hit marks vertices
    owning a materialized ELL row (the chained path's segment_max)."""
    n = ell.n
    row_sum, front_rows, _ = FK.fused_spmv_rows(
        ell.ell_src, ell.row2dst, vals_n1,
        block=block or FK.ROW_TILE, interpret=interpret)
    seg = jnp.minimum(ell.row2dst, n)
    vsum = jax.ops.segment_sum(row_sum, seg, num_segments=n + 1)[:n]
    return vsum, _frontier_hit(ell, front_rows)
