"""Pure-jnp oracles for every kernel in this package."""
from __future__ import annotations

import jax

import jax.numpy as jnp


def relax_rowmin_ref(ell_src, ell_w, vals):
    return jnp.min(vals[ell_src] + ell_w, axis=1)


def spmv_rowsum_ref(ell_src, vals):
    return jnp.sum(vals[ell_src], axis=1)


def relax_rowargmin_ref(ell_src, ell_w, vals, row_targets, *, n):
    cand = vals[ell_src] + ell_w
    achieved = cand == row_targets[:, None]
    return jnp.min(jnp.where(achieved, ell_src, n), axis=1)


def flash_ref(q, k, v, *, causal=True, softcap=None):
    """O(S²) oracle for the flash kernel. q,k,v: (BH, S, dh)."""
    BH, S, dh = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q * dh ** -0.5, k).astype(jnp.float32)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v).astype(q.dtype)
