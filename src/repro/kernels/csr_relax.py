"""Pallas TPU kernels for the paper's hot loop: edge relaxation over ELL.

Three kernels, all the same shape discipline:

  grid = (R // ROW_TILE,)
  in:  ell_src (ROW_TILE, K) VMEM tile          — gathered indices
       ell_w   (ROW_TILE, K) VMEM tile          — edge weights
       vals    (n+1,)        full VMEM residency — property vector,
                                                   identity at slot n
  out: (ROW_TILE,) per-row combined value

``relax_rowmin``   : out[r] = min_k  vals[src[r,k]] + w[r,k]   (SSSP)
``spmv_rowsum``    : out[r] = sum_k  vals[src[r,k]]            (PageRank)
``relax_rowargmin``: out[r] = min_k  {src | vals[src]+w == target[row2dst]}
                     (deterministic parent selection for SSSP)

The MXU plays no role here (no contractions); these are VPU kernels whose
win is VMEM residency of the property vector across the whole row tile —
the TPU reinterpretation of the paper's "CUDA kernel with per-edge
threads + atomics".  Cross-row combination back to vertices is a cheap
segment reduction outside the kernel (rows ≪ edges after packing).

Hardware alignment: ROW_TILE=128 rows (lane width), K defaults to 8 so a
tile is 128×8 int32 = 4 KiB per operand; the vals vector is the dominant
VMEM tenant (n+1 ints), sized by the caller to fit (≤ ~2M vertices).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.graph.csr import INF_W, INT

ROW_TILE = 128


def _rowmin_kernel(src_ref, w_ref, vals_ref, out_ref):
    s = src_ref[...]                      # (T, K) int32
    w = w_ref[...]
    gathered = vals_ref[s]                # gather from VMEM-resident vector
    cand = gathered + w
    out_ref[...] = jnp.min(cand, axis=1)


def _rowsum_kernel(src_ref, vals_ref, out_ref):
    s = src_ref[...]
    out_ref[...] = jnp.sum(vals_ref[s], axis=1)


def _rowargmin_kernel(src_ref, w_ref, vals_ref, tgt_ref, out_ref, *, n):
    s = src_ref[...]
    w = w_ref[...]
    cand = vals_ref[s] + w
    achieved = cand == tgt_ref[...][:, None]
    out_ref[...] = jnp.min(jnp.where(achieved, s, n), axis=1)


def _grid_specs(R, K, n1, extra_rows=0):
    row_spec = pl.BlockSpec((ROW_TILE, K), lambda i: (i, 0))
    vec_spec = pl.BlockSpec((n1,), lambda i: (0,))
    out_spec = pl.BlockSpec((ROW_TILE,), lambda i: (i,))
    return row_spec, vec_spec, out_spec


@functools.partial(jax.jit, static_argnames=("interpret",))
def relax_rowmin(ell_src, ell_w, vals, *, interpret=True):
    """vals: (n+1,) int32 with identity INF_W at slot n."""
    R, K = ell_src.shape
    row_spec, vec_spec, out_spec = _grid_specs(R, K, vals.shape[0])
    return pl.pallas_call(
        _rowmin_kernel,
        grid=(R // ROW_TILE,),
        in_specs=[row_spec, row_spec, vec_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((R,), vals.dtype),
        interpret=interpret,
    )(ell_src, ell_w, vals)


@functools.partial(jax.jit, static_argnames=("interpret",))
def spmv_rowsum(ell_src, vals, *, interpret=True):
    """vals: (n+1,) float32 with 0.0 at slot n."""
    R, K = ell_src.shape
    row_spec, vec_spec, out_spec = _grid_specs(R, K, vals.shape[0])
    return pl.pallas_call(
        _rowsum_kernel,
        grid=(R // ROW_TILE,),
        in_specs=[row_spec, vec_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((R,), vals.dtype),
        interpret=interpret,
    )(ell_src, vals)


@functools.partial(jax.jit, static_argnames=("n", "interpret"))
def relax_rowargmin(ell_src, ell_w, vals, row_targets, *, n, interpret=True):
    """row_targets: (R,) the already-combined per-row target value."""
    R, K = ell_src.shape
    row_spec, vec_spec, out_spec = _grid_specs(R, K, vals.shape[0])
    return pl.pallas_call(
        functools.partial(_rowargmin_kernel, n=n),
        grid=(R // ROW_TILE,),
        in_specs=[row_spec, row_spec, vec_spec, out_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((R,), ell_src.dtype),
        interpret=interpret,
    )(ell_src, ell_w, vals, row_targets)
