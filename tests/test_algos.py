"""Algorithms vs from-scratch oracles, on the jnp engine (paper's OpenMP
analogue).  Dynamic results must equal the static oracle on the
post-update graph — the paper's correctness criterion."""
import numpy as np
import jax.numpy as jnp
import pytest

from conftest import random_digraph, random_symgraph, sym_stream
from repro.graph import random_updates
from repro.core.engine import JnpEngine
from repro.algos import sssp, pagerank, triangles, oracles


@pytest.fixture(scope="module")
def setup():
    n, csr, edges, w = random_digraph()
    eng = JnpEngine()
    g = eng.prepare(csr, diff_capacity=64)
    return n, csr, edges, w, eng, g


def test_static_sssp(setup):
    n, csr, edges, w, eng, g = setup
    props = sssp.static_sssp(eng, g, source=0)
    ref = oracles.sssp_oracle(n, edges, w, 0)
    got = np.minimum(np.asarray(props["dist"]).astype(np.int64), oracles.INF)
    assert np.array_equal(got, ref)
    # parent pointers form valid shortest paths
    par = np.asarray(props["parent"])
    for v in range(n):
        if got[v] < oracles.INF and v != 0:
            p = par[v]
            assert p >= 0 and got[p] < got[v]


@pytest.mark.parametrize("percent,batch", [(10, 8), (30, 16)])
def test_dynamic_sssp(setup, percent, batch):
    n, csr, edges, w, eng, g = setup
    ups = random_updates(csr, percent=percent, seed=7)
    _, props = sssp.dyn_sssp(eng, g, 0, ups, batch_size=batch)
    e2, w2 = oracles.edges_after_updates(n, edges, w, ups.adds, ups.dels)
    ref = oracles.sssp_oracle(n, e2, w2, 0)
    got = np.minimum(np.asarray(props["dist"]).astype(np.int64), oracles.INF)
    assert np.array_equal(got, ref)


def test_static_pr(setup):
    n, csr, edges, w, eng, g = setup
    props = pagerank.static_pr(eng, g)
    ref = oracles.pagerank_oracle(n, edges)
    np.testing.assert_allclose(np.asarray(props["pr"]), ref,
                               rtol=2e-3, atol=1e-5)


def test_dynamic_pr(setup):
    n, csr, edges, w, eng, g = setup
    ups = random_updates(csr, percent=20, seed=9)
    _, props = pagerank.dyn_pr(eng, g, ups, batch_size=8)
    e2, _ = oracles.edges_after_updates(n, edges, w, ups.adds, ups.dels)
    ref = oracles.pagerank_oracle(n, e2)
    np.testing.assert_allclose(np.asarray(props["pr"]), ref,
                               rtol=5e-2, atol=1e-4)


def test_static_tc():
    n, csr, edges = random_symgraph()
    eng = JnpEngine()
    g = eng.prepare(csr, diff_capacity=128)
    c = triangles.static_tc(eng, g)
    assert int(c) == oracles.tc_oracle(n, edges)


def test_dynamic_tc():
    n, csr, edges = random_symgraph()
    eng = JnpEngine()
    g = eng.prepare(csr, diff_capacity=256)
    ups = sym_stream(csr, percent=20, seed=5)
    _, c = triangles.dyn_tc(eng, g, ups, batch_size=16)
    e2, _ = oracles.edges_after_updates(
        n, edges, np.ones(len(edges), np.int32), ups.adds, ups.dels)
    assert int(c) == oracles.tc_oracle(n, e2)


def test_propagate_flags():
    # chain 0->1->2, isolated 3
    from repro.graph import build_csr
    csr = build_csr(4, np.array([(0, 1), (1, 2)]))
    eng = JnpEngine()
    g = eng.prepare(csr, diff_capacity=2)
    props = {"flag": jnp.array([True, False, False, False])}
    props = eng.propagate_flags(g, props, "flag")
    assert np.asarray(props["flag"]).tolist() == [True, True, True, False]
