"""Regression tests pinning the four PR 5 stream-executor perf debts
(failing before the fix, passing after):

  #1 ``_stream_cache`` leaked one compiled executable per capacity step
     because keys ignored the handle's static shapes — ``grow`` now
     evicts the stale entries;
  #2 ``ell_apply_add`` re-traced the repack branch on every eager call
     (fresh ``repack`` lambda per call) — engines now pass a stable
     jitted repack, pinned via the pack trace counter;
  #3 ``_run_stream_fused`` re-stacked the segment on every
     grow-and-replay retry — stacked once per segment window now;
  #4 baseline ``Engine.run_stream`` synced the pool counters twice per
     batch — one (overflow, used, dead) read per batch now.

Plus #5 (PR 7): session ``apply`` read the (overflow, used, dead)
triple twice per attempt (a pre-read to establish the baseline and a
post-read to detect overflow) — ``_retry_on_overflow`` now reads it
ONCE post-attempt against the running ``_of_base``.

Plus #6 (PR 10): ``DistEngine.pack_state``'s ``_gather_edges`` pulled
the stacked edge lanes to host one device_get per array (and per shard
before that) — the harvest is now ONE fused ``_host_fetch`` of the
whole lane pytree per save.
"""
import dataclasses

import numpy as np
import pytest

from repro.graph import build_csr
from repro.graph.updates import UpdateStream, random_updates
from repro.core.engine import Engine, JnpEngine
from repro.core.pallas_engine import PallasEngine
from repro.core.frontier_engine import FrontierEngine
from repro.kernels import ell as ell_mod
from repro.algos import sssp


def _graph(n=48, deg=4, seed=7, max_w=30):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(n * deg, 2))
    e = e[e[:, 0] != e[:, 1]]
    w = rng.integers(1, max_w, size=len(e)).astype(np.int32)
    return build_csr(n, e, w)


# ---------------------------------------------------------------------------
# #1: grow() evicts the stale-capacity stream executables
# ---------------------------------------------------------------------------

def test_stream_cache_evicted_on_grow():
    csr = _graph()
    ups = random_updates(csr, percent=40, seed=3)
    eng = JnpEngine()
    g = eng.prepare(csr, diff_capacity=4)          # guaranteed overflow
    g2, _ = sssp.dyn_sssp_stream(eng, g, 0, ups, batch_size=4,
                                 segment_size=3)
    assert eng.handle_graph(g2).diff_capacity > 4  # at least one grow
    final = eng._handle_shape_key(g2)
    assert eng._stream_cache, "fused path should have cached a runner"
    stale = [k for k in eng._stream_cache if final not in k]
    assert not stale, f"stale-capacity executables leaked: {stale}"


def test_stream_cache_keys_carry_shapes_and_batch_size():
    csr = _graph(seed=11)
    ups = random_updates(csr, percent=10, seed=5)
    eng = JnpEngine()
    g = eng.prepare(csr, diff_capacity=64)         # no overflow
    sssp.dyn_sssp_stream(eng, g, 0, ups, batch_size=8, segment_size=2)
    key = eng._handle_shape_key(g)
    assert all(key in k and 8 in k for k in eng._stream_cache)


# ---------------------------------------------------------------------------
# #2: structural adds stop re-tracing the repack once warm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine_cls", [PallasEngine, FrontierEngine],
                         ids=["pallas", "frontier"])
def test_repack_traces_once_across_eager_adds(engine_cls):
    csr = _graph(n=32, seed=17)
    eng = engine_cls()
    h = eng.prepare(csr, diff_capacity=32)
    fresh = [(1, 30), (2, 29), (3, 28), (4, 27)]

    def add(h, u, v):
        b = UpdateStream(adds=np.array([[u, v, 5]], np.int32),
                         dels=np.zeros((0, 2), np.int32)).batch(0, 4)
        return eng.update_add(h, b)

    h = add(h, *fresh[0])                          # warm the caches
    before = ell_mod.TRACE_COUNTS["pack"]
    for u, v in fresh[1:]:                         # same shapes, fresh edges
        h = add(h, u, v)
    traced = ell_mod.TRACE_COUNTS["pack"] - before
    assert traced == 0, (
        f"repack branch re-traced {traced}x on cached-shape eager adds")


# ---------------------------------------------------------------------------
# #3: one stacked() per segment window, replays included
# ---------------------------------------------------------------------------

class _CountingStream(UpdateStream):
    calls = {"stacked": 0}

    def stacked(self, *a, **kw):
        _CountingStream.calls["stacked"] += 1
        return super().stacked(*a, **kw)


def test_segment_stacked_once_across_overflow_replays():
    csr = _graph()
    ups = random_updates(csr, percent=40, seed=3)
    stream = _CountingStream(adds=ups.adds, dels=ups.dels)
    eng = JnpEngine()
    g = eng.prepare(csr, diff_capacity=4)          # guaranteed overflow
    _CountingStream.calls["stacked"] = 0
    batch_size, seg = 4, 3
    g2, _ = sssp.dyn_sssp_stream(eng, g, 0, stream, batch_size=batch_size,
                                 segment_size=seg)
    assert eng.handle_graph(g2).diff_capacity > 4  # replays happened
    nb = stream.num_batches(batch_size)
    windows = -(-nb // seg)
    assert _CountingStream.calls["stacked"] == windows, (
        f"expected one stacked() per segment window ({windows}), got "
        f"{_CountingStream.calls['stacked']} — replays must reuse the stack")


# ---------------------------------------------------------------------------
# #4: one counter sync per baseline batch
# ---------------------------------------------------------------------------

class _SyncCountingJnp(JnpEngine):
    def __init__(self):
        super().__init__()
        self.counter_syncs = 0

    def handle_counters(self, handle):
        self.counter_syncs += 1
        return super().handle_counters(handle)


def test_baseline_run_stream_syncs_counters_once_per_batch():
    csr = _graph(seed=13)
    ups = random_updates(csr, percent=20, seed=5)
    eng = _SyncCountingJnp()
    g = eng.prepare(csr, diff_capacity=64)         # ample: no replays
    props0 = sssp.static_sssp(eng, g, 0)
    eng.counter_syncs = 0
    Engine.run_stream(eng, g, ups, 4, sssp.stream_step, props0)
    nb = ups.num_batches(4)
    assert eng.counter_syncs == 1 + nb, (
        f"baseline dispatch synced {eng.counter_syncs}x for {nb} batches; "
        f"want 1 initial + 1 per batch")


# ---------------------------------------------------------------------------
# #6: one host sync per dist pack_state, diff pool included
# ---------------------------------------------------------------------------

def test_dist_pack_state_one_host_sync(monkeypatch):
    from repro.core import dist as dist_mod
    from repro.core.engine import JnpEngine, state_to_csr

    csr = _graph(seed=23)
    eng = dist_mod.DistEngine()
    g = eng.prepare(csr, diff_capacity=64)
    ups = random_updates(csr, percent=30, seed=4)
    b = ups.batch(0, max(ups.num_adds, ups.num_dels, 1))
    g = eng.update_del(g, b)
    g = eng.update_add(g, b)                  # populated diff pool

    calls = {"n": 0}
    real = dist_mod._host_fetch

    def counting(tree):
        calls["n"] += 1
        return real(tree)

    monkeypatch.setattr(dist_mod, "_host_fetch", counting)
    tree, meta = eng.pack_state(g)
    assert calls["n"] == 1, (
        f"pack_state cost {calls['n']} host syncs; the whole edge "
        f"harvest must be one fused transfer")

    # the fused harvest is a pure layout change: the packed edge set
    # must equal the jnp engine's canonical view of the same state
    jeng = JnpEngine()
    jg = jeng.prepare(csr, diff_capacity=64)
    jg = jeng.update_del(jg, b)
    jg = jeng.update_add(jg, b)
    jtree, jmeta = jeng.pack_state(jg)
    ref, _ = state_to_csr(jtree, jmeta)
    packed = np.stack([np.asarray(tree["src"]), np.asarray(tree["dst"]),
                       np.asarray(tree["w"])], 1)
    want = np.stack([np.asarray(ref.src), np.asarray(ref.dst),
                     np.asarray(ref.w)], 1)
    order = lambda e: e[np.lexsort((e[:, 2], e[:, 1], e[:, 0]))]
    np.testing.assert_array_equal(order(packed), order(want))


# ---------------------------------------------------------------------------
# #5: one counter sync per session apply (armed and structural)
# ---------------------------------------------------------------------------

def test_session_apply_syncs_counters_once_per_apply():
    import repro.api as api
    from repro.dsl_programs import path as program_path

    csr = _graph(seed=19)
    ups = random_updates(csr, percent=15, seed=9)
    batches = list(ups.batches(4))

    # armed DSL applies: the ΔG hot path of a long-lived session
    eng = _SyncCountingJnp()
    sess = api.Session(api.compile(program_path("sssp")), eng, csr,
                       capacity=64)                # ample: no replays
    sess.run("DynSSSP", batchSize=4, src=0)
    eng.counter_syncs = 0
    for b in batches:
        sess.apply(b)
    assert eng.counter_syncs == len(batches), (
        f"armed apply synced {eng.counter_syncs}x for {len(batches)} "
        f"batches; want exactly one post-attempt read per apply")

    # structural applies go through the same _retry_on_overflow
    eng2 = _SyncCountingJnp()
    gsess = api.GraphSession(eng2, csr, capacity=64)
    gsess.apply(batches[0])                        # prepares lazily
    eng2.counter_syncs = 0
    for b in batches[1:]:
        gsess.apply(b)
    assert eng2.counter_syncs == len(batches) - 1, (
        f"structural apply synced {eng2.counter_syncs}x for "
        f"{len(batches) - 1} batches; want one per apply")
