"""Distributed-runtime substrate: checkpoint atomicity + resume equality,
data-pipeline determinism, elastic restart, sharding-policy guards."""
import json
import pathlib
import types

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.data.pipeline import DataConfig, SyntheticSource
from repro.configs.archs import REGISTRY
from repro.models.sharding import Policy


def test_ckpt_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": (jnp.ones(4), {"c": jnp.zeros((), jnp.int32)})}
    ckpt.save(tmp_path, 3, tree, extra={"step": 4})
    assert ckpt.latest_step(tmp_path) == 3
    got, extra = ckpt.restore(tmp_path, 3, tree)
    assert extra == {"step": 4}
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_ckpt_uncommitted_ignored(tmp_path):
    tree = {"a": jnp.ones(3)}
    ckpt.save(tmp_path, 1, tree)
    # simulate a crash mid-save at step 2: tmp dir without COMMITTED
    broken = tmp_path / "step_00000002"
    broken.mkdir()
    (broken / "manifest.json").write_text("{}")
    assert ckpt.latest_step(tmp_path) == 1


def test_ckpt_gc(tmp_path):
    tree = {"a": jnp.ones(2)}
    for s in range(5):
        ckpt.save(tmp_path, s, tree, keep=2)
    steps = sorted(d.name for d in tmp_path.glob("step_*"))
    assert len(steps) == 2 and steps[-1] == "step_00000004"


def test_pipeline_determinism_and_sharding():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8, seed=1)
    s1 = SyntheticSource(cfg)
    s2 = SyntheticSource(cfg)
    b1, b2 = s1.batch(5), s2.batch(5)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(s1.batch(6)["tokens"], b1["tokens"])
    # host-sharded batches partition the global batch disjointly
    h0 = SyntheticSource(DataConfig(vocab=100, seq_len=16, global_batch=8,
                                    seed=1, n_hosts=2, host_id=0)).batch(5)
    h1 = SyntheticSource(DataConfig(vocab=100, seq_len=16, global_batch=8,
                                    seed=1, n_hosts=2, host_id=1)).batch(5)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


@pytest.mark.slow
def test_train_resume_equality(tmp_path):
    """Resumed training must produce bit-identical parameters — the
    checkpoint/restart contract at cluster scale."""
    from repro.launch.train import train, parser
    args = parser().parse_args([
        "--arch", "xlstm-125m", "--reduced", "--steps", "6",
        "--batch", "4", "--seq", "32", "--f32",
        "--ckpt", str(tmp_path / "a"), "--ckpt-every", "3",
        "--log-every", "100"])
    out_full = train(args)

    args2 = parser().parse_args([
        "--arch", "xlstm-125m", "--reduced", "--steps", "6",
        "--batch", "4", "--seq", "32", "--f32",
        "--ckpt", str(tmp_path / "b"), "--ckpt-every", "3",
        "--log-every", "100"])
    train(args2)  # runs to step 6, with a ckpt at step 3
    # delete the final checkpoint, resume from step 3
    import shutil
    shutil.rmtree(tmp_path / "b" / "step_00000006")
    args3 = parser().parse_args([
        "--arch", "xlstm-125m", "--reduced", "--steps", "6",
        "--batch", "4", "--seq", "32", "--f32",
        "--ckpt", str(tmp_path / "b"), "--ckpt-every", "100",
        "--log-every", "100"])
    out_res = train(args3)
    for a, b in zip(jax.tree_util.tree_leaves(out_full["params"]),
                    jax.tree_util.tree_leaves(out_res["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_elastic_restart(tmp_path):
    from repro.launch.train import train, parser
    from repro.launch.elastic import run_elastic
    args = parser().parse_args([
        "--arch", "xlstm-125m", "--reduced", "--steps", "5",
        "--batch", "4", "--seq", "32", "--f32",
        "--ckpt", str(tmp_path), "--ckpt-every", "2",
        "--log-every", "100", "--fail-at", "3"])
    out = run_elastic(train, args)          # injected failure, then restart
    assert ckpt.latest_step(tmp_path) == 5


class _FakeMesh:
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}


def test_sharding_policy_guards():
    cfg = REGISTRY["llama4-maverick-400b-a17b"]
    pol = Policy(cfg=cfg, mesh=_FakeMesh())
    from jax.sharding import PartitionSpec as P
    # divisible: kept; non-divisible: dropped
    assert pol.guard(P("model"), (256,)) == P("model")
    assert pol.guard(P("model"), (40,)) == P(None)
    assert pol.guard(P(("pod", "data")), (64,)) == P(("pod", "data"))
    assert pol.guard(P(("pod", "data")), (33,)) == P(None)
    # expert weights: EP over model, FSDP over data
    spec = pol.param_spec("units/0/ffn/w_gate", (48, 128, 5120, 8192))
    assert spec == P(None, "model", "data", None)
    spec = pol.param_spec("units/0/mixer/wq", (48, 5120, 5120))
    assert spec == P(None, "data", "model")


def test_all_arch_param_specs_lower():
    """Every arch's full param tree gets a consistent spec tree."""
    from repro.models.model import Model
    for name, cfg in REGISTRY.items():
        m = Model(cfg=cfg, mesh=None)
        shapes = jax.eval_shape(
            lambda c=cfg: __import__("repro.models.transformer",
                                     fromlist=["x"]).init_params(
                jax.random.PRNGKey(0), c))
        pol = Policy(cfg=cfg, mesh=_FakeMesh())
        specs = pol.param_specs(shapes)
        n_leaves = len(jax.tree_util.tree_leaves(shapes))
        n_specs = len(jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(
                x, jax.sharding.PartitionSpec)))
        assert n_leaves == n_specs, name
