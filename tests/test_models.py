"""Per-arch smoke tests (reduced configs): one train step + one decode
step on CPU, asserting output shapes and finiteness (brief §(f)),
plus a prefill↔decode consistency check for the attention families."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.archs import REGISTRY, cells, SHAPES
from repro.configs.reduced import get_reduced
from repro.models.model import Model
from repro.models import transformer as T

ARCHS = list(REGISTRY)


def make_batch(cfg, B, S, key):
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    if cfg.family == "vlm":
        batch["src"] = jnp.ones((B, cfg.n_img_tokens, cfg.d_model),
                                jnp.float32)
    if cfg.family == "audio":
        batch["src"] = jnp.ones((B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_train_and_decode_smoke(arch):
    cfg = get_reduced(arch)
    m = Model(cfg=cfg, dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    B, S = 2, 32
    params = m.init(key)
    opt = m.init_opt(params)
    batch = make_batch(cfg, B, S, key)
    p2, o2, metrics = m.train_step(params, opt, jnp.zeros((), jnp.int32),
                                   batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree_util.tree_leaves(params),
                                jax.tree_util.tree_leaves(p2)))
    assert delta > 0

    cache = T.init_cache(cfg, B, S, jnp.float32)
    logits, cache2 = m.serve_step(params, cache,
                                  jnp.ones((B, 1), jnp.int32),
                                  jnp.asarray(0), src=batch.get("src"))
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["granite-20b", "gemma2-9b",
                                  "qwen1.5-32b", "starcoder2-7b"])
def test_prefill_decode_consistency(arch):
    """logits(serve_step at pos t | prefill of 0..t-1) ==
    logits(full forward)[t] — the incremental-vs-static equivalence that
    mirrors the paper's dynamic==static-recompute criterion."""
    cfg = get_reduced(arch)
    m = Model(cfg=cfg, dtype=jnp.float32)
    key = jax.random.PRNGKey(1)
    B, S = 2, 16
    params = m.init(key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)

    # full forward logits at last position
    logits_full, _ = m.prefill_step(params, {"tokens": tokens})

    # prefill S-1, then decode token S-1 at pos S-1
    logits_pre, caches = m.prefill_step(params, {"tokens": tokens[:, :-1]})
    # pad prefill cache (length S-1) up to S for the decode write
    def pad(x):
        if x.ndim == 5:   # (R,B,kv,S-1,dh)
            return jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, 1), (0, 0)))
        return x
    caches = jax.tree_util.tree_map(pad, caches)
    logits_dec, _ = m.serve_step(params, caches, tokens[:, -1:],
                                 jnp.asarray(S - 1))
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full),
                               rtol=2e-4, atol=2e-4)


def test_param_count_sane():
    """Analytic param count equals actual init count (reduced configs)."""
    for arch in ARCHS:
        cfg = get_reduced(arch)
        m = Model(cfg=cfg, dtype=jnp.float32)
        params = m.init(jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
        # analytic count excludes norm scales / small vectors: allow 5%
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.08, \
            (arch, actual, analytic)


def test_full_configs_match_assignment():
    """The registered FULL configs carry the exact assigned dimensions."""
    spec = {
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 202048),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 151936),
        "granite-20b": (52, 6144, 48, 1, 49152),
        "qwen1.5-32b": (64, 5120, 40, 40, 152064),
        "starcoder2-7b": (32, 4608, 36, 4, 49152),
        "gemma2-9b": (42, 3584, 16, 8, 256000),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 128256),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 65536),
        "xlstm-125m": (12, 768, 4, 4, 50304),
        "whisper-large-v3": (32, 1280, 20, 20, 51872),
    }
    for name, (L, D, H, KV, V) in spec.items():
        c = REGISTRY[name]
        assert c.n_layers == L and c.d_model == D and c.n_heads == H \
            and c.n_kv == KV and c.vocab == V, name
        assert len(c.pattern) * c.repeat == \
            c.n_layers * c.pattern_entries_per_layer, name


def test_cells_cover_assignment():
    cs = cells()
    # 10 archs × 4 shapes − 7 long_500k skips (full-attention archs)
    assert len(cs) == 33
    skips = [c for c in cells(include_skips=True) if len(c) == 3]
    assert len(skips) == 7
