"""Chaos-injection suite (DESIGN.md §6): every named fault seam must
either degrade to a surviving backend with state equal to the
uninterrupted run, or raise a typed error after rolling back — never
wedge, never silently corrupt.  Health counters must reflect every
event.
"""
import numpy as np
import pytest

import repro.api as api
from repro.core.engine import state_to_csr
from repro.graph import build_csr
from repro.graph.updates import UpdateStream
from repro.runtime import faults
from repro.runtime.errors import (AdmissionError, CheckpointCorrupt,
                                  DivergenceError, KernelFailure,
                                  PoolOverflowError, RuntimeFault)
from repro.runtime.failover import FailoverPolicy, backoff_delay


@pytest.fixture(autouse=True)
def _clean_seams():
    faults.reset()
    yield
    faults.reset()


def _graph(n=32):
    edges = np.array([(i, (i + 1) % n) for i in range(n)] +
                     [(0, 5), (3, 9)])
    return build_csr(n, edges)


def _stream(rows=((1, 7, 3), (2, 8, 1), (4, 11, 2), (5, 12, 1))):
    return UpdateStream(adds=np.asarray(rows, np.int64),
                        dels=np.zeros((0, 2), np.int64))


def _step(view, h, batch, carry):
    h = view.update_del(h, batch)
    h = view.update_add(h, batch)
    return h, carry


def _alive_edges(sess):
    import jax
    tree, meta = sess.engine.pack_state(sess.handle)
    tree = jax.tree_util.tree_map(np.asarray, tree)
    c, _ = state_to_csr(tree, meta)
    return sorted(zip(np.asarray(c.src).tolist(),
                      np.asarray(c.dst).tolist(),
                      np.asarray(c.w).tolist()))


# ---------------------------------------------------------------------------
# harness mechanics
# ---------------------------------------------------------------------------

def test_injector_counts_and_scopes():
    with faults.inject("counter_sync", exc=RuntimeError("boom"),
                       after=1, times=1) as inj:
        faults.fire("counter_sync")            # skipped (after=1)
        with pytest.raises(RuntimeError):
            faults.fire("counter_sync")
        faults.fire("counter_sync")            # exhausted (times=1)
        assert inj.fired == 1 and inj.seen == 3
    faults.fire("counter_sync")                # registry empty again


def test_injector_match_and_unknown_seam():
    with pytest.raises(ValueError):
        faults.inject("not_a_seam").__enter__()
    with faults.inject("kernel_launch",
                       match=lambda ctx: ctx.get("engine") == "pallas"):
        faults.fire("kernel_launch", engine="jnp")       # no match
        with pytest.raises(KernelFailure):
            faults.fire("kernel_launch", engine="pallas")


# ---------------------------------------------------------------------------
# typed errors + bounded overflow retry
# ---------------------------------------------------------------------------

def test_error_taxonomy_roots():
    for cls in (AdmissionError, PoolOverflowError, KernelFailure,
                CheckpointCorrupt, DivergenceError):
        assert issubclass(cls, RuntimeFault)
        assert issubclass(cls, RuntimeError)


def test_pool_overflow_bounded_and_rolled_back():
    """A batch that can never fit raises PoolOverflowError after the
    bounded grow budget — with the pre-batch state restored — instead of
    growing device memory forever."""
    csr = _graph()
    sess = api.bind_graph(csr, backend="jnp", capacity=4)
    sess.apply(_stream().batch(0, 4))          # prepare + one clean batch
    before = _alive_edges(sess)

    # make grow a no-op so the overflow can never be repaired
    sess._max_grow_attempts = 3
    sess.engine.grow = lambda g, factor=2.0: g
    big = UpdateStream(
        adds=np.array([(i % 30, (i * 7 + 1) % 31, 1) for i in range(64)]),
        dels=np.zeros((0, 2), np.int64))
    with pytest.raises(PoolOverflowError) as ei:
        sess.apply(big.batch(0, 64))
    err = ei.value
    assert err.attempts == 3
    assert err.batch is not None
    assert len(err.counters) == 3
    assert sess.health.overflow_retries >= 3
    assert sess.health.last_error_kind == "PoolOverflowError"
    assert _alive_edges(sess) == before, "state must roll back"


def test_checkpoint_write_seam_and_corrupt_manifest(tmp_path):
    from repro.ckpt import checkpoint as ckpt
    csr = _graph()
    sess = api.bind_graph(csr, backend="jnp")
    sess.apply(_stream().batch(0, 4))
    with faults.inject("checkpoint_write", exc=OSError("disk gone"),
                       match=lambda ctx: ctx.get("point") == "manifest"):
        with pytest.raises(OSError):
            sess.save(tmp_path)
    assert ckpt.latest_step(tmp_path) is None, \
        "crashed save must not commit"
    sess.save(tmp_path)                         # clean save commits
    step = ckpt.latest_step(tmp_path)
    assert step is not None

    # corrupt the committed manifest: restore must raise the typed error
    d = tmp_path / f"step_{step:08d}"
    (d / "manifest.json").write_text("{ not json")
    with pytest.raises(CheckpointCorrupt) as ei:
        api.restore_session(tmp_path)
    assert ei.value.step == step


# ---------------------------------------------------------------------------
# graceful degradation: every seam either fails over bit-exactly or
# raises typed after rollback
# ---------------------------------------------------------------------------

def test_kernel_launch_failover_bit_exact():
    csr, stream = _graph(), _stream()
    ref = api.bind_graph(csr, backend="jnp")
    ref.run_stream(stream, 2, _step, None)

    sess = api.bind_graph(csr, backend="pallas", failover=True)
    with faults.inject("kernel_launch", times=None,
                       match=lambda ctx: ctx.get("engine") == "pallas"):
        sess.run_stream(stream, 2, _step, None)
    h = sess.health
    assert sess.backend_name == "jnp"
    assert h.degraded and h.failovers == 2 and h.kernel_failures >= 1
    assert _alive_edges(sess) == _alive_edges(ref)


def test_segment_scan_failover_bit_exact():
    csr, stream = _graph(), _stream()
    ref = api.bind_graph(csr, backend="jnp")
    ref.run_stream(stream, 2, _step, None)

    sess = api.bind_graph(csr, backend="pallas", failover=True)
    with faults.inject("segment_scan", times=1,
                       match=lambda ctx: ctx.get("engine") == "pallas"):
        sess.run_stream(stream, 2, _step, None)
    assert sess.health.failovers >= 1
    assert _alive_edges(sess) == _alive_edges(ref)


def test_pool_merge_failover_bit_exact():
    """A fault at the pool-merge (grow) seam mid-stream degrades and the
    survivor replays — final state equal to an uninterrupted run."""
    csr = _graph()
    big = UpdateStream(
        adds=np.array([(i % 30, (i * 7 + 1) % 31, 1) for i in range(48)]),
        dels=np.zeros((0, 2), np.int64))
    ref = api.bind_graph(csr, backend="jnp", capacity=4)
    ref.run_stream(big, 8, _step, None)

    sess = api.bind_graph(csr, backend="pallas", capacity=4, failover=True)
    with faults.inject("pool_merge", times=1,
                       match=lambda ctx: ctx.get("engine") == "pallas"):
        sess.run_stream(big, 8, _step, None)
    assert sess.health.failovers >= 1
    assert _alive_edges(sess) == _alive_edges(ref)


def test_no_failover_raises_typed_with_rollback():
    """Without a failover chain the kernel fault surfaces to the caller
    — but only after the per-batch rollback ran, so the session state is
    the pre-batch graph and stays usable."""
    csr, stream = _graph(), _stream()
    sess = api.bind_graph(csr, backend="pallas")
    sess.apply(stream.batch(0, 2))
    before = _alive_edges(sess)
    with faults.inject("kernel_launch", times=None,
                       match=lambda ctx: ctx.get("engine") == "pallas"):
        with pytest.raises(KernelFailure):
            sess.apply(stream.batch(1, 2))
    assert _alive_edges(sess) == before
    sess.apply(stream.batch(1, 2))             # seam clear: still serving
    assert sess.health.last_error_kind == "KernelFailure"


def test_chain_exhausted_raises_kernel_failure():
    csr, stream = _graph(), _stream()
    sess = api.bind_graph(csr, backend="pallas", failover=True)
    sess.apply(stream.batch(0, 2))
    # jnp's update path crosses no kernel seam, so break it directly
    with faults.inject("kernel_launch", times=None), \
            faults.inject("counter_sync", times=None,
                          exc=RuntimeError("sync dead"),
                          match=lambda ctx: ctx.get("engine") == "jnp"):
        with pytest.raises(KernelFailure) as ei:
            sess.apply(stream.batch(1, 2))
    assert "failover chain" in str(ei.value)
    assert sess.health.kernel_failures >= 2


def test_bind_failover_chain_dedupes_order_preserving():
    """A chain that re-lists backends (user-supplied, or a custom chain
    that repeats the requested backend) must try each backend at most
    once, in first-seen order.  The old bind path walked duplicates:
    a failing factory was constructed once per listing, and the
    total-failure report named the same backend twice."""
    from repro.core import registry
    calls = []

    def flaky_factory(**kw):
        calls.append("flaky_dup")
        raise RuntimeError("accelerator missing")

    registry.register_engine("flaky_dup", flaky_factory, overwrite=True)
    try:
        csr = _graph()
        sess = api.bind_graph(
            csr, backend="flaky_dup",
            failover=("flaky_dup", "jnp", "flaky_dup", "jnp"))
        assert sess.backend_name == "jnp"
        assert calls == ["flaky_dup"]      # constructed exactly once
        # degradation was recorded against the deduped chain
        assert sess.health.preferred_backend == "flaky_dup"

        calls.clear()
        with pytest.raises(KernelFailure) as ei:
            api.bind_graph(csr, backend="flaky_dup",
                           failover=("flaky_dup", "flaky_dup"))
        assert calls == ["flaky_dup"]
        assert str(ei.value).count("flaky_dup") == 1   # reported once
    finally:
        registry.unregister_engine("flaky_dup")


def test_dedupe_chain_order_preserving():
    assert api._dedupe_chain(("a", "b", "a", "c", "b")) == ("a", "b", "c")
    assert api._dedupe_chain(()) == ()


def test_armed_session_failover_preserves_loop():
    """The armed DSL Batch loop must survive a mid-stream backend hop:
    the paused frame is re-staged on the survivor and the final dist is
    bit-identical to an undisturbed jnp run."""
    from repro.dsl_programs import path as program_path
    csr, stream = _graph(), _stream()
    prog = api.compile(program_path("sssp"))
    args = dict(batchSize=2, src=0)

    ref = prog.bind(csr, backend="jnp").run(
        "DynSSSP", updateBatch=stream, **args)
    ref_dist = ref.props.host("dist")

    sess = prog.bind(csr, backend="pallas", failover=True)
    sess.run("DynSSSP", **args)                 # arm
    with faults.inject("kernel_launch", times=None,
                       match=lambda ctx: ctx.get("engine") == "pallas"):
        res = sess.run_stream(stream, 2)
    assert sess.backend_name == "jnp" and sess.armed
    np.testing.assert_array_equal(res.props.host("dist"), ref_dist)


def test_reprobe_returns_to_preferred():
    """Sticky degradation re-probes: once the fault clears and the
    backoff window elapses, the session migrates back to the preferred
    backend and health records the recovery."""
    csr, stream = _graph(), _stream()
    sess = api.bind_graph(csr, backend="pallas", failover=True)
    sess._failover.probe_base_s = 0.0           # probe immediately
    with faults.inject("kernel_launch", times=None,
                       match=lambda ctx: ctx.get("engine") == "pallas"):
        sess.run_stream(stream, 2, _step, None)
    assert sess.health.degraded
    sess.apply(UpdateStream(adds=np.array([[6, 13, 1]]),
                            dels=np.zeros((0, 2), np.int64)).batch(0, 2))
    assert sess.backend_name == "pallas"
    assert not sess.health.degraded
    assert sess.health.reprobes >= 1


# ---------------------------------------------------------------------------
# divergence watchdog
# ---------------------------------------------------------------------------

def test_divergence_watchdog_fires_on_nan_props():
    import jax.numpy as jnp
    csr = _graph()
    sess = api.bind_graph(csr, backend="jnp")
    _ = sess.handle
    sess._props = {"rank": jnp.full(csr.n, jnp.nan, jnp.float32),
                   "dist": jnp.zeros(csr.n, jnp.int32)}
    with pytest.raises(DivergenceError) as ei:
        sess.check_divergence()
    assert "rank" in ei.value.props and "dist" not in ei.value.props
    assert sess.health.divergence_probes >= 1
    assert sess.health.last_error_kind == "DivergenceError"


def test_watchdog_clean_props_pass():
    import jax.numpy as jnp
    csr = _graph()
    sess = api.bind_graph(csr, backend="jnp")
    _ = sess.handle
    sess._props = {"rank": jnp.ones(csr.n, jnp.float32)}
    sess.check_divergence()                     # must not raise
    assert sess.health.divergence_probes == 1


# ---------------------------------------------------------------------------
# shared backoff policy (elastic restarts + failover re-probe)
# ---------------------------------------------------------------------------

def test_backoff_delay_shape():
    rng = __import__("random").Random(7)
    d0 = backoff_delay(0, base=0.5, cap=30.0, rng=rng)
    d4 = backoff_delay(4, base=0.5, cap=30.0, rng=rng)
    assert 0.25 <= d0 <= 0.5
    assert 4.0 <= d4 <= 8.0
    assert backoff_delay(50, base=0.5, cap=30.0, rng=rng) <= 30.0
    assert backoff_delay(3, base=0.0) == 0.0


def test_failover_policy_probe_windows():
    pol = FailoverPolicy("pallas", ("jnp",), probe_base_s=10.0)
    assert pol.candidates("pallas") == ["jnp"]
    assert pol.candidates("jnp") == []
    pol.degraded_from(now=100.0)
    assert not pol.should_probe(now=100.0 + 1.0)
    assert pol.should_probe(now=100.0 + 3600.0)
    pol.probe_failed(now=200.0)                 # window doubles: >= 10s
    assert not pol.should_probe(now=200.0 + 9.0)
    pol.recovered()
    assert not pol.should_probe(now=1e9)


def test_run_elastic_backs_off_and_restarts(monkeypatch):
    from repro.launch import elastic
    sleeps = []
    monkeypatch.setattr(elastic.time, "sleep", sleeps.append)
    calls = {"n": 0}

    def flaky(args):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "done"

    assert elastic.run_elastic(flaky, None, max_restarts=3,
                               backoff_s=0.5) == "done"
    assert len(sleeps) == 2 and all(s > 0 for s in sleeps)
    assert sleeps[1] > sleeps[0] * 0.5          # exponential-ish w/ jitter


def test_run_elastic_session_backs_off(monkeypatch):
    from repro.launch import elastic
    sleeps = []
    monkeypatch.setattr(elastic.time, "sleep", sleeps.append)
    csr = _graph()
    made = []

    def make_session(attempt):
        made.append(attempt)
        return api.bind_graph(csr, backend="jnp")

    def work(sess):
        if len(made) < 2:
            raise RuntimeError("lost host")
        sess.apply(_stream().batch(0, 4))
        return "ok"

    assert elastic.run_elastic_session(make_session, work,
                                       max_restarts=2) == "ok"
    assert made == [0, 1]
    assert len(sleeps) == 1 and sleeps[0] > 0, \
        "default backoff must be non-zero (the old 0.0 was a hot loop)"
