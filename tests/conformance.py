"""Cross-backend conformance harness — the paper's Table-level evaluation
turned into an executable, seedable test matrix.

A :class:`Scenario` fixes (graph, update stream, batch size); the
``assert_*`` runners drive a compiled ``src/repro/dsl_programs/*.sp``
program through the public API (``repro.api.compile(...).bind(...)``)
on a chosen backend name and require a four-way agreement:

    api Session output   ==  deprecated Program.run shim (bit-exact)
                         ==  repro.algos.oracles (from-scratch numpy)
                         ==  hand-staged repro.algos.{sssp,pagerank,triangles}

Scenarios deliberately cover the degenerate shapes the paper's
evaluation never exercises: the empty graph, self-loops, duplicate
edges inside one batch, deletes of absent edges, delete-then-re-add
streams (same batch and across batches), and batch sizes 1 / 8 / 64.

Every future engine or kernel PR must keep this matrix green; to add an
algorithm, compile its ``.sp`` program, add an ``assert_<algo>`` runner
against its oracle, and register scenarios below (see ROADMAP.md).
Backends are addressed by registry name ('jnp' | 'dist' | 'pallas' |
'frontier'), so a newly registered engine joins the matrix by adding
its name to the lists in test_conformance.py.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings

import numpy as np

import repro.api as api
from repro.core.registry import make_engine
from repro.graph import build_csr, random_updates
from repro.graph.updates import UpdateStream
from repro.dsl_programs import path as program_path
from repro.algos import oracles
from repro.algos import sssp as hand_sssp
from repro.algos import pagerank as hand_pr
from repro.algos import triangles as hand_tc


def program(name: str) -> api.CompiledProgram:
    """Compile one of the shipped .sp programs (cached in api.compile)."""
    return api.compile(program_path(name))


def _shim_run(name: str, func: str, backend: str, csr, args, capacity):
    """The deprecated Program.run path, for the bit-exact cross-check."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return program(name).program.run(func, make_engine(backend), csr,
                                         args=args, diff_capacity=capacity)


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    n: int
    edges: np.ndarray          # canonical (deduped, sorted) base edge set
    w: np.ndarray
    stream: UpdateStream
    batch_size: int
    src: int = 0
    diff_capacity: int = 64


def _canonical(n, edges, w=None):
    """Dedup/sort through build_csr so scenario base == engine base."""
    csr = build_csr(n, edges, w)
    e = np.stack([np.asarray(csr.src), np.asarray(csr.dst)], 1) \
        .astype(np.int64)
    return csr, e, np.asarray(csr.w)


def _digraph(n, deg, seed, max_w=50, self_loops=False):
    rng = np.random.default_rng(seed)
    edges = rng.integers(0, n, size=(n * deg, 2)).astype(np.int64)
    if not self_loops:
        edges = edges[edges[:, 0] != edges[:, 1]]
    w = rng.integers(1, max_w, size=edges.shape[0]).astype(np.int32)
    return _canonical(n, edges, w)


def _symgraph(n, m, seed):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(m, 2))
    e = e[e[:, 0] != e[:, 1]]
    e, w = oracles.symmetrize(e, np.ones(len(e), np.int32))
    return _canonical(n, e, w)


def _sym_pairs(rows):
    """[(u, v, w), ...] → adds array with both directions adjacent."""
    out = []
    for u, v, w in rows:
        out.append((u, v, w))
        out.append((v, u, w))
    return np.asarray(out, np.int32).reshape(-1, 3)


def _sym_del_pairs(rows):
    out = []
    for u, v in rows:
        out.append((u, v))
        out.append((v, u))
    return np.asarray(out, np.int32).reshape(-1, 2)


# ---------------------------------------------------------------------------
# Directed scenarios (SSSP, PageRank)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def digraph_scenario(name: str) -> Scenario:
    if name == "batch1":
        # every update is its own batch
        _, e, w = _digraph(n=20, deg=3, seed=5)
        ups = random_updates(build_csr(20, e, w), percent=12, seed=9)
        return Scenario(name, 20, e, w, ups, batch_size=1)
    if name == "batch8":
        _, e, w = _digraph(n=32, deg=4, seed=3)
        ups = random_updates(build_csr(32, e, w), percent=15, seed=2)
        return Scenario(name, 32, e, w, ups, batch_size=8)
    if name == "batch64":
        # the whole Δ lands in a single batch
        _, e, w = _digraph(n=32, deg=4, seed=7)
        ups = random_updates(build_csr(32, e, w), percent=25, seed=4)
        return Scenario(name, 32, e, w, ups, batch_size=64)
    if name == "empty":
        # no base edges at all; adds grow a graph; one del hits nothing
        n = 10
        e = np.zeros((0, 2), np.int64)
        w = np.zeros((0,), np.int32)
        adds = np.asarray([(0, 1, 3), (1, 2, 4), (2, 3, 1), (0, 3, 9),
                           (3, 4, 2), (4, 5, 7)], np.int32)
        dels = np.asarray([(5, 6)], np.int32)     # absent edge: no-op
        return Scenario(name, n, e, w, UpdateStream(adds=adds, dels=dels),
                        batch_size=4, diff_capacity=16)
    if name == "self_loops":
        _, e, w = _digraph(n=24, deg=3, seed=11, self_loops=True)
        assert (e[:, 0] == e[:, 1]).any(), "scenario needs self-loops"
        ups = random_updates(build_csr(24, e, w), percent=15, seed=6)
        return Scenario(name, 24, e, w, ups, batch_size=8)
    if name == "dup_in_batch":
        # the same add / del repeated inside one batch (same weight)
        _, e, w = _digraph(n=24, deg=3, seed=13)
        e0 = (int(e[0, 0]), int(e[0, 1]))
        f1 = _fresh_edge(24, e, seed=1)
        f2 = _fresh_edge(
            24, np.concatenate([e, np.asarray([f1], np.int64)]), seed=2)
        adds = np.asarray([f1 + (9,), f1 + (9,), f2 + (5,)], np.int32)
        dels = np.asarray([e0, e0, (int(e[3, 0]), int(e[3, 1]))], np.int32)
        return Scenario(name, 24, e, w, UpdateStream(adds=adds, dels=dels),
                        batch_size=8)
    if name == "del_then_readd":
        # e0 deleted+re-added in one batch; e1 deleted in batch 0 and
        # re-added (new weight) in batch 2 — exercises tombstone revival
        _, e, w = _digraph(n=24, deg=3, seed=17)
        e0 = (int(e[0, 0]), int(e[0, 1]))
        e1 = (int(e[5, 0]), int(e[5, 1]))
        adds = np.asarray([e0 + (4,),
                           _fresh_edge(24, e, seed=3) + (6,),
                           e1 + (2,)], np.int32)
        dels = np.asarray([e0, e1], np.int32)
        return Scenario(name, 24, e, w, UpdateStream(adds=adds, dels=dels),
                        batch_size=1)
    raise KeyError(name)


def _fresh_edge(n, edges, seed):
    existing = set(map(tuple, edges.tolist()))
    rng = np.random.default_rng(seed)
    while True:
        u, v = (int(x) for x in rng.integers(0, n, size=2))
        if u != v and (u, v) not in existing:
            return (u, v)


# ---------------------------------------------------------------------------
# Symmetric scenarios (Triangle Counting) — paired directions must share
# a batch, so batch sizes are even
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def sym_scenario(name: str) -> Scenario:
    if name == "sym_batch2":
        _, e, w = _symgraph(n=18, m=70, seed=0)
        return Scenario(name, 18, e, w, _rand_sym_stream(18, e, k=4, seed=5),
                        batch_size=2, diff_capacity=64)
    if name == "sym_batch16":
        _, e, w = _symgraph(n=24, m=110, seed=4)
        return Scenario(name, 24, e, w, _rand_sym_stream(24, e, k=8, seed=7),
                        batch_size=16, diff_capacity=128)
    if name == "sym_empty":
        # grow two triangles sharing edge (0,1) out of nothing
        n = 8
        e = np.zeros((0, 2), np.int64)
        w = np.zeros((0,), np.int32)
        adds = _sym_pairs([(0, 1, 1), (1, 2, 1), (0, 2, 1),
                           (1, 3, 1), (0, 3, 1)])
        dels = np.zeros((0, 2), np.int32)
        return Scenario(name, n, e, w, UpdateStream(adds=adds, dels=dels),
                        batch_size=4, diff_capacity=32)
    if name == "sym_del_readd":
        # delete a triangle edge (pair) in batch 0, re-add it in batch 1
        _, e, w = _symgraph(n=16, m=60, seed=9)
        u, v = int(e[0, 0]), int(e[0, 1])
        filler = _fresh_sym_pair(16, e, seed=2)
        adds = np.concatenate([_sym_pairs([filler + (1,)]),
                               _sym_pairs([(u, v, 1)])])
        dels = _sym_del_pairs([(u, v)])
        return Scenario(name, 16, e, w, UpdateStream(adds=adds, dels=dels),
                        batch_size=2, diff_capacity=64)
    raise KeyError(name)


def _rand_sym_stream(n, edges, k, seed):
    """k deleted pairs (sampled from base) + k fresh added pairs."""
    rng = np.random.default_rng(seed)
    half = edges[edges[:, 0] < edges[:, 1]]
    del_rows = half[rng.choice(len(half), size=min(k, len(half)),
                               replace=False)]
    existing = set(map(tuple, edges.tolist()))
    add_rows = []
    while len(add_rows) < k:
        u, v = (int(x) for x in rng.integers(0, n, size=2))
        if u != v and (u, v) not in existing:
            add_rows.append((u, v, 1))
            existing.add((u, v))
            existing.add((v, u))
    return UpdateStream(adds=_sym_pairs(add_rows),
                        dels=_sym_del_pairs(del_rows.tolist()))


def _fresh_sym_pair(n, edges, seed):
    existing = set(map(tuple, edges.tolist()))
    rng = np.random.default_rng(seed)
    while True:
        u, v = (int(x) for x in rng.integers(0, n, size=2))
        if u != v and (u, v) not in existing:
            return (u, v)


# ---------------------------------------------------------------------------
# Differential runners: api Session == shim == oracle == hand-staged
# ---------------------------------------------------------------------------

def assert_sssp(backend: str, sc: Scenario):
    csr = build_csr(sc.n, sc.edges, sc.w)
    args = {"updateBatch": sc.stream, "batchSize": sc.batch_size,
            "src": sc.src}
    sess = program("sssp").bind(csr, backend=backend,
                                capacity=sc.diff_capacity)
    res = sess.run("DynSSSP", **args)
    dist = res.props.host("dist")

    shim = _shim_run("sssp", "DynSSSP", backend, csr, args,
                     sc.diff_capacity)
    np.testing.assert_array_equal(
        dist, shim.props["dist"],
        err_msg=f"[{sc.name}] session DynSSSP != Program.run shim")

    e2, w2 = oracles.edges_after_updates(sc.n, sc.edges, sc.w,
                                         sc.stream.adds, sc.stream.dels)
    ref = oracles.sssp_oracle(sc.n, e2, w2, sc.src)
    got = np.minimum(dist.astype(np.int64), oracles.INF)
    np.testing.assert_array_equal(
        got, ref, err_msg=f"[{sc.name}] DSL DynSSSP != oracle")

    gsess = api.bind_graph(csr, backend=backend,
                           capacity=sc.diff_capacity)
    gsess.call(hand_sssp.dyn_sssp, sc.src, sc.stream, sc.batch_size)
    hand = np.minimum(gsess.props.host("dist").astype(np.int64),
                      oracles.INF)
    np.testing.assert_array_equal(
        hand, ref, err_msg=f"[{sc.name}] hand-staged dyn_sssp != oracle")


def assert_pagerank(backend: str, sc: Scenario, beta=1e-4, delta=0.85,
                    max_iter=100, rtol=5e-2, atol=1e-4):
    # beta is tighter than the paper's 1e-3 so per-batch convergence
    # slack (≈ beta/(1-delta) per recompute) stays well inside rtol even
    # for batchSize=1 streams.
    csr = build_csr(sc.n, sc.edges, sc.w)
    args = {"updateBatch": sc.stream, "batchSize": sc.batch_size,
            "beta": beta, "delta": delta, "maxIter": max_iter}
    sess = program("pagerank").bind(csr, backend=backend,
                                    capacity=sc.diff_capacity)
    res = sess.run("DynPR", **args)
    pr = res.props.host("pageRank")

    shim = _shim_run("pagerank", "DynPR", backend, csr, args,
                     sc.diff_capacity)
    np.testing.assert_array_equal(
        pr, shim.props["pageRank"],
        err_msg=f"[{sc.name}] session DynPR != Program.run shim")

    e2, _ = oracles.edges_after_updates(sc.n, sc.edges, sc.w,
                                        sc.stream.adds, sc.stream.dels)
    ref = oracles.pagerank_oracle(sc.n, e2, beta=beta, delta=delta,
                                  max_iter=max_iter)
    np.testing.assert_allclose(
        pr, ref, rtol=rtol, atol=atol,
        err_msg=f"[{sc.name}] DSL DynPR != oracle")

    gsess = api.bind_graph(csr, backend=backend,
                           capacity=sc.diff_capacity)
    gsess.call(hand_pr.dyn_pr, sc.stream, sc.batch_size, beta=beta,
               delta=delta, max_iter=max_iter)
    np.testing.assert_allclose(
        gsess.props.host("pr"), ref, rtol=rtol, atol=atol,
        err_msg=f"[{sc.name}] hand-staged dyn_pr != oracle")


def assert_sssp_stream(backend: str, sc: Scenario, segment_size: int = 4):
    """Streaming-executor cell: GraphSession.run_stream (the fused
    engine executor) must stay oracle-exact — same contract as the
    per-batch dispatch path."""
    csr = build_csr(sc.n, sc.edges, sc.w)
    e2, w2 = oracles.edges_after_updates(sc.n, sc.edges, sc.w,
                                         sc.stream.adds, sc.stream.dels)
    ref = oracles.sssp_oracle(sc.n, e2, w2, sc.src)
    sess = api.bind_graph(csr, backend=backend, capacity=sc.diff_capacity)
    props0 = sess.call(hand_sssp.static_sssp, sc.src)
    props = sess.run_stream(sc.stream, sc.batch_size,
                            hand_sssp.stream_step, props0,
                            segment_size=segment_size)
    got = np.minimum(sess.props.host("dist").astype(np.int64),
                     oracles.INF)
    np.testing.assert_array_equal(
        got, ref, err_msg=f"[{sc.name}] session sssp run_stream != oracle")


def assert_pagerank_stream(backend: str, sc: Scenario, beta=1e-4,
                           delta=0.85, max_iter=100, rtol=5e-2, atol=1e-4,
                           segment_size: int = 4):
    csr = build_csr(sc.n, sc.edges, sc.w)
    e2, _ = oracles.edges_after_updates(sc.n, sc.edges, sc.w,
                                        sc.stream.adds, sc.stream.dels)
    ref = oracles.pagerank_oracle(sc.n, e2, beta=beta, delta=delta,
                                  max_iter=max_iter)
    sess = api.bind_graph(csr, backend=backend, capacity=sc.diff_capacity)
    props0 = sess.call(hand_pr.static_pr, beta, delta, max_iter)
    step = hand_pr.make_stream_step(beta, delta, max_iter)
    sess.run_stream(sc.stream, sc.batch_size, step, props0,
                    segment_size=segment_size)
    np.testing.assert_allclose(
        sess.props.host("pr"), ref, rtol=rtol, atol=atol,
        err_msg=f"[{sc.name}] session pr run_stream != oracle")


def assert_tc_stream(backend: str, sc: Scenario, segment_size: int = 4):
    import jax.numpy as jnp
    csr = build_csr(sc.n, sc.edges, sc.w)
    e2, _ = oracles.edges_after_updates(sc.n, sc.edges, sc.w,
                                        sc.stream.adds, sc.stream.dels)
    ref = oracles.tc_oracle(sc.n, e2)
    sess = api.bind_graph(csr, backend=backend, capacity=sc.diff_capacity)
    count0 = jnp.asarray(sess.call(hand_tc.static_tc), jnp.int32)
    count = sess.run_stream(sc.stream, sc.batch_size,
                            hand_tc.stream_step, count0,
                            segment_size=segment_size)
    assert int(count) == ref, \
        f"[{sc.name}] session tc run_stream {int(count)} != oracle {ref}"


def assert_sssp_save_restore(backend: str, sc: Scenario, ckpt_dir,
                             restore_backend: str = None,
                             restore_opts: dict = None):
    """Durability cell: arm DynSSSP, apply the first half of the stream,
    ``save``, ``restore_session``, apply the rest — the final ``dist``
    must be bit-identical to the uninterrupted armed run, and
    oracle-exact.  ``restore_backend`` names a different backend to
    restore onto (cross-backend / elastic cells); SSSP's int-min fold is
    order-independent, so the bit-exact contract holds across backends
    and across dist re-partitioning."""
    restore_opts = restore_opts or {}
    csr = build_csr(sc.n, sc.edges, sc.w)
    batches = list(sc.stream.batches(sc.batch_size))
    k = max(1, len(batches) // 2)

    # uninterrupted reference: one armed session over every batch
    ref_sess = program("sssp").bind(csr, backend=backend,
                                    capacity=sc.diff_capacity)
    ref_sess.run("DynSSSP", batchSize=sc.batch_size, src=sc.src)
    for b in batches:
        ref_sess.apply(b)
    ref = np.asarray(ref_sess.props.host("dist"))

    # interrupted: save after k batches, drop everything, restore, finish
    sess = program("sssp").bind(csr, backend=backend,
                                capacity=sc.diff_capacity)
    sess.run("DynSSSP", batchSize=sc.batch_size, src=sc.src)
    for b in batches[:k]:
        sess.apply(b)
    sess.save(ckpt_dir)
    del sess

    res = api.restore_session(ckpt_dir, backend=restore_backend,
                              **restore_opts)
    assert res.armed, f"[{sc.name}] restore must re-arm the Batch loop"
    assert res.stream_cursor == k, \
        f"[{sc.name}] cursor {res.stream_cursor} != batches applied {k}"
    for b in batches[k:]:
        res.apply(b)
    got = np.asarray(res.props.host("dist"))
    np.testing.assert_array_equal(
        got, ref,
        err_msg=f"[{sc.name}] save/restore DynSSSP != uninterrupted "
                f"({backend} -> {restore_backend or backend})")

    e2, w2 = oracles.edges_after_updates(sc.n, sc.edges, sc.w,
                                         sc.stream.adds, sc.stream.dels)
    oracle = oracles.sssp_oracle(sc.n, e2, w2, sc.src)
    np.testing.assert_array_equal(
        np.minimum(got.astype(np.int64), oracles.INF), oracle,
        err_msg=f"[{sc.name}] save/restore DynSSSP != oracle")


def assert_pagerank_save_restore(backend: str, sc: Scenario, ckpt_dir,
                                 beta=1e-4, delta=0.85, max_iter=100):
    """Float bit-exactness cell: same-backend save/restore must resume
    PageRank *bit-identically* — raw handle leaves (diff pool layout,
    ELL pack) are restored, so float summation order is preserved.
    Same-backend only: dist re-meshes and cross-backend converts, which
    keeps values but not float bit patterns."""
    csr = build_csr(sc.n, sc.edges, sc.w)
    args = {"batchSize": sc.batch_size, "beta": beta, "delta": delta,
            "maxIter": max_iter}
    batches = list(sc.stream.batches(sc.batch_size))
    k = max(1, len(batches) // 2)

    ref_sess = program("pagerank").bind(csr, backend=backend,
                                        capacity=sc.diff_capacity)
    ref_sess.run("DynPR", **args)
    for b in batches:
        ref_sess.apply(b)
    ref = np.asarray(ref_sess.props.host("pageRank"))

    sess = program("pagerank").bind(csr, backend=backend,
                                    capacity=sc.diff_capacity)
    sess.run("DynPR", **args)
    for b in batches[:k]:
        sess.apply(b)
    sess.save(ckpt_dir)
    del sess

    res = api.restore_session(ckpt_dir)
    for b in batches[k:]:
        res.apply(b)
    np.testing.assert_array_equal(
        np.asarray(res.props.host("pageRank")), ref,
        err_msg=f"[{sc.name}] save/restore DynPR not bit-exact on "
                f"{backend}")


# ---------------------------------------------------------------------------
# Adversarial-stream runners (admission guard, DESIGN.md §6): a
# pure-poison batch PREPENDED to the scenario stream shifts every clean
# batch by exactly one, so after the guard disposes of batch 0 (clamp
# masks every lane — a no-op batch; quarantine dead-letters it) the
# applied updates are identical to the clean stream and the oracle is
# unchanged.
# ---------------------------------------------------------------------------

def poison_stream(sc: Scenario, with_weight_poison: bool = False
                  ) -> UpdateStream:
    """The scenario stream with one batch of poison rows up front:
    out-of-range and negative vertex ids (never clampable into a real
    update), plus — for the quarantine cells — one NaN-weight row with
    valid ids (only detectable on the raw host arrays;
    ``UpdateStream.batch`` would silently int-cast it)."""
    bs, n = sc.batch_size, sc.n
    rows = [(n + 1 + i, 0, 1) if i % 2 == 0 else (0, -(i + 1), 1)
            for i in range(bs)]
    pa = np.asarray(rows, np.float64).reshape(bs, 3)
    if with_weight_poison:
        pa[0] = (0, min(1, n - 1), np.nan)
    adds = np.concatenate(
        [pa, np.asarray(sc.stream.adds, np.float64).reshape(-1, 3)])
    pd = np.asarray([(n + 7, n + 8)] * bs, np.int64)
    dels = np.concatenate(
        [pd, np.asarray(sc.stream.dels, np.int64).reshape(-1, 2)])
    return UpdateStream(adds=adds, dels=dels)


def assert_sssp_poison(backend: str, sc: Scenario, policy: str):
    """DSL one-shot cell under attack: ``run`` must survive the poison
    batch per policy and end oracle-exact against the CLEAN stream."""
    csr = build_csr(sc.n, sc.edges, sc.w)
    e2, w2 = oracles.edges_after_updates(sc.n, sc.edges, sc.w,
                                         sc.stream.adds, sc.stream.dels)
    ref = oracles.sssp_oracle(sc.n, e2, w2, sc.src)
    pstream = poison_stream(sc, with_weight_poison=(policy == "quarantine"))
    sess = program("sssp").bind(csr, backend=backend,
                                capacity=sc.diff_capacity,
                                admission=policy)
    res = sess.run("DynSSSP", updateBatch=pstream,
                   batchSize=sc.batch_size, src=sc.src)
    got = np.minimum(res.props.host("dist").astype(np.int64), oracles.INF)
    np.testing.assert_array_equal(
        got, ref,
        err_msg=f"[{sc.name}/{policy}] poisoned DynSSSP != clean oracle "
                f"on {backend}")
    h = sess.health
    if policy == "quarantine":
        assert h.quarantined >= 1, "poison batch must be dead-lettered"
        assert len(sess.dead_letter) >= 1
    else:
        assert h.clamped >= 1, "poison batch must be sanitized"
    assert h.admitted >= pstream.num_batches(sc.batch_size) - 1


def assert_sssp_stream_poison(backend: str, sc: Scenario, policy: str,
                              segment_size: int = 4):
    """Fused-executor cell under attack: poison batches are spliced out
    per policy while clean contiguous ranges still run fused; final
    state oracle-exact against the clean stream."""
    csr = build_csr(sc.n, sc.edges, sc.w)
    e2, w2 = oracles.edges_after_updates(sc.n, sc.edges, sc.w,
                                         sc.stream.adds, sc.stream.dels)
    ref = oracles.sssp_oracle(sc.n, e2, w2, sc.src)
    pstream = poison_stream(sc, with_weight_poison=(policy == "quarantine"))
    sess = api.bind_graph(csr, backend=backend, capacity=sc.diff_capacity,
                          admission=policy)
    props0 = sess.call(hand_sssp.static_sssp, sc.src)
    sess.run_stream(pstream, sc.batch_size, hand_sssp.stream_step, props0,
                    segment_size=segment_size)
    got = np.minimum(sess.props.host("dist").astype(np.int64), oracles.INF)
    np.testing.assert_array_equal(
        got, ref,
        err_msg=f"[{sc.name}/{policy}] poisoned sssp run_stream != clean "
                f"oracle on {backend}")
    h = sess.health
    if policy == "quarantine":
        assert h.quarantined >= 1 and len(sess.dead_letter) >= 1
    else:
        assert h.clamped >= 1
    assert sess.stream_cursor == pstream.num_batches(sc.batch_size)


def assert_tc(backend: str, sc: Scenario):
    csr = build_csr(sc.n, sc.edges, sc.w)
    args = {"updateBatch": sc.stream, "batchSize": sc.batch_size}
    sess = program("tc").bind(csr, backend=backend,
                              capacity=sc.diff_capacity)
    res = sess.run("DynTC", **args)
    e2, _ = oracles.edges_after_updates(sc.n, sc.edges, sc.w,
                                        sc.stream.adds, sc.stream.dels)
    ref = oracles.tc_oracle(sc.n, e2)
    assert int(res.value) == ref, \
        f"[{sc.name}] DSL DynTC {int(res.value)} != oracle {ref}"

    shim = _shim_run("tc", "DynTC", backend, csr, args, sc.diff_capacity)
    assert int(shim.value) == int(res.value), \
        f"[{sc.name}] session DynTC != Program.run shim"

    gsess = api.bind_graph(csr, backend=backend, capacity=sc.diff_capacity)
    count = gsess.call(hand_tc.dyn_tc, sc.stream, sc.batch_size)
    assert int(count) == ref, \
        f"[{sc.name}] hand-staged dyn_tc {int(count)} != oracle {ref}"
