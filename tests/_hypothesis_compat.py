"""Degrade gracefully when ``hypothesis`` is not installed.

When hypothesis is available this module re-exports the real
``given`` / ``settings`` / ``strategies`` unchanged, so property tests
run at full strength (install via the ``test`` extra in pyproject.toml).

Without it, each ``@given`` test degrades to a *fixed-seed example
test*: the strategies draw one deterministic sample (seeded RNG), the
test body runs once against it, and the test is marked with the
``hypothesis_fallback`` marker so the degradation is visible in
``pytest -m hypothesis_fallback`` / CI logs instead of failing
collection outright.

Only the strategy surface the suite actually uses is implemented
(integers / sampled_from / data).  Add stand-ins here as tests grow.
"""
from __future__ import annotations

import functools
import inspect
import random

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # fixed-seed fallback
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def example(self, rng: random.Random):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def example(self, rng):
            return rng.randint(self.lo, self.hi)

    class _SampledFrom(_Strategy):
        def __init__(self, options):
            self.options = list(options)

        def example(self, rng):
            return rng.choice(self.options)

    class _DataObject:
        """Stand-in for hypothesis's interactive ``data()`` object."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.example(self._rng)

    class _Data(_Strategy):
        def example(self, rng):
            return _DataObject(rng)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def sampled_from(options):
            return _SampledFrom(options)

        @staticmethod
        def data():
            return _Data()

    st = _St()

    def given(*strategies, **kw_strategies):
        def decorate(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(0)
                drawn = [s.example(rng) for s in strategies]
                drawn_kw = {k: s.example(rng)
                            for k, s in kw_strategies.items()}
                return fn(*args, *drawn, **drawn_kw, **kwargs)

            # pytest follows __wrapped__ back to the original signature
            # and would demand fixtures for the strategy-filled params.
            del wrapper.__wrapped__
            # ...but parametrize/fixture params NOT drawn by strategies
            # must stay visible, or stacking @parametrize over @given
            # breaks ("function uses no argument 'x'"): re-expose the
            # original signature minus the strategy-filled names
            # (positional strategies fill from the right, as hypothesis
            # does).
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            keep = names[:len(names) - len(strategies)] if strategies \
                else names
            keep = [n for n in keep if n not in kw_strategies]
            wrapper.__signature__ = sig.replace(
                parameters=[sig.parameters[n] for n in keep])
            return pytest.mark.hypothesis_fallback(wrapper)

        return decorate

    def settings(*args, **kwargs):
        if args and callable(args[0]) and not kwargs:
            return args[0]

        def decorate(fn):
            return fn

        return decorate
