"""Per-kernel interpret-mode validation against pure-jnp oracles,
with shape/dtype sweeps and hypothesis randomization (brief §(c))."""
import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.graph import build_csr, from_csr
from repro.graph import diffcsr
from repro.graph.csr import INF_W, INT
from repro.kernels.ell import pack_ell, Ell
from repro.kernels import csr_relax as K
from repro.kernels import pallas_repair as FK
from repro.kernels import ref as R
from repro.kernels import ops as kops
from repro.kernels.flash_attention import flash_attention


def _random_ell(rng, n, e, k=8):
    edges = rng.integers(0, n, size=(e, 2))
    edges = edges[edges[:, 0] != edges[:, 1]]
    csr = build_csr(n, edges, rng.integers(1, 50, len(edges)).astype(np.int32))
    g = from_csr(csr, diff_capacity=4)
    return g, pack_ell(g, k=k)


@pytest.mark.parametrize("n,e,k", [(64, 256, 8), (200, 1000, 4),
                                   (300, 600, 16)])
def test_rowmin_matches_ref(n, e, k):
    rng = np.random.default_rng(n + e)
    _, ell = _random_ell(rng, n, e, k)
    vals = jnp.concatenate([
        jnp.asarray(rng.integers(0, 1000, n).astype(np.int32)),
        jnp.full((1,), INF_W, jnp.int32)])
    out = K.relax_rowmin(ell.ell_src, ell.ell_w, vals)
    ref = R.relax_rowmin_ref(ell.ell_src, ell.ell_w, vals)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("n,e,k", [(64, 256, 8), (128, 512, 8)])
def test_rowsum_matches_ref(n, e, k):
    rng = np.random.default_rng(7)
    _, ell = _random_ell(rng, n, e, k)
    vals = jnp.concatenate([
        jnp.asarray(rng.random(n).astype(np.float32)),
        jnp.zeros((1,), jnp.float32)])
    out = K.spmv_rowsum(ell.ell_src, vals)
    ref = R.spmv_rowsum_ref(ell.ell_src, vals)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


def test_argmin_matches_ref():
    rng = np.random.default_rng(13)
    _, ell = _random_ell(rng, 64, 256, 8)
    vals = jnp.concatenate([
        jnp.asarray(rng.integers(0, 1000, 64).astype(np.int32)),
        jnp.full((1,), INF_W, jnp.int32)])
    vmin = kops.vertex_min_plus(ell, vals)
    tgt_full = jnp.concatenate([vmin, jnp.full((1,), INF_W, jnp.int32)])
    row_tgt = tgt_full[jnp.minimum(ell.row2dst, 64)]
    out = K.relax_rowargmin(ell.ell_src, ell.ell_w, vals, row_tgt, n=64)
    ref = R.relax_rowargmin_ref(ell.ell_src, ell.ell_w, vals, row_tgt, n=64)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_vertex_ops_match_segment_reduction():
    """ELL path == direct segment reduction over the edge list."""
    rng = np.random.default_rng(3)
    g, ell = _random_ell(rng, 100, 700, 8)
    esrc, edst, ew, ealive = g.edge_arrays()
    vals = jnp.concatenate([
        jnp.asarray(rng.integers(0, 1000, 100).astype(np.int32)),
        jnp.full((1,), INF_W, jnp.int32)])
    got = kops.vertex_min_plus(ell, vals)
    cand = jnp.where(ealive, vals[esrc] + ew, INF_W)
    want = jax.ops.segment_min(cand, edst, num_segments=100)
    assert np.array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# fused repair kernels (kernels/pallas_repair.py) vs the chained path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,e,k,block", [(64, 256, 8, 128),
                                         (200, 1000, 4, 128),
                                         (300, 2000, 8, 256)])
def test_fused_relax_matches_chained(n, e, k, block):
    """One fused launch == rowmin → hit → rowargmin chain, bit-exact."""
    rng = np.random.default_rng(n + e)
    _, ell = _random_ell(rng, n, e, k)
    vals = jnp.concatenate([
        jnp.asarray(rng.integers(0, 1000, n).astype(np.int32)),
        jnp.full((1,), INF_W, jnp.int32)])
    vmin, parent, hit = kops.vertex_relax_fused(ell, vals, block=block)
    want_min = kops.vertex_min_plus(ell, vals)
    want_par = kops.vertex_argmin_src(ell, vals, want_min)
    assert np.array_equal(np.asarray(vmin), np.asarray(want_min))
    assert np.array_equal(np.asarray(parent), np.asarray(want_par))
    assert np.array_equal(np.asarray(hit), np.asarray(want_min) < INF_W)


def test_fused_relax_frontier_compaction_invariants():
    """The in-kernel compaction packs frontier row ids to each tile's
    prefix (padded with sentinel R), and per-tile counts match."""
    rng = np.random.default_rng(5)
    n, block = 64, 128
    _, ell = _random_ell(rng, n, 256, 8)
    vals = jnp.concatenate([
        jnp.asarray(rng.integers(0, 1000, n).astype(np.int32)),
        jnp.full((1,), INF_W, jnp.int32)])
    rmin, _, rows, cnts = FK.fused_relax_rows(ell.ell_src, ell.ell_w, vals,
                                              block=block)
    R_ = ell.R
    hit_rows = np.nonzero(np.asarray(rmin) < INF_W)[0]
    got_rows, got = np.asarray(rows), []
    for t in range(R_ // block):
        c = int(np.asarray(cnts)[t])
        seg = got_rows[t * block:(t + 1) * block]
        assert (seg[:c] < R_).all() and (seg[c:] == R_).all(), t
        got.extend(seg[:c].tolist())
    assert sorted(got) == hit_rows.tolist()


def test_fused_spmv_matches_chained():
    rng = np.random.default_rng(9)
    n = 100
    _, ell = _random_ell(rng, n, 700, 8)
    vals = jnp.concatenate([jnp.asarray(rng.random(n).astype(np.float32)),
                            jnp.zeros((1,), jnp.float32)])
    vsum, hit = kops.vertex_spmv_fused(ell, vals)
    want = kops.vertex_spmv(ell, vals)
    want_hit = jax.ops.segment_max(
        (ell.row2dst < n).astype(INT), jnp.minimum(ell.row2dst, n),
        num_segments=n + 1)[:n].astype(bool)
    assert np.array_equal(np.asarray(vsum), np.asarray(want))
    assert np.array_equal(np.asarray(hit), np.asarray(want_hit))


def _assert_graphs_equal(g1, g2):
    for f in dataclasses.fields(g1):
        a, b = getattr(g1, f.name), getattr(g2, f.name)
        if f.name == "n":
            assert a == b
        else:
            assert np.array_equal(np.asarray(a), np.asarray(b)), f.name


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_merge_kernel_matches_jnp_update(seed):
    """update_csr_add with the merge-path kernel plugged in is bit-exact
    against the scatter path — dedupe, revivals and overflow included."""
    rng = np.random.default_rng(seed)
    n = 40
    e = rng.integers(0, n, size=(120, 2))
    e = e[e[:, 0] != e[:, 1]]
    csr = build_csr(n, e, rng.integers(1, 50, len(e)).astype(np.int32))
    d = int(rng.integers(3, 24))
    g = from_csr(csr, diff_capacity=d)
    merge_impl = functools.partial(FK.merge_pool_sorted, block=128,
                                   interpret=True)
    for step in range(4):
        B = int(rng.integers(2, 12))
        qs = jnp.asarray(rng.integers(0, n, B).astype(np.int32))
        qd = jnp.asarray(rng.integers(0, n, B).astype(np.int32))
        qw = jnp.asarray(rng.integers(1, 50, B).astype(np.int32))
        mask = jnp.asarray(rng.random(B) < 0.9)
        g1 = diffcsr.update_csr_add(g, qs, qd, qw, mask)
        g2 = diffcsr.update_csr_add(g, qs, qd, qw, mask,
                                    pool_merge=merge_impl)
        _assert_graphs_equal(g1, g2)
        g = g1
        if step == 1:  # interleave tombstones so revivals get exercised
            g = diffcsr.update_csr_del(g, qs[: B // 2], qd[: B // 2])


def test_repair_config_cached_per_shape():
    FK.clear_tune_cache()
    c1 = FK.repair_config(64, 300, 8)
    assert FK.repair_config(64, 300, 8) is c1          # cache hit
    assert FK.repair_config(64, 600, 8) is not c1      # new shape, new cfg
    R_ = 128 * ((64 + -(-300 // 8) + 127) // 128)
    assert R_ % c1.row_block == 0
    FK.clear_tune_cache()
    cm = FK.repair_config(32, 64, 8, measure=True)     # timed candidates
    assert cm.row_block in (128, 256, 512)
    assert cm.merge_block in (128, 256)
    FK.clear_tune_cache()


@pytest.mark.parametrize("S,dh,causal,dtype", [
    (128, 64, True, jnp.float32),
    (256, 128, True, jnp.float32),
    (256, 128, False, jnp.float32),
    (512, 64, True, jnp.bfloat16),
    (128, 256, True, jnp.float32),
])
def test_flash_attention_sweep(S, dh, causal, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(S + dh), 3)
    q = jax.random.normal(k1, (2, S, dh), dtype)
    k = jax.random.normal(k2, (2, S, dh), dtype)
    v = jax.random.normal(k3, (2, S, dh), dtype)
    out = flash_attention(q, k, v, causal=causal, bq=128, bk=128)
    ref = R.flash_ref(q, k, v, causal=causal)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < tol, err


def test_flash_softcap():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (2, 256, 128))
    k = jax.random.normal(k2, (2, 256, 128))
    v = jax.random.normal(k3, (2, 256, 128))
    out = flash_attention(q, k, v, causal=True, bq=128, bk=128, softcap=30.0)
    ref = R.flash_ref(q, k, v, causal=True, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 50), st.integers(2, 30), st.sampled_from([4, 8, 16]))
def test_ell_pack_property(seed, n, k):
    """pack_ell places every alive edge in exactly one slot with its dst."""
    rng = np.random.default_rng(seed)
    e = max(n, 4) * 3
    g, ell = _random_ell(rng, n, e, k)
    esrc, edst, ew, ealive = (np.asarray(x) for x in g.edge_arrays())
    want = {}
    for s, d, w, a in zip(esrc, edst, ew, ealive):
        if a:
            want[(s, d)] = want.get((s, d), 0) + 1
    got = {}
    src = np.asarray(ell.ell_src)
    r2d = np.asarray(ell.row2dst)
    for r in range(src.shape[0]):
        for c in range(src.shape[1]):
            if src[r, c] < n:
                assert r2d[r] < n
                got[(src[r, c], r2d[r])] = got.get((src[r, c], r2d[r]), 0) + 1
    assert got == want
