"""Per-kernel interpret-mode validation against pure-jnp oracles,
with shape/dtype sweeps and hypothesis randomization (brief §(c))."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.graph import build_csr, from_csr
from repro.graph.csr import INF_W
from repro.kernels.ell import pack_ell, Ell
from repro.kernels import csr_relax as K
from repro.kernels import ref as R
from repro.kernels import ops as kops
from repro.kernels.flash_attention import flash_attention


def _random_ell(rng, n, e, k=8):
    edges = rng.integers(0, n, size=(e, 2))
    edges = edges[edges[:, 0] != edges[:, 1]]
    csr = build_csr(n, edges, rng.integers(1, 50, len(edges)).astype(np.int32))
    g = from_csr(csr, diff_capacity=4)
    return g, pack_ell(g, k=k)


@pytest.mark.parametrize("n,e,k", [(64, 256, 8), (200, 1000, 4),
                                   (300, 600, 16)])
def test_rowmin_matches_ref(n, e, k):
    rng = np.random.default_rng(n + e)
    _, ell = _random_ell(rng, n, e, k)
    vals = jnp.concatenate([
        jnp.asarray(rng.integers(0, 1000, n).astype(np.int32)),
        jnp.full((1,), INF_W, jnp.int32)])
    out = K.relax_rowmin(ell.ell_src, ell.ell_w, vals)
    ref = R.relax_rowmin_ref(ell.ell_src, ell.ell_w, vals)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("n,e,k", [(64, 256, 8), (128, 512, 8)])
def test_rowsum_matches_ref(n, e, k):
    rng = np.random.default_rng(7)
    _, ell = _random_ell(rng, n, e, k)
    vals = jnp.concatenate([
        jnp.asarray(rng.random(n).astype(np.float32)),
        jnp.zeros((1,), jnp.float32)])
    out = K.spmv_rowsum(ell.ell_src, vals)
    ref = R.spmv_rowsum_ref(ell.ell_src, vals)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


def test_argmin_matches_ref():
    rng = np.random.default_rng(13)
    _, ell = _random_ell(rng, 64, 256, 8)
    vals = jnp.concatenate([
        jnp.asarray(rng.integers(0, 1000, 64).astype(np.int32)),
        jnp.full((1,), INF_W, jnp.int32)])
    vmin = kops.vertex_min_plus(ell, vals)
    tgt_full = jnp.concatenate([vmin, jnp.full((1,), INF_W, jnp.int32)])
    row_tgt = tgt_full[jnp.minimum(ell.row2dst, 64)]
    out = K.relax_rowargmin(ell.ell_src, ell.ell_w, vals, row_tgt, n=64)
    ref = R.relax_rowargmin_ref(ell.ell_src, ell.ell_w, vals, row_tgt, n=64)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_vertex_ops_match_segment_reduction():
    """ELL path == direct segment reduction over the edge list."""
    rng = np.random.default_rng(3)
    g, ell = _random_ell(rng, 100, 700, 8)
    esrc, edst, ew, ealive = g.edge_arrays()
    vals = jnp.concatenate([
        jnp.asarray(rng.integers(0, 1000, 100).astype(np.int32)),
        jnp.full((1,), INF_W, jnp.int32)])
    got = kops.vertex_min_plus(ell, vals)
    cand = jnp.where(ealive, vals[esrc] + ew, INF_W)
    want = jax.ops.segment_min(cand, edst, num_segments=100)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("S,dh,causal,dtype", [
    (128, 64, True, jnp.float32),
    (256, 128, True, jnp.float32),
    (256, 128, False, jnp.float32),
    (512, 64, True, jnp.bfloat16),
    (128, 256, True, jnp.float32),
])
def test_flash_attention_sweep(S, dh, causal, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(S + dh), 3)
    q = jax.random.normal(k1, (2, S, dh), dtype)
    k = jax.random.normal(k2, (2, S, dh), dtype)
    v = jax.random.normal(k3, (2, S, dh), dtype)
    out = flash_attention(q, k, v, causal=causal, bq=128, bk=128)
    ref = R.flash_ref(q, k, v, causal=causal)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < tol, err


def test_flash_softcap():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (2, 256, 128))
    k = jax.random.normal(k2, (2, 256, 128))
    v = jax.random.normal(k3, (2, 256, 128))
    out = flash_attention(q, k, v, causal=True, bq=128, bk=128, softcap=30.0)
    ref = R.flash_ref(q, k, v, causal=True, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 50), st.integers(2, 30), st.sampled_from([4, 8, 16]))
def test_ell_pack_property(seed, n, k):
    """pack_ell places every alive edge in exactly one slot with its dst."""
    rng = np.random.default_rng(seed)
    e = max(n, 4) * 3
    g, ell = _random_ell(rng, n, e, k)
    esrc, edst, ew, ealive = (np.asarray(x) for x in g.edge_arrays())
    want = {}
    for s, d, w, a in zip(esrc, edst, ew, ealive):
        if a:
            want[(s, d)] = want.get((s, d), 0) + 1
    got = {}
    src = np.asarray(ell.ell_src)
    r2d = np.asarray(ell.row2dst)
    for r in range(src.shape[0]):
        for c in range(src.shape[1]):
            if src[r, c] < n:
                assert r2d[r] < n
                got[(src[r, c], r2d[r])] = got.get((src[r, c], r2d[r]), 0) + 1
    assert got == want
