"""§Perf tuning knobs: the optimized lowerings must be numerically
equivalent to the baselines (the whole point — same math, cheaper wires)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.reduced import get_reduced
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.model import Model
from repro.models.tuning import BASELINE, OPTIMIZED, Tuning, use_tuning


def test_moe_dispatch_equivalence():
    """'gather' dispatch == 'scatter' dispatch, bit-for-bit in f32."""
    cfg = get_reduced("qwen3-moe-235b-a22b")
    key = jax.random.PRNGKey(0)
    p = L.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    shard = lambda t, kind: t
    with use_tuning(Tuning(moe_dispatch="scatter")):
        y_scatter = L.moe(p, x, cfg, shard)
    with use_tuning(Tuning(moe_dispatch="gather")):
        y_gather = L.moe(p, x, cfg, shard)
    np.testing.assert_allclose(np.asarray(y_scatter), np.asarray(y_gather),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.slow
def test_decode_equal_under_both_cache_shardings():
    """serve_step logits identical for 'seq' and 'dh' cache sharding
    (single host device: constraints are placement-only, math must
    match exactly)."""
    cfg = get_reduced("gemma2-9b")
    key = jax.random.PRNGKey(2)
    outs = {}
    for name, tun in (("seq", BASELINE), ("dh", OPTIMIZED)):
        m = Model(cfg=cfg, dtype=jnp.float32, tuning=tun)
        params = m.init(key)
        B, S = 2, 16
        cache = T.init_cache(cfg, B, S, jnp.float32)
        tok = jnp.ones((B, 1), jnp.int32)
        logits, _ = m.serve_step(params, cache, tok, jnp.asarray(3))
        outs[name] = np.asarray(logits)
    np.testing.assert_allclose(outs["seq"], outs["dh"], rtol=1e-6,
                               atol=1e-6)


@pytest.mark.slow
def test_train_step_equal_under_dispatch():
    """One reduced MoE train step: loss equal under both dispatches."""
    cfg = get_reduced("llama4-maverick-400b-a17b")
    key = jax.random.PRNGKey(3)
    tokens = jax.random.randint(key, (2, 17), 0, cfg.vocab)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    losses = {}
    for name, tun in (("scatter", BASELINE), ("gather", OPTIMIZED)):
        m = Model(cfg=cfg, dtype=jnp.float32, tuning=tun)
        params = m.init(key)
        opt = m.init_opt(params)
        _, _, metrics = m.train_step(params, opt,
                                     jnp.zeros((), jnp.int32), batch)
        losses[name] = float(metrics["loss"])
    assert abs(losses["scatter"] - losses["gather"]) < 1e-5, losses
