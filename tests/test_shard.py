"""Sharded-DynGraph suite (``repro.shard``, DESIGN.md §5): partition
correctness, halo-plan invariants, equivalence against the jnp
reference engine, and the elastic pack/unpack roundtrip.

Host-side partition/halo properties run everywhere.  Cells that need a
real multi-device mesh skip on a single-device host and run for real in
CI's dist-smoke job (``XLA_FLAGS=--xla_force_host_platform_device_count=8``);
the slow subprocess cell at the bottom drives the full 8-shard stream +
re-mesh path regardless of the parent process's device count.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.graph import build_csr, random_updates
from repro.graph.partition import (PARTITIONERS, block_partition,
                                   degree_partition, make_partition)
from repro.graph.halo import build_plan, ghost_sets
from repro.algos import sssp

MULTIDEV = len(jax.devices()) >= 2
needs_mesh = pytest.mark.skipif(
    not MULTIDEV, reason="needs >1 XLA device (run under "
    "XLA_FLAGS=--xla_force_host_platform_device_count=N)")


def _edges(n, deg, seed, skew=False):
    rng = np.random.default_rng(seed)
    if skew:
        # zipf-ish source skew: low ids emit most of the edges
        src = (n * rng.random(n * deg) ** 3).astype(np.int64)
    else:
        src = np.repeat(np.arange(n), deg)
    dst = rng.integers(0, n, len(src))
    keep = src != dst
    return src[keep], dst[keep]


# ---------------------------------------------------------------------------
# Partition correctness (both partitioners, property-style)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", PARTITIONERS)
@pytest.mark.parametrize("n,P,seed", [(1, 1, 0), (7, 3, 1), (64, 4, 2),
                                      (64, 8, 3), (100, 7, 4), (257, 8, 5)])
def test_partition_covers_every_vertex_exactly_once(kind, n, P, seed):
    src, _ = _edges(n, 4, seed, skew=True)
    part = make_partition(kind, n, P, src)
    assert part.starts[0] == 0 and part.starts[-1] == n
    assert (np.diff(part.starts) >= 0).all()
    owners = part.assign
    assert owners.shape == (n,)
    assert ((owners >= 0) & (owners < P)).all()
    # contiguous ranges: each vertex lands in exactly the one range that
    # contains it, and the ranges tile [0, n)
    for p in range(P):
        lo, hi = part.starts[p], part.starts[p + 1]
        assert (owners[lo:hi] == p).all()
    counts = np.bincount(owners, minlength=P)
    assert counts.sum() == n


@pytest.mark.parametrize("n,P,seed", [(64, 4, 2), (200, 8, 7), (500, 5, 9)])
def test_degree_partition_balances_mass(n, P, seed):
    src, _ = _edges(n, 6, seed, skew=True)
    part = degree_partition(n, P, src)
    deg = np.bincount(src, minlength=n)
    total, dmax = int(deg.sum()), int(deg.max())
    for p in range(P):
        mass = int(deg[part.starts[p]:part.starts[p + 1]].sum())
        # each cut overshoots the ideal total/P by at most one vertex
        assert mass <= total / P + dmax, (p, mass, total / P, dmax)


def test_degree_partition_edgeless_falls_back_to_block():
    part = degree_partition(10, 4, np.zeros(0, np.int64))
    assert part.kind == "degree"
    np.testing.assert_array_equal(part.starts, block_partition(10, 4).starts)


def test_block_partition_matches_property_ownership():
    part = block_partition(100, 8)
    v = np.arange(100)
    np.testing.assert_array_equal(part.owner_of(v), v // part.block)


# ---------------------------------------------------------------------------
# Halo-plan invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", PARTITIONERS)
@pytest.mark.parametrize("n,P,seed", [(32, 2, 0), (64, 4, 1), (100, 8, 2)])
def test_ghosts_are_exactly_the_cut_edge_endpoints(kind, n, P, seed):
    src, dst = _edges(n, 4, seed, skew=True)
    part = make_partition(kind, n, P, src)
    gsets = ghost_sets(src, dst, part.owner_of(src), part.block, P)
    for p in range(P):
        mine = part.owner_of(src) == p
        ends = np.unique(np.concatenate([src[mine], dst[mine]]))
        expect = ends[(ends // part.block) != p]   # foreign endpoints only
        np.testing.assert_array_equal(gsets[p], expect)
        assert (np.diff(gsets[p]) > 0).all() if len(gsets[p]) > 1 else True


def test_ghost_hints_added_to_every_foreign_shard():
    n, P = 32, 4
    src, dst = _edges(n, 3, 5)
    part = block_partition(n, P)
    hints = np.array([0, 9, 31])
    gsets = ghost_sets(src, dst, part.owner_of(src), part.block, P,
                       hints=hints)
    for p in range(P):
        for h in hints:
            if h // part.block != p:
                assert h in gsets[p], (p, h)


@pytest.mark.parametrize("kind", PARTITIONERS)
def test_halo_plan_tables_describe_one_bijective_packet(kind):
    n, P, seed = 96, 4, 11
    src, dst = _edges(n, 5, seed, skew=True)
    part = make_partition(kind, n, P, src)
    blk = part.block
    gsets = ghost_sets(src, dst, part.owner_of(src), blk, P)
    plan = build_plan(gsets, P, blk, blk * P)
    for p in range(P):
        gh = gsets[p]
        slots = []
        for q in range(P):
            tgt = plan.recv_tgt[p, q]
            real = tgt[tgt < plan.H]
            slots.extend(real.tolist())
            # the same packet seen from the owner side: send_idx entries
            # are owner-local offsets of exactly the ghost ids p expects
            sidx = plan.send_idx[q, p][:len(real)]
            assert ((sidx >= 0) & (sidx < blk)).all()
            np.testing.assert_array_equal(sidx + q * blk, gh[real])
            # pad lanes stay pads on both sides
            assert (plan.send_idx[q, p][len(real):] == blk).all()
            assert (tgt[len(real):] == plan.H).all()
        # every real ghost slot of p is filled exactly once
        np.testing.assert_array_equal(np.sort(slots), np.arange(len(gh)))
        np.testing.assert_array_equal(plan.ghosts[p, :len(gh)], gh)
        assert (plan.ghosts[p, len(gh):] == blk * P).all()


# ---------------------------------------------------------------------------
# Engine equivalence vs the jnp reference
# ---------------------------------------------------------------------------

def _csr_stream(n=48, deg=4, seed=3, percent=40, add_frac=0.6):
    rng = np.random.default_rng(seed)
    src = np.repeat(np.arange(n), deg)
    dst = rng.integers(0, n, n * deg)
    keep = src != dst
    w = rng.integers(1, 40, keep.sum()).astype(np.int64)
    csr = build_csr(n, np.stack([src[keep], dst[keep]], 1), w)
    return csr, random_updates(csr, percent=percent, seed=seed + 1,
                               add_frac=add_frac)


def _sssp_stream(backend_engine, csr, ups, cap=16):
    g0 = backend_engine.prepare(csr, diff_capacity=cap)
    _, props = sssp.dyn_sssp_stream(backend_engine, g0, 0, ups,
                                    batch_size=8, segment_size=3)
    return np.asarray(props["dist"])


def test_single_shard_matches_jnp_bit_exact():
    from repro.core.engine import JnpEngine
    from repro.shard.engine import ShardedEngine
    csr, ups = _csr_stream()
    ref = _sssp_stream(JnpEngine(), csr, ups)
    # tiny capacity on purpose: the rollback-grow-replay path must stay
    # bit-exact through capacity growth
    got = _sssp_stream(ShardedEngine(num_shards=1), csr, ups, cap=4)
    np.testing.assert_array_equal(got, ref)


@needs_mesh
@pytest.mark.parametrize("kind", PARTITIONERS)
def test_two_shards_match_jnp_bit_exact(kind):
    from repro.core.engine import JnpEngine
    from repro.shard.engine import ShardedEngine
    # add-heavy stream so inserted endpoints fall outside the initial
    # ghost tables: the halo-miss → rollback → rebuild-with-hints →
    # replay loop must land bit-exactly on the reference
    csr, ups = _csr_stream(percent=50, add_frac=0.7)
    ref = _sssp_stream(JnpEngine(), csr, ups)
    eng = ShardedEngine(num_shards=2, partitioner=kind)
    got = _sssp_stream(eng, csr, ups)
    np.testing.assert_array_equal(got, ref)


@needs_mesh
def test_per_shard_bytes_below_replicated_footprint():
    from repro.shard.engine import ShardedEngine
    csr, _ = _csr_stream(n=256, deg=8)
    eng = ShardedEngine(num_shards=2)
    sg = eng.prepare(csr, diff_capacity=64)
    # a shard holds its rows + halo tables, not the whole edge set
    per = eng.per_shard_bytes(sg)
    whole = sum(int(np.prod(np.asarray(a).shape[1:] or (1,))) *
                np.asarray(a).dtype.itemsize
                for a in (sg.src, sg.dst, sg.w, sg.alive))
    assert per < 2 * whole  # sanity: same order as one shard's lanes
    assert eng.per_shard_bytes(sg) == per  # deterministic


# ---------------------------------------------------------------------------
# Elastic pack/unpack roundtrip
# ---------------------------------------------------------------------------

def _sorted_triples(tree):
    e = np.stack([np.asarray(tree["src"]), np.asarray(tree["dst"]),
                  np.asarray(tree["w"])], axis=1)
    return e[np.lexsort((e[:, 2], e[:, 1], e[:, 0]))]


@needs_mesh
@pytest.mark.parametrize("kind", PARTITIONERS)
def test_heavy_insertion_pack_state_roundtrips_bit_exact(kind):
    from repro.shard.engine import ShardedEngine
    csr, ups = _csr_stream(n=40, percent=60, add_frac=0.9)
    eng = ShardedEngine(num_shards=2, partitioner=kind)
    sg = eng.prepare(csr, diff_capacity=2 * ups.num_adds + 8)
    # one wide batch: eager shard_map updates re-trace per call, so the
    # heavy insertion goes in as a single del+add round
    width = max(ups.num_adds, ups.num_dels, 1)
    b = ups.batch(0, width)
    sg = eng.update_del(sg, b)
    sg = eng.update_add(sg, b)
    tree1, meta1 = eng.pack_state(sg)
    assert meta1["partitioner"] == kind
    assert meta1["kind"] == "dist"          # shard-count-independent

    # restore onto a DIFFERENT mesh width: the edge set must survive
    # re-partitioning exactly (order may differ, triples may not)
    eng2 = ShardedEngine(num_shards=1, partitioner=kind)
    sg2 = eng2.unpack_state(tree1, meta1)
    tree2, _ = eng2.pack_state(sg2)
    np.testing.assert_array_equal(_sorted_triples(tree2),
                                  _sorted_triples(tree1))

    # a second pack of untouched state is bit-identical, not just
    # set-equal: the canonical snapshot is deterministic
    tree3, _ = eng2.pack_state(sg2)
    for k in ("src", "dst", "w"):
        np.testing.assert_array_equal(np.asarray(tree2[k]),
                                      np.asarray(tree3[k]))


# ---------------------------------------------------------------------------
# Full 8-shard stream + elastic re-mesh (subprocess: needs its own
# XLA_FLAGS before jax initialises; ~5 min of shard_map compiles)
# ---------------------------------------------------------------------------

_EIGHT_SHARD_SCRIPT = textwrap.dedent("""
    import numpy as np
    import jax
    assert len(jax.devices()) == 8, jax.devices()
    import repro.api as api
    from repro.dsl_programs import path as program_path
    from repro.graph import build_csr, random_updates

    rng = np.random.default_rng(11)
    n, deg = 64, 4
    src = np.repeat(np.arange(n), deg)
    dst = rng.integers(0, n, n * deg)
    keep = src != dst
    w = rng.integers(1, 50, keep.sum()).astype(np.int64)
    csr = build_csr(n, np.stack([src[keep], dst[keep]], 1), w)
    ups = random_updates(csr, percent=30, seed=5)
    batches = list(ups.batches(8))
    k = max(1, len(batches) // 2)

    prog = api.compile(program_path("sssp"))
    ref_sess = prog.bind(csr, backend="jnp", capacity=256)
    ref_sess.run("DynSSSP", src=0, batchSize=8)
    for b in batches:
        ref_sess.apply(b)
    ref = np.asarray(ref_sess.props.host("dist"))

    sess = prog.bind(csr, backend="dist_sharded", capacity=256,
                     num_shards=8)
    sess.run("DynSSSP", src=0, batchSize=8)
    for b in batches[:k]:
        sess.apply(b)
    sess.save("/tmp/shard_ckpt")
    del sess

    res = api.restore_session("/tmp/shard_ckpt", num_shards=2)
    assert res.armed and res.stream_cursor == k
    for b in batches[k:]:
        res.apply(b)
    got = np.asarray(res.props.host("dist"))
    np.testing.assert_array_equal(got, ref)
    print("SHARD-OK")
""")


@pytest.mark.slow
def test_eight_shard_stream_and_remesh_subprocess():
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "JAX_PLATFORMS": "cpu"}
    out = subprocess.run([sys.executable, "-c", _EIGHT_SHARD_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=1500)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SHARD-OK" in out.stdout
