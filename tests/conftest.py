import sys
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np
import pytest

from repro.graph import build_csr
from repro.algos import oracles

# XLA's CPU backend segfaults mid-compile once enough jitted executables
# accumulate in one long pytest process (observed deterministically around
# the 59th fast-lane test on single-core hosts).  Every test builds its
# own engines/graphs, so dropping the global jit caches between tests is
# semantically free — do it every CLEAR_EVERY tests to bound the resident
# compiled-code footprint without paying a full recompile per test.
_CLEAR_EVERY = 24
_test_count = {"n": 0}


@pytest.fixture(autouse=True)
def _bounded_jit_cache():
    yield
    _test_count["n"] += 1
    if _test_count["n"] % _CLEAR_EVERY == 0:
        import jax
        jax.clear_caches()


def random_digraph(n=60, deg=4, seed=3, max_w=100):
    rng = np.random.default_rng(seed)
    edges = rng.integers(0, n, size=(n * deg, 2)).astype(np.int64)
    edges = edges[edges[:, 0] != edges[:, 1]]
    w = rng.integers(1, max_w, size=edges.shape[0]).astype(np.int32)
    csr = build_csr(n, edges, w)
    edges = np.stack([np.asarray(csr.src), np.asarray(csr.dst)], 1) \
        .astype(np.int64)
    return n, csr, edges, np.asarray(csr.w)


def random_symgraph(n=40, m=160, seed=0):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(m, 2))
    e = e[e[:, 0] != e[:, 1]]
    e, w = oracles.symmetrize(e, np.ones(len(e), np.int32))
    csr = build_csr(n, e)
    edges = np.stack([np.asarray(csr.src), np.asarray(csr.dst)], 1) \
        .astype(np.int64)
    return n, csr, edges


def sym_stream(csr, percent, seed):
    """Symmetric update stream with paired directions in the same batch."""
    from repro.graph import random_updates
    from repro.graph.updates import UpdateStream
    ups = random_updates(csr, percent=percent, seed=seed)
    adds, dels = ups.adds, ups.dels
    adds = np.stack([adds, adds[:, [1, 0, 2]]], axis=1).reshape(-1, 3)
    dels = np.stack([dels, dels[:, [1, 0]]], axis=1).reshape(-1, 2)
    return UpdateStream(adds=adds, dels=dels)
