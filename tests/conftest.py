import sys
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np
import pytest

from repro.graph import build_csr
from repro.algos import oracles


def random_digraph(n=60, deg=4, seed=3, max_w=100):
    rng = np.random.default_rng(seed)
    edges = rng.integers(0, n, size=(n * deg, 2)).astype(np.int64)
    edges = edges[edges[:, 0] != edges[:, 1]]
    w = rng.integers(1, max_w, size=edges.shape[0]).astype(np.int32)
    csr = build_csr(n, edges, w)
    edges = np.stack([np.asarray(csr.src), np.asarray(csr.dst)], 1) \
        .astype(np.int64)
    return n, csr, edges, np.asarray(csr.w)


def random_symgraph(n=40, m=160, seed=0):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(m, 2))
    e = e[e[:, 0] != e[:, 1]]
    e, w = oracles.symmetrize(e, np.ones(len(e), np.int32))
    csr = build_csr(n, e)
    edges = np.stack([np.asarray(csr.src), np.asarray(csr.dst)], 1) \
        .astype(np.int64)
    return n, csr, edges


def sym_stream(csr, percent, seed):
    """Symmetric update stream with paired directions in the same batch."""
    from repro.graph import random_updates
    from repro.graph.updates import UpdateStream
    ups = random_updates(csr, percent=percent, seed=seed)
    adds, dels = ups.adds, ups.dels
    adds = np.stack([adds, adds[:, [1, 0, 2]]], axis=1).reshape(-1, 3)
    dels = np.stack([dels, dels[:, [1, 0]]], axis=1).reshape(-1, 2)
    return UpdateStream(adds=adds, dels=dels)
