"""The public API surface: repro.api sessions, the engine registry, and
capacity="auto" sizing/recovery.

The contract under test (ISSUE 5 acceptance criteria):

  * ``CompiledProgram.bind(...).run(...)`` matches the deprecated
    ``Program.run`` shim bit-exactly (spot-checked here; the full
    program × backend × scenario sweep lives in test_conformance.py);
  * a session that stays bound across N update batches produces
    identical results to a one-shot run over the same N batches, while
    calling ``engine.prepare`` exactly once;
  * unknown/duplicate backend names fail loudly; ``register_engine``
    plugs a new engine in by name without touching the facade;
  * ``capacity="auto"`` sizes the diff pool from the bound stream, and
    recovers from underestimates via grow-and-replay.
"""
import numpy as np
import pytest

import repro
import repro.api as api
from repro.core import registry
from repro.core.engine import JnpEngine
from repro.dsl_programs import path as program_path
from repro.graph import build_csr
from repro.algos import oracles, sssp as hand_sssp

from conformance import digraph_scenario


def _scenario_bits(name="batch8"):
    sc = digraph_scenario(name)
    csr = build_csr(sc.n, sc.edges, sc.w)
    e2, w2 = oracles.edges_after_updates(sc.n, sc.edges, sc.w,
                                         sc.stream.adds, sc.stream.dels)
    ref = oracles.sssp_oracle(sc.n, e2, w2, sc.src)
    return sc, csr, ref


def _as_oracle(dist):
    return np.minimum(np.asarray(dist).astype(np.int64), oracles.INF)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_unknown_backend():
    with pytest.raises(registry.UnknownBackendError) as ei:
        registry.make_engine("no-such-backend")
    msg = str(ei.value)
    assert "no-such-backend" in msg and "jnp" in msg  # lists what exists

    sc, csr, _ = _scenario_bits()
    prog = api.compile(program_path("sssp"))
    with pytest.raises(registry.UnknownBackendError):
        prog.bind(csr, backend="no-such-backend")


def test_registry_duplicate_registration():
    with pytest.raises(registry.DuplicateBackendError):
        registry.register_engine("jnp", JnpEngine)
    # non-callable factories and bad names are rejected up front
    with pytest.raises(TypeError):
        registry.register_engine("bad", object())
    with pytest.raises(ValueError):
        registry.register_engine("", JnpEngine)


def test_registry_plugin_engine_binds_by_name():
    class TracedEngine(JnpEngine):
        name = "traced"

    try:
        registry.register_engine("traced", TracedEngine)
        with pytest.raises(registry.DuplicateBackendError):
            registry.register_engine("traced", TracedEngine)
        registry.register_engine("traced", TracedEngine, overwrite=True)
        assert "traced" in registry.available_backends()

        sc, csr, ref = _scenario_bits()
        sess = api.compile(program_path("sssp")).bind(
            csr, backend="traced", capacity=sc.diff_capacity)
        assert isinstance(sess.engine, TracedEngine)
        res = sess.run("DynSSSP", updateBatch=sc.stream,
                       batchSize=sc.batch_size, src=sc.src)
        np.testing.assert_array_equal(
            _as_oracle(res.props.host("dist")), ref)
    finally:
        registry.unregister_engine("traced")
    assert "traced" not in registry.available_backends()


# ---------------------------------------------------------------------------
# sessions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", [
    "jnp", "pallas", "frontier",
    pytest.param("dist", marks=pytest.mark.slow),
])
def test_session_reuse_matches_one_shot(backend):
    """N applies on one bound session == run_stream == one-shot run ==
    deprecated shim, oracle-exact, with engine.prepare called once."""
    sc, csr, ref = _scenario_bits("batch8")
    prog = api.compile(program_path("sssp"))
    args = dict(batchSize=sc.batch_size, src=sc.src)

    # one-shot session + deprecated shim (bit-exact cross-check)
    one = prog.bind(csr, backend=backend, capacity=sc.diff_capacity)
    res = one.run("DynSSSP", updateBatch=sc.stream, **args)
    with pytest.warns(DeprecationWarning):
        shim = prog.program.run(
            "DynSSSP", registry.make_engine(backend), csr,
            args={"updateBatch": sc.stream, **args},
            diff_capacity=sc.diff_capacity)
    np.testing.assert_array_equal(res.props.host("dist"),
                                  shim.props["dist"])
    np.testing.assert_array_equal(_as_oracle(res.props["dist"]), ref)

    # armed session: count prepares, apply every batch one by one
    sess = prog.bind(csr, backend=backend, capacity=sc.diff_capacity)
    prepares = []
    orig_prepare = sess.engine.prepare
    sess.engine.prepare = lambda *a, **k: (prepares.append(1),
                                           orig_prepare(*a, **k))[1]
    sess.run("DynSSSP", **args)
    assert sess.armed and sess.prepared
    for batch in sc.stream.batches(sc.batch_size):
        sess.apply(batch)
    np.testing.assert_array_equal(sess.props.host("dist"),
                                  shim.props["dist"])
    assert len(prepares) == 1, "prepare must run exactly once per session"

    # run_stream drives the same armed loop
    sess2 = prog.bind(csr, backend=backend, capacity=sc.diff_capacity)
    sess2.run("DynSSSP", **args)
    out = sess2.run_stream(sc.stream)          # batchSize from arm time
    np.testing.assert_array_equal(out.props.host("dist"),
                                  shim.props["dist"])


def test_session_props_are_device_resident():
    sc, csr, _ = _scenario_bits()
    sess = api.compile(program_path("sssp")).bind(
        csr, backend="jnp", capacity=sc.diff_capacity)
    sess.run("DynSSSP", src=sc.src, batchSize=sc.batch_size)
    import jax
    dist = sess.props["dist"]
    assert isinstance(dist, jax.Array)          # no implicit host sync
    assert dist.shape[0] == sess.engine.n_pad   # padded device layout
    host = sess.props.to_host()
    assert isinstance(host["dist"], np.ndarray)
    assert host["dist"].shape[0] == sc.n        # sliced to real vertices
    assert set(sess.props) >= {"dist", "parent", "modified"}


def test_session_value_epilogue_is_stable():
    """TC's armed session: reading .value evaluates the post-Batch
    epilogue without disturbing the live state (same answer twice)."""
    from conformance import sym_scenario
    sc = sym_scenario("sym_batch2")
    csr = build_csr(sc.n, sc.edges, sc.w)
    e2, _ = oracles.edges_after_updates(sc.n, sc.edges, sc.w,
                                        sc.stream.adds, sc.stream.dels)
    ref = oracles.tc_oracle(sc.n, e2)
    sess = api.compile(program_path("tc")).bind(
        csr, backend="jnp", capacity=sc.diff_capacity)
    sess.run("DynTC", batchSize=sc.batch_size)
    for batch in sc.stream.batches(sc.batch_size):
        sess.apply(batch)
    assert int(sess.value) == ref
    assert int(sess.value) == ref               # re-read: state untouched


def test_capacity_auto_sizes_from_stream():
    sc, csr, ref = _scenario_bits("batch8")
    sess = api.compile(program_path("sssp")).bind(csr, backend="jnp",
                                                  capacity="auto")
    assert not sess.prepared                    # lazy until first use
    res = sess.run("DynSSSP", updateBatch=sc.stream,
                   batchSize=sc.batch_size, src=sc.src)
    g = sess.handle
    assert g.diff_capacity >= 2 * sc.stream.num_adds
    np.testing.assert_array_equal(_as_oracle(res.props["dist"]), ref)


def test_capacity_auto_floors_at_default():
    """Every ``capacity='auto'`` path floors at the same default.  The
    stream path used to floor at 16, so a tiny probe stream prepared a
    pool 4x smaller than an identically-bound armed session's and paid a
    grow-merge-replay on its first real batch."""
    from repro.api import _auto_capacity, _DEFAULT_CAPACITY
    from repro.graph.updates import UpdateStream
    tiny = UpdateStream(adds=np.asarray([(0, 1, 5)], dtype=np.int64),
                        dels=np.zeros((0, 2), dtype=np.int64))
    assert _auto_capacity(stream=tiny) == _DEFAULT_CAPACITY
    assert _auto_capacity(batch=tiny.batch(0, 2)) == _DEFAULT_CAPACITY
    assert _auto_capacity() == _DEFAULT_CAPACITY
    # and the floor still yields to real demand
    big = UpdateStream(
        adds=np.asarray([(0, 1, 5)] * 100, dtype=np.int64),
        dels=np.zeros((0, 2), dtype=np.int64))
    assert _auto_capacity(stream=big) == 2 * big.num_adds


def test_capacity_overflow_grows_and_replays():
    """An undersized pool must not drop adds: the armed apply path rolls
    back, grows, and replays — final state stays oracle-exact."""
    sc, csr, ref = _scenario_bits("batch8")
    assert sc.stream.num_adds > 2
    prog = api.compile(program_path("sssp"))
    sess = prog.bind(csr, backend="jnp", capacity=2)   # way too small
    sess.run("DynSSSP", src=sc.src, batchSize=sc.batch_size)
    cap0 = sess.handle.diff_capacity
    for batch in sc.stream.batches(sc.batch_size):
        sess.apply(batch)
    assert sess.handle.diff_capacity > cap0            # grew at least once
    np.testing.assert_array_equal(_as_oracle(sess.props["dist"]), ref)

    # the one-shot run path recovers too (grow + whole-run replay)
    one = prog.bind(csr, backend="jnp", capacity=2)
    res = one.run("DynSSSP", updateBatch=sc.stream,
                  batchSize=sc.batch_size, src=sc.src)
    assert one.handle.diff_capacity > 2
    np.testing.assert_array_equal(_as_oracle(res.props["dist"]), ref)

    # the structural (GraphSession) apply path recovers too
    gsess = api.bind_graph(csr, backend="jnp", capacity=2)
    for batch in sc.stream.batches(sc.batch_size):
        gsess.apply(batch)
    props = hand_sssp.static_sssp(gsess.engine, gsess.handle, sc.src)
    np.testing.assert_array_equal(_as_oracle(props["dist"][: sc.n]), ref)

    # ... and hand-staged drivers through call() (grow + driver replay)
    csess = api.bind_graph(csr, backend="jnp", capacity=2)
    csess.call(hand_sssp.dyn_sssp, sc.src, sc.stream, sc.batch_size)
    np.testing.assert_array_equal(
        _as_oracle(csess.props.host("dist")), ref)


def test_bind_graph_call_adopts_handle():
    sc, csr, ref = _scenario_bits("batch8")
    sess = repro.bind_graph(csr, backend="jnp",
                            capacity=sc.diff_capacity)
    h0 = sess.handle
    props = sess.call(hand_sssp.dyn_sssp, sc.src, sc.stream,
                      sc.batch_size)
    assert sess.handle is not h0                # updated handle adopted
    np.testing.assert_array_equal(
        _as_oracle(sess.props.host("dist")), ref)
    np.testing.assert_array_equal(_as_oracle(props["dist"][: sc.n]), ref)


def test_compile_is_cached_and_lists_functions():
    p = program_path("sssp")
    prog = api.compile(p)
    assert prog is api.compile(p)               # compile once per source
    assert "DynSSSP" in prog.functions and "staticSSSP" in prog.functions
    # repro top-level re-export
    assert repro.compile is api.compile


def test_run_unknown_function_fails_early():
    sc, csr, _ = _scenario_bits()
    sess = api.compile(program_path("sssp")).bind(csr, backend="jnp")
    with pytest.raises(KeyError):
        sess.run("NoSuchFunc")


def test_missing_scalar_arg_fails_for_one_shot():
    from repro.core.dsl.codegen import CodegenError
    sc, csr, _ = _scenario_bits()
    sess = api.compile(program_path("sssp")).bind(csr, backend="jnp")
    with pytest.raises(CodegenError, match="src"):
        sess.run("DynSSSP", updateBatch=sc.stream,
                 batchSize=sc.batch_size)   # src missing


def test_missing_scalar_arg_fails_when_arming():
    """Armed mode may only omit the stream and the Batch batch-size;
    scalars the prologue needs still fail loudly up front."""
    from repro.core.dsl.codegen import CodegenError
    sc, csr, _ = _scenario_bits()
    sess = api.compile(program_path("sssp")).bind(csr, backend="jnp")
    with pytest.raises(CodegenError, match="src"):
        sess.run("DynSSSP")                  # src missing, stream omitted
    sess.run("DynSSSP", src=sc.src)          # batchSize omittable: armed
    assert sess.armed


def test_failed_run_leaves_armed_loop_intact():
    """A one-shot run that raises (bad args) must not disarm a live
    Batch loop — later applies keep doing algorithmic repair."""
    from repro.core.dsl.codegen import CodegenError
    sc, csr, ref = _scenario_bits("batch8")
    sess = api.compile(program_path("sssp")).bind(
        csr, backend="jnp", capacity=sc.diff_capacity)
    sess.run("DynSSSP", src=sc.src, batchSize=sc.batch_size)
    with pytest.raises(CodegenError):
        sess.run("DynSSSP", updateBatch=sc.stream,
                 batchSize=sc.batch_size, src=sc.src, bogus=1)
    assert sess.armed
    for batch in sc.stream.batches(sc.batch_size):
        sess.apply(batch)
    np.testing.assert_array_equal(_as_oracle(sess.props["dist"]), ref)


def test_apply_without_arm_is_structural():
    """apply on a DSL session with nothing armed falls back to the
    structural path (graph updated, no algorithm state)."""
    sc, csr, _ = _scenario_bits()
    sess = api.compile(program_path("sssp")).bind(
        csr, backend="jnp", capacity=sc.diff_capacity)
    assert not sess.armed
    batch = sc.stream.batch(0, sc.batch_size)
    sess.apply(batch)
    from repro.graph import diffcsr
    used = int(np.asarray(diffcsr.pool_counters(sess.handle))[1])
    assert used > 0 or sc.stream.num_adds == 0
