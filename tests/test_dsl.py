"""DSL frontend tests: lexer/parser/analysis units + end-to-end
compilation of the paper's appendix programs (Figs. 19-21) validated
against oracles on all three backends — the paper's 'one spec, three
backends' claim exercised through the real compiler pipeline."""
import pathlib

import numpy as np
import pytest

from conftest import random_digraph, random_symgraph, sym_stream
from repro.graph import random_updates
from repro.core.dsl import (compile_source, parse, tokenize, analyze,
                            LexError, ParseError, SemanticError)
from repro.core.dsl import ast_nodes as A
from repro.core.dsl.emit import emit_report
from repro.core.engine import JnpEngine
from repro.core.dist import DistEngine
from repro.core.pallas_engine import PallasEngine
from repro.algos import oracles

PROGS = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro" / \
    "dsl_programs"

ENGINES = [JnpEngine, DistEngine, PallasEngine]


# ---------------------------------------------------------------------------
# front-end units
# ---------------------------------------------------------------------------

def test_lexer_basic():
    toks = tokenize("forall (v in g.nodes()) { v.dist = 0; } // c")
    kinds = [t.kind for t in toks]
    assert kinds[-1] == "eof"
    assert toks[0].kind == "kw" and toks[0].text == "forall"
    texts = [t.text for t in toks]
    assert "//" not in " ".join(texts)          # comments stripped


def test_parser_multiassign_and_min():
    src = """
    Static f(Graph g, propNode<int> dist, propEdge<int> weight) {
      forall (v in g.nodes().filter(modified == True)) {
        forall (nbr in g.neighbors(v)) {
          edge e = g.get_edge(v, nbr);
          <nbr.dist, nbr.mod2, nbr.parent> =
              <Min(nbr.dist, v.dist + e.weight), True, v>;
        }
      }
    }
    """
    ast = parse(src)
    fa = ast.funcs[0].body.stmts[0]
    assert isinstance(fa, A.ForAll) and fa.filter is not None
    inner = fa.body.stmts[0]
    ma = inner.body.stmts[1]
    assert isinstance(ma, A.MultiAssign)
    assert isinstance(ma.values[0], A.MinMax)


def test_parser_rejects_arity_mismatch():
    with pytest.raises(ParseError):
        parse("Static f(Graph g) { <a.x, a.y> = <1>; }")


@pytest.mark.parametrize("src,err", [
    # lexer: characters outside the token alphabet
    ("Static f(Graph g) { int x = 3 @ 4; }", LexError),
    ("Static f(Graph g) { int q = `; }", LexError),
    # parser: malformed forall / multi-assign / missing terminator
    ("Static f(Graph g) { forall (v in ) { } }", ParseError),
    ("Static f(Graph g) { forall (v g.nodes()) { } }", ParseError),
    ("Static f(Graph g) { <a.x> = <1, 2>; }", ParseError),
    ("Static f(Graph g) { <a.x, a.y> = <1>; }", ParseError),
    ("Static f(Graph g) { int x = 1 }", ParseError),
    # semantic analysis: undeclared properties, undeclared names,
    # read-before-write — analysis failure rejects the program
    ("Static f(Graph g, propNode<int> dist) {\n"
     "  forall (v in g.nodes()) { v.distt = 0; } }", SemanticError),
    ("Static f(Graph g) {\n"
     "  forall (v in g.nodes()) { int y = v.missing + 1; } }",
     SemanticError),
    ("Static f(Graph g) { int y = z + 1; }", SemanticError),
    ("Static f(Graph g) { int x; int y = x + 1; }", SemanticError),
    ("Static f(Graph g) { float d; d += 1.0; }", SemanticError),
    ("Static f(Graph g) { int x; bool c = True;\n"
     "  if (c) { x = 1; } int y = x; }", SemanticError),
    ("Static f(Graph g) { int x; bool c = True;\n"
     "  while (c) { x = 1; } int y = x; }", SemanticError),
], ids=["lex-at", "lex-backtick", "forall-empty-iter", "forall-no-in",
        "multiassign-1v2", "multiassign-2v1", "missing-semicolon",
        "undeclared-prop-write", "undeclared-prop-read", "undeclared-name",
        "read-before-write", "accum-before-write",
        "one-branch-init", "zero-iteration-loop-init"])
def test_frontend_error_paths(src, err):
    """LexError / ParseError / SemanticError each fire on the malformed
    program and carry a line number in the message."""
    with pytest.raises(err):
        compile_source(src)


@pytest.mark.parametrize("src", [
    # a do-while body runs before its condition is first evaluated
    "Static f(Graph g) { int i; do { i = 0; i = i + 1; } "
    "while (i < 3); }",
    # assigned on both branches → initialized afterwards
    "Static f(Graph g) { int x; bool c = True;\n"
    "  if (c) { x = 1; } else { x = 2; } int y = x; }",
], ids=["dowhile-body-initializes", "both-branches-initialize"])
def test_init_order_accepts_valid_paths(src):
    compile_source(src)        # must not raise


def test_analysis_race_inference():
    src = (PROGS / "sssp.sp").read_text()
    infos = analyze(parse(src))
    sweeps = infos["staticSSSP"].sweeps
    races = [r for s in sweeps for r in s.races]
    kinds = sorted({r.kind for r in races})
    assert "min" in kinds and "argmin" in kinds and "or" in kinds
    # read/write sets: the relax sweep reads dist+modified, writes dist etc
    edge_sweeps = [s for s in sweeps if s.orientation == "push"]
    assert any("dist" in s.reads and "dist" in s.writes
               for s in edge_sweeps)


def test_emit_report_mentions_combiners():
    prog = compile_source(str(PROGS / "sssp.sp"))
    rep = emit_report(prog, backend="dist")
    assert "Reduce(min" in rep
    assert "argmin" in rep
    assert "update_del" in rep or "updateCSRDel" in rep


# ---------------------------------------------------------------------------
# end-to-end: paper programs vs oracles on all three backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine_cls", ENGINES, ids=lambda c: c.name)
def test_dsl_dynamic_sssp(engine_cls):
    prog = compile_source(str(PROGS / "sssp.sp"))
    n, csr, edges, w = random_digraph(seed=11)
    eng = engine_cls()
    ups = random_updates(csr, percent=15, seed=2)
    res = prog.run("DynSSSP", eng, csr,
                   args={"updateBatch": ups, "batchSize": 8, "src": 0},
                   diff_capacity=64)
    e2, w2 = oracles.edges_after_updates(n, edges, w, ups.adds, ups.dels)
    ref = oracles.sssp_oracle(n, e2, w2, 0)
    got = np.minimum(res.props["dist"].astype(np.int64), oracles.INF)
    assert np.array_equal(got, ref)


@pytest.mark.parametrize("engine_cls", ENGINES, ids=lambda c: c.name)
def test_dsl_dynamic_pagerank(engine_cls):
    prog = compile_source(str(PROGS / "pagerank.sp"))
    n, csr, edges, w = random_digraph(seed=12)
    eng = engine_cls()
    ups = random_updates(csr, percent=10, seed=3)
    res = prog.run("DynPR", eng, csr,
                   args={"updateBatch": ups, "batchSize": 8,
                         "beta": 1e-3, "delta": 0.85, "maxIter": 100},
                   diff_capacity=64)
    e2, _ = oracles.edges_after_updates(n, edges, w, ups.adds, ups.dels)
    ref = oracles.pagerank_oracle(n, e2)
    np.testing.assert_allclose(res.props["pageRank"], ref,
                               rtol=5e-2, atol=1e-4)


@pytest.mark.parametrize("engine_cls", [JnpEngine, PallasEngine],
                         ids=lambda c: c.name)
def test_dsl_dynamic_tc(engine_cls):
    prog = compile_source(str(PROGS / "tc.sp"))
    n, csr, edges = random_symgraph(seed=4)
    eng = engine_cls()
    ups = sym_stream(csr, percent=15, seed=6)
    res = prog.run("DynTC", eng, csr,
                   args={"updateBatch": ups, "batchSize": 16},
                   diff_capacity=256)
    e2, _ = oracles.edges_after_updates(
        n, edges, np.ones(len(edges), np.int32), ups.adds, ups.dels)
    assert int(res.value) == oracles.tc_oracle(n, e2)


def test_dsl_static_matches_handwritten():
    """DSL-compiled static SSSP ≡ the hand-staged repro.algos version."""
    from repro.algos import sssp as hand
    prog = compile_source(str(PROGS / "sssp.sp"))
    n, csr, edges, w = random_digraph(seed=21)
    eng = JnpEngine()
    res = prog.run("staticSSSP", eng, csr, args={"src": 0})
    g = eng.prepare(csr, diff_capacity=16)
    ref = hand.static_sssp(eng, g, 0)
    assert np.array_equal(res.props["dist"],
                          np.asarray(ref["dist"])[:n])
    assert np.array_equal(res.props["parent"],
                          np.asarray(ref["parent"])[:n])
