"""The cross-backend conformance matrix: every (program × backend ×
scenario) cell must satisfy  api Session == Program.run shim (bit-exact)
== oracle == hand-staged  (see conformance.py).  This is the executable
form of the paper's evaluation tables; new engines/kernels must keep it
green.

Backends are addressed by registry name — a newly registered engine
joins the matrix by adding its name to the lists below.

The dist and dist_sharded columns pay a large shard_map tracing cost
per case (~1 min on CPU, growing with the mesh width), so only one
representative cell per (program, distributed backend) stays in the
fast lane; the rest carry the `slow` marker and run in the full lane.
On a single-device host the distributed columns run at one shard; CI's
dist-smoke job re-runs the dist_sharded fast cells on 8 virtual host
devices.
"""
import pytest

from conformance import (assert_pagerank, assert_pagerank_save_restore,
                         assert_pagerank_stream, assert_sssp,
                         assert_sssp_poison, assert_sssp_save_restore,
                         assert_sssp_stream, assert_sssp_stream_poison,
                         assert_tc, assert_tc_stream, digraph_scenario,
                         sym_scenario)

BACKENDS = ["jnp", "dist", "dist_sharded", "pallas"]

SSSP_SCENARIOS = ["batch1", "batch8", "batch64", "empty", "self_loops",
                  "dup_in_batch", "del_then_readd"]
PR_SCENARIOS = ["batch1", "batch8", "batch64", "del_then_readd"]
TC_SCENARIOS = ["sym_batch2", "sym_batch16", "sym_empty", "sym_del_readd"]

# the dist cell that stays fast: a single whole-Δ batch (fewest traces)
DIST_FAST = {"batch64"}


# backends whose cells mostly run in the slow lane (one fast
# representative each): dist and dist_sharded pay shard_map tracing,
# pallas_chained is the pre-fusion baseline kept honest by one cell per
# program.
_MOSTLY_SLOW = {"dist", "dist_sharded", "pallas_chained"}


def _cells(scenarios, backends, fast=DIST_FAST, prefix=""):
    out = []
    for s in scenarios:
        for b in backends:
            marks = ()
            if b in _MOSTLY_SLOW and s not in fast:
                marks = (pytest.mark.slow,)
            out.append(pytest.param(s, b, marks=marks,
                                    id=f"{prefix}{s}-{b}"))
    return out


@pytest.mark.parametrize("scenario,backend", _cells(SSSP_SCENARIOS,
                                                    BACKENDS))
def test_conformance_sssp(scenario, backend):
    assert_sssp(backend, digraph_scenario(scenario))


@pytest.mark.parametrize("scenario,backend", _cells(PR_SCENARIOS,
                                                    BACKENDS))
def test_conformance_pagerank(scenario, backend):
    assert_pagerank(backend, digraph_scenario(scenario))


# TC's wedge enumeration on the dist backend is the paper's admitted MPI
# bottleneck; the two fast engines cover the kernel surface here while
# test_backends.py keeps one dist TC case.  dist_sharded joins the
# column (halo'd wedge bounds make sharded TC work) with its own fast
# representative — DIST_FAST names no symmetric scenario.
TC_FAST = {"sym_batch2"}


@pytest.mark.parametrize("scenario,backend",
                         _cells(TC_SCENARIOS,
                                ["jnp", "dist_sharded", "pallas"],
                                fast=TC_FAST))
def test_conformance_tc(scenario, backend):
    assert_tc(backend, sym_scenario(scenario))


# ---------------------------------------------------------------------------
# Streaming-executor cells: the same scenarios driven through
# GraphSession.run_stream (one fused lax.scan per segment) must stay
# oracle-exact on every backend.  Scenario-representative subset per
# program keeps the fast lane fast; dist cells follow the DIST_FAST rule.
# ---------------------------------------------------------------------------

STREAM_SSSP = ["batch1", "batch8", "empty", "dup_in_batch", "del_then_readd"]
STREAM_PR = ["batch1", "batch8", "del_then_readd"]
STREAM_TC = ["sym_batch2", "sym_empty", "sym_del_readd"]
# the dist stream cell that stays fast (fewest shard_map traces)
DIST_STREAM_FAST = {"batch8"}


@pytest.mark.parametrize("scenario,backend",
                         _cells(STREAM_SSSP,
                                BACKENDS + ["pallas_chained", "frontier"],
                                fast=DIST_STREAM_FAST, prefix="stream-"))
def test_stream_conformance_sssp(scenario, backend):
    assert_sssp_stream(backend, digraph_scenario(scenario))


@pytest.mark.parametrize("scenario,backend",
                         _cells(STREAM_PR,
                                BACKENDS + ["pallas_chained", "frontier"],
                                fast=DIST_STREAM_FAST, prefix="stream-"))
def test_stream_conformance_pagerank(scenario, backend):
    assert_pagerank_stream(backend, digraph_scenario(scenario))


# dist refuses wedge enumeration inside the fused scan (no static
# bounds); dist_sharded provides them, so the sharded column is the
# FIRST distributed engine in the streaming-TC row.
@pytest.mark.parametrize("scenario,backend",
                         _cells(STREAM_TC,
                                ["jnp", "dist_sharded", "pallas"],
                                fast=TC_FAST, prefix="stream-"))
def test_stream_conformance_tc(scenario, backend):
    assert_tc_stream(backend, sym_scenario(scenario))


# ---------------------------------------------------------------------------
# Adversarial-stream cells (admission guard, DESIGN.md §6): a poison
# batch prepended to the scenario stream must leave the final state
# oracle-exact against the CLEAN stream after the guard disposes of it
# (clamp → masked no-op, quarantine → dead-letter).  Both the DSL
# one-shot path and the fused streaming executor get cells on every
# registered backend.
# ---------------------------------------------------------------------------

POISON_SCENARIOS = ["batch8", "batch64"]
POISON_POLICIES = ["clamp", "quarantine"]
# the admission guard sits in front of the engine, so its cells need
# one distributed representative, not two: dist covers the shard_map
# column and dist_sharded stays out of the poison grid
POISON_BACKENDS = [b for b in BACKENDS if b != "dist_sharded"]


def _poison_cells(scenarios, backends, fast=DIST_FAST):
    out = []
    for s in scenarios:
        for b in backends:
            for p in POISON_POLICIES:
                marks = ()
                if b in _MOSTLY_SLOW and s not in fast:
                    marks = (pytest.mark.slow,)
                out.append(pytest.param(s, b, p, marks=marks,
                                        id=f"poison-{s}-{b}-{p}"))
    return out


@pytest.mark.parametrize("scenario,backend,policy",
                         _poison_cells(POISON_SCENARIOS, POISON_BACKENDS))
def test_conformance_sssp_poison(scenario, backend, policy):
    assert_sssp_poison(backend, digraph_scenario(scenario), policy)


@pytest.mark.parametrize("scenario,backend,policy",
                         _poison_cells(["batch8"],
                                       POISON_BACKENDS + ["pallas_chained",
                                                          "frontier"],
                                       fast=DIST_STREAM_FAST))
def test_stream_conformance_sssp_poison(scenario, backend, policy):
    assert_sssp_stream_poison(backend, digraph_scenario(scenario), policy)


# ---------------------------------------------------------------------------
# Durability cells: arm the Batch loop, apply half the stream, save,
# restore from disk, apply the rest — bit-identical to the uninterrupted
# armed run (see conformance.assert_sssp_save_restore).  Every registered
# backend gets a cell; dist's pays its shard_map tracing cost twice (the
# saving and the restored engine both trace), so it rides the slow lane
# alongside pallas_chained per the _MOSTLY_SLOW convention.
# ---------------------------------------------------------------------------

DURABLE_BACKENDS = ["jnp", "dist", "dist_sharded", "pallas",
                    "pallas_chained", "frontier"]


@pytest.mark.parametrize("scenario,backend",
                         _cells(["batch8"], DURABLE_BACKENDS, fast=set(),
                                prefix="ckpt-"))
def test_conformance_sssp_save_restore(scenario, backend, tmp_path):
    assert_sssp_save_restore(backend, digraph_scenario(scenario), tmp_path)


# elastic re-mesh: save on the full mesh, restore onto half of it (on a
# single-device host this degenerates to 1 -> 1, which still walks the
# pack/re-partition path; CI's dist-smoke job runs it at 8 -> 4)
@pytest.mark.slow
def test_conformance_sssp_save_restore_remesh(tmp_path):
    import jax
    m = max(1, len(jax.devices()) // 2)
    assert_sssp_save_restore("dist_sharded", digraph_scenario("batch8"),
                             tmp_path, restore_opts={"num_shards": m})


# float bit-exactness: raw-leaf restore preserves the diff-pool layout
# and ELL pack, so resumed PageRank is bit-identical, not just close
@pytest.mark.parametrize("scenario,backend",
                         _cells(["batch8"], ["jnp", "pallas"], fast=set(),
                                prefix="ckpt-"))
def test_conformance_pagerank_save_restore(scenario, backend, tmp_path):
    assert_pagerank_save_restore(backend, digraph_scenario(scenario),
                                 tmp_path)


# cross-backend restore: the checkpoint converts through the canonical
# alive-edge list; SSSP's int-min fold makes the contract still bit-exact
@pytest.mark.parametrize("save_backend,restore_backend",
                         [pytest.param("jnp", "pallas",
                                       id="ckpt-jnp-to-pallas"),
                          pytest.param("pallas", "jnp",
                                       id="ckpt-pallas-to-jnp")])
def test_conformance_cross_backend_restore(save_backend, restore_backend,
                                           tmp_path):
    assert_sssp_save_restore(save_backend, digraph_scenario("batch8"),
                             tmp_path, restore_backend=restore_backend)
