"""The cross-backend conformance matrix: every (program × engine ×
scenario) cell must satisfy DSL == oracle == hand-staged (see
conformance.py).  This is the executable form of the paper's evaluation
tables; new engines/kernels must keep it green.

The dist column pays a large shard_map tracing cost per case (~1 min on
CPU), so only one representative dist cell per program stays in the
fast lane; the rest carry the `slow` marker and run in the full lane.
"""
import pytest

from conformance import (assert_pagerank, assert_pagerank_stream,
                         assert_sssp, assert_sssp_stream, assert_tc,
                         assert_tc_stream, digraph_scenario, sym_scenario)
from repro.core.engine import JnpEngine
from repro.core.dist import DistEngine
from repro.core.frontier_engine import FrontierEngine
from repro.core.pallas_engine import PallasEngine

ENGINES = [JnpEngine, DistEngine, PallasEngine]

SSSP_SCENARIOS = ["batch1", "batch8", "batch64", "empty", "self_loops",
                  "dup_in_batch", "del_then_readd"]
PR_SCENARIOS = ["batch1", "batch8", "batch64", "del_then_readd"]
TC_SCENARIOS = ["sym_batch2", "sym_batch16", "sym_empty", "sym_del_readd"]

# the dist cell that stays fast: a single whole-Δ batch (fewest traces)
DIST_FAST = {"batch64"}


def _cells(scenarios, engines):
    out = []
    for s in scenarios:
        for e in engines:
            marks = ()
            if e is DistEngine and s not in DIST_FAST:
                marks = (pytest.mark.slow,)
            out.append(pytest.param(s, e, marks=marks,
                                    id=f"{s}-{e.name}"))
    return out


@pytest.mark.parametrize("scenario,engine_cls", _cells(SSSP_SCENARIOS,
                                                       ENGINES))
def test_conformance_sssp(scenario, engine_cls):
    assert_sssp(engine_cls, digraph_scenario(scenario))


@pytest.mark.parametrize("scenario,engine_cls", _cells(PR_SCENARIOS,
                                                       ENGINES))
def test_conformance_pagerank(scenario, engine_cls):
    assert_pagerank(engine_cls, digraph_scenario(scenario))


# TC's wedge enumeration on the dist backend is the paper's admitted MPI
# bottleneck; the two fast engines cover the kernel surface here while
# test_backends.py keeps one dist TC case.
@pytest.mark.parametrize("scenario,engine_cls",
                         _cells(TC_SCENARIOS, [JnpEngine, PallasEngine]))
def test_conformance_tc(scenario, engine_cls):
    assert_tc(engine_cls, sym_scenario(scenario))


# ---------------------------------------------------------------------------
# Streaming-executor cells: the same scenarios driven through
# Engine.run_stream (one fused lax.scan per segment) must stay
# oracle-exact on every engine.  Scenario-representative subset per
# program keeps the fast lane fast; dist cells follow the DIST_FAST rule.
# ---------------------------------------------------------------------------

STREAM_SSSP = ["batch1", "batch8", "empty", "dup_in_batch", "del_then_readd"]
STREAM_PR = ["batch1", "batch8", "del_then_readd"]
STREAM_TC = ["sym_batch2", "sym_empty", "sym_del_readd"]
# the dist stream cell that stays fast (fewest shard_map traces)
DIST_STREAM_FAST = {"batch8"}


def _stream_cells(scenarios, engines):
    out = []
    for s in scenarios:
        for e in engines:
            marks = ()
            if e is DistEngine and s not in DIST_STREAM_FAST:
                marks = (pytest.mark.slow,)
            out.append(pytest.param(s, e, marks=marks,
                                    id=f"stream-{s}-{e.name}"))
    return out


@pytest.mark.parametrize("scenario,engine_cls",
                         _stream_cells(STREAM_SSSP,
                                       ENGINES + [FrontierEngine]))
def test_stream_conformance_sssp(scenario, engine_cls):
    assert_sssp_stream(engine_cls, digraph_scenario(scenario))


@pytest.mark.parametrize("scenario,engine_cls",
                         _stream_cells(STREAM_PR, ENGINES + [FrontierEngine]))
def test_stream_conformance_pagerank(scenario, engine_cls):
    assert_pagerank_stream(engine_cls, digraph_scenario(scenario))


@pytest.mark.parametrize("scenario,engine_cls",
                         _stream_cells(STREAM_TC, [JnpEngine, PallasEngine]))
def test_stream_conformance_tc(scenario, engine_cls):
    assert_tc_stream(engine_cls, sym_scenario(scenario))
