"""Optimizer substrate tests: gradient compression properties
(unbiasedness + bounded error) and the cross-pod compressed psum."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.optim import compress


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
def test_quantize_roundtrip_error(seed, scale_pow):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((3, 130)) * 10 ** scale_pow).astype(np.float32)
    q, s = compress.quantize(jnp.asarray(x), jax.random.PRNGKey(seed))
    y = np.asarray(compress.dequantize(q, s, x.shape, jnp.float32))
    # error per element bounded by one quantization step (per-block scale)
    step = np.asarray(s)[:, None] * np.ones((1, compress.BLOCK))
    step = step.reshape(-1)[: x.size].reshape(x.shape)
    assert np.all(np.abs(y - x) <= step + 1e-6)


def test_quantize_unbiased():
    x = jnp.asarray(np.linspace(-3, 3, 512, dtype=np.float32))
    outs = []
    for k in range(200):
        q, s = compress.quantize(x, jax.random.PRNGKey(k))
        outs.append(np.asarray(compress.dequantize(q, s, x.shape,
                                                   jnp.float32)))
    mean = np.mean(outs, axis=0)
    scale = float(np.max(np.abs(x))) / 127.0
    # stochastic rounding: mean converges to x (tolerance ~ step/sqrt(N))
    assert np.max(np.abs(mean - x)) < 0.35 * scale


def test_compression_ratio_beats_bf16():
    r = compress.compression_ratio((4096, 4096))
    assert r < 0.6           # int8+scales ≈ 0.51 of bf16 wire bytes


def test_compressed_psum_single_axis():
    """shard_map over the host device(s): compressed sum ≈ exact sum."""
    from jax.sharding import PartitionSpec as P
    devs = jax.devices()
    mesh = jax.make_mesh((len(devs),), ("pod",))
    x = np.random.default_rng(0).standard_normal(
        (len(devs), 512)).astype(np.float32)

    def f(xs):
        y = compress.compressed_psum(xs[0], "pod", jax.random.PRNGKey(0),
                                     group_size=len(devs))
        return y[None]

    from jax.experimental.shard_map import shard_map
    out = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("pod"),),
                            out_specs=P("pod")))(jnp.asarray(x))
    exact = x.sum(axis=0)
    got = np.asarray(out)[0]
    err = np.abs(got - exact)
    step = np.abs(x).max() / 127.0 * len(devs)
    assert np.all(err <= step * 1.5 + 1e-5)
