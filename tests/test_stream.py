"""Streaming-executor + diff-pool maintenance tests (DESIGN.md §3).

Covers the paths the conformance matrix cannot see directly:
  * diff-pool overflow: the counter trips, the host-side merge recovers,
    and no edge is silently lost (numpy dict oracle);
  * on-device compact(): tombstoned slots are reclaimed in place, the
    pool stays sorted, the edge set is unchanged;
  * run_stream segment replay: a stream that overflows mid-segment rolls
    back, grows capacity and replays to the oracle-exact answer;
  * in-place ELL patching: revive/tombstone batches patch the pack
    (lane2slot) to exactly what a from-scratch repack would build.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.graph import build_csr, from_csr, update_csr_add, update_csr_del, \
    merge, is_edge
from repro.graph import diffcsr
from repro.graph.updates import UpdateStream, random_updates
from repro.core.engine import Engine, JnpEngine
from repro.core.pallas_engine import PallasEngine
from repro.core.frontier_engine import FrontierEngine
from repro.kernels.ell import pack_ell, pack_push_ell
from repro.algos import sssp, oracles


def _graph(n=48, deg=4, seed=7, max_w=30):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(n * deg, 2))
    e = e[e[:, 0] != e[:, 1]]
    w = rng.integers(1, max_w, size=len(e)).astype(np.int32)
    csr = build_csr(n, e, w)
    e0 = np.stack([np.asarray(csr.src), np.asarray(csr.dst)], 1) \
        .astype(np.int64)
    return csr, e0, np.asarray(csr.w)


def _edge_set(g):
    es, ed, _, ea = (np.asarray(x) for x in g.edge_arrays())
    return set(map(tuple, np.stack([es[ea], ed[ea]], 1).tolist()))


# ---------------------------------------------------------------------------
# diff-pool overflow: trip → merge → recover, no silent loss
# ---------------------------------------------------------------------------

def test_overflow_trips_and_merge_recovers():
    n = 16
    g = from_csr(build_csr(n, np.array([(0, 1)])), diff_capacity=3)
    # 6 fresh edges into a 3-slot pool: 3 admitted, 3 counted as dropped
    qs = jnp.asarray(np.array([1, 2, 3, 4, 5, 6], np.int32))
    qd = jnp.asarray(np.array([2, 3, 4, 5, 6, 7], np.int32))
    g1 = update_csr_add(g, qs, qd)
    assert int(g1.overflow) == 3
    # the oracle protocol (Engine.run_stream's): roll back to the
    # pre-batch graph, merge with grown capacity, replay the batch
    g2 = update_csr_add(merge(g, diff_capacity=16), qs, qd)
    assert int(g2.overflow) == 0
    want = {(0, 1)} | set(zip(qs.tolist(), qd.tolist()))
    assert _edge_set(g2) == want, "edges lost across overflow recovery"


def test_overflow_admits_prefix_never_drops_existing():
    """Admitted adds fill the remaining slots; pre-existing pool edges
    are never displaced by an overflowing batch."""
    n = 16
    g = from_csr(build_csr(n, np.array([(0, 1)])), diff_capacity=3)
    g = update_csr_add(g, jnp.asarray([2], jnp.int32),
                       jnp.asarray([3], jnp.int32))
    before = _edge_set(g)
    g1 = update_csr_add(g, jnp.asarray([4, 5, 6], jnp.int32),
                        jnp.asarray([5, 6, 7], jnp.int32))
    assert int(g1.overflow) == 1
    after = _edge_set(g1)
    assert before <= after, "existing edges displaced by overflow"
    assert len(after) == len(before) + 2     # exactly the admitted adds


# ---------------------------------------------------------------------------
# on-device compact
# ---------------------------------------------------------------------------

def test_compact_reclaims_tombstones_in_place():
    n = 24
    rng = np.random.default_rng(0)
    g = from_csr(build_csr(n, np.zeros((0, 2), np.int64)), diff_capacity=16)
    e = rng.integers(0, n, size=(12, 2)).astype(np.int32)
    e = e[e[:, 0] != e[:, 1]][:10]
    g = update_csr_add(g, jnp.asarray(e[:, 0]), jnp.asarray(e[:, 1]))
    # tombstone half the pool
    g = update_csr_del(g, jnp.asarray(e[:5, 0]), jnp.asarray(e[:5, 1]))
    want = _edge_set(g)
    used0 = int(jnp.sum(g.d_src < g.n))
    dead0 = int(diffcsr.pool_counters(g)[2])
    assert dead0 > 0
    gc = diffcsr.compact(g)
    assert _edge_set(gc) == want
    assert int(jnp.sum(gc.d_src < gc.n)) == used0 - dead0
    assert int(diffcsr.pool_counters(gc)[2]) == 0
    # pool stays sorted by (src, dst) with vacant rows sunk
    ds, dd = np.asarray(gc.d_src), np.asarray(gc.d_dst)
    key = ds.astype(np.int64) * (n + 1) + dd
    assert (np.diff(key) >= 0).all()
    # freed slots are reusable: the next adds append without overflow
    g2 = update_csr_add(gc, jnp.asarray(e[:5, 0]), jnp.asarray(e[:5, 1]))
    assert int(g2.overflow) == 0
    assert _edge_set(g2) == want | set(map(tuple, e[:5].tolist()))


# ---------------------------------------------------------------------------
# run_stream: fused == per-batch == oracle, incl. overflow segment replay
# ---------------------------------------------------------------------------

STREAM_ENGINES = [JnpEngine, PallasEngine, FrontierEngine]


@pytest.mark.parametrize("engine_cls", STREAM_ENGINES,
                         ids=[e.name for e in STREAM_ENGINES])
def test_run_stream_overflow_replay_oracle_exact(engine_cls):
    csr, e0, w0 = _graph()
    ups = random_updates(csr, percent=40, seed=3)
    e2, w2 = oracles.edges_after_updates(csr.n, e0, w0, ups.adds, ups.dels)
    ref = oracles.sssp_oracle(csr.n, e2, w2, 0)
    eng = engine_cls()
    g = eng.prepare(csr, diff_capacity=4)      # guaranteed overflow
    g2, props = sssp.dyn_sssp_stream(eng, g, 0, ups, batch_size=4,
                                     segment_size=3)
    got = np.minimum(np.asarray(props["dist"])[: csr.n].astype(np.int64),
                     oracles.INF)
    np.testing.assert_array_equal(got, ref)
    gg = eng.handle_graph(g2)
    assert int(gg.overflow) == 0               # recovery cleared the counter
    assert gg.diff_capacity > 4                # capacity actually grew


@pytest.mark.parametrize("engine_cls", STREAM_ENGINES,
                         ids=[e.name for e in STREAM_ENGINES])
def test_run_stream_matches_per_batch_dispatch(engine_cls):
    csr, e0, w0 = _graph(seed=11)
    ups = random_updates(csr, percent=20, seed=5)
    eng = engine_cls()
    g = eng.prepare(csr, diff_capacity=64)
    props0 = sssp.static_sssp(eng, g, 0)
    _, p_fused = sssp.dyn_sssp_stream(eng, g, 0, ups, 8, props=props0,
                                      segment_size=2)
    _, p_batch = sssp.dyn_sssp(eng, g, 0, ups, 8, props=props0)
    np.testing.assert_array_equal(np.asarray(p_fused["dist"]),
                                  np.asarray(p_batch["dist"]))


def test_run_stream_baseline_dispatch_recovers():
    """Engine.run_stream (the per-batch baseline) also grows + replays."""
    csr, e0, w0 = _graph(seed=13)
    ups = random_updates(csr, percent=40, seed=3)
    e2, w2 = oracles.edges_after_updates(csr.n, e0, w0, ups.adds, ups.dels)
    ref = oracles.sssp_oracle(csr.n, e2, w2, 0)
    eng = JnpEngine()
    g = eng.prepare(csr, diff_capacity=4)
    props0 = sssp.static_sssp(eng, g, 0)
    _, props = Engine.run_stream(eng, g, ups, 4, sssp.stream_step, props0)
    got = np.minimum(np.asarray(props["dist"])[: csr.n].astype(np.int64),
                     oracles.INF)
    np.testing.assert_array_equal(got, ref)


# ---------------------------------------------------------------------------
# in-place ELL patching == from-scratch repack
# ---------------------------------------------------------------------------

def _ell_semantics(ell):
    """Multiset of (group_vertex, other_endpoint, w) alive slots."""
    n = ell.n
    row2 = np.asarray(ell.row2dst)
    src = np.asarray(ell.ell_src)
    w = np.asarray(ell.ell_w)
    out = []
    for r in range(ell.R):
        if row2[r] >= n:
            continue
        for k in range(ell.K):
            if src[r, k] < n:
                out.append((int(row2[r]), int(src[r, k]), int(w[r, k])))
    return sorted(out)


@pytest.mark.parametrize("engine_cls,packer", [
    (PallasEngine, pack_ell), (FrontierEngine, pack_push_ell)],
    ids=["pallas-pull", "frontier-push"])
def test_ell_patch_matches_repack(engine_cls, packer):
    csr, e0, w0 = _graph(n=32, seed=17)
    eng = engine_cls()
    h = eng.prepare(csr, diff_capacity=32)
    # delete a few existing edges (pure tombstone batch: patch path)
    rng = np.random.default_rng(2)
    idx = rng.choice(len(e0), size=6, replace=False)
    b_del = UpdateStream(adds=np.zeros((0, 3), np.int32),
                         dels=e0[idx].astype(np.int32)).batch(0, 8)
    h = eng.update_del(h, b_del)
    # revive two of them with new weights (pure revive batch: patch path)
    readds = np.concatenate([e0[idx[:2]], [[7], [9]]], axis=1)
    b_add = UpdateStream(adds=readds.astype(np.int32),
                         dels=np.zeros((0, 2), np.int32)).batch(0, 8)
    h = eng.update_add(h, b_add)
    ell = h.ell if engine_cls is PallasEngine else h.push
    assert _ell_semantics(ell) == _ell_semantics(packer(h.g, eng.k)), \
        "patched ELL diverged from a from-scratch repack"
