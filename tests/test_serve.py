"""Session-pool suite (DESIGN.md §7): the serving contract.

The load-bearing guarantee is **bit-exactness** — a pooled tenant's
state after any mix of mega-calls, sequential fallbacks, evictions and
restores must equal, bit for bit, a solo session fed the same batches.
Everything else (backpressure, fairness, thread safety, health
accounting) is checked against its typed surface.
"""
import threading

import numpy as np
import pytest

import jax

import repro.api as api
from repro.core import registry
from repro.dsl_programs import path as program_path
from repro.graph import build_csr, random_updates
from repro.graph.updates import UpdateStream
from repro.runtime import PoolSaturatedError
from repro.serve import SessionPool, next_pow2
from conftest import random_digraph

FAST_BACKENDS = ("jnp", "pallas", "frontier")


@pytest.fixture(autouse=True)
def _fresh_shared_engines():
    registry.clear_shared_engines()
    yield
    registry.clear_shared_engines()


def _graph(n=48, seed=3):
    _, csr, _, _ = random_digraph(n=n, seed=seed)
    return csr


def _state_bits(sess):
    tree, _ = sess._engine.pack_state(sess._handle)
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


def _assert_same_state(pooled, solo, ctx=""):
    fa, fb = _state_bits(pooled), _state_bits(solo)
    assert len(fa) == len(fb), ctx
    for x, y in zip(fa, fb):
        np.testing.assert_array_equal(x, y, err_msg=ctx)
    assert pooled.stream_cursor == solo.stream_cursor, ctx


# ---------------------------------------------------------------------------
# batched mega-call == sequential solo applies, bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", FAST_BACKENDS)
@pytest.mark.parametrize("mode", ("vmap", "scan"))
def test_mega_call_bit_exact_vs_solo(backend, mode):
    csr = _graph()
    pool = SessionPool(backend=backend, batch_mode=mode)
    streams = {}
    for t in range(5):        # 5 tenants: bucket pads 5 -> 8
        pool.bind(f"t{t}", csr)
        streams[f"t{t}"] = random_updates(csr, 30, seed=t)
    for i in range(2):
        pool.apply_many([(nm, s.batch(i, 8)) for nm, s in streams.items()])
    assert pool.health.mega_calls >= 2
    assert pool.health.mega_sessions == 10
    for nm, s in streams.items():
        solo = api.bind_graph(csr, backend=backend)
        for i in range(2):
            solo.apply(s.batch(i, 8))
        _assert_same_state(pool.session(nm), solo,
                           ctx=f"{backend}/{mode}/{nm}")


def test_batch_mode_off_is_solo_path():
    csr = _graph()
    pool = SessionPool(backend="jnp", batch_mode="off")
    for t in range(3):
        pool.bind(f"t{t}", csr)
    s = random_updates(csr, 20, seed=0)
    pool.apply_many([(f"t{t}", s.batch(0, 8)) for t in range(3)])
    assert pool.health.mega_calls == 0
    assert pool.health.sequential_fallbacks == 3


def test_mixed_shapes_group_separately():
    """Tenants on different graph scales can't stack — each scale forms
    its own group (and its own shared engine), both still correct."""
    csr_a, csr_b = _graph(n=48), _graph(n=32, seed=7)
    pool = SessionPool(backend="jnp")
    pool.bind("a0", csr_a); pool.bind("a1", csr_a)
    pool.bind("b0", csr_b); pool.bind("b1", csr_b)
    sa = random_updates(csr_a, 25, seed=1)
    sb = random_updates(csr_b, 25, seed=2)
    pool.apply_many([("a0", sa.batch(0, 8)), ("a1", sa.batch(0, 8)),
                     ("b0", sb.batch(0, 8)), ("b1", sb.batch(0, 8))])
    assert pool.health.mega_calls == 2        # one per scale
    assert pool.session("a0")._engine is pool.session("a1")._engine
    assert pool.session("b0")._engine is pool.session("b1")._engine
    assert pool.session("a0")._engine is not pool.session("b0")._engine
    for nm, csr, st in (("a0", csr_a, sa), ("b1", csr_b, sb)):
        solo = api.bind_graph(csr, backend="jnp")
        solo.apply(st.batch(0, 8))
        _assert_same_state(pool.session(nm), solo, ctx=nm)


def test_mega_overflow_falls_back_per_session():
    """A tenant whose diff pool overflows inside the mega-call must
    discard its slot and replay through grow-and-replay — no dropped
    adds, other tenants unaffected, all still solo-exact."""
    csr = _graph()
    stream = random_updates(csr, 60, seed=5)
    width = max(stream.num_adds, stream.num_dels)
    big = stream.batch(0, width)
    # cold's tiny Δ padded to the same lane width so both sessions
    # stack into one mega-call group
    cold_b = random_updates(csr, 2, seed=6).batch(0, width)
    pool = SessionPool(backend="jnp")
    pool.bind("hot", csr, capacity=4)      # guaranteed to overflow
    pool.bind("cold", csr, capacity=4)
    pool.apply_many([("hot", big), ("cold", cold_b)])
    assert pool.session("hot").health.pool_grows >= 1
    solo_hot = api.bind_graph(csr, backend="jnp", capacity=4)
    solo_hot.apply(big)
    _assert_same_state(pool.session("hot"), solo_hot, ctx="hot")
    solo_cold = api.bind_graph(csr, backend="jnp", capacity=4)
    solo_cold.apply(cold_b)
    _assert_same_state(pool.session("cold"), solo_cold, ctx="cold")


# ---------------------------------------------------------------------------
# eviction -> restore transparency
# ---------------------------------------------------------------------------

def test_eviction_restore_transparent(tmp_path):
    csr = _graph()
    pool = SessionPool(backend="jnp", max_resident=2,
                       spill_dir=str(tmp_path))
    streams = {}
    for t in range(4):
        pool.bind(f"t{t}", csr)
        streams[f"t{t}"] = random_updates(csr, 25, seed=10 + t)
    assert pool.stats()["resident"] == 2
    for i in range(2):
        pool.apply_many([(nm, s.batch(i, 8)) for nm, s in streams.items()])
    assert pool.health.evictions > 0 and pool.health.restores > 0
    for nm, s in streams.items():
        solo = api.bind_graph(csr, backend="jnp")
        for i in range(2):
            solo.apply(s.batch(i, 8))
        _assert_same_state(pool.session(nm), solo, ctx=nm)


def test_restored_tenant_shares_pool_engine(tmp_path):
    csr = _graph()
    pool = SessionPool(backend="jnp", spill_dir=str(tmp_path))
    pool.bind("a", csr)
    pool.bind("b", csr)
    pool.apply("a", random_updates(csr, 20, seed=0).batch(0, 8))
    pool.evict("a")
    assert "a" in pool.stats()["evicted"]
    revived = pool.session("a")            # transparent restore
    assert revived._engine is pool.session("b")._engine
    assert pool.health.restores == 1


def test_evicted_armed_session_resumes_mid_batch_loop(tmp_path):
    """The ISSUE's hardest lifecycle cell: an ARMED DSL session is
    idle-evicted mid-Batch-loop and must resume exactly where it
    paused — identical dist and cursor vs an uninterrupted twin."""
    csr = _graph()
    prog = api.compile(program_path("sssp"))
    stream = random_updates(csr, 30, seed=3)
    batches = list(stream.batches(8))

    pool = SessionPool(prog, backend="jnp", spill_dir=str(tmp_path))
    sess = pool.bind("t", csr)
    sess.run("DynSSSP", batchSize=8, src=0)           # arm
    for b in batches[: len(batches) // 2]:
        pool.apply("t", b)
    pool.evict("t")
    for b in batches[len(batches) // 2:]:
        pool.apply("t", b)                # restores transparently
    revived = pool.session("t")
    assert revived.armed

    solo = prog.bind(csr, backend="jnp")
    solo.run("DynSSSP", batchSize=8, src=0)
    for b in batches:
        solo.apply(b)
    np.testing.assert_array_equal(
        np.asarray(revived.props["dist"]), np.asarray(solo.props["dist"]))
    assert revived.stream_cursor == solo.stream_cursor


# ---------------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------------

def test_reject_raises_typed_with_machine_readable_detail():
    csr = _graph()
    pool = SessionPool(backend="jnp", max_pending=2, overload="reject")
    pool.bind("a", csr)
    pool.bind("b", csr)
    s = random_updates(csr, 20, seed=1)
    pool.submit("a", s.batch(0, 8))
    pool.submit("b", s.batch(0, 8))
    with pytest.raises(PoolSaturatedError) as ei:
        pool.submit("a", s.batch(1, 8))
    d = ei.value.describe()
    assert d["kind"] == "PoolSaturatedError"
    assert d["tenant"] == "a" and d["policy"] == "reject"
    assert d["pending"] == 2 and d["max_pending"] == 2
    assert d["depths"] == {"a": 1, "b": 1}
    assert pool.health.rejected == 1
    assert pool.pending() == 2            # refused submit touched nothing
    pool.drain()
    pool.submit("a", s.batch(1, 8))       # room again after drain


def test_shed_drops_oldest_of_deepest_queue_with_record():
    csr = _graph()
    pool = SessionPool(backend="jnp", max_pending=3, overload="shed")
    pool.bind("deep", csr)
    pool.bind("shallow", csr)
    s = random_updates(csr, 20, seed=1)
    pool.submit("deep", s.batch(0, 8))
    pool.submit("deep", s.batch(1, 8))
    pool.submit("shallow", s.batch(0, 8))
    pool.submit("shallow", s.batch(1, 8))       # sheds deep's oldest
    assert pool.pending() == 3
    assert pool.health.shed == 1
    recs = pool.shed_records.records()
    assert len(recs) == 1
    r = recs[0].as_dict()
    assert recs[0].reasons[0].kind == "pool_saturated"
    assert "deep" in recs[0].reasons[0].detail
    assert recs[0].batch is not None            # replayable
    assert r["n_adds"] + r["n_dels"] > 0
    # deep lost its FIRST request: after drain its cursor is 1, not 2
    pool.drain()
    assert pool.session("deep").stream_cursor == 1
    assert pool.session("shallow").stream_cursor == 2


def test_round_robin_fairness():
    """A tenant with a deep queue cannot starve others: each round takes
    at most one request per tenant, so everyone's first request executes
    in round one regardless of queue depths."""
    csr = _graph()
    pool = SessionPool(backend="jnp", max_pending=64)
    order = []
    for t in range(3):
        pool.bind(f"t{t}", csr)
    s = random_updates(csr, 20, seed=1)
    for i in range(5):
        pool.submit("t0", s.batch(i % 3, 8))    # hog
    pool.submit("t1", s.batch(0, 8))
    pool.submit("t2", s.batch(0, 8))
    pool.drain()
    # all queues fully drained, and the non-hogs each applied exactly one
    assert pool.pending() == 0
    assert pool.session("t0").stream_cursor == 5
    assert pool.session("t1").stream_cursor == 1
    assert pool.session("t2").stream_cursor == 1


def test_submit_unknown_tenant_raises():
    pool = SessionPool(backend="jnp")
    with pytest.raises(KeyError):
        pool.submit("ghost", None)


# ---------------------------------------------------------------------------
# admission rides along per tenant
# ---------------------------------------------------------------------------

def test_pool_admission_quarantines_per_tenant():
    csr = _graph()
    n = csr.n
    pool = SessionPool(backend="jnp", admission="quarantine")
    pool.bind("good", csr)
    pool.bind("bad", csr)
    clean = random_updates(csr, 20, seed=1).batch(0, 8)
    poison = UpdateStream(
        adds=np.asarray([(n + 5, 0, 1)] * 4, np.int64),
        dels=np.zeros((0, 2), np.int64)).batch(0, 8)
    pool.apply_many([("good", clean), ("bad", poison)])
    assert pool.session("bad").health.quarantined == 1
    assert len(pool.session("bad").dead_letter) == 1
    assert pool.session("good").health.quarantined == 0
    solo = api.bind_graph(csr, backend="jnp")
    solo.apply(clean)
    _assert_same_state(pool.session("good"), solo)


# ---------------------------------------------------------------------------
# thread safety: concurrent binds and applies from worker threads
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_threaded_stress_eight_workers():
    """8 threads bind and apply concurrently against one pool; every
    tenant must end bit-identical to a solo session fed the same
    batches (the caches they share — compile, stream executables,
    autotuner, shared engines — are all behind locks now)."""
    csr = _graph()
    pool = SessionPool(backend="jnp", max_pending=512)
    n_workers, n_batches = 8, 3
    streams = [random_updates(csr, 25, seed=100 + w)
               for w in range(n_workers)]
    errors = []

    def worker(w):
        try:
            pool.bind(f"w{w}", csr)
            for i in range(n_batches):
                pool.apply(f"w{w}", streams[w].batch(i, 8))
        except Exception as e:        # noqa: BLE001 — surfaced below
            errors.append((w, repr(e)))

    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(n_workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    assert pool.health.applied == n_workers * n_batches
    for w in range(n_workers):
        solo = api.bind_graph(csr, backend="jnp")
        for i in range(n_batches):
            solo.apply(streams[w].batch(i, 8))
        _assert_same_state(pool.session(f"w{w}"), solo, ctx=f"w{w}")


@pytest.mark.slow
def test_threaded_compile_and_bind_race():
    """The bind path's process-wide caches under contention: 8 threads
    compile the same program and bind fresh pallas sessions at once.
    All must resolve to the SAME CompiledProgram (identity — it is the
    pool's grouping key) and produce working sessions."""
    csr = _graph(n=32, seed=9)
    results = []

    def worker():
        prog = api.compile(program_path("sssp"))
        sess = prog.bind(csr, backend="pallas")
        sess.run("DynSSSP", batchSize=8, src=0)
        results.append((prog, np.asarray(sess.props["dist"])))

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(results) == 8
    progs = {id(p) for p, _ in results}
    assert len(progs) == 1
    ref = results[0][1]
    for _, dist in results[1:]:
        np.testing.assert_array_equal(dist, ref)


# ---------------------------------------------------------------------------
# plumbing
# ---------------------------------------------------------------------------

def test_next_pow2_buckets():
    assert [next_pow2(k) for k in (1, 2, 3, 4, 5, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 16]


def test_shared_engine_scoped_and_clearable():
    e1 = registry.shared_engine("jnp", scope=(None, 48))
    e2 = registry.shared_engine("jnp", scope=(None, 48))
    e3 = registry.shared_engine("jnp", scope=(None, 32))
    assert e1 is e2 and e1 is not e3
    registry.clear_shared_engines()
    assert registry.shared_engine("jnp", scope=(None, 48)) is not e1


def test_shared_engine_keys_by_device_mesh(monkeypatch):
    # PR 10 regression: mesh-bound engines (dist / dist_sharded) key the
    # shared-engine cache by the shard count they would resolve — a
    # pooled tenant must never be handed an engine whose mesh was built
    # for a different device set.  The device count is read through the
    # registry._device_count seam so the cache behaviour is testable on
    # a single-device host.
    monkeypatch.setattr(registry, "_device_count", lambda: 4)
    assert registry._mesh_token("dist", {}) == ("mesh", 4)
    monkeypatch.setattr(registry, "_device_count", lambda: 8)
    assert registry._mesh_token("dist", {}) == ("mesh", 8)
    # explicit options win over the process device count
    assert registry._mesh_token("dist", {"num_shards": 2}) == ("mesh", 2)
    assert registry._mesh_token("dist_sharded",
                                {"num_shards": 2}) == ("mesh", 2)
    assert registry._mesh_token("dist", {"devices": [0, 0, 0]}) \
        == ("mesh", 3)
    # non-mesh engines carry no token and never split on device count
    assert registry._mesh_token("jnp", {}) is None

    monkeypatch.setattr(registry, "_device_count", lambda: 4)
    d1 = registry.shared_engine("dist", scope=(None, 48))
    assert registry.shared_engine("dist", scope=(None, 48)) is d1
    j1 = registry.shared_engine("jnp", scope=(None, 48))
    monkeypatch.setattr(registry, "_device_count", lambda: 8)
    assert registry.shared_engine("dist", scope=(None, 48)) is not d1
    assert registry.shared_engine("jnp", scope=(None, 48)) is j1


def test_pool_validates_knobs():
    with pytest.raises(ValueError):
        SessionPool(batch_mode="magic")
    with pytest.raises(ValueError):
        SessionPool(overload="panic")
    with pytest.raises(ValueError):
        SessionPool(max_pending=0)
    csr = _graph(n=16, seed=1)
    pool = SessionPool(backend="jnp")
    pool.bind("a", csr)
    with pytest.raises(ValueError):
        pool.bind("a", csr)               # duplicate tenant


def test_stats_snapshot_is_jsonable():
    import json
    csr = _graph(n=16, seed=1)
    pool = SessionPool(backend="jnp")
    pool.bind("a", csr)
    pool.apply("a", random_updates(csr, 20, seed=1).batch(0, 4))
    s = pool.stats()
    json.dumps(s)                         # must not raise
    assert s["tenants"] == 1 and s["applied"] == 1
