"""ΔG admission guard: unit coverage + a fixed-seed adversarial fuzz
suite driving hostile update streams through every registered backend.

The fuzz invariant is the acceptance bar from DESIGN.md §6: whatever
garbage arrives, the session either applies a well-defined subset of it
(clamp: bad-id lanes masked; quarantine: whole poison batches
dead-lettered) and the final alive-edge state matches replaying exactly
that subset through an *unguarded* session on the same backend — or
raises the typed ``AdmissionError`` (reject) with only the clean prefix
applied.  The reference reimplements the guard's *dispositions* with
independent numpy rules, while engine semantics (duplicate lanes,
self-loops, re-adds) cancel out between the two sessions.
"""
import numpy as np
import pytest

import repro.api as api
from _hypothesis_compat import given, settings, st
from repro.core.engine import state_to_csr
from repro.graph import build_csr
from repro.graph.updates import UpdateStream
from repro.runtime.admission import (ADMISSION_POLICIES, AdmissionGuard,
                                     DeadLetterBuffer, batch_violations,
                                     sanitize_batch,
                                     stream_batch_violations)
from repro.runtime.errors import AdmissionError
from repro.runtime.health import SessionHealth

FAST_BACKENDS = ["jnp", "pallas", "frontier"]
SLOW_BACKENDS = ["dist", "pallas_chained"]
ALL_BACKENDS = (FAST_BACKENDS +
                [pytest.param(b, marks=pytest.mark.slow)
                 for b in SLOW_BACKENDS])


def _batch(adds, dels=None, bs=None):
    adds = np.asarray(adds, np.float64).reshape(-1, 3)
    dels = (np.zeros((0, 2), np.int64) if dels is None
            else np.asarray(dels, np.int64).reshape(-1, 2))
    bs = bs or max(len(adds), len(dels), 1)
    return UpdateStream(adds=adds, dels=dels).batch(0, bs)


# ---------------------------------------------------------------------------
# units
# ---------------------------------------------------------------------------

def test_policy_names_validated():
    assert set(ADMISSION_POLICIES) == {"reject", "clamp", "quarantine",
                                       "off"}
    with pytest.raises(ValueError):
        AdmissionGuard("shrug")
    assert AdmissionGuard(None).policy == "off"


def test_dead_letter_buffer_bounded():
    buf = DeadLetterBuffer(capacity=3)
    for i in range(8):
        buf.push(i)  # records are opaque to the buffer
    assert len(buf) == 3 and buf.total == 8 and buf.evicted == 5
    assert buf.records() == [5, 6, 7]


def test_batch_violation_kinds():
    n = 16
    # raw rows so NaN survives: ids out both ends, NaN weight, conflict
    stream = UpdateStream(
        adds=np.array([[99.0, 1, 1], [-2.0, 1, 1], [0, 1, np.nan],
                       [3, 4, 2]]),
        dels=np.array([[3, 4], [20, 20]], np.int64))
    kinds = {v.kind for v in stream_batch_violations(stream, 4, n)[0]}
    assert kinds == {"add_id_out_of_range", "del_id_out_of_range",
                     "weight_invalid", "add_del_conflict"}
    # batch-level sees the id violations and the conflict; the NaN was
    # int-cast to weight 1 by the batch view (why streams must be
    # inspected on the raw host arrays)
    bkinds = {v.kind for v in
              batch_violations(stream.batch(0, 4), n)}
    assert {"add_id_out_of_range", "del_id_out_of_range",
            "add_del_conflict"} <= bkinds
    assert "weight_invalid" not in bkinds


def test_batch_oversized_never_clamped():
    health = SessionHealth()
    guard = AdmissionGuard("clamp", max_batch=4, health=health)
    big = _batch([(0, 1, 1)] * 8)
    assert guard.admit(big, n=16) is None       # quarantined, not clamped
    assert health.quarantined == 1 and health.clamped == 0
    assert guard.buffer.records()[0].reasons[0].kind == "batch_oversized"


def test_sanitize_preserves_valid_lanes_bit_exact():
    n = 16
    b = _batch([(1, 2, 7), (99, 3, 1), (4, -5, 2), (6, 7, 9)],
               [(1, 2), (77, 0)])
    s = sanitize_batch(b, n)
    np.testing.assert_array_equal(np.asarray(s.add_mask),
                                  [True, False, False, True])
    np.testing.assert_array_equal(np.asarray(s.del_mask),
                                  [True, False, False, False])
    keep = np.asarray(s.add_mask)
    for f in ("add_src", "add_dst", "add_w"):
        np.testing.assert_array_equal(np.asarray(getattr(s, f))[keep],
                                      np.asarray(getattr(b, f))[keep])
    assert not np.asarray(s.add_src)[~keep].any(), "dead lanes zeroed"


def test_conflict_only_batch_admitted_untouched_under_clamp():
    health = SessionHealth()
    guard = AdmissionGuard("clamp", health=health)
    b = _batch([(1, 2, 7)], [(1, 2)])
    out = guard.admit(b, n=16)
    assert out is b, "conflict-only batch must pass through unchanged"
    assert health.conflicts == 1 and health.admitted == 1
    assert health.clamped == 0

    # ...but the strict policies treat it as a violation like any other
    strict = AdmissionGuard("reject")
    with pytest.raises(AdmissionError) as ei:
        strict.admit(b, n=16)
    assert any(r.kind == "add_del_conflict" for r in ei.value.reasons)


def test_stream_and_batch_agree_on_id_violations():
    n = 12
    rng = np.random.default_rng(3)
    adds = rng.integers(-4, n + 4, size=(24, 3)).astype(np.float64)
    adds[:, 2] = np.abs(adds[:, 2]) + 1
    dels = rng.integers(-4, n + 4, size=(10, 2)).astype(np.int64)
    stream = UpdateStream(adds=adds, dels=dels)
    per = stream_batch_violations(stream, 4, n)
    for i in range(stream.num_batches(4)):
        bkinds = {v.kind: v.count for v in
                  batch_violations(stream.batch(i, 4), n)}
        skinds = {v.kind: v.count for v in per.get(i, [])}
        for kind in ("add_id_out_of_range", "del_id_out_of_range"):
            assert bkinds.get(kind, 0) == skinds.get(kind, 0), \
                f"batch {i}: stream/batch disagree on {kind}"


# ---------------------------------------------------------------------------
# fixed-seed adversarial fuzz through every registered backend
# ---------------------------------------------------------------------------

def _base_graph(rng, n):
    e = rng.integers(0, n, size=(3 * n, 2)).astype(np.int64)
    e = np.unique(e[e[:, 0] != e[:, 1]], axis=0)
    w = rng.integers(1, 9, size=e.shape[0]).astype(np.int32)
    return build_csr(n, e, w)


def _hostile_stream(rng, n, nb, bs, weight_poison=True):
    """~40% hostile lanes: ids out both ends, duplicate lanes, and
    (optionally) NaN/Inf/negative raw weights."""
    adds, dels = [], []
    for _ in range(nb * bs):
        roll = rng.random()
        if roll < 0.55:                         # clean add
            u, v = rng.integers(0, n, 2)
            adds.append((float(u), float(v), float(rng.integers(1, 9))))
        elif roll < 0.72:                       # bad ids
            adds.append((float(rng.integers(n, n + 9)),
                         float(rng.integers(-6, n)), 1.0))
        elif roll < 0.82 and weight_poison:     # bad weight, valid ids
            u, v = rng.integers(0, n, 2)
            adds.append((float(u), float(v),
                         float(rng.choice([np.nan, np.inf, -3.0]))))
        else:                                   # duplicate of an earlier lane
            adds.append(adds[rng.integers(0, len(adds))] if adds
                        else (0.0, 1.0, 1.0))
    for _ in range(nb * bs // 2):
        if rng.random() < 0.7:
            u, v = rng.integers(0, n, 2)
            dels.append((int(u), int(v)))
        else:
            dels.append((int(rng.integers(-5, 0)),
                         int(rng.integers(0, n))))
    return UpdateStream(adds=np.asarray(adds, np.float64).reshape(-1, 3),
                        dels=np.asarray(dels, np.int64).reshape(-1, 2))


def _lane_ok(src, dst, mask, n):
    return mask & (src >= 0) & (src < n) & (dst >= 0) & (dst < n)


def _expected_batches(stream, bs, n, policy):
    """Reference dispositions, written against the CONTRACT (not the
    guard's code): per batch, out-of-range ids are the poison, conflicts
    block only the strict policies, raw-weight poison is invisible at
    batch level (the batch view already repaired it — identically for
    guarded and unguarded sessions).  Returns (batches_to_apply,
    first_rejected_index_or_None)."""
    out = []
    for i in range(stream.num_batches(bs)):
        b = stream.batch(i, bs)
        a_src, a_dst = np.asarray(b.add_src), np.asarray(b.add_dst)
        am, dm = np.asarray(b.add_mask), np.asarray(b.del_mask)
        d_src, d_dst = np.asarray(b.del_src), np.asarray(b.del_dst)
        a_ok = _lane_ok(a_src, a_dst, am, n)
        d_ok = _lane_ok(d_src, d_dst, dm, n)
        bad_ids = bool((am & ~a_ok).any() or (dm & ~d_ok).any())
        conflict = bool(
            {(int(u), int(v)) for u, v in zip(a_src[a_ok], a_dst[a_ok])}
            & {(int(u), int(v)) for u, v in zip(d_src[d_ok], d_dst[d_ok])})
        if policy == "reject" and (bad_ids or conflict):
            return out, i
        if policy == "quarantine" and (bad_ids or conflict):
            continue
        out.append(sanitize_batch(b, n) if (policy == "clamp" and bad_ids)
                   else b)
    return out, None


def _alive_edges(sess):
    import jax
    tree, meta = sess.engine.pack_state(sess.handle)
    tree = jax.tree_util.tree_map(np.asarray, tree)
    c, _ = state_to_csr(tree, meta)
    return sorted(zip(np.asarray(c.src).tolist(),
                      np.asarray(c.dst).tolist(),
                      np.asarray(c.w).tolist()))


def _replay(csr, backend, batches):
    ref = api.bind_graph(csr, backend=backend, admission="off")
    for b in batches:
        ref.apply(b)
    return _alive_edges(ref)


@pytest.mark.parametrize("backend", ALL_BACKENDS)
@pytest.mark.parametrize("policy", ["clamp", "quarantine"])
@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_fuzz_hostile_stream_policy_exact(backend, policy, seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 24))
    bs = int(rng.integers(2, 6))
    nb = int(rng.integers(2, 5))
    csr = _base_graph(rng, n)
    stream = _hostile_stream(rng, n, nb, bs)

    sess = api.bind_graph(csr, backend=backend, admission=policy)
    for i in range(nb):
        sess.apply(stream.batch(i, bs))

    want_batches, _ = _expected_batches(stream, bs, n, policy)
    assert _alive_edges(sess) == _replay(csr, backend, want_batches), \
        f"seed={seed} n={n} bs={bs} nb={nb}"
    h = sess.health
    assert h.admitted + h.quarantined == nb and h.rejected == 0
    assert h.quarantined == nb - len(want_batches)
    assert len(sess.dead_letter) == h.quarantined
    assert sess.stream_cursor == nb


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_fuzz_reject_applies_only_clean_prefix(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 24))
    bs, nb = 4, 4
    csr = _base_graph(rng, n)
    stream = _hostile_stream(rng, n, nb, bs)

    sess = api.bind_graph(csr, backend="jnp", admission="reject")
    prefix, first = _expected_batches(stream, bs, n, "reject")
    if first is None:
        for i in range(nb):
            sess.apply(stream.batch(i, bs))
        assert sess.health.rejected == 0
    else:
        with pytest.raises(AdmissionError) as ei:
            for i in range(nb):
                sess.apply(stream.batch(i, bs))
        assert ei.value.reasons, "machine-readable reasons required"
        assert sess.health.rejected == 1
        assert sess.stream_cursor == first, \
            "rejected batch must not advance the cursor"
    assert _alive_edges(sess) == _replay(csr, "jnp", prefix)


# ---------------------------------------------------------------------------
# stream-level admission: the fused splice path matches per-batch applies,
# and raw-array weight validation catches what batch views cannot
# ---------------------------------------------------------------------------

def _step(view, h, batch, carry):
    h = view.update_del(h, batch)
    h = view.update_add(h, batch)
    return h, carry


@pytest.mark.parametrize("backend", ALL_BACKENDS)
@pytest.mark.parametrize("policy", ["clamp", "quarantine"])
def test_stream_splice_matches_per_batch_applies(backend, policy):
    # id-poison only: raw-weight poison is (by design) visible to the
    # stream pass but not the batch pass, so the two paths would
    # legitimately diverge on it under quarantine
    rng = np.random.default_rng(11)
    n, bs, nb = 20, 4, 4
    csr = _base_graph(rng, n)
    stream = _hostile_stream(rng, n, nb, bs, weight_poison=False)

    a = api.bind_graph(csr, backend=backend, admission=policy)
    a.run_stream(stream, bs, _step, None)

    b = api.bind_graph(csr, backend=backend, admission=policy)
    for i in range(nb):
        b.apply(stream.batch(i, bs))

    assert _alive_edges(a) == _alive_edges(b)
    assert a.health.quarantined == b.health.quarantined
    assert a.stream_cursor == b.stream_cursor == nb


@pytest.mark.parametrize("backend", ALL_BACKENDS)
@pytest.mark.parametrize("policy", ["reject", "clamp", "quarantine"])
def test_zero_length_batch_is_a_counted_noop(backend, policy):
    csr = _base_graph(np.random.default_rng(5), 10)
    empty = UpdateStream(adds=np.zeros((0, 3)),
                         dels=np.zeros((0, 2), np.int64)).batch(0, 4)
    sess = api.bind_graph(csr, backend=backend, admission=policy)
    before = _alive_edges(sess)
    sess.apply(empty)                  # all lanes masked: no device work
    assert _alive_edges(sess) == before
    assert sess.health.empty_skipped == 1
    assert sess.health.rejected == 0 and sess.health.quarantined == 0
    assert sess.stream_cursor == 1


def test_stream_quarantine_catches_raw_weight_poison():
    # batch 1 is poisoned ONLY through raw NaN/negative weights — the
    # padded batch view int-casts them to weight 1, so only the raw-array
    # stream pass can see them; quarantine must drop the whole batch
    n, bs = 12, 3
    csr = _base_graph(np.random.default_rng(0), n)
    adds = np.array([[0, 1, 2], [1, 2, 3], [2, 3, 4],
                     [3, 4, np.nan], [4, 5, -7.0], [5, 6, np.inf],
                     [6, 7, 5], [7, 8, 6], [8, 9, 7]], np.float64)
    stream = UpdateStream(adds=adds, dels=np.zeros((0, 2), np.int64))

    sess = api.bind_graph(csr, backend="jnp", admission="quarantine")
    sess.run_stream(stream, bs, _step, None)
    assert sess.health.quarantined == 1
    rec = sess.dead_letter[0]
    assert rec.index == 1
    assert {r.kind for r in rec.reasons} == {"weight_invalid"}

    want = _replay(csr, "jnp", [stream.batch(0, bs), stream.batch(2, bs)])
    assert _alive_edges(sess) == want

    # ...and the batch-level path admits the same batch (weights were
    # already repaired to 1 by the view): documents the layering contract
    b2 = api.bind_graph(csr, backend="jnp", admission="quarantine")
    for i in range(3):
        b2.apply(stream.batch(i, bs))
    assert b2.health.quarantined == 0
