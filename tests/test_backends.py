"""Backend equivalence: the same algorithm spec must produce identical
results on all three lowerings (the paper's core claim, §4).

DistEngine runs in-process over however many devices exist (1 on plain
CPU); an 8-virtual-device sweep runs in a subprocess since jax locks the
device count at first init.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from conftest import random_digraph, random_symgraph, sym_stream
from repro.graph import random_updates
from repro.core.engine import JnpEngine
from repro.core.dist import DistEngine
from repro.core.pallas_engine import PallasEngine
from repro.core.frontier_engine import FrontierEngine
from repro.algos import sssp, pagerank, triangles, oracles

# shard_map tracing makes the dist cells ~2min each on CPU; they run in
# the full lane, while conformance keeps a fast dist cell per program.
ENGINES = [JnpEngine,
           pytest.param(DistEngine, marks=pytest.mark.slow),
           PallasEngine, FrontierEngine]


@pytest.mark.parametrize("engine_cls", ENGINES, ids=lambda c: c.name)
def test_sssp_all_backends(engine_cls):
    n, csr, edges, w = random_digraph(seed=11)
    eng = engine_cls()
    g = eng.prepare(csr, diff_capacity=64)
    ups = random_updates(csr, percent=15, seed=2)
    _, props = sssp.dyn_sssp(eng, g, 0, ups, batch_size=8)
    e2, w2 = oracles.edges_after_updates(n, edges, w, ups.adds, ups.dels)
    ref = oracles.sssp_oracle(n, e2, w2, 0)
    got = np.minimum(np.asarray(props["dist"]).astype(np.int64)[:n],
                     oracles.INF)
    assert np.array_equal(got, ref)


@pytest.mark.parametrize("engine_cls", ENGINES, ids=lambda c: c.name)
def test_pr_all_backends(engine_cls):
    n, csr, edges, w = random_digraph(seed=12)
    eng = engine_cls()
    g = eng.prepare(csr, diff_capacity=64)
    ups = random_updates(csr, percent=10, seed=3)
    _, props = pagerank.dyn_pr(eng, g, ups, batch_size=8)
    e2, _ = oracles.edges_after_updates(n, edges, w, ups.adds, ups.dels)
    ref = oracles.pagerank_oracle(n, e2)
    np.testing.assert_allclose(np.asarray(props["pr"])[:n], ref,
                               rtol=5e-2, atol=1e-4)


@pytest.mark.parametrize("engine_cls", ENGINES, ids=lambda c: c.name)
def test_tc_all_backends(engine_cls):
    n, csr, edges = random_symgraph(seed=4)
    eng = engine_cls()
    g = eng.prepare(csr, diff_capacity=256)
    ups = sym_stream(csr, percent=15, seed=6)
    _, c = triangles.dyn_tc(eng, g, ups, batch_size=16)
    e2, _ = oracles.edges_after_updates(
        n, edges, np.ones(len(edges), np.int32), ups.adds, ups.dels)
    assert int(c) == oracles.tc_oracle(n, e2)


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, sys.argv[1]); sys.path.insert(0, sys.argv[2])
    import numpy as np
    from conftest import random_digraph, random_symgraph, sym_stream
    from repro.graph import random_updates
    from repro.core.dist import DistEngine
    from repro.algos import sssp, pagerank, triangles, oracles
    import jax
    assert len(jax.devices()) == 8

    n, csr, edges, w = random_digraph(seed=21)
    eng = DistEngine()
    assert eng.P == 8
    g = eng.prepare(csr, diff_capacity=64)
    ups = random_updates(csr, percent=15, seed=2)
    _, props = sssp.dyn_sssp(eng, g, 0, ups, batch_size=8)
    e2, w2 = oracles.edges_after_updates(n, edges, w, ups.adds, ups.dels)
    ref = oracles.sssp_oracle(n, e2, w2, 0)
    got = np.minimum(np.asarray(props["dist"]).astype(np.int64)[:n],
                     oracles.INF)
    assert np.array_equal(got, ref), "dist-8dev SSSP mismatch"

    n, csr, edges = random_symgraph(seed=4)
    eng = DistEngine()
    g = eng.prepare(csr, diff_capacity=256)
    ups = sym_stream(csr, percent=15, seed=6)
    _, c = triangles.dyn_tc(eng, g, ups, batch_size=16)
    e2, _ = oracles.edges_after_updates(
        n, edges, np.ones(len(edges), np.int32), ups.adds, ups.dels)
    assert int(c) == oracles.tc_oracle(n, e2), "dist-8dev TC mismatch"
    print("8DEV-OK")
""")


@pytest.mark.slow
def test_dist_8_virtual_devices(tmp_path):
    import pathlib
    here = pathlib.Path(__file__).resolve()
    src = str(here.parents[1] / "src")
    script = tmp_path / "run8.py"
    script.write_text(_SUBPROC)
    r = subprocess.run(
        [sys.executable, str(script), src, str(here.parent)],
        capture_output=True, text=True, timeout=1200,
        env={**os.environ, "JAX_PLATFORMS": "cpu"})
    assert "8DEV-OK" in r.stdout, r.stdout + r.stderr
