"""Crash-injection suite for the checkpoint commit protocol.

The protocol (DESIGN.md §5) claims: a kill at ANY point during
``ckpt.save`` leaves either the previous committed step or the new one
fully restorable — never a COMMITTED step with missing or truncated
payloads.  These tests simulate the kill at each commit-protocol
boundary via the ``ckpt._crash_point`` seam ("shard" = after the shard
npz is durable, "manifest" = after the manifest, "committed" = after
the marker but before the rename, "renamed" = after the rename but
before gc) and assert ``latest_step`` always names a restorable step.
"""
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt

POINTS = ["shard", "manifest", "committed", "renamed"]


class _Kill(Exception):
    """Simulated preemption."""


def _tree(step):
    return {"a": jnp.arange(4, dtype=jnp.int32) + step,
            "b": jnp.full((2, 3), float(step), jnp.float32)}


def _save(d, step, point=None):
    """Save step; if `point` is given, die at that protocol boundary."""
    if point is None:
        ckpt.save(d, step, _tree(step), extra={"cursor": step})
        return

    def boom(p):
        if p == point:
            raise _Kill(p)

    ckpt._crash_point = boom
    try:
        with pytest.raises(_Kill):
            ckpt.save(d, step, _tree(step), extra={"cursor": step})
    finally:
        ckpt._crash_point = None


def _assert_restorable(d, step):
    """The step must restore completely, values intact."""
    tree, extra = ckpt.restore(d, step, _tree(0))
    np.testing.assert_array_equal(np.asarray(tree["a"]),
                                  np.arange(4, dtype=np.int32) + step)
    np.testing.assert_array_equal(np.asarray(tree["b"]),
                                  np.full((2, 3), float(step), np.float32))
    assert extra["cursor"] == step
    assert ckpt.read_manifest(d, step)["extra"]["cursor"] == step


@pytest.mark.parametrize("point", POINTS)
def test_crash_keeps_previous_step_restorable(point, tmp_path):
    _save(tmp_path, 1)
    _save(tmp_path, 2, point=point)
    latest = ckpt.latest_step(tmp_path)
    # the rename is the commit: before it the new step is invisible,
    # after it the new step is the one restarts resume from
    assert latest == (2 if point == "renamed" else 1)
    _assert_restorable(tmp_path, latest)


@pytest.mark.parametrize("point", POINTS)
def test_crash_on_first_checkpoint(point, tmp_path):
    _save(tmp_path, 1, point=point)
    latest = ckpt.latest_step(tmp_path)
    if point == "renamed":
        assert latest == 1
        _assert_restorable(tmp_path, 1)
    else:
        assert latest is None


@pytest.mark.parametrize("point", POINTS)
def test_retry_after_crash_commits(point, tmp_path):
    """An elastic restart re-saves the same step after restoring: the
    leftover tmp (or already-renamed dir) must not wedge the retry."""
    _save(tmp_path, 1)
    _save(tmp_path, 2, point=point)
    _save(tmp_path, 2)                 # clean retry
    assert ckpt.latest_step(tmp_path) == 2
    _assert_restorable(tmp_path, 2)
    # retry's gc swept the crashed attempt's tmp dir
    assert not list(tmp_path.glob("*.tmp"))


def test_marker_written_only_after_payloads(tmp_path):
    """Record tmp-dir contents at each boundary: COMMITTED must not
    exist until both the shard and the manifest are durable."""
    seen = {}

    def probe(p):
        tmp = tmp_path / "step_00000001.tmp"
        seen[p] = {f.name for f in tmp.iterdir()} if tmp.exists() else None

    ckpt._crash_point = probe
    try:
        ckpt.save(tmp_path, 1, _tree(1))
    finally:
        ckpt._crash_point = None
    assert "COMMITTED" not in seen["shard"]
    assert "shard_0.npz" in seen["shard"]
    assert "COMMITTED" not in seen["manifest"]
    assert {"shard_0.npz", "manifest.json"} <= seen["manifest"]
    assert {"shard_0.npz", "manifest.json", "COMMITTED"} <= seen["committed"]
    assert seen["renamed"] is None     # tmp is gone once renamed


def test_unrenamed_tmp_with_marker_is_not_committed(tmp_path):
    """The crash-at-'committed' state: step_N.tmp contains COMMITTED but
    was never renamed.  latest_step must neither count it nor crash on
    its unparseable name, and read_manifest must refuse the step."""
    _save(tmp_path, 1)
    _save(tmp_path, 2, point="committed")
    tmp = tmp_path / "step_00000002.tmp"
    assert tmp.is_dir() and (tmp / "COMMITTED").exists()
    assert ckpt.latest_step(tmp_path) == 1
    with pytest.raises(FileNotFoundError):
        ckpt.read_manifest(tmp_path, 2)


def test_latest_step_ignores_marker_less_dirs(tmp_path):
    _save(tmp_path, 3)
    bare = tmp_path / "step_00000007"
    bare.mkdir()
    (bare / "manifest.json").write_text("{}")
    assert ckpt.latest_step(tmp_path) == 3
    with pytest.raises(FileNotFoundError):
        ckpt.read_manifest(tmp_path, 7)


def test_gc_keep_zero_prunes_everything(tmp_path):
    for s in (1, 2, 3):
        _save(tmp_path, s)
    ckpt._gc(tmp_path, keep=0)
    assert ckpt.latest_step(tmp_path) is None
    assert not list(tmp_path.glob("step_*"))


def test_save_keeps_last_k_steps(tmp_path):
    for s in range(1, 6):
        ckpt.save(tmp_path, s, _tree(s), extra={"cursor": s}, keep=3)
    steps = sorted(int(d.name.split("_")[1])
                   for d in tmp_path.glob("step_*"))
    assert steps == [3, 4, 5]
    _assert_restorable(tmp_path, 5)


def test_gc_after_crash_before_gc(tmp_path):
    """'renamed' kills save after commit but before gc: stale steps
    linger, and the *next* successful save sweeps them."""
    for s in (1, 2):
        ckpt.save(tmp_path, s, _tree(s), keep=2)
    _save(tmp_path, 3, point="renamed")
    assert ckpt.latest_step(tmp_path) == 3
    ckpt.save(tmp_path, 4, _tree(4), keep=2)
    steps = sorted(int(d.name.split("_")[1])
                   for d in tmp_path.glob("step_*"))
    assert steps == [3, 4]


def test_restore_closes_shard_file(tmp_path):
    """restore() must not leak the NpzFile's zip descriptor — a
    long-lived elastic session restores many times from one pool."""
    _save(tmp_path, 1)
    ckpt.restore(tmp_path, 1, _tree(0))
    shard = os.path.realpath(tmp_path / "step_00000001" / "shard_0.npz")
    open_fds = []
    for fd in os.listdir("/proc/self/fd"):
        try:
            if os.path.realpath(f"/proc/self/fd/{fd}") == shard:
                open_fds.append(fd)
        except OSError:
            pass
    assert not open_fds, f"shard npz still open after restore: {open_fds}"
