"""Durable + elastic sessions: save/restore roundtrips through the
public api surface, the ``run_elastic_session`` tear-down → restore
loop, and the dist backend's elastic re-mesh (restore onto a different
device count, in a subprocess since jax pins the host device count at
first init).

The cross-backend and per-backend bit-exactness cells live in
test_conformance.py; this file covers the session *mechanics*: cursor
bookkeeping, armed-frame serialization, step selection, engine-option
guards, and the elastic retry loop.
"""
import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax

import repro.api as api
from repro.algos import oracles, sssp as hand_sssp
from repro.ckpt import checkpoint as ckpt
from repro.dsl_programs import path as program_path
from repro.graph import build_csr, random_updates
from repro.launch.elastic import run_elastic_session

from conftest import random_digraph


def _scenario(batch_size=4):
    n, csr, edges, w = random_digraph(n=40, deg=4, seed=21, max_w=60)
    stream = random_updates(csr, percent=15, seed=8)
    return n, csr, edges, w, stream, list(stream.batches(batch_size))


# ---------------------------------------------------------------------------
# GraphSession (hand-staged) roundtrip
# ---------------------------------------------------------------------------

def test_graphsession_roundtrip_bit_exact(tmp_path):
    n, csr, _, _, stream, batches = _scenario()
    sess = api.bind_graph(csr, backend="jnp", capacity=64)
    props0 = sess.call(hand_sssp.static_sssp, 0)
    sess.run_stream(stream, 4, hand_sssp.stream_step, props0)
    assert sess.stream_cursor == len(batches)
    sess.save(tmp_path)

    res = api.restore_session(tmp_path)
    assert type(res) is api.GraphSession      # no program in the manifest
    assert res.stream_cursor == len(batches)
    np.testing.assert_array_equal(np.asarray(res.props.host("dist")),
                                  np.asarray(sess.props.host("dist")))
    # the resident handle itself roundtrips bit-exactly, pool layout and
    # tombstones included
    t1, m1 = sess._engine.pack_state(sess._handle)
    t2, m2 = res._engine.pack_state(res._handle)
    assert m1 == m2
    l1 = jax.tree_util.tree_leaves(t1)
    l2 = jax.tree_util.tree_leaves(t2)
    assert len(l1) == len(l2)
    for a, b in zip(l1, l2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_explicit_and_latest_step(tmp_path):
    _, csr, _, _, _, batches = _scenario()
    sess = api.bind_graph(csr, backend="jnp", capacity=64)
    sess.call(hand_sssp.static_sssp, 0)
    sess.apply(batches[0])
    sess.save(tmp_path, keep=5)               # step 1
    sess.apply(batches[1])
    sess.save(tmp_path, keep=5)               # step 2
    assert ckpt.latest_step(tmp_path) == 2

    old = api.restore_session(tmp_path, step=1)
    assert old.stream_cursor == 1
    new = api.restore_session(tmp_path)
    assert new.stream_cursor == 2


def test_restore_empty_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        api.restore_session(tmp_path)


def test_pallas_block_mismatch_refused(tmp_path):
    """Raw ELL leaves are only valid at the k they were packed with —
    restoring onto a pallas engine with a different k must fail loudly,
    not silently mis-index lanes."""
    _, csr, _, _, _, batches = _scenario()
    sess = api.bind_graph(csr, backend="pallas", capacity=64)
    sess.call(hand_sssp.static_sssp, 0)
    sess.apply(batches[0])
    sess.save(tmp_path)
    with pytest.raises(ValueError, match="k"):
        api.restore_session(tmp_path, backend="pallas", k=16)


# ---------------------------------------------------------------------------
# Armed Session: epilogue value + program identity
# ---------------------------------------------------------------------------

def test_armed_restore_preserves_epilogue_value(tmp_path):
    """DynTC returns its count from the epilogue: a restored armed
    session must evaluate .value exactly like the uninterrupted one."""
    from conformance import sym_scenario
    sc = sym_scenario("sym_batch16")
    csr = build_csr(sc.n, sc.edges, sc.w)
    batches = list(sc.stream.batches(sc.batch_size))
    k = max(1, len(batches) // 2)

    ref = api.compile(program_path("tc")).bind(
        csr, backend="jnp", capacity=sc.diff_capacity)
    ref.run("DynTC", batchSize=sc.batch_size)
    for b in batches:
        ref.apply(b)
    want = int(ref.value)

    sess = api.compile(program_path("tc")).bind(
        csr, backend="jnp", capacity=sc.diff_capacity)
    sess.run("DynTC", batchSize=sc.batch_size)
    for b in batches[:k]:
        sess.apply(b)
    sess.save(tmp_path)
    del sess

    res = api.restore_session(tmp_path)
    assert isinstance(res, api.Session) and res.armed
    for b in batches[k:]:
        res.apply(b)
    assert int(res.value) == want
    e2, _ = oracles.edges_after_updates(sc.n, sc.edges, sc.w,
                                        sc.stream.adds, sc.stream.dels)
    assert want == oracles.tc_oracle(sc.n, e2)


def test_armed_restore_after_single_batch(tmp_path):
    """Kill-after-first-batch: the deserialized frame must carry the
    armed batchSize and per-vertex props so the remaining applies land
    on the oracle."""
    n, csr, edges, w, stream, batches = _scenario()
    sess = api.compile(program_path("sssp")).bind(csr, backend="jnp",
                                                  capacity=64)
    sess.run("DynSSSP", batchSize=4, src=0)
    for b in batches[:1]:
        sess.apply(b)
    sess.save(tmp_path)
    del sess

    res = api.restore_session(tmp_path)
    for b in batches[res.stream_cursor:]:
        res.apply(b)
    e2, w2 = oracles.edges_after_updates(n, edges, w, stream.adds,
                                         stream.dels)
    ref = oracles.sssp_oracle(n, e2, w2, 0)
    got = np.minimum(
        np.asarray(res.props.host("dist")).astype(np.int64), oracles.INF)
    np.testing.assert_array_equal(got, ref)


# ---------------------------------------------------------------------------
# Elastic loop: injected preemption mid-stream, restore, finish
# ---------------------------------------------------------------------------

def test_run_elastic_session_resumes_bit_exact(tmp_path):
    n, csr, edges, w, stream, batches = _scenario()

    ref_sess = api.compile(program_path("sssp")).bind(csr, backend="jnp",
                                                      capacity=64)
    ref_sess.run("DynSSSP", batchSize=4, src=0)
    for b in batches:
        ref_sess.apply(b)
    ref = np.asarray(ref_sess.props.host("dist"))

    crash = {"armed": True}

    def make_session(attempt):
        if attempt == 0:
            s = api.compile(program_path("sssp")).bind(
                csr, backend="jnp", capacity=64)
            s.run("DynSSSP", batchSize=4, src=0)
            return s
        return api.restore_session(tmp_path)

    def work(sess):
        for i, b in enumerate(batches):
            if i < sess.stream_cursor:
                continue               # already applied before the kill
            sess.apply(b)
            sess.save(tmp_path)
            if i == 1 and crash["armed"]:
                crash["armed"] = False
                raise RuntimeError("injected preemption")
        return np.asarray(sess.props.host("dist"))

    got = run_elastic_session(make_session, work, max_restarts=2)
    assert not crash["armed"], "fault injection never fired"
    np.testing.assert_array_equal(got, ref)


def test_run_elastic_session_gives_up(tmp_path):
    def make_session(attempt):
        return object()

    def work(sess):
        raise RuntimeError("permanent failure")

    with pytest.raises(RuntimeError, match="permanent"):
        run_elastic_session(make_session, work, max_restarts=2)


# ---------------------------------------------------------------------------
# Elastic re-mesh: dist save on P=4, restore on P=2 (different device
# count) — subprocess so the 8-virtual-device jax init stays isolated
# ---------------------------------------------------------------------------

_REMESH_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, sys.argv[1]); sys.path.insert(0, sys.argv[2])
    import numpy as np
    from conftest import random_digraph
    import repro.api as api
    from repro.dsl_programs import path as program_path
    from repro.graph import random_updates
    from repro.algos import oracles

    ckpt_dir = sys.argv[3]
    n, csr, edges, w = random_digraph(n=48, deg=4, seed=33, max_w=60)
    stream = random_updates(csr, percent=15, seed=11)
    batches = list(stream.batches(8))
    k = max(1, len(batches) // 2)

    # uninterrupted single-backend reference on jnp
    ref = api.compile(program_path("sssp")).bind(csr, backend="jnp",
                                                 capacity=64)
    ref.run("DynSSSP", batchSize=8, src=0)
    for b in batches:
        ref.apply(b)
    want = np.asarray(ref.props.host("dist"))

    # save armed mid-stream on a 4-shard mesh
    sess = api.compile(program_path("sssp")).bind(
        csr, backend="dist", capacity=64, num_shards=4)
    sess.run("DynSSSP", batchSize=8, src=0)
    for b in batches[:k]:
        sess.apply(b)
    sess.save(ckpt_dir)
    del sess

    # "two hosts died": restore onto a 2-shard mesh and finish
    res = api.restore_session(ckpt_dir, backend="dist", num_shards=2)
    assert res.armed and res.stream_cursor == k
    for b in batches[k:]:
        res.apply(b)
    got = np.asarray(res.props.host("dist"))
    np.testing.assert_array_equal(got, want)

    e2, w2 = oracles.edges_after_updates(n, edges, w, stream.adds,
                                         stream.dels)
    np.testing.assert_array_equal(
        np.minimum(got.astype(np.int64), oracles.INF),
        oracles.sssp_oracle(n, e2, w2, 0))
    print("REMESH-OK")
""")


@pytest.mark.slow
def test_dist_elastic_remesh_4_to_2(tmp_path):
    here = pathlib.Path(__file__).resolve()
    src = str(here.parents[1] / "src")
    script = tmp_path / "remesh.py"
    script.write_text(_REMESH_SUBPROC)
    r = subprocess.run(
        [sys.executable, str(script), src, str(here.parent),
         str(tmp_path / "ckpt")],
        capture_output=True, text=True, timeout=1200,
        env={**os.environ, "JAX_PLATFORMS": "cpu"})
    assert "REMESH-OK" in r.stdout, r.stdout + r.stderr
