"""diff-CSR substrate: unit + hypothesis property tests.

Property: any sequence of add/delete batches applied to a DynGraph equals
a python dict-of-sets model of the same edge multiset.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.graph import (build_csr, from_csr, update_csr_add, update_csr_del,
                         merge, is_edge, edge_weight)
from repro.graph.csr import row_searchsorted
from repro.graph.diffcsr import DynGraph


def test_fig6_example():
    """The paper's Figure 6 walk-through."""
    edges = [(0, 1), (1, 2), (1, 3), (2, 0), (3, 4), (4, 5), (5, 3)]
    g = from_csr(build_csr(6, np.array(edges)), diff_capacity=4)
    g = update_csr_del(g, jnp.array([1]), jnp.array([3]))      # B->D deleted
    assert not bool(is_edge(g, 1, 3))
    g = update_csr_add(g, jnp.array([4]), jnp.array([2]))      # E->C added
    assert bool(is_edge(g, 4, 2))
    # vacant-slot revival: re-adding B->D reuses its tombstoned slot
    before_diff = int(jnp.sum(g.d_src < g.n))
    g = update_csr_add(g, jnp.array([1]), jnp.array([3]), jnp.array([9]))
    assert bool(is_edge(g, 1, 3)) and int(edge_weight(g, 1, 3)) == 9
    assert int(jnp.sum(g.d_src < g.n)) == before_diff  # no diff growth
    assert g.out_degrees().tolist() == [1, 2, 1, 1, 2, 1]


def test_overflow_counter():
    g = from_csr(build_csr(4, np.array([(0, 1)])), diff_capacity=2)
    g = update_csr_add(g, jnp.array([0, 0, 0, 1]), jnp.array([2, 3, 1, 0]))
    # 0->1 revives in main; 0->2, 0->3 fill diff; 1->0 overflows
    assert int(g.overflow) == 1
    gm = merge(g, diff_capacity=8)
    assert int(gm.overflow) == 0
    for u, v in [(0, 1), (0, 2), (0, 3)]:
        assert bool(is_edge(gm, u, v))
    assert not bool(is_edge(gm, 1, 0))  # dropped by capacity, as declared


def test_row_searchsorted():
    vals = jnp.array([1, 3, 5, 2, 2, 9], jnp.int32)  # rows [0,3) and [3,6)
    lo = jnp.array([0, 3, 3], jnp.int32)
    hi = jnp.array([3, 6, 6], jnp.int32)
    q = jnp.array([3, 2, 10], jnp.int32)
    out = row_searchsorted(vals, lo, hi, q)
    assert out.tolist() == [1, 3, 6]


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_updates_match_model(data):
    n = data.draw(st.integers(4, 20))
    rng = np.random.default_rng(data.draw(st.integers(0, 10_000)))
    m = data.draw(st.integers(0, 40))
    edges = rng.integers(0, n, size=(m, 2))
    edges = edges[edges[:, 0] != edges[:, 1]]
    csr = build_csr(n, edges)
    model = set(map(tuple, np.stack(
        [np.asarray(csr.src), np.asarray(csr.dst)], 1).tolist())) \
        if csr.num_edges else set()
    g = from_csr(csr, diff_capacity=64)

    for _ in range(data.draw(st.integers(1, 4))):
        k = data.draw(st.integers(1, 6))
        adds = rng.integers(0, n, size=(k, 2))
        adds = adds[adds[:, 0] != adds[:, 1]]
        dels_pool = list(model) or [(0, 1)]
        didx = rng.integers(0, len(dels_pool), size=k)
        dels = np.array([dels_pool[i] for i in didx])
        if len(dels):
            g = update_csr_del(g, jnp.asarray(dels[:, 0], jnp.int32),
                               jnp.asarray(dels[:, 1], jnp.int32))
            model -= set(map(tuple, dels.tolist()))
        if len(adds):
            g = update_csr_add(g, jnp.asarray(adds[:, 0], jnp.int32),
                               jnp.asarray(adds[:, 1], jnp.int32))
            model |= set(map(tuple, adds.tolist()))

    assert int(g.overflow) == 0
    # full membership check against the model
    qs, qd = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    got = np.asarray(is_edge(g, qs.ravel(), qd.ravel())).reshape(n, n)
    want = np.zeros((n, n), bool)
    for u, v in model:
        want[u, v] = True
    assert np.array_equal(got, want)
    # degrees
    deg = np.asarray(g.out_degrees())
    wdeg = want.sum(1)
    assert np.array_equal(deg, wdeg)
    # merge preserves the edge set
    gm = merge(g)
    got2 = np.asarray(is_edge(gm, qs.ravel(), qd.ravel())).reshape(n, n)
    assert np.array_equal(got2, want)
