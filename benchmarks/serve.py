"""Serving-pool benchmark: the multi-tenant numbers DESIGN.md §7 quotes.

Three questions, one suite:

* **latency** — p50/p99 per service tick (one ΔG batch per tenant,
  ingested via ``apply_many``) at several pool sizes;
* **batched speedup** — the same tick stream with ``batch_mode="vmap"``
  (one mega-call per round) vs ``"off"`` (N solo applies): the win the
  batched execution path exists for;
* **capacity** — resident bytes per session (handle + props), converted
  to sessions-per-device against a nominal 16 GiB HBM budget.  CPU runs
  measure the same arrays a TPU run would hold.
"""
from __future__ import annotations

import time

import numpy as np
import jax

import common
from common import emit


def _tenant_streams(csr, n_tenants, percent=30):
    from repro.graph.updates import random_updates
    return [random_updates(csr, percent, seed=1000 + t)
            for t in range(n_tenants)]


def _tick_times(pool, streams, batch_size, ticks):
    """Wall time per service tick: one batch per tenant, one drain."""
    names = pool.tenants()
    out = []
    for i in range(ticks):
        reqs = [(nm, streams[j].batch(i % streams[j].num_batches(batch_size),
                                      batch_size))
                for j, nm in enumerate(names)]
        t0 = time.perf_counter()
        pool.apply_many(reqs)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready()
            if hasattr(x, "block_until_ready") else x,
            [pool.session(nm)._handle for nm in names])
        out.append(time.perf_counter() - t0)
    return np.asarray(out[1:])   # drop the compile tick


def _session_bytes(sess) -> int:
    tree, _ = sess.state_tree()
    return int(sum(np.asarray(l).nbytes
                   for l in jax.tree_util.tree_leaves(tree)))


def run(small: bool = True, quick: bool = False,
        backends=("jnp", "pallas"), pool_sizes=(4, 16, 32),
        batch_size: int = 16, ticks: int = 12) -> None:
    from repro.core import registry
    from repro.graph.csr import build_csr, rmat_graph
    from repro.serve import SessionPool

    if quick:
        backends = ("jnp",)
        pool_sizes = (4, 8)
        ticks = 6
    n, edges, w = rmat_graph(9 if small else 12, 8, seed=1)
    keep = edges[:, 0] != edges[:, 1]
    csr = build_csr(n, edges[keep], w[keep])

    for backend in backends:
        # interpret-mode pallas pays minutes per sequential tick and its
        # N-wide vmapped kernels are LLVM-compile heavy: cap the grid
        sizes = pool_sizes if backend != "pallas" \
            else tuple(s for s in pool_sizes if s <= 16)[:2]
        for n_tenants in sizes:
            times = {}
            for mode in ("vmap", "off"):
                registry.clear_shared_engines()
                pool = SessionPool(backend=backend, batch_mode=mode,
                                   max_pending=4 * n_tenants)
                streams = _tenant_streams(csr, n_tenants)
                for t in range(n_tenants):
                    pool.bind(f"t{t}", csr)
                ts = _tick_times(pool, streams, batch_size, ticks)
                times[mode] = ts
                p50, p99 = np.percentile(ts, [50, 99])
                per_sess = np.median(ts) / n_tenants
                emit(f"serve/{backend}/{mode}/N{n_tenants}",
                     np.median(ts) * 1e6,
                     f"p50_ms={p50 * 1e3:.3f};p99_ms={p99 * 1e3:.3f};"
                     f"per_session_us={per_sess * 1e6:.1f};"
                     f"tenants={n_tenants};"
                     f"mega_calls={pool.stats()['mega_calls']}")
            speedup = float(np.median(times["off"]) /
                            max(np.median(times["vmap"]), 1e-12))
            emit(f"serve/{backend}/speedup/N{n_tenants}",
                 np.median(times["vmap"]) * 1e6,
                 f"batched_speedup={speedup:.2f};tenants={n_tenants}")

        # capacity: resident bytes per session -> sessions per device
        registry.clear_shared_engines()
        pool = SessionPool(backend=backend)
        streams = _tenant_streams(csr, 1)
        pool.bind("cap", csr)
        pool.apply("cap", streams[0].batch(0, batch_size))
        per = _session_bytes(pool.session("cap"))
        hbm = 16 * (1 << 30)
        emit(f"serve/{backend}/capacity", float(per),
             f"bytes_per_session={per};"
             f"sessions_per_16GiB={hbm // max(per, 1)};"
             f"n={csr.n};edges={csr.num_edges}")
    registry.clear_shared_engines()


if __name__ == "__main__":
    run()
    common.write_json("serve")
