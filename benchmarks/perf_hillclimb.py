"""§Perf hillclimb driver: lower + analyze chosen cells under a given
tuning variant, append results to benchmarks/results/perf_iters.json.

Usage:
  PYTHONPATH=src python benchmarks/perf_hillclimb.py \
      --cell gemma2-9b:long_500k --variant opt --label cache-dh

Each record keeps the roofline terms so iterations are comparable:
  compute_s / memory_s / collective_s (per-chip, TPU v5e constants).
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 2 * 50e9

OUT = pathlib.Path(__file__).resolve().parent / "results" / \
    "perf_iters.json"


def measure(arch: str, shape: str, variant: str) -> dict:
    from repro.launch.dryrun import lower_cell, analyze
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh(multi_pod=False)
    t0 = time.time()
    lowered, compiled = lower_cell(arch, shape, mesh, False, tuning=variant)
    rec = analyze(compiled, 256)
    hc = rec["hlo_cost"]
    out = {
        "arch": arch, "shape": shape, "variant": variant,
        "compute_s": hc["flops_per_device"] / PEAK_FLOPS,
        "memory_s": hc["bytes_fused_per_device"] / HBM_BW,
        "collective_s": hc["collective_bytes_per_device"] / ICI_BW,
        "flops_per_device": hc["flops_per_device"],
        "bytes_fused_per_device": hc["bytes_fused_per_device"],
        "collective_bytes_per_device": hc["collective_bytes_per_device"],
        "bytes_breakdown": {k[6:]: v for k, v in hc.items()
                            if k.startswith("bytes_")},
        "coll_breakdown": {k[5:]: v for k, v in hc.items()
                           if k.startswith("coll_")},
        "compile_s": round(time.time() - t0, 1),
    }
    out["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                          key=lambda k: out[k])
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    help="arch:shape, e.g. gemma2-9b:long_500k")
    ap.add_argument("--variant", default="opt",
                    choices=["baseline", "opt"])
    ap.add_argument("--label", default="")
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    rec = measure(arch, shape, args.variant)
    rec["label"] = args.label
    hist = json.loads(OUT.read_text()) if OUT.exists() else []
    hist.append(rec)
    OUT.write_text(json.dumps(hist, indent=1))
    print(json.dumps({k: v for k, v in rec.items()
                      if not isinstance(v, dict)}, indent=1))
    print("bytes:", {k: f"{v:.2e}" for k, v in
                     rec["bytes_breakdown"].items()})
    print("coll :", {k: f"{v:.2e}" for k, v in
                     rec["coll_breakdown"].items()})


if __name__ == "__main__":
    main()
