"""Benchmark orchestrator — one suite per paper table/figure.

  dynamic_vs_static   paper Tables 2–4 / Figs 10–18 (dyn vs static × pct)
  tc                  paper TC columns (wedge enumeration, uniform graphs)
  merge_policy        diff-CSR merge cadence ablation (paper §3.5 knob)
  scheduling          backend scheduling trade-offs (paper Table 6 analogue)
  roofline            §Roofline terms per (arch × shape × mesh) from the
                      dry-run artifacts (reads benchmarks/results/dryrun.json)

CSV lines: ``name,us_per_call,derived`` on stdout.

Usage:
  PYTHONPATH=src python -m benchmarks.run [--suite S] [--small]
"""
from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="all",
                    choices=["all", "dynamic_vs_static", "tc", "merge_policy",
                             "scheduling", "static_baselines", "roofline"])
    ap.add_argument("--small", action="store_true", default=True,
                    help="reduced graph sizes (CI-speed; default on CPU)")
    ap.add_argument("--full", dest="small", action="store_false",
                    help="full bench-scale graphs")
    args = ap.parse_args()

    if args.suite in ("all", "dynamic_vs_static"):
        import dynamic_vs_static
        dynamic_vs_static.run(small=args.small)
    if args.suite in ("all", "tc"):
        import dynamic_vs_static
        dynamic_vs_static.run_tc(small=True)
    if args.suite in ("all", "merge_policy"):
        import merge_policy
        merge_policy.run()
    if args.suite in ("all", "scheduling"):
        import scheduling_ablation
        scheduling_ablation.run(small=args.small)
    if args.suite in ("all", "static_baselines"):
        import static_baselines
        static_baselines.run(small=True)
    if args.suite in ("all", "roofline"):
        import roofline
        roofline.run()


if __name__ == "__main__":
    main()
