"""Benchmark orchestrator — one suite per paper table/figure.

  dynamic_vs_static   paper Tables 2–4 / Figs 10–18 (dyn vs static × pct)
  stream              streaming-executor throughput (fused scan vs
                      per-batch dispatch; updates/sec + edges/sec)
  tc                  paper TC columns (wedge enumeration, uniform graphs)
  merge_policy        diff-CSR merge cadence ablation (paper §3.5 knob)
  scheduling          backend scheduling trade-offs (paper Table 6 analogue)
  pallas              fused vs chained Pallas repair kernels (relax /
                      spmv / ΔG pool merge / e2e) with roofline-relative
                      efficiency per row (ISSUE 6 tentpole scorecard)
  roofline            §Roofline terms per (arch × shape × mesh) from the
                      dry-run artifacts (reads benchmarks/results/dryrun.json)
  robustness          guarded vs unguarded streaming (ΔG admission guard
                      overhead; ISSUE 8 < 5% gate, warn-only)
  serve               multi-tenant session pool: p50/p99 tick latency,
                      batched-vs-sequential speedup, sessions/device
  dist                sharded-backend weak scaling (per-batch cost,
                      bytes/shard at 1/2/4/8 shards); forces 8 virtual
                      host devices, so it is NOT part of --suite all —
                      run it explicitly (or via benchmarks/dist_sharded.py)

Output: ``name,us_per_call,derived`` CSV lines on stdout AND a
machine-readable ``BENCH_<suite>.json`` at the repo root per suite run —
the perf trajectory successive PRs diff against.

Usage:
  PYTHONPATH=src python -m benchmarks.run [--suite S] [--small]
"""
from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="all",
                    choices=["all", "dynamic_vs_static", "stream", "tc",
                             "merge_policy", "scheduling", "static_baselines",
                             "pallas", "roofline", "robustness", "serve",
                             "dist"])
    ap.add_argument("--small", action="store_true", default=True,
                    help="reduced graph sizes (CI-speed; default on CPU)")
    ap.add_argument("--full", dest="small", action="store_false",
                    help="full bench-scale graphs")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: reduced engine × percent grids")
    args = ap.parse_args()

    if args.suite == "dist":
        # must precede the first jax import (common imports jax): the
        # weak-scaling rows need a multi-device host platform
        import os
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

    import common

    def suite(name, fn):
        common.reset_results()
        fn()
        common.write_json(name, meta={"small": bool(args.small)})

    if args.suite in ("all", "dynamic_vs_static"):
        import dynamic_vs_static
        kw = dict(small=args.small)
        if args.quick:
            kw.update(percents=(1, 10), engines=("jnp", "pallas"))
        suite("dynamic_vs_static", lambda: dynamic_vs_static.run(**kw))
    if args.suite in ("all", "stream"):
        import stream_executor
        kw = dict(small=args.small)
        if args.quick:
            kw.update(engines=("jnp", "pallas"))
        suite("stream", lambda: stream_executor.run(**kw))
    if args.suite in ("all", "tc"):
        import dynamic_vs_static
        suite("tc", lambda: dynamic_vs_static.run_tc(small=True))
    if args.suite in ("all", "merge_policy"):
        import merge_policy
        suite("merge_policy", merge_policy.run)
    if args.suite in ("all", "scheduling"):
        import scheduling_ablation
        suite("scheduling", lambda: scheduling_ablation.run(small=args.small))
    if args.suite in ("all", "static_baselines"):
        import static_baselines
        suite("static_baselines", lambda: static_baselines.run(small=True))
    if args.suite in ("all", "pallas"):
        import pallas_repair
        suite("pallas", lambda: pallas_repair.run(small=args.small,
                                                  quick=args.quick))
    if args.suite in ("all", "roofline"):
        import roofline
        suite("roofline", roofline.run)
    if args.suite in ("all", "robustness"):
        import robustness
        suite("robustness", lambda: robustness.run(small=args.small,
                                                   quick=args.quick))
    if args.suite in ("all", "serve"):
        import serve
        suite("serve", lambda: serve.run(small=args.small,
                                         quick=args.quick))
    if args.suite == "dist":                 # explicit only, see above
        import dist_sharded
        suite("dist", lambda: dist_sharded.run(small=args.small,
                                               quick=args.quick))


if __name__ == "__main__":
    main()
