"""Fused vs chained Pallas repair path (ISSUE 6 tentpole scorecard).

Races the two kernel regimes of ``PallasEngine`` on the paper's graph
mix and lands every row in ``BENCH_pallas.json``:

  relax    one fused launch (gather → relax → frontier-flag → in-kernel
           compaction, ``kernels/pallas_repair.fused_relax_rows``) vs
           the chained rowmin → hit → rowargmin kernel chain
  spmv     fused SpMV+frontier launch vs the chained rowsum + segment_max
  merge    ``update_csr_add`` with the merge-path pool kernel plugged in
           vs the jnp binary-search + scatter rounds
  e2e      dynamic SSSP end to end on the ``pallas`` vs the
           ``pallas_chained`` registry engines

Each row carries a *roofline-relative efficiency*: achieved bytes/s for
a coarse traffic model of the launch (ELL arrays streamed once per
launch, vertex arrays once, outputs once — the chained rows pay the
re-stream per op) against ``roofline.HBM_BW``.  On the CPU interpret
backend these fractions are tiny by construction; the quantity exists so
the same JSON rows become meaningful when the suite runs on a real TPU,
and so PRs can still compare fused-vs-chained *ratios* on CPU.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from common import timeit, emit, bench_graphs
from roofline import HBM_BW
from repro.graph import build_csr, random_updates
from repro.graph import diffcsr
from repro.graph.csr import INF_W
from repro.core.registry import make_engine
from repro.core.pallas_engine import _fused_upd_add
from repro.kernels import ops as kops
from repro.kernels import pallas_repair as FK
from repro.algos import sssp

_ITM = 4  # int32/float32 lanes throughout the repair path

_upd_scatter = jax.jit(diffcsr.update_csr_add)


def _relax_bytes(R, K, n, fused):
    """Coarse HBM traffic model (bytes) for one repair sweep.

    fused:   ell_src + ell_w once, vals once, min/arg/rows/counts out.
    chained: rowmin and rowargmin each re-stream the ELL arrays and
             vals, plus the hit pass over the vertex arrays.
    """
    if fused:
        return _ITM * (2 * R * K + (n + 1) + 3 * R)
    return _ITM * (2 * (2 * R * K + (n + 1) + R) + 2 * R)


def _merge_bytes(D, B, fused):
    """fused: one merge-path pass (read pool+batch, write pool).
    scatter: two searchsorted sweeps + scatter rounds ~ 3 pool passes."""
    if fused:
        return _ITM * (4 * D + 4 * B + 4 * D)
    return _ITM * (3 * 2 * 4 * D + 4 * B)


def _roofline(nbytes, us):
    gbps = nbytes / (us / 1e6) / 1e9
    return gbps, nbytes / (us / 1e6) / HBM_BW


def run(small=True, quick=False, percent=5, batch=16, iters=2):
    graphs = bench_graphs(small)
    if quick:
        graphs = {"uniform": graphs["uniform"]}
        iters = 1
    for gname, (n, edges, w) in graphs.items():
        keep = edges[:, 0] != edges[:, 1]
        csr = build_csr(n, edges[keep], w[keep])
        ups = random_updates(csr, percent=percent, seed=7)
        cap = max(2 * ups.num_adds, 16)

        eng = make_engine("pallas")
        h = eng.prepare(csr, diff_capacity=cap)
        ell = h.ell
        R, K = ell.ell_src.shape
        cfg = eng._config(h.g)

        rng = np.random.default_rng(1)
        dist = jnp.concatenate([
            jnp.asarray(rng.integers(0, 1000, n).astype(np.int32)),
            jnp.full((1,), INF_W, jnp.int32)])
        rank = jnp.concatenate([
            jnp.asarray(rng.random(n).astype(np.float32)),
            jnp.zeros((1,), jnp.float32)])

        # -- relax: one fused launch vs the per-op chain -------------------
        def relax_fused():
            return kops.vertex_relax_fused(ell, dist, block=cfg.row_block)

        def relax_chained():
            vmin = kops.vertex_min_plus(ell, dist)
            parent = kops.vertex_argmin_src(ell, dist, vmin)
            return vmin, parent, vmin < INF_W

        t_f = timeit(relax_fused, iters=iters)
        t_c = timeit(relax_chained, iters=iters)
        for mode, t in (("fused", t_f), ("chained", t_c)):
            nbytes = _relax_bytes(R, K, n, mode == "fused")
            gbps, frac = _roofline(nbytes, t)
            emit(f"pallas/relax/{gname}/{mode}", t,
                 f"fused_speedup={t_c / max(t_f, 1):.2f};"
                 f"model_bytes={nbytes};gbps={gbps:.3f};"
                 f"roofline_frac={frac:.2e};"
                 f"rows={R};lanes={K};row_block={cfg.row_block}")

        # -- spmv: fused launch vs rowsum + segment_max --------------------
        def spmv_fused():
            return kops.vertex_spmv_fused(ell, rank, block=cfg.row_block)

        def spmv_chained():
            return kops.vertex_spmv(ell, rank)

        t_f = timeit(spmv_fused, iters=iters)
        t_c = timeit(spmv_chained, iters=iters)
        for mode, t in (("fused", t_f), ("chained", t_c)):
            nbytes = _relax_bytes(R, K, n, mode == "fused")
            gbps, frac = _roofline(nbytes, t)
            emit(f"pallas/spmv/{gname}/{mode}", t,
                 f"fused_speedup={t_c / max(t_f, 1):.2f};"
                 f"model_bytes={nbytes};gbps={gbps:.3f};"
                 f"roofline_frac={frac:.2e}")

        # -- update merge: merge-path kernel vs scatter rounds -------------
        b0 = ups.batch(0, batch)
        g0, B, D = h.g, batch, h.g.diff_capacity
        upd_fused = _fused_upd_add(True, cfg.merge_block)

        def merge_fused():
            return upd_fused(g0, b0.add_src, b0.add_dst, b0.add_w,
                             b0.add_mask)

        def merge_scatter():
            return _upd_scatter(g0, b0.add_src, b0.add_dst, b0.add_w,
                                b0.add_mask)

        t_f = timeit(merge_fused, iters=iters)
        t_c = timeit(merge_scatter, iters=iters)
        for mode, t in (("fused", t_f), ("scatter", t_c)):
            nbytes = _merge_bytes(D, B, mode == "fused")
            gbps, frac = _roofline(nbytes, t)
            emit(f"pallas/merge/{gname}/{mode}", t,
                 f"fused_speedup={t_c / max(t_f, 1):.2f};"
                 f"model_bytes={nbytes};gbps={gbps:.3f};"
                 f"roofline_frac={frac:.2e};"
                 f"pool={D};batch={B};merge_block={cfg.merge_block}")

        # -- end to end: the two registry engines race dynamic SSSP --------
        if quick:
            continue
        times = {}
        for ename in ("pallas", "pallas_chained"):
            e2 = make_engine(ename)
            g2 = e2.prepare(csr, diff_capacity=cap)
            props0 = sssp.static_sssp(e2, g2, 0)
            times[ename] = timeit(
                lambda e2=e2, g2=g2, props0=props0: sssp.dyn_sssp(
                    e2, g2, 0, ups, batch, props=props0)[1]["dist"],
                iters=iters)
        for ename, t in times.items():
            mode = "fused" if ename == "pallas" else "chained"
            emit(f"pallas/e2e_sssp/{gname}/{mode}", t,
                 f"fused_speedup="
                 f"{times['pallas_chained'] / max(times['pallas'], 1):.2f};"
                 f"num_updates={ups.num_adds + ups.num_dels}")


if __name__ == "__main__":
    run()
