"""diff-CSR merge-cadence sweep (paper §3.5: "after a configurable number
of batches ... merged into the main CSR").

Processes a long update stream in batches while varying how often the
diff chain is compacted; reports per-batch dynamic-SSSP time and the
final diff occupancy.
"""
from __future__ import annotations

import numpy as np

from common import timeit, emit
from repro.graph import build_csr, random_updates
from repro.graph.csr import uniform_graph
from repro.core.registry import make_engine
from repro.algos import sssp


def run(n=4096, deg=8, pct=20, batch=64, cadences=(0, 1, 4, 16)):
    n, edges, w = uniform_graph(n, deg, seed=5)
    keep = edges[:, 0] != edges[:, 1]
    csr = build_csr(n, edges[keep], w[keep])
    eng = make_engine("jnp")
    ups = random_updates(csr, percent=pct, seed=11)
    nb = ups.num_batches(batch)

    for cadence in cadences:
        def process():
            g = eng.prepare(csr, diff_capacity=2 * batch * nb)
            props = sssp.static_sssp(eng, g, 0)
            for i, b in enumerate(ups.batches(batch)):
                gb, props_b = sssp.dyn_sssp(
                    eng, g, 0,
                    type(ups)(adds=ups.adds[i * batch:(i + 1) * batch],
                              dels=ups.dels[i * batch:(i + 1) * batch]),
                    batch, props=props)
                g, props = gb, props_b
                if cadence and (i + 1) % cadence == 0:
                    g = eng.merge(g)
            return g

        t = timeit(process, warmup=0, iters=1)
        tag = f"merge_every_{cadence}" if cadence else "never_merge"
        emit(f"merge_policy/sssp/{tag}", t, f"batches={nb}")


if __name__ == "__main__":
    run()
