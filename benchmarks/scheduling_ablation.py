"""Paper Table 6 analogue: scheduling/lowering ablation for SSSP.

The paper compares OpenMP dynamic vs static scheduling; the TPU analogue
is the choice of relaxation lowering: segment-reduce (jnp), ELL kernel
with K ∈ {4,8,16} (pallas row-split width = the work-per-row 'schedule'),
and the distributed lowering.
"""
from __future__ import annotations

from common import timeit, emit, bench_graphs
from repro.graph import build_csr
from repro.core.registry import make_engine
from repro.algos import sssp


def run(small=False):
    graphs = bench_graphs(small)
    for gname, (n, edges, w) in graphs.items():
        keep = edges[:, 0] != edges[:, 1]
        csr = build_csr(n, edges[keep], w[keep])
        variants = [("jnp-segment", make_engine("jnp")),
                    ("dist", make_engine("dist")),
                    ("ell-k4", make_engine("pallas", k=4)),
                    ("ell-k8", make_engine("pallas", k=8)),
                    ("ell-k16", make_engine("pallas", k=16))]
        for vname, eng in variants:
            g = eng.prepare(csr, diff_capacity=16)
            t = timeit(lambda: sssp.static_sssp(eng, g, 0)["dist"], iters=2)
            emit(f"sched/sssp/{gname}/{vname}", t, "")


if __name__ == "__main__":
    run()
