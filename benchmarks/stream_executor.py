"""Streaming-executor throughput: fused scan vs per-batch dispatch.

The tentpole quantity of the perf trajectory (ISSUE 3 / DESIGN.md §3):
drive one ΔG update stream through dynamic SSSP twice —

  batched  per-batch dispatch (the pre-existing ``dyn_sssp`` loop: one
           host round-trip and one overflow check per batch), and
  fused    ``Engine.run_stream``: the whole stream lax.scanned in one
           compiled program per segment, counters read once per segment

— and record updates/sec (update events applied per wall-second, the
paper's Tables 2–4 x-axis quantity) plus edges/sec (graph edge-lanes
streamed through the repair sweeps per wall-second).  Both rows land in
BENCH_stream.json so successive PRs can track the fused-over-batched
speedup; the acceptance bar is fused ≥ 2× batched updates/sec on the
--small suite.
"""
from __future__ import annotations

import numpy as np

from common import timeit, emit, bench_graphs
from repro.graph import build_csr, random_updates
from repro.core.registry import make_engine
from repro.algos import sssp


def run(small=True, engines=("jnp", "pallas", "frontier"),
        percent=5, batch=16, iters=2):
    # NB: 'dist' runs correctly but pays shard_map emulation costs on a
    # CPU host; pass engines=(..., "dist") explicitly to include it.
    graphs = bench_graphs(small)
    for gname, (n, edges, w) in graphs.items():
        keep = edges[:, 0] != edges[:, 1]
        csr = build_csr(n, edges[keep], w[keep])
        ups = random_updates(csr, percent=percent, seed=7)
        nb = ups.num_batches(batch)
        n_updates = ups.num_adds + ups.num_dels
        # edge-lanes each repair sweep streams over, per batch
        lanes = csr.num_edges + max(2 * ups.num_adds, 16)
        for ename in engines:
            eng = make_engine(ename)
            cap = max(2 * ups.num_adds, 16)
            g0 = eng.prepare(csr, diff_capacity=cap)
            props0 = sssp.static_sssp(eng, g0, 0)

            def fused():
                return sssp.dyn_sssp_stream(
                    eng, g0, 0, ups, batch, props=props0,
                    segment_size=nb)[1]["dist"]

            def batched():
                return sssp.dyn_sssp(eng, g0, 0, ups, batch,
                                     props=props0)[1]["dist"]

            t_f = timeit(fused, iters=iters)
            t_b = timeit(batched, iters=iters)
            for mode, t in (("fused", t_f), ("batched", t_b)):
                ups_s = n_updates / (t / 1e6)
                edges_s = lanes * nb / (t / 1e6)
                emit(f"stream/sssp/{ename}/{gname}/{mode}", t,
                     f"updates_per_sec={ups_s:.0f};"
                     f"edges_per_sec={edges_s:.0f};"
                     f"num_updates={n_updates};num_batches={nb};"
                     f"fused_speedup={t_b / max(t_f, 1):.2f}")


if __name__ == "__main__":
    run()
