import json
import sys
import pathlib
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np
import jax

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]

# rows accumulated by emit() since the last reset_results(); run.py
# flushes them to BENCH_<suite>.json so successive PRs can track the
# perf trajectory in machine-readable form.
_RESULTS: list = []


def timeit(fn, *args, warmup=1, iters=3):
    """Median wall time in microseconds (results block_until_ready)."""
    for _ in range(warmup):
        r = fn(*args)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
            else x, r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
            else x, r)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}", flush=True)
    row = {"name": name, "us_per_call": round(us, 1)}
    for kv in derived.split(";"):
        if "=" in kv:
            k, v = kv.split("=", 1)
            try:
                row[k] = float(v)
            except ValueError:
                row[k] = v
    _RESULTS.append(row)


def reset_results() -> None:
    _RESULTS.clear()


def write_json(suite: str, meta: dict | None = None) -> pathlib.Path:
    """Flush the emit() rows to BENCH_<suite>.json at the repo root."""
    payload = {"suite": suite,
               "generated_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
               "results": list(_RESULTS)}
    if meta:
        payload["meta"] = meta
    path = REPO_ROOT / f"BENCH_{suite}.json"
    path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"[bench] wrote {path}", flush=True)
    return path


def bench_graphs(small=False):
    """The paper's graph-type mix, at CPU-tractable scale:
    skewed (RMAT ~ TW/RM), uniform (~UR/social), grid (~US/GR roads)."""
    from repro.graph.csr import rmat_graph, uniform_graph, grid_graph
    if small:
        return {
            "rmat": rmat_graph(10, 8, seed=1),
            "uniform": uniform_graph(1024, 8, seed=1),
            "grid": grid_graph(32, seed=1),
        }
    return {
        "rmat": rmat_graph(13, 8, seed=1),       # 8k vertices, 64k edges
        "uniform": uniform_graph(8192, 8, seed=1),
        "grid": grid_graph(96, seed=1),           # 9.2k vertices, large diam
    }
