"""Weak-scaling evidence for the sharded backend (BENCH_dist.json).

The scaling claim of the ``dist_sharded`` engine (DESIGN.md §5): a
graph partitioned over P shards streams ΔG batches at (near) the
per-batch cost of a single shard, while each shard holds only its own
rows plus the halo tables.  This suite grows the graph WITH the mesh —
``n = n0 * P`` at constant degree, so per-shard row mass stays fixed —
and records, per shard count:

  per_batch_us         fused-scan streaming cost per ΔG batch
  edges_per_sec        edge-lanes streamed through repair sweeps / sec
  bytes_per_shard      one shard's resident graph bytes (rows + halo)
  single_device_bytes  the jnp engine's footprint for the SAME graph
  mem_frac             bytes_per_shard / single_device_bytes
  per_batch_vs_1shard  per-batch cost normalised to the 1-shard row

The CI dist-smoke job runs ``--quick`` on 8 virtual host devices and
warn-gates ``per_batch_vs_1shard`` at 2x; the ISSUE 10 memory bar is
``mem_frac < 0.6`` on the 8-shard graph.

Shard counts above ``len(jax.devices())`` are skipped, so this file
must fix the device count BEFORE jax initialises — it does so when run
as a script; ``benchmarks/run.py --suite dist`` does the same on the
orchestrator path.

Usage:
  PYTHONPATH=src python benchmarks/dist_sharded.py [--quick]
"""
from __future__ import annotations

import argparse
import os
import pathlib
import sys

if __name__ == "__main__":                   # before any jax import
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

import numpy as np
import jax

from common import timeit, emit, write_json
from repro.graph import build_csr, random_updates
from repro.graph.csr import uniform_graph
from repro.algos import sssp

SHARD_COUNTS = (1, 2, 4, 8)


def _footprint(handle) -> int:
    return sum(np.asarray(leaf).nbytes
               for leaf in jax.tree_util.tree_leaves(handle))


def run(small=True, quick=False):
    from repro.core.engine import JnpEngine
    from repro.shard.engine import ShardedEngine

    ndev = len(jax.devices())
    counts = [p for p in SHARD_COUNTS if p <= ndev]
    if counts != list(SHARD_COUNTS):
        print(f"[bench] only {ndev} devices: weak-scaling rows limited "
              f"to P={counts} (set XLA_FLAGS="
              f"--xla_force_host_platform_device_count=8)", flush=True)
    n0 = 192 if quick else (512 if small else 2048)
    deg = 4 if quick else 8
    batch = 16
    base_pb = None
    for P in counts:
        n, edges, w = uniform_graph(n0 * P, deg, seed=1)
        keep = edges[:, 0] != edges[:, 1]
        csr = build_csr(n, edges[keep], w[keep])
        ups = random_updates(csr, percent=10, seed=7)
        nb = ups.num_batches(batch)
        lanes = csr.num_edges + max(2 * ups.num_adds, 16)
        cap = max(2 * ups.num_adds, 16)

        eng = ShardedEngine(num_shards=P)
        g0 = eng.prepare(csr, diff_capacity=cap)
        props0 = sssp.static_sssp(eng, g0, 0)

        def fused():
            return sssp.dyn_sssp_stream(eng, g0, 0, ups, batch,
                                        props=props0,
                                        segment_size=nb)[1]["dist"]

        t = timeit(fused, iters=1 if quick else 2)
        per_batch = t / nb
        if base_pb is None:
            base_pb = per_batch
        bps = eng.per_shard_bytes(g0)
        single = _footprint(JnpEngine().prepare(csr, diff_capacity=cap))
        emit(f"dist/weak/P{P}", t,
             f"per_batch_us={per_batch:.1f};"
             f"per_batch_vs_1shard={per_batch / max(base_pb, 1e-9):.2f};"
             f"edges_per_sec={lanes * nb / (t / 1e6):.0f};"
             f"bytes_per_shard={bps};single_device_bytes={single};"
             f"mem_frac={bps / max(single, 1):.3f};"
             f"n={n};num_batches={nb};shards={P}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: tiny graphs, one timing iteration")
    ap.add_argument("--full", action="store_true",
                    help="bench-scale graphs")
    args = ap.parse_args()
    from common import reset_results
    reset_results()
    run(small=not args.full, quick=args.quick)
    write_json("dist", meta={"small": not args.full,
                             "quick": bool(args.quick)})
