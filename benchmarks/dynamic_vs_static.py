"""Paper Tables 2–4: dynamic vs static processing per backend × update %.

For each (backend, algorithm, graph, percent): time
  static  = full recomputation on the post-update graph (the paper's
            static baseline: "updates performed at the start, properties
            calculated from scratch"), and
  dynamic = batched OnDelete/Decremental + OnAdd/Incremental processing.
Derived column reports the dynamic-over-static speedup — the paper's
headline quantity (expected >1 at low %, crossing below 1 as % grows).
"""
from __future__ import annotations

import numpy as np

from common import timeit, emit, bench_graphs
from repro.graph import build_csr, random_updates
from repro.core.registry import make_engine
from repro.algos import sssp, pagerank

PERCENTS = (1, 5, 10, 20)


def run(percents=PERCENTS, engines=("jnp", "pallas", "frontier"),
        small=False):
    # NB: 'dist' is correct but slow on the CPU host (shard_map emulation);
    # pass engines=(..., "dist") explicitly for the full table.
    graphs = bench_graphs(small)
    for gname, (n, edges, w) in graphs.items():
        keep = edges[:, 0] != edges[:, 1]
        csr = build_csr(n, edges[keep], w[keep])
        for ename in engines:
            eng = make_engine(ename)
            for pct in percents:
                ups = random_updates(csr, percent=pct, seed=42)
                cap = max(2 * ups.num_adds, 16)
                batch = max(ups.num_adds, ups.num_dels, 1)

                # ---- SSSP ----
                g0 = eng.prepare(csr, diff_capacity=cap)
                props0 = sssp.static_sssp(eng, g0, 0)

                def dyn():
                    return sssp.dyn_sssp(eng, g0, 0, ups, batch,
                                         props=props0)[1]["dist"]

                def dyn_stream():
                    return sssp.dyn_sssp_stream(eng, g0, 0, ups, batch,
                                                props=props0)[1]["dist"]

                def stat():
                    g1 = eng.prepare(csr, diff_capacity=cap)
                    b = ups.batch(0, max(ups.num_adds, ups.num_dels, 1))
                    g1 = eng.update_del(g1, b)
                    g1 = eng.update_add(g1, b)
                    return sssp.static_sssp(eng, g1, 0)["dist"]

                t_dyn = timeit(dyn, iters=2)
                t_stream = timeit(dyn_stream, iters=2)
                t_stat = timeit(stat, iters=2)
                emit(f"sssp/{ename}/{gname}/pct{pct}/dynamic", t_dyn,
                     f"speedup_vs_static={t_stat / max(t_dyn, 1):.2f}")
                emit(f"sssp/{ename}/{gname}/pct{pct}/dynamic_stream",
                     t_stream,
                     f"speedup_vs_static={t_stat / max(t_stream, 1):.2f}")
                emit(f"sssp/{ename}/{gname}/pct{pct}/static", t_stat, "")

                # ---- PageRank ----
                pr0 = pagerank.static_pr(eng, g0)

                def dyn_pr():
                    return pagerank.dyn_pr(eng, g0, ups, batch,
                                           props=pr0)[1]["pr"]

                def dyn_pr_stream():
                    return pagerank.dyn_pr_stream(eng, g0, ups, batch,
                                                  props=pr0)[1]["pr"]

                def stat_pr():
                    g1 = eng.prepare(csr, diff_capacity=cap)
                    b = ups.batch(0, max(ups.num_adds, ups.num_dels, 1))
                    g1 = eng.update_del(g1, b)
                    g1 = eng.update_add(g1, b)
                    return pagerank.static_pr(eng, g1)["pr"]

                t_dyn = timeit(dyn_pr, iters=2)
                t_stream = timeit(dyn_pr_stream, iters=2)
                t_stat = timeit(stat_pr, iters=2)
                emit(f"pr/{ename}/{gname}/pct{pct}/dynamic", t_dyn,
                     f"speedup_vs_static={t_stat / max(t_dyn, 1):.2f}")
                emit(f"pr/{ename}/{gname}/pct{pct}/dynamic_stream", t_stream,
                     f"speedup_vs_static={t_stat / max(t_stream, 1):.2f}")
                emit(f"pr/{ename}/{gname}/pct{pct}/static", t_stat, "")


def run_tc(percents=(1, 5), engines=("jnp",), small=True):
    """TC separately (wedge enumeration is O(E·max_deg) — uniform graphs
    only at bench scale, mirroring the paper's TC DNFs on skewed MPI)."""
    from repro.algos import triangles, oracles
    from repro.graph.updates import UpdateStream
    from repro.graph.csr import uniform_graph
    n, edges, w = uniform_graph(512 if small else 2048, 6, seed=2)
    keep = edges[:, 0] != edges[:, 1]
    e, w2 = oracles.symmetrize(edges[keep], w[keep])
    csr = build_csr(n, e)
    for ename in engines:
        eng = make_engine(ename)
        for pct in percents:
            ups0 = random_updates(csr, percent=pct, seed=3)
            adds = np.stack([ups0.adds, ups0.adds[:, [1, 0, 2]]],
                            axis=1).reshape(-1, 3)
            dels = np.stack([ups0.dels, ups0.dels[:, [1, 0]]],
                            axis=1).reshape(-1, 2)
            ups = UpdateStream(adds=adds, dels=dels)
            cap = max(2 * ups.num_adds, 16)
            g0 = eng.prepare(csr, diff_capacity=cap)
            c0 = triangles.static_tc(eng, g0)
            batch = max(ups.num_adds, ups.num_dels, 1)

            def dyn():
                return triangles.dyn_tc(eng, g0, ups, batch, count=c0)[1]

            def stat():
                g1 = eng.prepare(csr, diff_capacity=cap)
                b = ups.batch(0, batch)
                g1 = eng.update_del(g1, b)
                g1 = eng.update_add(g1, b)
                return triangles.static_tc(eng, g1)

            t_dyn = timeit(dyn, iters=2)
            t_stat = timeit(stat, iters=2)
            emit(f"tc/{ename}/uniform/pct{pct}/dynamic", t_dyn,
                 f"speedup_vs_static={t_stat / max(t_dyn, 1):.2f}")
            emit(f"tc/{ename}/uniform/pct{pct}/static", t_stat, "")


if __name__ == "__main__":
    run()
    run_tc()
