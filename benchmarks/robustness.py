"""Guarded-vs-unguarded streaming: what does admission cost?

The ISSUE 8 acceptance row: the ΔG admission guard runs ONE vectorized
host pass over the raw stream arrays before the fused executor launches,
so a clean stream (the serving common case) must pay < 5% overhead
versus ``admission="off"`` (the pre-PR-8 behavior).  Each backend gets a
``stream_unguarded_*`` / ``stream_guarded_*`` pair plus the isolated
host-pass cost; the 5% gate is *warn-only* (CI smoke prints a WARNING
line instead of failing — CPU wall clocks are noisy).
"""
from __future__ import annotations

import time

import numpy as np
import jax

import common
from common import emit

import repro.api as api
from repro.graph import build_csr, random_updates
from repro.graph.csr import rmat_graph
from repro.runtime.admission import stream_batch_violations

OVERHEAD_GATE_PCT = 5.0


def _graph(small: bool):
    scale = 11 if small else 14
    n, edges, w = rmat_graph(scale, 8, seed=7)
    keep = edges[:, 0] != edges[:, 1]
    return build_csr(n, edges[keep], w[keep])


def _step(view, h, batch, carry):
    h = view.update_del(h, batch)
    h = view.update_add(h, batch)
    return h, carry


def _time_stream(csr, stream, bs, backend, policy, iters=3):
    """Median run_stream wall time (fresh session per iter; bind/prepare
    and the shared jit cache stay outside the timed region)."""
    ts = []
    for i in range(iters + 1):
        sess = api.bind_graph(csr, backend=backend, admission=policy)
        sess.handle                              # prepare untimed
        t0 = time.perf_counter()
        sess.run_stream(stream, bs, _step, None)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready()
            if hasattr(x, "block_until_ready") else x, sess.handle)
        if i:                                    # drop tracing warmup
            ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def run(small: bool = True, quick: bool = False,
        backends=("jnp", "pallas")) -> None:
    if quick:
        backends = ("jnp",)
    csr = _graph(small)
    stream = random_updates(csr, percent=20, seed=13)
    bs = max(1, stream.num_adds // 16)
    nb = stream.num_batches(bs)

    # the guard's actual work, isolated: one host pass over raw arrays
    t0 = time.perf_counter()
    for _ in range(5):
        assert not stream_batch_violations(stream, bs, csr.n)
    host_pass_us = (time.perf_counter() - t0) / 5 * 1e6
    emit("admission_host_pass", host_pass_us,
         f"batches={nb};rows={stream.num_adds + stream.num_dels}")

    for backend in backends:
        off = _time_stream(csr, stream, bs, backend, "off")
        clamp = _time_stream(csr, stream, bs, backend, "clamp")
        pct = (clamp - off) / off * 100.0
        emit(f"stream_unguarded_{backend}", off, f"batches={nb}")
        emit(f"stream_guarded_{backend}", clamp,
             f"batches={nb};overhead_pct={pct:.2f}")
        if pct > OVERHEAD_GATE_PCT:
            print(f"WARNING: admission overhead {pct:.2f}% on {backend} "
                  f"exceeds the {OVERHEAD_GATE_PCT}% gate (warn-only)",
                  flush=True)


if __name__ == "__main__":
    common.reset_results()
    run(small=True)
    common.write_json("robustness")
