"""Roofline analysis over the dry-run artifacts (brief §ROOFLINE).

Per (arch × shape) single-pod cell:
  compute   = HLO_FLOPs        / (chips × 197e12 bf16 FLOP/s)
  memory    = HLO_bytes        / (chips × 819e9  B/s HBM)
  collective= collective_bytes / (chips × 2 × 50e9 B/s ICI)

Notes on terms:
  * cost_analysis() reports whole-program (global) FLOPs/bytes for the
    SPMD module; dividing by chip count gives the per-chip rate the
    roofline needs.
  * collective_bytes comes from the HLO parse in launch/dryrun.py
    (result-shape volume per collective op — all-gather counts its
    gathered output once).
  * MODEL_FLOPS = 6·N(_active)·tokens for train; 2·N·tokens for a
    forward-only prefill; 2·N_active·1 per decoded token.

Emits the EXPERIMENTS.md §Roofline table and a machine-readable JSON.
"""
from __future__ import annotations

import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.configs.archs import REGISTRY, SHAPES

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # B/s / chip
ICI_BW = 2 * 50e9            # 2 usable links × 50 GB/s (conservative)

RESULTS = pathlib.Path(__file__).resolve().parent / "results" / "dryrun.json"
OUT = pathlib.Path(__file__).resolve().parent / "results" / "roofline.json"


def model_flops(arch: str, shape_name: str) -> float:
    cfg = REGISTRY[arch]
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analyze(mesh: str = "single") -> dict:
    """Roofline terms per cell from the trip-count-aware HLO cost model
    (rec['hlo_cost'], see repro/launch/hlo_cost.py — all PER-DEVICE)."""
    res = json.loads(RESULTS.read_text())
    out = {}
    for key, rec in res.items():
        arch, shape_name, mname = key.split("|")
        if mname != mesh or not rec.get("ok"):
            continue
        hc = rec.get("hlo_cost")
        if not hc:
            continue
        chips = rec["n_chips"]
        comp = hc["flops_per_device"] / PEAK_FLOPS
        mem = hc["bytes_fused_per_device"] / HBM_BW
        coll = hc["collective_bytes_per_device"] / ICI_BW
        terms = {"compute_s": comp, "memory_s": mem, "collective_s": coll}
        dom = max(terms, key=terms.get)
        mf = model_flops(arch, shape_name)
        useful = (mf / chips) / hc["flops_per_device"] \
            if hc["flops_per_device"] else 0.0
        bound = max(terms.values())
        # roofline fraction: ideal useful-compute time / the bounding term
        ideal_compute = mf / (chips * PEAK_FLOPS)
        frac = ideal_compute / bound if bound else 0.0
        out[f"{arch}|{shape_name}"] = {
            **terms,
            "dominant": dom.replace("_s", ""),
            "model_flops": mf,
            "hlo_flops_per_device": hc["flops_per_device"],
            "useful_flop_ratio": useful,
            "roofline_fraction": frac,
            "collective_count": hc.get("collective_count", 0),
            "hbm_temp_gib": rec.get("temp_size_in_bytes", 0) / 2**30,
        }
    return out


def table(rows: dict) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | useful FLOP ratio | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = []
    for key in sorted(rows):
        r = rows[key]
        a, s = key.split("|")
        lines.append(
            f"| {a} | {s} | {r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | {r['dominant']} | "
            f"{r['useful_flop_ratio']:.2f} | {r['roofline_fraction']:.2f} |")
    return hdr + "\n".join(lines)


def run():
    main()


def main():
    rows = analyze("single")
    OUT.write_text(json.dumps(rows, indent=1, sort_keys=True))
    print(table(rows))
    worst = sorted(rows.items(), key=lambda kv: kv[1]["roofline_fraction"])
    coll = sorted(rows.items(), key=lambda kv: -kv[1]["collective_s"])
    print("\nworst roofline fraction:", worst[0][0] if worst else "-")
    print("most collective-bound:", coll[0][0] if coll else "-")


if __name__ == "__main__":
    main()
