"""Paper Tables 5/7/8 analogue: static-algorithm comparison.

The paper benches its static codegen against Galois/Ligra/Green-Marl/
Gunrock — none of which exist in this offline TPU container — so the
comparison here is between *our* lowerings and two reference baselines
implementable in the same environment:

  * ``scipy-free dense``: PR as dense matrix power iteration and SSSP as
    dense min-plus Bellman-Ford (the O(n²) "obvious" implementation — a
    Ligra-like frontier-free baseline);
  * ``numpy-csr``: host NumPy CSR relaxation loop (OpenMP-ish scalar
    baseline, no JIT).

Emits speedups of each engine over the baselines per graph family.
"""
from __future__ import annotations

import numpy as np

from common import timeit, emit, bench_graphs
from repro.graph import build_csr
from repro.core.registry import make_engine
from repro.algos import sssp, pagerank, oracles


def dense_pr(n, edges, iters=30, delta=0.85):
    A = np.zeros((n, n), np.float32)
    A[edges[:, 1], edges[:, 0]] = 1.0
    deg = np.maximum(A.sum(axis=0), 1.0)
    M = A / deg
    pr = np.full(n, 1.0 / n, np.float32)
    for _ in range(iters):
        pr = (1 - delta) / n + delta * (M @ pr)
    return pr


def dense_sssp(n, edges, w, src=0):
    INF = np.int64(1) << 40
    D = np.full((n, n), INF, np.int64)
    np.minimum.at(D, (edges[:, 0], edges[:, 1]), w.astype(np.int64))
    dist = np.full(n, INF, np.int64)
    dist[src] = 0
    for _ in range(n):
        new = np.minimum(dist, (dist[:, None] + D).min(axis=0))
        if np.array_equal(new, dist):
            break
        dist = new
    return dist


def numpy_csr_sssp(csr, src=0):
    n = csr.n
    offs = np.asarray(csr.offsets)
    dst = np.asarray(csr.dst)
    w = np.asarray(csr.w)
    INF = np.int64(1) << 40
    dist = np.full(n, INF, np.int64)
    dist[src] = 0
    frontier = [src]
    while frontier:
        nxt = set()
        for u in frontier:
            du = dist[u]
            for i in range(offs[u], offs[u + 1]):
                v = dst[i]
                nd = du + w[i]
                if nd < dist[v]:
                    dist[v] = nd
                    nxt.add(v)
        frontier = list(nxt)
    return dist


def run(small=True):
    graphs = bench_graphs(small)
    engines = [(name, make_engine(name))
               for name in ("jnp", "dist", "pallas")]
    for gname, (n, edges, w) in graphs.items():
        keep = edges[:, 0] != edges[:, 1]
        edges, w = edges[keep], w[keep]
        csr = build_csr(n, edges, w)

        t_dense_pr = timeit(lambda: dense_pr(n, edges), iters=1, warmup=0)
        t_dense_ss = timeit(lambda: dense_sssp(n, edges, w), iters=1,
                            warmup=0)
        t_np_ss = timeit(lambda: numpy_csr_sssp(csr), iters=1, warmup=0)
        emit(f"static/{gname}/baseline-dense/pr", t_dense_pr, "")
        emit(f"static/{gname}/baseline-dense/sssp", t_dense_ss, "")
        emit(f"static/{gname}/baseline-numpycsr/sssp", t_np_ss, "")

        for ename, eng in engines:
            g = eng.prepare(csr, diff_capacity=16)
            t_pr = timeit(lambda: pagerank.static_pr(eng, g)["pr"], iters=2)
            t_ss = timeit(lambda: sssp.static_sssp(eng, g, 0)["dist"],
                          iters=2)
            emit(f"static/{gname}/{ename}/pr", t_pr,
                 f"speedup_vs_dense={t_dense_pr / max(t_pr, 1):.2f}")
            emit(f"static/{gname}/{ename}/sssp", t_ss,
                 f"speedup_vs_dense={t_dense_ss / max(t_ss, 1):.2f};"
                 f"vs_numpycsr={t_np_ss / max(t_ss, 1):.2f}")


if __name__ == "__main__":
    run()
