"""Durable streaming: kill-and-resume for session-resident graph state.

The streaming_analytics scenario, made preemption-proof: a long-lived
``repro.api`` session maintains dynamic SSSP over a live edge stream,
checkpointing after every ΔG batch (atomic-rename commit protocol).  A
simulated preemption kills the session mid-stream; the elastic loop
(``repro.launch.elastic.run_elastic_session``) tears down, restores from
the latest committed checkpoint, and finishes the stream.  The resumed
result must be **bit-identical** to an uninterrupted run — the restore
brings back the raw diff-pool leaves, the armed Batch-loop position, and
the stream cursor, so not a single batch is re-applied or skipped.

    PYTHONPATH=src python examples/durable_streaming.py
"""
import shutil
import tempfile

import numpy as np

import repro
from repro.dsl_programs import path as program_path
from repro.graph import build_csr, random_updates
from repro.graph.csr import rmat_graph
from repro.launch.elastic import run_elastic_session


def main():
    n, edges, w = rmat_graph(10, 8, seed=3)        # 1k vertices, skewed
    keep = edges[:, 0] != edges[:, 1]
    csr = build_csr(n, edges[keep], w[keep])
    stream = random_updates(csr, percent=10, seed=42)
    batch_size = max(1, stream.num_adds // 6)
    batches = list(stream.batches(batch_size))
    prog = repro.compile(program_path("sssp"))
    print(f"rmat graph: {n} vertices, {csr.num_edges} edges; "
          f"{len(batches)} ΔG batches of {batch_size}")

    # ---- uninterrupted reference: one armed session, every batch ----
    ref = prog.bind(csr, backend="jnp", capacity="auto")
    ref.run("DynSSSP", batchSize=batch_size, src=0)
    for b in batches:
        ref.apply(b)
    want = np.asarray(ref.props.host("dist"))

    # ---- preempted run: checkpoint per batch, die mid-stream --------
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    kill_at = len(batches) // 2
    fault = {"armed": True}

    def make_session(attempt):
        if attempt == 0:
            s = prog.bind(csr, backend="jnp", capacity="auto")
            s.run("DynSSSP", batchSize=batch_size, src=0)
            return s
        # a retry means we were preempted: restore the armed session
        # (graph handle, props, Batch-loop position, cursor) from the
        # latest committed step
        s = repro.restore_session(ckpt_dir)
        print(f"[resume] restored at batch {s.stream_cursor}/"
              f"{len(batches)} (attempt {attempt})")
        return s

    def work(sess):
        for i, b in enumerate(batches):
            if i < sess.stream_cursor:
                continue               # applied before the preemption
            sess.apply(b)
            sess.save(ckpt_dir)
            if i == kill_at and fault["armed"]:
                fault["armed"] = False
                print(f"[kill]   simulated preemption after batch {i}")
                raise RuntimeError("SIGTERM")
        return np.asarray(sess.props.host("dist"))

    got = run_elastic_session(make_session, work, max_restarts=2)
    shutil.rmtree(ckpt_dir, ignore_errors=True)

    assert not fault["armed"], "the simulated preemption never fired"
    np.testing.assert_array_equal(got, want)
    reachable = int((want < np.iinfo(np.int32).max // 4).sum())
    print(f"kill-and-resume SSSP == uninterrupted: bit-identical over "
          f"{n} vertices ({reachable} reachable)")
    print("DURABLE-OK")


if __name__ == "__main__":
    main()
