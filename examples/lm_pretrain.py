"""End-to-end driver: pretrain a ~100M-param LM (xlstm-125m, the one
assigned arch at laptop scale) for a few hundred steps with the full
production stack — sharded data pipeline, AdamW, checkpoints, resume.

    PYTHONPATH=src python examples/lm_pretrain.py [--steps 200]

This wraps repro.launch.train, the same driver the cluster launch uses;
on real hardware you'd add --mesh --model-parallel 16 and point --ckpt
at durable storage.
"""
import argparse
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.launch import train as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (slow on CPU)")
    args_in = ap.parse_args()

    ckpt_dir = tempfile.mkdtemp(prefix="lm_pretrain_")
    argv = ["--arch", args_in.arch,
            "--steps", str(args_in.steps),
            "--batch", "8", "--seq", "256",
            "--ckpt", ckpt_dir, "--ckpt-every", "50",
            "--log-every", "20"]
    if not args_in.full_size:
        argv.append("--reduced")
    args = T.parser().parse_args(argv)

    out = T.train(args)
    losses = out["losses"]
    print(f"\nloss: {losses[0]:.3f} → {losses[-1]:.3f} over "
          f"{args_in.steps} steps (ckpts in {ckpt_dir})")
    assert losses[-1] < losses[0], "loss should decrease"
    print("resume check: restarting from the latest checkpoint...")
    args2 = T.parser().parse_args(argv)       # same ckpt dir → resumes
    T.train(args2)
    print("done ✓")


if __name__ == "__main__":
    main()
