"""Chaos streaming: kernel failures mid-stream must not change answers.

The streaming_analytics scenario under fire: a ``repro.api`` session
maintains dynamic SSSP on the ``pallas`` backend with ``failover=True``
while the chaos harness (``repro.runtime.faults``) makes every pallas
kernel launch fail mid-stream.  The session must degrade down the
failover chain (``pallas → pallas_chained → jnp`` — both pallas regimes
share the poisoned kernels here, so it lands on ``jnp``), migrating the
device-resident diff-CSR state and the armed Batch-loop across engines,
and keep applying ΔG batches as if nothing happened.  One poison batch
(out-of-range vertex ids) rides along and is quarantined by the
admission guard.  The final distance vector must be **bit-identical**
to a clean, fault-free run.

    PYTHONPATH=src python examples/chaos_streaming.py
"""
import numpy as np

import repro
from repro.dsl_programs import path as program_path
from repro.graph import build_csr
from repro.graph.csr import rmat_graph
from repro.graph.updates import UpdateStream, random_updates
from repro.runtime import faults


def main():
    n, edges, w = rmat_graph(10, 8, seed=3)        # 1k vertices, skewed
    keep = edges[:, 0] != edges[:, 1]
    csr = build_csr(n, edges[keep], w[keep])
    stream = random_updates(csr, percent=10, seed=42)
    batch_size = max(1, stream.num_adds // 6)
    batches = list(stream.batches(batch_size))
    kill_at = len(batches) // 2
    poison = UpdateStream(                         # ids far outside [0, n)
        adds=np.array([[n + 5, -3, 1], [2 * n, 7, 1]], np.float64),
        dels=np.zeros((0, 2), np.int64),
    ).batch(0, batch_size)
    prog = repro.compile(program_path("sssp"))
    print(f"rmat graph: {n} vertices, {csr.num_edges} edges; "
          f"{len(batches)} ΔG batches of {batch_size}")

    # ---- clean reference: no faults, plain jnp ----------------------
    ref = prog.bind(csr, backend="jnp", capacity="auto")
    ref.run("DynSSSP", batchSize=batch_size, src=0)
    for b in batches:
        ref.apply(b)
    want = np.asarray(ref.props.host("dist"))

    # ---- chaos run: pallas with failover, kernels die mid-stream ----
    sess = prog.bind(csr, backend="pallas", capacity="auto",
                     admission="quarantine", failover=True)
    sess.run("DynSSSP", batchSize=batch_size, src=0)
    for b in batches[:kill_at]:
        sess.apply(b)
    print(f"[chaos]  {kill_at} batches applied on "
          f"{sess.backend_name!r}; poisoning every pallas kernel launch")

    with faults.inject("kernel_launch", times=None,
                       match=lambda ctx: ctx.get("engine") == "pallas"):
        sess.apply(poison)                         # quarantined, no state
        for b in batches[kill_at:]:
            sess.apply(b)
        got = np.asarray(sess.props.host("dist"))
        h = sess.health
        print(f"[chaos]  survived on {sess.backend_name!r}: "
              f"failovers={h.failovers} kernel_failures="
              f"{h.kernel_failures} quarantined={h.quarantined} "
              f"(last: {h.last_error_kind})")

        assert sess.backend_name == "jnp", \
            f"expected the chain to land on jnp, got {sess.backend_name}"
        # >= 2 hops: pallas → pallas_chained → jnp (periodic re-probes
        # may add recover/degrade round-trips on the armed path)
        assert h.failovers >= 2, h.failovers
        assert h.quarantined == 1 and len(sess.dead_letter) == 1

    np.testing.assert_array_equal(got, want)
    reachable = int((want < np.iinfo(np.int32).max // 4).sum())
    print(f"chaos SSSP == clean run: bit-identical over {n} vertices "
          f"({reachable} reachable)")
    print("CHAOS-OK")


if __name__ == "__main__":
    main()
