"""Serve a small model with batched requests: prefill + incremental
decode — the inference-side example (wraps repro.launch.serve).

    PYTHONPATH=src python examples/serve_decode.py [--arch gemma2-9b]
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.launch import serve as S


def main():
    argv = sys.argv[1:] or ["--arch", "gemma2-9b"]
    args = S.parser().parse_args(argv + ["--reduced"])
    out = S.serve(args)
    print(f"generated token matrix shape: {out['tokens'].shape} ✓")


if __name__ == "__main__":
    main()
