"""Multi-tenant serving: 32 live graphs, one process, one compiled program.

The DESIGN.md §7 serving contract end to end: a
:class:`repro.serve.SessionPool` hosts 32 independent tenants over ONE
shared engine and one compiled executable.  The tenant mix is
deliberately uneven — armed DSL sessions maintaining dynamic SSSP
(served per-session: their Batch loop holds host-side frames),
structural tenants on mixed-size ΔG streams (served through the batched
mega-call, many sessions per launch), and a resident cap small enough
that tenants are idle-evicted to disk and transparently restored
mid-service.  The exit bar is the pool's contract: **every** tenant's
final state must be oracle-exact, as if it had been served alone.

    PYTHONPATH=src python examples/multi_tenant.py
"""
import numpy as np

import repro
from repro.algos import oracles
from repro.core.engine import state_to_csr
from repro.dsl_programs import path as program_path
from repro.graph import build_csr
from repro.graph.csr import rmat_graph
from repro.graph.updates import random_updates
from repro.serve import SessionPool

N_TENANTS = 32
N_ARMED = 8            # tenants 0..7 run the armed DynSSSP Batch loop
BATCH_SIZE = 8
TICKS = 3
SRC = 0


def _alive_edges(sess):
    import jax
    tree, meta = sess.engine.pack_state(sess.handle)
    tree = jax.tree_util.tree_map(np.asarray, tree)
    c, _ = state_to_csr(tree, meta)
    return (np.stack([np.asarray(c.src), np.asarray(c.dst)], axis=1),
            np.asarray(c.w))


def main():
    n, edges, w = rmat_graph(9, 8, seed=1)         # 512 vertices, skewed
    keep = edges[:, 0] != edges[:, 1]
    csr = build_csr(n, edges[keep], w[keep])
    # the oracle must start from the DEDUPED edge set the sessions hold
    # (rmat emits duplicate edges; build_csr keeps one row per edge)
    edges = np.stack([np.asarray(csr.src), np.asarray(csr.dst)], axis=1)
    w = np.asarray(csr.w)
    prog = repro.compile(program_path("sssp"))

    pool = SessionPool(prog, backend="jnp", max_resident=24)
    streams = []
    for t in range(N_TENANTS):
        # mixed load: every tenant gets its own Δ stream, sizes varied
        streams.append(random_updates(csr, percent=10 + 5 * (t % 5),
                                      seed=100 + t))
        sess = pool.bind(f"tenant{t}", csr)
        if t < N_ARMED:
            sess.run("DynSSSP", batchSize=BATCH_SIZE, src=SRC)
    print(f"pool: {N_TENANTS} tenants ({N_ARMED} armed DynSSSP, "
          f"{N_TENANTS - N_ARMED} structural) on one shared "
          f"{pool.backend!r} engine; max_resident=24")

    for i in range(TICKS):
        pool.apply_many(
            [(f"tenant{t}",
              streams[t].batch(i % streams[t].num_batches(BATCH_SIZE),
                               BATCH_SIZE))
             for t in range(N_TENANTS)])
    s = pool.stats()
    print(f"served {s['applied']} requests in {s['mega_calls']} mega-calls "
          f"(+{s['sequential_fallbacks']} armed/solo applies); "
          f"evictions={s['evictions']} restores={s['restores']}")
    assert s["evictions"] > 0, "resident cap never exercised"

    # ---- the contract: every tenant ends oracle-exact -------------------
    for t in range(N_TENANTS):
        st = streams[t]
        nb = st.num_batches(BATCH_SIZE)
        window = st.window(BATCH_SIZE, 0, min(TICKS, nb))
        e2, w2 = oracles.edges_after_updates(n, edges, w,
                                             window.adds, window.dels)
        sess = pool.session(f"tenant{t}")
        got_e, got_w = _alive_edges(sess)
        want = {(int(u), int(v)): int(x) for (u, v), x in zip(e2, w2)}
        got = {(int(u), int(v)): int(x) for (u, v), x in zip(got_e, got_w)}
        assert got == want, f"tenant{t}: edge set diverged"
        if t < N_ARMED:
            ref = oracles.sssp_oracle(n, e2, w2, SRC)
            dist = np.asarray(sess.props.host("dist"))
            np.testing.assert_array_equal(dist, ref,
                                          err_msg=f"tenant{t} dist")
    print(f"all {N_TENANTS} tenants oracle-exact "
          f"(edge sets; dist for the {N_ARMED} armed)")
    print("SERVE-OK")


if __name__ == "__main__":
    main()
