"""Quickstart: compile the paper's Dynamic SSSP DSL and run it on all
three backends, checking the three lowerings agree.

    PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.graph import build_csr, random_updates
from repro.core.dsl import compile_source
from repro.core.dsl.emit import emit_report
from repro.core.engine import JnpEngine
from repro.core.dist import DistEngine
from repro.core.pallas_engine import PallasEngine

PROGS = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro" / \
    "dsl_programs"


def main():
    # a small random digraph + a 10% update stream (half adds, half dels)
    rng = np.random.default_rng(7)
    n = 200
    edges = rng.integers(0, n, size=(n * 5, 2))
    edges = edges[edges[:, 0] != edges[:, 1]]
    w = rng.integers(1, 50, size=len(edges)).astype(np.int32)
    csr = build_csr(n, edges, w)
    ups = random_updates(csr, percent=10, seed=1)
    print(f"graph: {n} vertices, {csr.num_edges} edges; "
          f"updates: +{ups.num_adds} / -{ups.num_dels}")

    # compile once — the paper's pipeline: parse → analyze → stage
    prog = compile_source(str(PROGS / "sssp.sp"))
    print("\n--- lowering report (what the compiler decided) ---")
    print(emit_report(prog, backend="jnp"))

    print("\n--- running DynSSSP on the three backends ---")
    dists = {}
    for eng in (JnpEngine(), DistEngine(), PallasEngine()):
        res = prog.run("DynSSSP", eng, csr,
                       args={"updateBatch": ups, "batchSize": 16, "src": 0},
                       diff_capacity=2 * ups.num_adds + 8)
        dists[eng.name] = res.props["dist"]
        reach = int((res.props["dist"] < 2**30).sum())
        print(f"  [{eng.name:6s}] reachable={reach}  "
              f"d(0→{n-1})={res.props['dist'][n-1]}")

    assert np.array_equal(dists["jnp"], dists["dist"])
    assert np.array_equal(dists["jnp"], dists["pallas"])
    print("\nall three backends agree ✓")


if __name__ == "__main__":
    main()
