"""Quickstart: compile the paper's Dynamic SSSP DSL once and bind it to
every registered backend through the public API, checking the
lowerings agree.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import repro
from repro.core.dsl.emit import emit_report
from repro.dsl_programs import path as program_path
from repro.graph import build_csr, random_updates


def main():
    # a small random digraph + a 10% update stream (half adds, half dels)
    rng = np.random.default_rng(7)
    n = 200
    edges = rng.integers(0, n, size=(n * 5, 2))
    edges = edges[edges[:, 0] != edges[:, 1]]
    w = rng.integers(1, 50, size=len(edges)).astype(np.int32)
    csr = build_csr(n, edges, w)
    ups = random_updates(csr, percent=10, seed=1)
    print(f"graph: {n} vertices, {csr.num_edges} edges; "
          f"updates: +{ups.num_adds} / -{ups.num_dels}")

    # compile once — the paper's pipeline: parse → analyze → stage
    prog = repro.compile(program_path("sssp"))
    print("\n--- lowering report (what the compiler decided) ---")
    print(emit_report(prog.program, backend="jnp"))

    print("\n--- binding DynSSSP to three backends ---")
    dists = {}
    for backend in ("jnp", "dist", "pallas"):
        # capacity="auto" sizes the diff pool from the bound stream
        sess = prog.bind(csr, backend=backend, capacity="auto")
        res = sess.run("DynSSSP", updateBatch=ups, batchSize=16, src=0)
        dist = res.props.host("dist")       # explicit device→host sync
        dists[backend] = dist
        reach = int((dist < 2**30).sum())
        print(f"  [{backend:6s}] reachable={reach}  "
              f"d(0→{n-1})={dist[n-1]}")

    assert np.array_equal(dists["jnp"], dists["dist"])
    assert np.array_equal(dists["jnp"], dists["pallas"])
    print("\nall three backends agree ✓")

    # the long-lived streaming-consumer mode: arm the Batch loop, feed
    # ΔG batches as they arrive; graph + properties stay device-resident
    # and the graph is prepared exactly once.
    sess = prog.bind(csr, backend="jnp", capacity="auto")
    sess.run("DynSSSP", src=0, batchSize=16)       # prologue: static SSSP
    for batch in ups.batches(16):
        sess.apply(batch)                          # incremental repair
    assert np.array_equal(sess.props.host("dist"), dists["jnp"])
    print("armed session (per-batch apply) matches the one-shot run ✓")


if __name__ == "__main__":
    main()
