"""Streaming analytics scenario: maintain PageRank + SSSP over a live
edge stream, dynamic (incremental) vs static (recompute) — the paper's
Tables 2–4 experiment in miniature, with the crossover point.

Everything runs through ``repro.api`` sessions: the graph handle is
prepared once per session and stays device-resident across the update
stream (the ROADMAP's long-lived streaming consumer).

    PYTHONPATH=src python examples/streaming_analytics.py
"""
import time

import numpy as np

import repro
from repro.algos import pagerank, sssp
from repro.graph import build_csr, random_updates
from repro.graph.csr import rmat_graph


def timed(fn):
    """Steady-state time: first call warms the jit caches (compile time
    excluded, as in the paper's measured runs), second call is timed."""
    import jax
    jax.block_until_ready(fn())
    t0 = time.time()
    out = fn()
    jax.block_until_ready(out)
    return out, time.time() - t0


def main():
    n, edges, w = rmat_graph(11, 8, seed=3)        # 2k vertices, skewed
    keep = edges[:, 0] != edges[:, 1]
    csr = build_csr(n, edges[keep], w[keep])
    print(f"rmat graph: {n} vertices, {csr.num_edges} edges (skewed)")
    print(f"{'pct':>5} {'dyn PR (s)':>11} {'static PR (s)':>14} "
          f"{'speedup':>8}   {'dyn SSSP':>9} {'static SSSP':>12} "
          f"{'speedup':>8}")

    for pct in (1, 5, 10, 20):
        ups = random_updates(csr, percent=pct, seed=42)
        bs = max(ups.num_adds, ups.num_dels, 1)
        # one explicit capacity for BOTH warm and cold sessions, so the
        # dynamic and static timings sweep the same number of edge
        # lanes (and because the raw-handle dyn_* timing calls below
        # bypass the session's grow-on-overflow backstop)
        cap = 2 * ups.num_adds + 8

        # warm session: converged on the pre-update graph, state resident
        sess = repro.bind_graph(csr, backend="jnp", capacity=cap)
        pr0 = sess.call(pagerank.static_pr)
        d0 = sess.call(sssp.static_sssp, 0)
        eng, g0 = sess.engine, sess.handle

        (_, t_dpr) = timed(lambda: pagerank.dyn_pr(
            eng, g0, ups, bs, props=pr0)[1]["pr"])

        def static_pr_new():
            cold = repro.bind_graph(csr, backend="jnp", capacity=cap)
            cold.apply(ups.batch(0, bs))
            return cold.call(pagerank.static_pr)["pr"]
        (_, t_spr) = timed(static_pr_new)

        (_, t_dss) = timed(lambda: sssp.dyn_sssp(
            eng, g0, 0, ups, bs, props=d0)[1]["dist"])

        def static_sssp_new():
            cold = repro.bind_graph(csr, backend="jnp", capacity=cap)
            cold.apply(ups.batch(0, bs))
            return cold.call(sssp.static_sssp, 0)["dist"]
        (_, t_sss) = timed(static_sssp_new)

        print(f"{pct:>4}% {t_dpr:>11.3f} {t_spr:>14.3f} "
              f"{t_spr/max(t_dpr,1e-9):>7.2f}x   {t_dss:>9.3f} "
              f"{t_sss:>12.3f} {t_sss/max(t_dss,1e-9):>7.2f}x")

    print("\n(dynamic wins at low update %, static catches up as the "
          "affected subgraph grows — the paper's crossover)")


if __name__ == "__main__":
    main()
