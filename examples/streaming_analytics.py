"""Streaming analytics scenario: maintain PageRank + triangle count over
a live edge stream, dynamic (incremental) vs static (recompute) — the
paper's Tables 2–4 experiment in miniature, with the crossover point.

    PYTHONPATH=src python examples/streaming_analytics.py
"""
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.graph import build_csr, random_updates
from repro.graph.csr import rmat_graph
from repro.core.engine import JnpEngine
from repro.algos import sssp, pagerank


def timed(fn):
    """Steady-state time: first call warms the jit caches (compile time
    excluded, as in the paper's measured runs), second call is timed."""
    import jax
    jax.block_until_ready(fn())
    t0 = time.time()
    out = fn()
    jax.block_until_ready(out)
    return out, time.time() - t0


def main():
    n, edges, w = rmat_graph(11, 8, seed=3)        # 2k vertices, skewed
    keep = edges[:, 0] != edges[:, 1]
    csr = build_csr(n, edges[keep], w[keep])
    eng = JnpEngine()
    print(f"rmat graph: {n} vertices, {csr.num_edges} edges (skewed)")
    print(f"{'pct':>5} {'dyn PR (s)':>11} {'static PR (s)':>14} "
          f"{'speedup':>8}   {'dyn SSSP':>9} {'static SSSP':>12} "
          f"{'speedup':>8}")

    for pct in (1, 5, 10, 20):
        ups = random_updates(csr, percent=pct, seed=42)
        cap = 2 * ups.num_adds + 8
        bs = max(ups.num_adds, ups.num_dels, 1)

        # warm state: converged on the pre-update graph
        g0 = eng.prepare(csr, diff_capacity=cap)
        pr0 = pagerank.static_pr(eng, g0)
        d0 = sssp.static_sssp(eng, g0, 0)

        (_, t_dpr) = timed(lambda: pagerank.dyn_pr(
            eng, g0, ups, bs, props=pr0)[1]["pr"])

        def static_pr_new():
            g1 = eng.prepare(csr, diff_capacity=cap)
            b = ups.batch(0, bs)
            g1 = eng.update_del(g1, b)
            g1 = eng.update_add(g1, b)
            return pagerank.static_pr(eng, g1)["pr"]
        (_, t_spr) = timed(static_pr_new)

        (_, t_dss) = timed(lambda: sssp.dyn_sssp(
            eng, g0, 0, ups, bs, props=d0)[1]["dist"])

        def static_sssp_new():
            g1 = eng.prepare(csr, diff_capacity=cap)
            b = ups.batch(0, bs)
            g1 = eng.update_del(g1, b)
            g1 = eng.update_add(g1, b)
            return sssp.static_sssp(eng, g1, 0)["dist"]
        (_, t_sss) = timed(static_sssp_new)

        print(f"{pct:>4}% {t_dpr:>11.3f} {t_spr:>14.3f} "
              f"{t_spr/max(t_dpr,1e-9):>7.2f}x   {t_dss:>9.3f} "
              f"{t_sss:>12.3f} {t_sss/max(t_dss,1e-9):>7.2f}x")

    print("\n(dynamic wins at low update %, static catches up as the "
          "affected subgraph grows — the paper's crossover)")


if __name__ == "__main__":
    main()
